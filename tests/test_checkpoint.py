"""Checkpointing: bit-exact roundtrip, atomicity, keep-k, async, elastic."""
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 16)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jax.random.normal(k2, (3,)).astype(jnp.bfloat16)}}


def test_roundtrip_bit_exact(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 7, tree)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, step = restore_checkpoint(tmp_path, like)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_k_gc(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    for s in range(6):
        save_checkpoint(tmp_path, s, tree, keep=2)
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["step_00000004", "step_00000005"]


def test_latest_ignores_partial(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    save_checkpoint(tmp_path, 3, tree)
    # simulate a crash mid-write: tmp dir + incomplete final dir
    (tmp_path / "step_00000009.tmp").mkdir()
    (tmp_path / "step_00000010").mkdir()   # no manifest -> incomplete
    assert latest_step(tmp_path) == 3


def test_async_manager_and_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
    tree = _tree(jax.random.PRNGKey(3))
    mgr.save(11, tree)
    mgr.wait()
    assert mgr.latest_step() == 11
    restored, step = mgr.restore(jax.tree_util.tree_map(
        jnp.zeros_like, tree))
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_elastic_restore_with_sharding(tmp_path):
    """Restore with explicit shardings (the elastic path: the restart
    mesh may differ from the save mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(tmp_path, 1, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = restore_checkpoint(tmp_path, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_save_is_atomic_under_failure(tmp_path, monkeypatch):
    """If serialization dies mid-way, the previous checkpoint survives
    and the partial write is invisible to latest_step."""
    tree = _tree(jax.random.PRNGKey(4))
    save_checkpoint(tmp_path, 1, tree)

    calls = {"n": 0}
    orig = np.save

    def failing_save(path, arr):
        calls["n"] += 1
        if calls["n"] > 2:
            raise IOError("disk died")
        orig(path, arr)

    monkeypatch.setattr(np, "save", failing_save)
    with pytest.raises(IOError):
        save_checkpoint(tmp_path, 2, tree)
    monkeypatch.setattr(np, "save", orig)
    assert latest_step(tmp_path) == 1
    restored, _ = restore_checkpoint(tmp_path, jax.tree_util.tree_map(
        jnp.zeros_like, tree))
    np.testing.assert_array_equal(restored["a"], tree["a"])
