"""Checkpointing: bit-exact roundtrip, atomicity, keep-k, async, elastic."""
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 16)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jax.random.normal(k2, (3,)).astype(jnp.bfloat16)}}


def test_roundtrip_bit_exact(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 7, tree)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, step = restore_checkpoint(tmp_path, like)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_k_gc(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    for s in range(6):
        save_checkpoint(tmp_path, s, tree, keep=2)
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["step_00000004", "step_00000005"]


def test_latest_ignores_partial(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    save_checkpoint(tmp_path, 3, tree)
    # simulate a crash mid-write: tmp dir + incomplete final dir
    (tmp_path / "step_00000009.tmp").mkdir()
    (tmp_path / "step_00000010").mkdir()   # no manifest -> incomplete
    assert latest_step(tmp_path) == 3


def test_async_manager_and_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
    tree = _tree(jax.random.PRNGKey(3))
    mgr.save(11, tree)
    mgr.wait()
    assert mgr.latest_step() == 11
    restored, step = mgr.restore(jax.tree_util.tree_map(
        jnp.zeros_like, tree))
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_elastic_restore_with_sharding(tmp_path):
    """Restore with explicit shardings (the elastic path: the restart
    mesh may differ from the save mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(tmp_path, 1, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = restore_checkpoint(tmp_path, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_save_is_atomic_under_failure(tmp_path, monkeypatch):
    """If serialization dies mid-way, the previous checkpoint survives
    and the partial write is invisible to latest_step."""
    tree = _tree(jax.random.PRNGKey(4))
    save_checkpoint(tmp_path, 1, tree)

    calls = {"n": 0}
    orig = np.save

    def failing_save(path, arr):
        calls["n"] += 1
        if calls["n"] > 2:
            raise IOError("disk died")
        orig(path, arr)

    monkeypatch.setattr(np, "save", failing_save)
    with pytest.raises(IOError):
        save_checkpoint(tmp_path, 2, tree)
    monkeypatch.setattr(np, "save", orig)
    assert latest_step(tmp_path) == 1
    restored, _ = restore_checkpoint(tmp_path, jax.tree_util.tree_map(
        jnp.zeros_like, tree))
    np.testing.assert_array_equal(restored["a"], tree["a"])


# ---------------------------------------------------------------------------
# PR 6: integrity (CRC + treedef) and corruption fallback
# ---------------------------------------------------------------------------

from repro.checkpoint.checkpoint import (CheckpointCorruptError,  # noqa: E402
                                         complete_steps)
from repro.resilience import corrupt_checkpoint  # noqa: E402


def _zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


@pytest.mark.parametrize("mode", ["truncate_leaf", "bad_manifest"])
def test_corrupt_latest_falls_back_to_previous(tmp_path, mode):
    """Acceptance: latest checkpoint damaged -> restore falls back to the
    newest step that passes full CRC verification."""
    t2 = _tree(jax.random.PRNGKey(5))
    t3 = jax.tree_util.tree_map(lambda x: x + 1, t2)
    save_checkpoint(tmp_path, 2, t2)
    save_checkpoint(tmp_path, 3, t3)
    corrupt_checkpoint(tmp_path, mode=mode)       # hits latest (step 3)
    restored, step = restore_checkpoint(tmp_path, _zeros_like(t2))
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t2["a"]))


def test_crc_catches_silent_bitflip(tmp_path):
    """A flipped byte in the payload leaves shape/dtype intact — only
    the per-leaf CRC32 catches it."""
    t1 = _tree(jax.random.PRNGKey(6))
    t2 = jax.tree_util.tree_map(lambda x: x * 2, t1)
    save_checkpoint(tmp_path, 1, t1)
    save_checkpoint(tmp_path, 2, t2)
    leaf = tmp_path / "step_00000002" / "000.npy"
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF                               # corrupt payload byte
    leaf.write_bytes(bytes(raw))
    restored, step = restore_checkpoint(tmp_path, _zeros_like(t1))
    assert step == 1                              # CRC rejected step 2
    with pytest.raises(CheckpointCorruptError, match="crc"):
        restore_checkpoint(tmp_path, _zeros_like(t1), step=2)


def test_explicit_step_corrupt_raises_no_fallback(tmp_path):
    t = _tree(jax.random.PRNGKey(7))
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 2, t)
    corrupt_checkpoint(tmp_path, step=2, mode="truncate_leaf")
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(tmp_path, _zeros_like(t), step=2)


def test_all_checkpoints_corrupt_raises(tmp_path):
    t = _tree(jax.random.PRNGKey(8))
    save_checkpoint(tmp_path, 1, t)
    corrupt_checkpoint(tmp_path, step=1, mode="bad_manifest")
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(tmp_path, _zeros_like(t))


def test_leaf_count_mismatch_is_friendly_valueerror(tmp_path):
    """Satellite: restoring into a structurally different target is a
    caller bug — a ValueError naming the path and both leaf counts, and
    never a silent fallback."""
    t = _tree(jax.random.PRNGKey(9))              # 3 leaves
    save_checkpoint(tmp_path, 4, t)
    wrong = {"only": jnp.zeros((2, 2))}           # 1 leaf
    with pytest.raises(ValueError) as ei:
        restore_checkpoint(tmp_path, wrong)
    msg = str(ei.value)
    assert "step_00000004" in msg and "3" in msg and "1" in msg


def test_treedef_mismatch_is_valueerror(tmp_path):
    """Same leaf count, different structure: the stored treedef is
    validated against the restore target."""
    t = {"a": jnp.zeros((2,)), "b": jnp.ones((3,))}
    save_checkpoint(tmp_path, 1, t)
    wrong = {"x": jnp.zeros((2,)), "y": jnp.ones((3,))}
    with pytest.raises(ValueError, match="different structure"):
        restore_checkpoint(tmp_path, wrong)


def test_manager_sweeps_stale_tmp_dirs(tmp_path):
    (tmp_path / "step_00000005.tmp").mkdir(parents=True)
    (tmp_path / "step_00000005.tmp" / "000.npy").write_bytes(b"junk")
    mgr = CheckpointManager(tmp_path, keep=2)
    assert not (tmp_path / "step_00000005.tmp").exists()
    t = _tree(jax.random.PRNGKey(10))
    mgr.save(1, t)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_async_wait_propagates_writer_error(tmp_path):
    """Satellite: an exception in the async writer thread surfaces on
    the next wait() instead of being lost."""
    target = tmp_path / "ckpt"
    target.write_text("i am a file, not a directory")
    mgr = CheckpointManager(target, keep=2, async_write=True)
    t = {"w": jnp.ones((2, 2))}
    mgr.save(1, t)
    with pytest.raises(Exception) as ei:
        mgr.wait()
    assert "ckpt" in str(ei.value) or isinstance(
        ei.value, (OSError, NotADirectoryError, FileExistsError))
    mgr.wait()                                    # error raised once


def test_complete_steps_newest_first(tmp_path):
    t = {"w": jnp.ones((2,))}
    for s in (1, 5, 3):
        save_checkpoint(tmp_path, s, t)
    (tmp_path / "step_00000007").mkdir()          # incomplete
    assert complete_steps(tmp_path) == [5, 3, 1]
