"""Per-kernel validation: pallas_call(interpret=True) vs ref.py oracles,
sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.matmul import matmul
from repro.kernels.deform_sample import band_geometry

TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Tiled MXU matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 8, 8), (128, 128, 128), (300, 200, 100), (512, 1024, 256),
    (1, 7, 3), (257, 129, 65),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * 31 + n))
    x = _rand(k1, (m, k), dtype)
    w = _rand(k2, (k, n), dtype)
    got = matmul(x, w, block_m=128, block_n=128, block_k=128)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_matmul_block_shape_invariance():
    key = jax.random.PRNGKey(0)
    x = _rand(key, (192, 160), jnp.float32)
    w = _rand(jax.random.fold_in(key, 1), (160, 224), jnp.float32)
    outs = [matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
            for bm, bn, bk in [(64, 64, 64), (128, 256, 32), (192, 224, 160)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Deformable sampling / fused conv
# ---------------------------------------------------------------------------

CASES = [
    # (H, W, C, M, K, stride, dil, bound, tile_h, tile_c)
    (16, 20, 8, 16, 3, 1, 1, 2.0, 4, None),
    (16, 20, 8, 16, 3, 1, 1, 2.0, 4, 4),
    (16, 20, 8, 8, 3, 2, 1, 1.5, 4, None),
    (16, 20, 8, 8, 5, 1, 2, 2.0, 5, None),
    (15, 17, 4, 8, 3, 1, 1, 3.0, 4, 2),   # ragged H vs tile_h
    (8, 8, 16, 32, 3, 1, 1, 0.5, 8, 8),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_deform_sample_sweep(case, dtype):
    h, w, c, m, k, s, d, bound, th, tc = case
    key = jax.random.PRNGKey(hash(case) % (2**31))
    x = _rand(key, (2, h, w, c), dtype)
    pad = d * (k // 2)
    ho = (h + 2 * pad - d * (k - 1) - 1) // s + 1
    wo = (w + 2 * pad - d * (k - 1) - 1) // s + 1
    offs = _rand(jax.random.fold_in(key, 1), (2, ho, wo, 2 * k * k),
                 dtype) * 3.0
    got = ops.deform_sample(x, offs, kernel_size=k, stride=s, dilation=d,
                            offset_bound=bound, tile_h=th, tile_c=tc)
    want = ref.deform_sample_ref(x, offs, kernel_size=k, stride=s,
                                 dilation=d, offset_bound=bound)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_deform_conv_fused_sweep(case, dtype):
    h, w, c, m, k, s, d, bound, th, tc = case
    key = jax.random.PRNGKey(hash(case) % (2**31) + 1)
    x = _rand(key, (2, h, w, c), dtype)
    pad = d * (k // 2)
    ho = (h + 2 * pad - d * (k - 1) - 1) // s + 1
    wo = (w + 2 * pad - d * (k - 1) - 1) // s + 1
    offs = _rand(jax.random.fold_in(key, 1), (2, ho, wo, 2 * k * k),
                 dtype) * 3.0
    wgt = _rand(jax.random.fold_in(key, 2), (k * k, c, m), dtype) * 0.2
    got = ops.deform_conv(x, offs, wgt, kernel_size=k, stride=s, dilation=d,
                          offset_bound=bound, tile_h=th, tile_c=tc)
    want = ref.deform_conv_fused_ref(x, offs, wgt, kernel_size=k, stride=s,
                                     dilation=d, offset_bound=bound)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_fused_equals_two_stage():
    """The fused kernel == sample kernel + explicit matmul (the paper's
    two-stage dataflow) — the fusion is a pure dataflow optimization."""
    key = jax.random.PRNGKey(3)
    x = _rand(key, (1, 12, 12, 8), jnp.float32)
    offs = _rand(jax.random.fold_in(key, 1), (1, 12, 12, 18),
                 jnp.float32) * 2
    wgt = _rand(jax.random.fold_in(key, 2), (9, 8, 16), jnp.float32) * 0.2
    fused = ops.deform_conv(x, offs, wgt, offset_bound=1.5, tile_h=4)
    patches = ops.deform_sample(x, offs, offset_bound=1.5, tile_h=4)
    twostage = jnp.einsum("nhwkc,kcm->nhwm", patches, wgt)
    np.testing.assert_allclose(fused, twostage, rtol=1e-4, atol=1e-4)


def test_band_geometry_covers_bound():
    """Eq. 6 guarantee: band height covers every reachable corner."""
    for k, s, d, b, th in [(3, 1, 1, 2.0, 8), (5, 2, 2, 3.5, 4),
                           (3, 1, 1, 0.0, 1)]:
        hb, band_h = band_geometry(kernel_size=k, stride=s, dilation=d,
                                   offset_bound=b, tile_h=th)
        import math
        assert hb == math.ceil(b)
        # reachable rows (band-local): [hb - b, (th-1)s + (k-1)d + hb + b]
        lo = math.floor(hb - b)
        hi = math.floor((th - 1) * s + (k - 1) * d + hb + b) + 1
        assert lo >= 0
        assert hi <= band_h - 1


def test_unbounded_path_matches_ref():
    key = jax.random.PRNGKey(9)
    x = _rand(key, (2, 10, 10, 4), jnp.float32)
    offs = _rand(jax.random.fold_in(key, 1), (2, 10, 10, 18),
                 jnp.float32) * 4
    wgt = _rand(jax.random.fold_in(key, 2), (9, 4, 8), jnp.float32)
    got = ops.deform_conv(x, offs, wgt)          # offset_bound=None
    want = ref.deform_conv_fused_ref(x, offs, wgt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
