"""Model-core invariants: attention paths, decode==train, mixers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # fallback: deterministic parametrize shim
    from _propshim import given, settings, st

from repro.models import layers as L
from repro.models.transformer import (ModelConfig, decode_step, forward,
                                      init_params, prefill)
from repro.models.rwkv6 import RWKVConfig, wkv_chunked, wkv_step
from repro.models.rglru import (RGLRUConfig, rg_lru_scan, rg_lru_step,
                                rglru_block_apply, rglru_block_step)
from repro.models.moe import MoEConfig

V = 64


def _toks(key, b, s):
    return jax.random.randint(key, (b, s), 0, V)


# ---------------------------------------------------------------------------
# Attention implementations agree
# ---------------------------------------------------------------------------

@given(s=st.sampled_from([17, 32, 50, 64]),
       window=st.sampled_from([None, 8, 16]),
       softcap=st.sampled_from([None, 5.0]))
@settings(max_examples=20, deadline=None)
def test_attention_impls_agree(s, window, softcap):
    key = jax.random.PRNGKey(s)
    B, KV, G, Dh = 2, 2, 2, 8
    q = jax.random.normal(key, (B, s, KV, G, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, s, KV, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, s, KV, Dh))
    pos = jnp.broadcast_to(jnp.arange(s), (B, s))
    dense = L.attention(q, k, v, pos, pos, window=window, softcap=softcap,
                        impl="dense")
    chunk = L.attention(q, k, v, pos, pos, window=window, softcap=softcap,
                        impl="chunked")
    np.testing.assert_allclose(dense, chunk, rtol=3e-5, atol=3e-5)
    if window is not None:
        wb = L.attention(q, k, v, pos, pos, window=window, softcap=softcap,
                         impl="window")
        np.testing.assert_allclose(dense, wb, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# decode == train for every mixer family
# ---------------------------------------------------------------------------

def _configs():
    yield ModelConfig(name="dense", n_layers=4, d_model=32, n_heads=4,
                      kv_heads=2, d_ff=64, vocab=V, dtype=jnp.float32)
    yield ModelConfig(name="win", n_layers=2, d_model=32, n_heads=4,
                      kv_heads=2, d_ff=64, vocab=V, dtype=jnp.float32,
                      window=8)
    yield ModelConfig(name="par", n_layers=2, d_model=32, n_heads=4,
                      kv_heads=2, d_ff=64, vocab=V, dtype=jnp.float32,
                      parallel_block=True, norm="layer",
                      logit_scale=0.0625)
    yield ModelConfig(name="rwkv", n_layers=3, d_model=32, n_heads=2,
                      kv_heads=2, d_ff=64, vocab=V, dtype=jnp.float32,
                      pattern=("rwkv6",), use_rope=False,
                      rwkv=RWKVConfig(d_model=32, d_ff=64, head_dim=16,
                                      decay_lora_rank=8))
    yield ModelConfig(name="hyb", n_layers=5, d_model=32, n_heads=4,
                      kv_heads=1, d_ff=64, vocab=V, dtype=jnp.float32,
                      pattern=("rglru", "rglru", "attn"), window=8,
                      rglru=RGLRUConfig(d_model=32, d_rnn=32))
    # capacity_factor high enough that the train pass drops nothing —
    # decode uses drop-free capacity, so they only agree drop-free.
    yield ModelConfig(name="moe", n_layers=2, d_model=32, n_heads=4,
                      kv_heads=4, d_ff=64, vocab=V, dtype=jnp.float32,
                      moe=MoEConfig(d_model=32, d_ff=64, num_experts=4,
                                    top_k=2, capacity_factor=8.0))


@pytest.mark.parametrize("cfg", list(_configs()), ids=lambda c: c.name)
def test_decode_matches_train(cfg):
    key = jax.random.PRNGKey(0)
    B, S = 2, 12
    toks = _toks(key, B, S + 3)
    params = init_params(key, cfg)
    full, _, _ = forward(params, cfg, tokens=toks, mode="train")
    cache_len = 8 if cfg.window else 32
    lg, caches = prefill(params, cfg, toks[:, :S], cache_len=cache_len)
    np.testing.assert_allclose(lg, full[:, S - 1], rtol=3e-3, atol=3e-3)
    for i in range(3):
        lg, caches = decode_step(params, cfg, toks[:, S + i], caches,
                                 jnp.full((B,), S + i, jnp.int32))
        np.testing.assert_allclose(lg, full[:, S + i], rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# RWKV-6 chunked scan == recurrence (hypothesis)
# ---------------------------------------------------------------------------

@given(s=st.sampled_from([32, 64, 96]), seed=st.integers(0, 2**16))
@settings(max_examples=12, deadline=None)
def test_wkv_chunked_equals_recurrence(s, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    B, H, D = 1, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, s, H, D)) for i in range(3))
    lw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, s, H, D)) * 0.5),
                  -2.5, -1e-6)
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    st_ = jnp.zeros((B, H, D, D))
    outs = []
    for t in range(s):
        o, st_ = wkv_step(r[:, t], k[:, t], v[:, t], lw[:, t], u, st_)
        outs.append(o)
    naive = jnp.stack(outs, 1)
    got, S_fin = wkv_chunked(r, k, v, lw, u, chunk=32)
    np.testing.assert_allclose(got, naive, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(S_fin, st_, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# RG-LRU associative scan == step recurrence
# ---------------------------------------------------------------------------

def test_rglru_scan_equals_step():
    cfg = RGLRUConfig(d_model=16, d_rnn=16)
    from repro.models.layers import init_tree
    from repro.models.rglru import rglru_block_def
    params = init_tree(jax.random.PRNGKey(0), rglru_block_def(cfg))
    key = jax.random.PRNGKey(1)
    B, S = 2, 20
    x = jax.random.normal(key, (B, S, 16))
    y_scan, state = rglru_block_apply(params, x, cfg)
    # step-by-step from fresh state
    st_ = {"h": jnp.zeros((B, 16)),
           "conv": jnp.zeros((B, 3, 16))}
    outs = []
    for t in range(S):
        o, st_ = rglru_block_step(params, x[:, t], cfg, state=st_)
        outs.append(o)
    y_step = jnp.stack(outs, 1)
    np.testing.assert_allclose(y_scan, y_step, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(state["h"], st_["h"], rtol=2e-4, atol=2e-4)


def test_rglru_decay_bounded():
    """RG-LRU is contractive: |a_t| <= 1 always (stability at 500k)."""
    cfg = RGLRUConfig(d_model=8, d_rnn=8)
    from repro.models.layers import init_tree
    from repro.models.rglru import rglru_block_def
    params = init_tree(jax.random.PRNGKey(0), rglru_block_def(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, 8)) * 10
    y, _ = rg_lru_scan(params, x)
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# Chunked CE == dense CE
# ---------------------------------------------------------------------------

@given(s=st.sampled_from([7, 16, 33]), tied=st.booleans())
@settings(max_examples=10, deadline=None)
def test_chunked_ce(s, tied):
    key = jax.random.PRNGKey(s)
    B, D, Vv = 2, 8, 32
    x = jax.random.normal(key, (B, s, D))
    w = jax.random.normal(jax.random.fold_in(key, 1),
                          (Vv, D) if tied else (D, Vv))
    t = jax.random.randint(jax.random.fold_in(key, 2), (B, s), 0, Vv)
    got = L.chunked_cross_entropy(x, w, t, tied=tied, chunk=8)
    logits = jnp.einsum("bsd,vd->bsv" if tied else "bsd,dv->bsv", x, w)
    want = L.cross_entropy(logits, t)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
