import os

# Tests must see the plain host device(s); the 512-device override is
# strictly dryrun.py's (set there before any jax import).
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
