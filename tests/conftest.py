import os

# Tests must see the plain host device(s); the 512-device override is
# strictly dryrun.py's (set there before any jax import).  Exception:
# the sharded-training CI job opts in to a forced multi-device CPU
# (XLA_FLAGS=--xla_force_host_platform_device_count=N) by also setting
# REPRO_KEEP_XLA_FLAGS=1 — see tests/test_sharded_training.py and
# .github/workflows/ci.yml.
if os.environ.get("REPRO_KEEP_XLA_FLAGS") != "1":
    os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
