"""Integration: the multi-pod dry-run path end-to-end for one fast cell.

Runs ``repro.launch.dryrun`` in a subprocess (it needs its own process:
the 512-device override must precede jax init) and checks the recorded
artifact is structurally complete: memory analysis, cost analysis with
While-corrected totals, and a parsed collective schedule.
"""
import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS = ROOT / "benchmarks" / "results" / "dryrun"


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "tinyllama-1.1b", "--shape", "decode_32k",
         "--mesh", "multi"],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"},
        cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(
        (RESULTS / "tinyllama-1.1b__decode_32k__multi.json").read_text())
    assert rec["mesh_shape"] == [2, 16, 16]
    assert rec["axes"] == ["pod", "data", "model"]
    assert rec["cost_total"]["flops"] > 0
    assert rec["collectives"]["total_count"] >= 0
    assert "temp_size_in_bytes" in rec["memory_analysis"]
    # the decode step must benefit from the serving rules: per-token
    # collective bytes far below the params size
    assert rec["collective_bytes_total"] < 1e9


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), replica_groups={}
  %ag.1 = bf16[8,256]{1,0} all-gather(bf16[8,16]{1,0} %y), dimensions={1}
  %start = (f32[4]{0}, f32[4]{0}) all-reduce-start(f32[4]{0} %z)
  %done = f32[4]{0} all-reduce-done((f32[4]{0}, f32[4]{0}) %start)
  %cp = u32[2]{0} collective-permute(u32[2]{0} %w), source_target_pairs={{0,1}}
"""
    got = parse_collectives(hlo)
    assert got["all-reduce"]["count"] == 2          # plain + -start
    assert got["all-reduce"]["bytes"] == 16 * 128 * 4 + 2 * 4 * 4
    assert got["all-gather"]["count"] == 1
    assert got["all-gather"]["bytes"] == 8 * 256 * 2
    assert got["collective-permute"]["count"] == 1
    assert got["total_count"] == 4
