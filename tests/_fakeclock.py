"""Shared deterministic clock for engine/trainer/tracer tests.

One definition (ISSUE 8) so every suite drives the same injectable
monotonic-clock seam — DCLServingEngine(clock=...), Trainer(clock=...),
Tracer(clock=...)."""


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt
