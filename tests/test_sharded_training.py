"""Mesh-sharded data-parallel DCL training (PR 4 tentpole, level 2).

``jax.grad`` through the shard_map-wrapped zero-copy kernel path on a
forced multi-device CPU mesh must match the single-device reference
parameter-for-parameter (<= 1e-4, the acceptance bound), including the
``quant="qat"`` path and a full data-parallel ``Trainer`` run; the
batch-divisibility error must be friendly; and the traced step must
show the sharded machinery (shard_map + custom-VJP kernels + the
d_weights psum epilogue) rather than a GSPMD fallback.

The heavy lifting lives in ``tests/_sharded_checks.py``, which runs
in-process when this pytest already sees >= 4 devices (the CI
``sharded-4dev`` job) and otherwise ONCE in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — jax locks the
device count at first init, and skipping would hide the coverage from
plain tier-1 boxes.
"""
import functools
import json
import os
import subprocess
import sys

import jax

HERE = os.path.dirname(os.path.abspath(__file__))


@functools.lru_cache(maxsize=1)
def _results() -> dict:
    if jax.device_count() >= 4:
        sys.path.insert(0, HERE)
        try:
            import _sharded_checks
        finally:
            sys.path.remove(HERE)
        return _sharded_checks.run_checks()
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(HERE), "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_sharded_checks.py")],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-4000:])
    return json.loads(proc.stdout.splitlines()[-1])


def test_mesh_really_multi_device():
    r = _results()
    assert r["device_count"] >= 4
    assert r["shard_active"] is True


def test_sharded_kernel_grad_parity():
    """d_input/d_offsets/d_weights of the shard_map kernel path match
    the XLA gather reference (the dw psum epilogue composes the
    per-device partials)."""
    r = _results()
    for k in ("dconv_dx_diff", "dconv_doff_diff", "dconv_dw_diff"):
        assert r[k] <= 1e-4, (k, r[k])


def test_sharded_qat_grad_parity():
    """quant='qat' (fake-quant STE outside the kernel) trains through
    the sharded custom-VJP path with full-layer grad parity (rtol+atol
    1e-4 — psum tree-sums reorder fp32 adds on large-magnitude
    grads)."""
    r = _results()
    assert r["qat_grad_tol_excess"] <= 0.0, r["qat_grad_tol_excess"]


def test_sharded_model_step_grad_parity():
    """Acceptance: one value_and_grad step of the miniature ResNet-DCN
    on the 4-device mesh matches the single-device reference over the
    FULL parameter vector to <= 1e-4."""
    r = _results()
    assert r["model_loss_diff"] <= 1e-5, r["model_loss_diff"]
    assert r["model_grad_diff"] <= 1e-4, r["model_grad_diff"]


def test_sharded_step_routes_through_shard_map_kernels():
    """No GSPMD fallback: the traced training step contains the
    shard_map wrap, the custom-VJP kernel call, and the d_weights psum
    epilogue."""
    r = _results()
    assert r["jaxpr_shard_map"], "shard_map missing from the step jaxpr"
    assert r["jaxpr_custom_vjp"], "custom-VJP kernel missing"
    assert r["jaxpr_psum"], "d_weights psum epilogue missing"


def test_sharded_trainer_end_to_end():
    """The production Trainer trains data-parallel through the
    zero-copy kernels and lands on the single-device parameters."""
    r = _results()
    assert r["trainer_steps"] == 3
    # sharded-vs-single parity is fp32-reordering-bound, not exact: the
    # two mesh configs produce different XLA fusions (and the trainer's
    # non-finite sentinel materializes grads for global_norm, which
    # shifts fusion boundaries).  3 SGD+momentum steps through the QAT
    # resnet accumulate a few 1e-4 of reorder noise; a real wiring bug
    # (missing psum, wrong spec) shows up as O(1e-1).
    assert r["trainer_param_diff"] <= 1e-3, r["trainer_param_diff"]


def test_mesh_divisibility_value_error():
    """Satellite: a batch that doesn't divide the mesh data axis raises
    the friendly ValueError naming the offending sizes."""
    r = _results()
    msg = r["mesh_divide_error"]
    assert "N=3" in msg and "4" in msg and "does not divide" in msg, msg
