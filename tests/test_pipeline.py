"""Pipeline parallelism: GPipe schedule == sequential execution.

The real multi-stage run needs >1 device, which conflicts with the
1-device test process — so the 2-stage check runs the demo script in a
subprocess (same pattern as the dry-run); the 1-stage degenerate case
runs in-process.
"""
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.pipeline import bubble_fraction, gpipe_forward

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_single_stage_degenerates_to_sequential():
    mesh = jax.make_mesh((1,), ("stage",))
    d = 8
    ws = jax.random.normal(jax.random.PRNGKey(0), (1, d, d)) / jnp.sqrt(d)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 2, d))
    ys = gpipe_forward(stage_fn, ws, xs, mesh=mesh)
    ref = jax.vmap(lambda x: stage_fn(ws[0], x))(xs)
    np.testing.assert_allclose(ys, ref, rtol=1e-6, atol=1e-6)


@pytest.mark.slow      # spawns a 4-host-device subprocess; minutes on CPU
def test_two_stage_pipeline_subprocess():
    out = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "pipeline_demo.py")],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
             "HOME": "/tmp"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "== sequential: OK" in out.stdout
    assert "pipelined transformer (4 layers / 2 stages) " \
           "== standard forward: OK" in out.stdout


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 4) == 3 / 7
    assert bubble_fraction(2, 30) < 0.04
