"""DCL detection serving engine: buckets, deadlines, admission control,
retry/backoff, and the per-request degradation ladder."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import resnet_dcn as R
from repro.quant.calibrate import calibrate_resnet_dcn
from repro.resilience import KernelDispatchFault
from repro.serve import (DCLServeConfig, DCLServingEngine, OUTCOMES,
                         resolve_bucket)

from _fakeclock import FakeClock

BUCKET = 32


@pytest.fixture(scope="module")
def model():
    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=BUCKET, offset_bound=2.0,
        use_kernel=True)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    table = calibrate_resnet_dcn(
        params, cfg, [rng.randn(2, BUCKET, BUCKET, 3).astype(np.float32)])
    return cfg, params, table


@pytest.fixture
def clean_dispatch():
    ops.set_dispatch_hook(None)
    ops.set_degradation(True)
    ops.reset_fallback_warnings()
    yield
    ops.set_dispatch_hook(None)
    ops.set_degradation(True)
    ops.reset_fallback_warnings()


def _engine(model, **kw):
    cfg, params, table = model
    kw.setdefault("buckets", (BUCKET,))
    kw.setdefault("slots", 2)
    extra = {k: kw.pop(k) for k in ("clock", "sleep", "step_hook",
                                    "admit_hook") if k in kw}
    return DCLServingEngine(params, cfg, DCLServeConfig(**kw),
                            scale_table=table, **extra)


def _img(seed, side=BUCKET):
    return np.random.RandomState(seed).randn(side, side, 3) \
        .astype(np.float32)


# -- bucket resolution ----------------------------------------------------

def test_resolve_bucket_strict_miss_names_resolution_and_nearest():
    with pytest.raises(ValueError) as ei:
        resolve_bucket(96, 96, (64, 128))
    msg = str(ei.value)
    assert "96x96" in msg
    assert "64x64" in msg and "128x128" in msg
    assert "strict_buckets=False" in msg


def test_resolve_bucket_pad_up_and_overflow():
    assert resolve_bucket(96, 80, (64, 128), strict=False) == 128
    assert resolve_bucket(64, 64, (64, 128), strict=False) == 64
    with pytest.raises(ValueError) as ei:
        resolve_bucket(200, 200, (64, 128), strict=False)
    assert "exceeds the largest" in str(ei.value)


def test_unbucketable_request_is_typed_not_raised(model):
    eng = _engine(model)
    r = eng.submit(_img(0, side=20))
    assert r.outcome == "unbucketable"
    assert "nearest" in r.error
    assert eng.counters["unbucketable"] == 1
    # the engine keeps serving
    eng.submit(_img(1))
    done = eng.run_until_drained()
    assert [q.outcome for q in done] == ["unbucketable", "ok"]


def test_strict_buckets_false_pads_up(model):
    eng = _engine(model, strict_buckets=False)
    small = _img(2)[:24, :28]
    r = eng.submit(small)
    eng.run_until_drained()
    assert r.outcome == "ok" and r.bucket == BUCKET
    # padding is explicit zero-fill: bit-exact vs a hand-padded submit
    padded = np.zeros((BUCKET, BUCKET, 3), np.float32)
    padded[:24, :28] = small
    eng2 = _engine(model)
    r2 = eng2.submit(padded)
    eng2.run_until_drained()
    assert np.array_equal(r.result["cls"], r2.result["cls"])


# -- datapath correctness -------------------------------------------------

def test_fp32_ref_rung_matches_direct_forward(model):
    cfg, params, _ = model
    eng = DCLServingEngine(params, cfg,
                           DCLServeConfig(buckets=(BUCKET,), slots=2,
                                          quant="fp32_ref"))
    r = eng.submit(_img(3))
    eng.run_until_drained()
    assert r.outcome == "ok" and r.ladder == "fp32_ref"
    ref_cfg = dataclasses.replace(cfg, quant="none", use_kernel=False)
    batch = np.zeros((2, BUCKET, BUCKET, 3), np.float32)
    batch[0] = _img(3)
    out, _ = R.forward(params, ref_cfg, jnp.asarray(batch))
    assert np.array_equal(r.result["cls"], np.asarray(out["cls"])[0])


def test_int8_chain_default_serves_and_reports_rung(model):
    eng = _engine(model)
    assert eng.scfg.quant == "int8_chain"
    for i in range(5):
        eng.submit(_img(10 + i))
    done = eng.run_until_drained()
    assert all(r.outcome == "ok" for r in done)
    assert all(r.ladder == "int8_chain" and not r.degraded for r in done)
    assert eng.counters == {"ok": 5}
    tel = eng.telemetry()
    assert tel["served_per_bucket"] == {str(BUCKET): 5}
    assert set(tel["plans"][str(BUCKET)]) == {"s2b0", "s3b0"}
    assert {"hits", "misses", "size"} <= set(tel["plan_cache"])
    assert all(r["outcome"] in OUTCOMES for r in tel["requests"])


# -- deadlines ------------------------------------------------------------

def test_deadline_checked_at_admission_and_in_queue(model):
    clock = FakeClock()
    eng = _engine(model, clock=clock)
    # expired the moment it arrives
    r0 = eng.submit(_img(20), deadline=-1.0)
    assert r0.outcome == "deadline_exceeded"
    # expires while queued behind nothing — swept by the next step
    r1 = eng.submit(_img(21), deadline=5.0)
    r2 = eng.submit(_img(22))
    clock.advance(10.0)
    eng.run_until_drained()
    assert r1.outcome == "deadline_exceeded"
    assert "expired in queue" in r1.error
    assert r2.outcome == "ok"


def test_slow_step_drops_result_past_deadline(model):
    clock = FakeClock()
    eng = _engine(model, clock=clock,
                  step_hook=lambda step, ctx: clock.advance(1.0))
    r = eng.submit(_img(23), deadline=0.5)
    eng.run_until_drained()
    assert r.outcome == "deadline_exceeded"
    assert "result dropped" in r.error
    assert r.result is None


# -- admission queue ------------------------------------------------------

def test_reject_new_backpressure(model):
    eng = _engine(model, queue_capacity=2)
    r0, r1, r2 = (eng.submit(_img(30 + i)) for i in range(3))
    assert r2.outcome == "rejected" and "capacity 2" in r2.error
    eng.run_until_drained()
    assert r0.outcome == "ok" and r1.outcome == "ok"


def test_shed_oldest_sacrifices_queue_head(model):
    eng = _engine(model, queue_capacity=2, shed_policy="shed_oldest")
    r0, r1, r2 = (eng.submit(_img(40 + i)) for i in range(3))
    assert r0.outcome == "shed" and "shed by request" in r0.error
    eng.run_until_drained()
    assert r1.outcome == "ok" and r2.outcome == "ok"
    assert eng.counters == {"shed": 1, "ok": 2}


# -- retries, backoff, degradation ladder ---------------------------------

def test_transient_fault_is_retried_without_degrading(model, clean_dispatch):
    calls = {"n": 0}

    def fail_once(ctx):
        if ctx.get("op") == "deform_conv_chain" and calls["n"] == 0:
            calls["n"] += 1
            raise KernelDispatchFault("transient")

    eng = _engine(model, max_retries=2)
    with ops.dispatch_hook_scope(fail_once):
        r = eng.submit(_img(50))
        eng.run_until_drained()
    assert r.outcome == "ok"
    assert r.retries == 1 and not r.degraded
    assert r.ladder == "int8_chain"


def test_retry_backoff_is_exponential(model, clean_dispatch):
    sleeps = []

    def chain_fail(ctx):
        if ctx.get("op") == "deform_conv_chain":
            raise KernelDispatchFault("persistent")

    eng = _engine(model, max_retries=2, retry_backoff=0.05,
                  sleep=sleeps.append)
    with ops.dispatch_hook_scope(chain_fail):
        r = eng.submit(_img(51))
        eng.run_until_drained()
    # two same-rung replays back off 0.05, 0.10; then the rung drops
    assert sleeps == [0.05, 0.1]
    assert r.outcome == "ok" and r.degraded and r.ladder == "int8"


def test_ladder_is_per_request_across_two_engines(model, clean_dispatch):
    """Two engines in one process keep independent ladders and never
    touch ops' global warn-once fallback state."""
    def chain_fail(ctx):
        if ctx.get("op") == "deform_conv_chain":
            raise KernelDispatchFault("persistent chain fault")

    eng_a = _engine(model, max_retries=0)
    eng_b = _engine(model, max_retries=0)
    with ops.dispatch_hook_scope(chain_fail):
        ra = eng_a.submit(_img(60))
        rb = eng_b.submit(_img(61))
        eng_a.run_until_drained()
        eng_b.run_until_drained()
    for r in (ra, rb):
        assert r.outcome == "ok"
        assert r.degraded and r.ladder == "int8" and r.retries == 1
    assert eng_a.counters["degraded_batches"] == 1
    assert eng_b.counters["degraded_batches"] == 1
    # the global warn-once fallback never fired — degradation was
    # recorded per request, not per process
    assert ops._FALLBACK_WARNED == set()
    # with the fault gone, fresh requests are back on the top rung
    ra2 = eng_a.submit(_img(62))
    eng_a.run_until_drained()
    assert ra2.ladder == "int8_chain" and not ra2.degraded


def test_malformed_request_is_typed_not_raised(model):
    eng = _engine(model)
    r = eng.submit(np.full((5,), np.nan, np.float32))
    assert r.outcome == "malformed"
    assert "(H, W, 3)" in r.error
    r2 = eng.submit("not an image at all")
    assert r2.outcome == "malformed"
    eng.submit(_img(70))
    done = eng.run_until_drained()
    assert done[-1].outcome == "ok"
