"""DCL detection serving engine: buckets, deadlines, admission control,
retry/backoff, and the per-request degradation ladder."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import resnet_dcn as R
from repro.quant.calibrate import calibrate_resnet_dcn
from repro.resilience import KernelDispatchFault
from repro.serve import (DCLServeConfig, DCLServingEngine, OUTCOMES,
                         resolve_bucket)

from _fakeclock import FakeClock

BUCKET = 32


@pytest.fixture(scope="module")
def model():
    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=BUCKET, offset_bound=2.0,
        use_kernel=True)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    table = calibrate_resnet_dcn(
        params, cfg, [rng.randn(2, BUCKET, BUCKET, 3).astype(np.float32)])
    return cfg, params, table


@pytest.fixture
def clean_dispatch():
    ops.set_dispatch_hook(None)
    ops.set_degradation(True)
    ops.reset_fallback_warnings()
    yield
    ops.set_dispatch_hook(None)
    ops.set_degradation(True)
    ops.reset_fallback_warnings()


def _engine(model, **kw):
    cfg, params, table = model
    kw.setdefault("buckets", (BUCKET,))
    kw.setdefault("slots", 2)
    extra = {k: kw.pop(k) for k in ("clock", "sleep", "step_hook",
                                    "admit_hook") if k in kw}
    return DCLServingEngine(params, cfg, DCLServeConfig(**kw),
                            scale_table=table, **extra)


def _img(seed, side=BUCKET):
    return np.random.RandomState(seed).randn(side, side, 3) \
        .astype(np.float32)


# -- bucket resolution ----------------------------------------------------

def test_resolve_bucket_strict_miss_names_resolution_and_nearest():
    with pytest.raises(ValueError) as ei:
        resolve_bucket(96, 96, (64, 128))
    msg = str(ei.value)
    assert "96x96" in msg
    assert "64x64" in msg and "128x128" in msg
    assert "strict_buckets=False" in msg


def test_resolve_bucket_pad_up_and_overflow():
    assert resolve_bucket(96, 80, (64, 128), strict=False) == 128
    assert resolve_bucket(64, 64, (64, 128), strict=False) == 64
    with pytest.raises(ValueError) as ei:
        resolve_bucket(200, 200, (64, 128), strict=False)
    assert "exceeds the largest" in str(ei.value)


def test_unbucketable_request_is_typed_not_raised(model):
    eng = _engine(model)
    r = eng.submit(_img(0, side=20))
    assert r.outcome == "unbucketable"
    assert "nearest" in r.error
    assert eng.counters["unbucketable"] == 1
    # the engine keeps serving
    eng.submit(_img(1))
    done = eng.run_until_drained()
    assert [q.outcome for q in done] == ["unbucketable", "ok"]


def test_strict_buckets_false_pads_up(model):
    eng = _engine(model, strict_buckets=False)
    small = _img(2)[:24, :28]
    r = eng.submit(small)
    eng.run_until_drained()
    assert r.outcome == "ok" and r.bucket == BUCKET
    # padding is explicit zero-fill: bit-exact vs a hand-padded submit
    padded = np.zeros((BUCKET, BUCKET, 3), np.float32)
    padded[:24, :28] = small
    eng2 = _engine(model)
    r2 = eng2.submit(padded)
    eng2.run_until_drained()
    assert np.array_equal(r.result["cls"], r2.result["cls"])


# -- datapath correctness -------------------------------------------------

def test_fp32_ref_rung_matches_direct_forward(model):
    cfg, params, _ = model
    eng = DCLServingEngine(params, cfg,
                           DCLServeConfig(buckets=(BUCKET,), slots=2,
                                          quant="fp32_ref"))
    r = eng.submit(_img(3))
    eng.run_until_drained()
    assert r.outcome == "ok" and r.ladder == "fp32_ref"
    ref_cfg = dataclasses.replace(cfg, quant="none", use_kernel=False)
    batch = np.zeros((2, BUCKET, BUCKET, 3), np.float32)
    batch[0] = _img(3)
    out, _ = R.forward(params, ref_cfg, jnp.asarray(batch))
    assert np.array_equal(r.result["cls"], np.asarray(out["cls"])[0])


def test_int8_chain_default_serves_and_reports_rung(model):
    eng = _engine(model)
    assert eng.scfg.quant == "int8_chain"
    for i in range(5):
        eng.submit(_img(10 + i))
    done = eng.run_until_drained()
    assert all(r.outcome == "ok" for r in done)
    assert all(r.ladder == "int8_chain" and not r.degraded for r in done)
    assert eng.counters == {"ok": 5}
    tel = eng.telemetry()
    assert tel["served_per_bucket"] == {str(BUCKET): 5}
    assert set(tel["plans"][str(BUCKET)]) == {"s2b0", "s3b0"}
    assert {"hits", "misses", "size"} <= set(tel["plan_cache"])
    assert all(r["outcome"] in OUTCOMES for r in tel["requests"])


# -- deadlines ------------------------------------------------------------

def test_deadline_checked_at_admission_and_in_queue(model):
    clock = FakeClock()
    eng = _engine(model, clock=clock)
    # expired the moment it arrives
    r0 = eng.submit(_img(20), deadline=-1.0)
    assert r0.outcome == "deadline_exceeded"
    # expires while queued behind nothing — swept by the next step
    r1 = eng.submit(_img(21), deadline=5.0)
    r2 = eng.submit(_img(22))
    clock.advance(10.0)
    eng.run_until_drained()
    assert r1.outcome == "deadline_exceeded"
    assert "expired in queue" in r1.error
    assert r2.outcome == "ok"


def test_slow_step_drops_result_past_deadline(model):
    clock = FakeClock()
    eng = _engine(model, clock=clock,
                  step_hook=lambda step, ctx: clock.advance(1.0))
    r = eng.submit(_img(23), deadline=0.5)
    eng.run_until_drained()
    assert r.outcome == "deadline_exceeded"
    assert "result dropped" in r.error
    assert r.result is None


# -- admission queue ------------------------------------------------------

def test_reject_new_backpressure(model):
    eng = _engine(model, queue_capacity=2)
    r0, r1, r2 = (eng.submit(_img(30 + i)) for i in range(3))
    assert r2.outcome == "rejected" and "capacity 2" in r2.error
    eng.run_until_drained()
    assert r0.outcome == "ok" and r1.outcome == "ok"


def test_shed_oldest_sacrifices_queue_head(model):
    eng = _engine(model, queue_capacity=2, shed_policy="shed_oldest")
    r0, r1, r2 = (eng.submit(_img(40 + i)) for i in range(3))
    assert r0.outcome == "shed" and "shed by request" in r0.error
    eng.run_until_drained()
    assert r1.outcome == "ok" and r2.outcome == "ok"
    assert eng.counters == {"shed": 1, "ok": 2}


# -- retries, backoff, degradation ladder ---------------------------------

def test_transient_fault_is_retried_without_degrading(model, clean_dispatch):
    calls = {"n": 0}

    def fail_once(ctx):
        if ctx.get("op") == "deform_conv_chain" and calls["n"] == 0:
            calls["n"] += 1
            raise KernelDispatchFault("transient")

    eng = _engine(model, max_retries=2)
    with ops.dispatch_hook_scope(fail_once):
        r = eng.submit(_img(50))
        eng.run_until_drained()
    assert r.outcome == "ok"
    assert r.retries == 1 and not r.degraded
    assert r.ladder == "int8_chain"


def test_retry_backoff_is_exponential(model, clean_dispatch):
    sleeps = []

    def chain_fail(ctx):
        if ctx.get("op") == "deform_conv_chain":
            raise KernelDispatchFault("persistent")

    eng = _engine(model, max_retries=2, retry_backoff=0.05,
                  sleep=sleeps.append)
    with ops.dispatch_hook_scope(chain_fail):
        r = eng.submit(_img(51))
        eng.run_until_drained()
    # two same-rung replays back off 0.05, 0.10; then the rung drops
    assert sleeps == [0.05, 0.1]
    assert r.outcome == "ok" and r.degraded and r.ladder == "int8"


def test_ladder_is_per_request_across_two_engines(model, clean_dispatch):
    """Two engines in one process keep independent ladders and never
    touch ops' global warn-once fallback state."""
    def chain_fail(ctx):
        if ctx.get("op") == "deform_conv_chain":
            raise KernelDispatchFault("persistent chain fault")

    eng_a = _engine(model, max_retries=0)
    eng_b = _engine(model, max_retries=0)
    with ops.dispatch_hook_scope(chain_fail):
        ra = eng_a.submit(_img(60))
        rb = eng_b.submit(_img(61))
        eng_a.run_until_drained()
        eng_b.run_until_drained()
    for r in (ra, rb):
        assert r.outcome == "ok"
        assert r.degraded and r.ladder == "int8" and r.retries == 1
    assert eng_a.counters["degraded_batches"] == 1
    assert eng_b.counters["degraded_batches"] == 1
    # the global warn-once fallback never fired — degradation was
    # recorded per request, not per process
    assert ops._FALLBACK_WARNED == set()
    # with the fault gone, fresh requests are back on the top rung
    ra2 = eng_a.submit(_img(62))
    eng_a.run_until_drained()
    assert ra2.ladder == "int8_chain" and not ra2.degraded


def test_malformed_request_is_typed_not_raised(model):
    eng = _engine(model)
    r = eng.submit(np.full((5,), np.nan, np.float32))
    assert r.outcome == "malformed"
    assert "(H, W, 3)" in r.error
    r2 = eng.submit("not an image at all")
    assert r2.outcome == "malformed"
    eng.submit(_img(70))
    done = eng.run_until_drained()
    assert done[-1].outcome == "ok"


# -- deadline-aware scheduling + spatial buckets (ISSUE 10) ---------------

def test_pick_bucket_orders_by_deadline_then_age():
    from repro.serve.admission import (AdmissionConfig, AdmissionQueue,
                                       DetRequest)
    q = AdmissionQueue(AdmissionConfig())
    q.queue.append(DetRequest(0, None, bucket=64, submitted_at=0.0))
    q.queue.append(DetRequest(1, None, bucket=128, deadline=9.0,
                              submitted_at=1.0))
    # blind head-of-line order would starve the tight-deadline bucket
    assert q.head_bucket() == 64
    assert q.pick_bucket(slots=4, now=2.0) == 128
    # ties on deadline (both None) break by oldest submit
    q2 = AdmissionQueue(AdmissionConfig())
    q2.queue.append(DetRequest(0, None, bucket=128, submitted_at=5.0))
    q2.queue.append(DetRequest(1, None, bucket=64, submitted_at=3.0))
    assert q2.pick_bucket(slots=4, now=9.0) == 64
    assert AdmissionQueue(AdmissionConfig()).pick_bucket(
        slots=4, now=0.0) is None


def test_pick_bucket_prefers_full_batches_and_honors_window():
    from repro.serve.admission import (AdmissionConfig, AdmissionQueue,
                                       DetRequest)
    q = AdmissionQueue(AdmissionConfig())
    q.queue.append(DetRequest(0, None, bucket=128, deadline=1.0,
                              submitted_at=0.0))
    for i in (1, 2):
        q.queue.append(DetRequest(i, None, bucket=64, submitted_at=2.0))
    # 64 fills all slots -> preferred over the more urgent partial 128
    assert q.pick_bucket(slots=2, now=3.0) == 64
    # with room for both, deadline order reasserts itself
    assert q.pick_bucket(slots=3, now=3.0) == 128
    # partials: held inside the window, eligible after it
    q3 = AdmissionQueue(AdmissionConfig())
    q3.queue.append(DetRequest(0, None, bucket=64, submitted_at=10.0))
    assert q3.pick_bucket(slots=2, now=10.5, batch_window=1.0) is None
    assert q3.pick_bucket(slots=2, now=11.0, batch_window=1.0) == 64
    assert q3.pick_bucket(slots=2, now=10.5) == 64   # window off


def test_urgent_bucket_served_before_older_lax_bucket(model):
    clock = FakeClock()
    eng = _engine(model, buckets=(BUCKET, 64), clock=clock)
    r_lax = eng.submit(_img(90))                     # older, no deadline
    clock.advance(1.0)
    r_tight = eng.submit(_img(91, side=64), deadline=100.0)
    eng.step()
    assert r_tight.outcome == "ok" and r_lax.outcome == "pending"
    eng.run_until_drained()
    assert r_lax.outcome == "ok"


def test_full_batch_preferred_over_urgent_partial(model):
    eng = _engine(model, buckets=(BUCKET, 64))       # slots=2
    r1, r2 = eng.submit(_img(92)), eng.submit(_img(93))
    ru = eng.submit(_img(94, side=64), deadline=50.0)
    eng.step()
    assert r1.outcome == "ok" and r2.outcome == "ok"
    assert ru.outcome == "pending"
    eng.run_until_drained()
    assert ru.outcome == "ok"


def test_batch_window_holds_partial_batches(model):
    clock = FakeClock()
    eng = _engine(model, batch_window=2.0, clock=clock)
    r = eng.submit(_img(95))
    assert eng.step() == 0 and r.outcome == "pending"
    clock.advance(1.0)
    assert eng.step() == 0                  # still inside the window
    clock.advance(1.0)
    eng.step()
    assert r.outcome == "ok"
    # a full batch never waits on the window
    r1, r2 = eng.submit(_img(96)), eng.submit(_img(97))
    eng.step()
    assert r1.outcome == "ok" and r2.outcome == "ok"


def test_serve_config_validates_window_and_spatial_shards():
    with pytest.raises(ValueError) as ei:
        DCLServeConfig(buckets=(32,), batch_window=-1.0)
    assert "batch_window" in str(ei.value)
    with pytest.raises(ValueError) as ei:
        DCLServeConfig(buckets=(32,), spatial_shards=((48, 2),))
    assert "not in buckets" in str(ei.value)
    with pytest.raises(ValueError):
        DCLServeConfig(buckets=(32,), spatial_shards=((32, 0),))
    cfg = DCLServeConfig(buckets=(32, 64), spatial_shards=((32, 2),))
    assert cfg.spatial_shards_for(32) == 2
    assert cfg.spatial_shards_for(64) == 1


def test_spatial_shards_beyond_devices_rejected_at_init(model):
    too_many = jax.device_count() + 1
    with pytest.raises(ValueError) as ei:
        _engine(model, spatial_shards=((BUCKET, too_many),))
    assert f"spatial_shards={too_many}" in str(ei.value)
    assert "available device" in str(ei.value)


def test_telemetry_reports_scheduler_config(model):
    eng = _engine(model, batch_window=0.5)
    tel = eng.telemetry()["engine"]
    assert tel["batch_window"] == 0.5
    assert tel["spatial_shards"] == []
