"""Kernel edge-geometry parity: fused Pallas kernels vs ``kernels/ref.py``
on ragged tiles, strided/dilated taps, and offsets that hit the Eq. 5
clamp — on both the legacy banded and the zero-copy dataflow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

DATAFLOWS = ["banded", "zero_copy"]

# (name, H, W, C, M, K, stride, dil, bound, tile_h, tile_w, off_scale)
EDGE_CASES = [
    # Ho % tile_h != 0: 13 output rows over tile_h=4 -> ragged last row tile
    ("ragged_h", 13, 16, 4, 8, 3, 1, 1, 2.0, 4, 8, 1.0),
    # Wo % tile_w != 0: 18 output cols over tile_w=8 -> ragged width tile
    ("ragged_w", 16, 18, 4, 8, 3, 1, 1, 2.0, 4, 8, 1.0),
    # both ragged at once
    ("ragged_hw", 11, 13, 4, 4, 3, 1, 1, 1.5, 4, 8, 1.0),
    # stride=2: output grid is half the input grid
    ("stride2", 16, 16, 4, 8, 3, 2, 1, 2.0, 4, 4, 1.0),
    # dilation=2: taps span 2x the kernel extent
    ("dilation2", 16, 16, 4, 8, 3, 1, 2, 2.0, 4, 8, 1.0),
    # offsets drawn at 4x the bound: the in-kernel clamp must engage
    ("clamp_hit", 12, 12, 4, 8, 3, 1, 1, 1.0, 4, 8, 4.0),
    # stride + ragged + clamp together
    ("stride2_ragged_clamp", 15, 13, 4, 4, 3, 2, 1, 1.5, 4, 4, 4.0),
]


def _case_arrays(name, h, w, c, m, k, s, d, off_scale):
    key = jax.random.PRNGKey(abs(hash(name)) % (2 ** 31))
    x = jax.random.normal(key, (2, h, w, c), jnp.float32)
    pad = d * (k // 2)
    ho = (h + 2 * pad - d * (k - 1) - 1) // s + 1
    wo = (w + 2 * pad - d * (k - 1) - 1) // s + 1
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (2, ho, wo, 2 * k * k), jnp.float32) * off_scale
    wgt = jax.random.normal(jax.random.fold_in(key, 2),
                            (k * k, c, m), jnp.float32) * 0.2
    return x, offs, wgt


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("case", EDGE_CASES, ids=lambda c: c[0])
def test_fused_edge_geometry_parity(case, dataflow):
    name, h, w, c, m, k, s, d, bound, th, tw, off_scale = case
    x, offs, wgt = _case_arrays(name, h, w, c, m, k, s, d, off_scale)
    got = ops.deform_conv(x, offs, wgt, kernel_size=k, stride=s, dilation=d,
                          offset_bound=bound, tile_h=th, tile_w=tw,
                          dataflow=dataflow)
    want = ref.deform_conv_fused_ref(x, offs, wgt, kernel_size=k, stride=s,
                                     dilation=d, offset_bound=bound)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("case", EDGE_CASES[:4] + EDGE_CASES[5:6],
                         ids=lambda c: c[0])
def test_sample_edge_geometry_parity(case, dataflow):
    name, h, w, c, m, k, s, d, bound, th, tw, off_scale = case
    x, offs, _ = _case_arrays(name, h, w, c, m, k, s, d, off_scale)
    got = ops.deform_sample(x, offs, kernel_size=k, stride=s, dilation=d,
                            offset_bound=bound, tile_h=th, tile_w=tw,
                            dataflow=dataflow)
    want = ref.deform_sample_ref(x, offs, kernel_size=k, stride=s,
                                 dilation=d, offset_bound=bound)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_zero_copy_matches_banded_bitwise_tiles():
    """Same tiles, same input: the two dataflows must agree (the DMA
    rewrite is a pure dataflow change, not a numerics change)."""
    x, offs, wgt = _case_arrays("xcheck", 16, 16, 8, 8, 3, 1, 1, 1.0)
    a = ops.deform_conv(x, offs, wgt, offset_bound=2.0, tile_h=4,
                        tile_w=8, dataflow="zero_copy")
    b = ops.deform_conv(x, offs, wgt, offset_bound=2.0, tile_h=4,
                        dataflow="banded")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_auto_tiles_resolve_and_divide():
    """Tile chooser integration: unspecified tiles resolve to divisors of
    (C, M) and the kernel runs with them."""
    from repro.core.tiling import LayerShape, choose_kernel_tiles
    for c, m in [(6, 10), (128, 128), (96, 64)]:
        kt = choose_kernel_tiles(
            LayerShape(h=32, w=32, c_in=c, c_out=m, offset_bound=2.0))
        assert c % kt.tile_c == 0 and m % kt.tile_m == 0
    x, offs, wgt = _case_arrays("auto", 12, 12, 6, 10, 3, 1, 1, 1.0)
    got = ops.deform_conv(x, offs, wgt, offset_bound=2.0)
    want = ref.deform_conv_fused_ref(x, offs, wgt, offset_bound=2.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_modeled_traffic_acceptance_gate():
    """PR acceptance: modeled HBM traffic for the bounded 3x3 DCL
    (H=W=64, C=M=128, B=4, tile_h=8) drops >= 2x under zero-copy."""
    from repro.core.perf_model import dataflow_traffic_report
    rep = dataflow_traffic_report(h=64, w=64, c=128, m=128, batch=4,
                                  tile_h=8, offset_bound=2.0)
    assert rep["ratio"] >= 2.0, rep
