"""Elastic scaling: checkpoint on a 4-device mesh, resume on 8 devices.

Each phase needs its own process (device count locks at jax init), so
the test drives ``examples/elastic_restart.py`` twice.  Phase 2 itself
asserts that the elastically-resumed parameters match a straight run.
"""
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run(phase: int, devices: int, ckpt: str):
    return subprocess.run(
        [sys.executable, str(ROOT / "examples" / "elastic_restart.py"),
         "--phase", str(phase), "--ckpt", ckpt],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp",
             "XLA_FLAGS":
                 f"--xla_force_host_platform_device_count={devices}"},
    )


@pytest.mark.slow
def test_elastic_mesh_change(tmp_path):
    ckpt = str(tmp_path / "elastic")
    p1 = _run(1, 4, ckpt)
    assert p1.returncode == 0, p1.stderr[-3000:]
    assert "mesh {'data': 2, 'model': 2}" in p1.stdout

    p2 = _run(2, 8, ckpt)
    assert p2.returncode == 0, p2.stderr[-3000:]
    assert "mesh {'data': 4, 'model': 2}" in p2.stdout
    assert "resumed at step 10" in p2.stdout
    assert "elastic resume == straight run: OK" in p2.stdout
