"""Serve chaos capstone (PR 7): the DCL serving engine under a seeded
fault schedule covering all four serve-relevant fault classes — a
kernel dispatch failure (degradation ladder), a slow step (deadline
expiry), a malformed request, and a bucket-miss storm.

The engine must retire EVERY submitted request with a typed outcome (no
crash, no hung slot, nothing left pending), degraded requests must
report their ladder rung in per-request telemetry, and requests the
faults never touched must be bit-exact against a fault-free run of the
same traffic.  If ``REPRO_SERVE_TELEMETRY`` is set, the chaos plan +
engine telemetry is written there — the artifact the CI ``chaos-serve``
job uploads.
"""
import os

import jax
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import resnet_dcn as R
from repro.obs import Tracer, tracer_scope
from repro.quant.calibrate import calibrate_resnet_dcn
from repro.resilience import ChaosHooks, FaultEvent, FaultPlan
from repro.serve import DCLServeConfig, DCLServingEngine, OUTCOMES

from _fakeclock import FakeClock

CHAOS_SEED = 20260808
BUCKET = 32
N_REQUESTS = 10
SLOW_STALL_S = 1.0      # fake-clock stall injected by slow_step
TIGHT_DEADLINE_S = 0.5  # two requests carry this; the stall expires them


@pytest.fixture(scope="module")
def model():
    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=BUCKET, offset_bound=2.0,
        use_kernel=True)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    table = calibrate_resnet_dcn(
        params, cfg, [rng.randn(2, BUCKET, BUCKET, 3).astype(np.float32)])
    return cfg, params, table


def _plan():
    """Seeded schedule: the slow-step placement comes from the seed; the
    admission faults are armed in plan order (consumed per submit)."""
    rng = np.random.default_rng(CHAOS_SEED)
    slow_at = int(rng.integers(1, 3))     # engine step 1 or 2
    return FaultPlan(events=(
        FaultEvent(step=slow_at, kind="slow_step", mode=str(SLOW_STALL_S)),
        FaultEvent(step=0, kind="malformed_request"),
        FaultEvent(step=0, kind="bucket_miss_storm", mode="2"),
        FaultEvent(step=0, kind="dispatch_fault"),
    ), seed=CHAOS_SEED)


def _run(model, hooks=None):
    cfg, params, table = model
    clock = FakeClock()
    if hooks is not None:
        hooks.sleep = clock.advance       # deterministic stall
    eng = DCLServingEngine(
        params, cfg,
        # max_retries=0: a one-shot dispatch fault degrades the batch
        # instead of being absorbed by a same-rung replay
        DCLServeConfig(buckets=(BUCKET,), slots=2, max_retries=0),
        scale_table=table, clock=clock,
        step_hook=hooks.serve_step_hook if hooks else None,
        admit_hook=hooks.admit_hook if hooks else None)
    rng = np.random.RandomState(CHAOS_SEED % 2**31)
    imgs = [rng.randn(BUCKET, BUCKET, 3).astype(np.float32)
            for _ in range(N_REQUESTS)]
    for uid, img in enumerate(imgs):
        deadline = TIGHT_DEADLINE_S if uid >= N_REQUESTS - 2 else None
        eng.submit(img, deadline=deadline)
    if hooks is not None:
        with ops.dispatch_hook_scope(hooks.dispatch_hook):
            eng.run_until_drained()
    else:
        eng.run_until_drained()
    return eng


def test_serve_chaos_every_request_typed_and_undisturbed_bit_exact(model):
    free = _run(model)
    assert all(r.outcome == "ok" for r in free.completed)
    free_by_uid = {r.uid: r for r in free.completed}

    # The chaos run is traced (ISSUE 8): the engine and ChaosHooks both
    # resolve the process-global tracer at use time, so the scope below
    # captures serve spans AND fault/* instant events — while every
    # bit-exactness assertion beneath must still hold (tracing cannot
    # perturb outcomes).
    tracer = Tracer(enabled=True)
    hooks = ChaosHooks(_plan())
    with tracer_scope(tracer):
        eng = _run(model, hooks)

    # every injected fault appears in the trace as a fault/* event,
    # nested among the serve spans
    event_names = {e["name"] for e in tracer.events}
    assert {"fault/slow_step", "fault/malformed_request",
            "fault/bucket_miss_storm",
            "fault/dispatch_fault"} <= event_names
    span_names = {s.name for s in tracer.spans}
    assert "serve/step" in span_names
    assert "kernel/dispatch" in span_names

    # every admitted request retired with a typed outcome; nothing hung
    assert len(eng.completed) == N_REQUESTS
    assert len(eng.queue) == 0
    by_uid = {r.uid: r for r in eng.completed}
    for r in eng.completed:
        assert r.done and r.outcome in OUTCOMES, (r.uid, r.outcome)
        assert r.outcome != "pending" and r.outcome != "failed"

    # all four fault kinds actually fired
    assert {f["kind"] for f in hooks.fired} == {
        "slow_step", "malformed_request", "bucket_miss_storm",
        "dispatch_fault"}

    # admission faults: uid 0 malformed, uids 1-2 the bucket-miss storm
    assert by_uid[0].outcome == "malformed"
    assert by_uid[1].outcome == "unbucketable"
    assert by_uid[2].outcome == "unbucketable"

    # the dispatch fault degraded the first served batch one rung, and
    # the rung is recorded per request in telemetry
    degraded = [r for r in eng.completed if r.degraded]
    assert degraded, "dispatch fault should have degraded a batch"
    for r in degraded:
        assert r.outcome == "ok" and r.ladder == "int8" and r.retries == 1
    tel = eng.telemetry()
    for rec in tel["requests"]:
        if rec["degraded"]:
            assert rec["ladder"] == "int8"
    assert eng.counters["degraded_batches"] == 1
    # ...without touching ops' process-global warn-once fallback
    assert ops._FALLBACK_WARNED == set()

    # the slow step expired the tight-deadline requests (typed, swept)
    expired = [r for r in eng.completed
               if r.outcome == "deadline_exceeded"]
    assert {r.uid for r in expired} <= {N_REQUESTS - 2, N_REQUESTS - 1}
    assert expired, "slow_step should have expired a tight deadline"
    for r in expired:
        assert r.result is None

    # undisturbed requests are bit-exact vs the fault-free run
    undisturbed = [r for r in eng.completed
                   if r.outcome == "ok" and not r.degraded
                   and r.retries == 0 and r.ladder == "int8_chain"]
    assert undisturbed, "some requests must be untouched by the plan"
    for r in undisturbed:
        ref = free_by_uid[r.uid]
        assert np.array_equal(r.result["cls"], ref.result["cls"]), r.uid
        assert np.array_equal(r.result["box"], ref.result["box"]), r.uid

    path = os.environ.get("REPRO_SERVE_TELEMETRY")
    if path:
        from repro.resilience import dump_telemetry
        dump_telemetry(path, tel, extra={
            "seed": CHAOS_SEED,
            "chaos": hooks.telemetry(),
            "undisturbed_uids": sorted(r.uid for r in undisturbed)})
        # companion trace artifact (CI uploads both; obs_report renders)
        root, _ = os.path.splitext(path)
        tracer.export_jsonl(root + "-trace.jsonl")
