"""CLI launchers: train (with resume) and serve, end to end."""
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
ENV = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
       "HOME": "/tmp"}


def _run(args, timeout=420):
    return subprocess.run([sys.executable, "-m", *args],
                          capture_output=True, text=True, timeout=timeout,
                          env=ENV, cwd=ROOT)


@pytest.mark.slow
def test_train_launcher_and_resume(tmp_path):
    ckpt = str(tmp_path / "ck")
    p = _run(["repro.launch.train", "--arch", "tinyllama-1.1b",
              "--steps", "12", "--ckpt-every", "6", "--ckpt", ckpt])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "'step': 10" in p.stdout
    # resume picks up from the checkpoint
    p2 = _run(["repro.launch.train", "--arch", "tinyllama-1.1b",
               "--steps", "14", "--ckpt-every", "6", "--ckpt", ckpt])
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "resumed from step 12" in p2.stdout


@pytest.mark.slow
def test_serve_launcher():
    p = _run(["repro.launch.serve", "--arch", "tinyllama-1.1b",
              "--requests", "4", "--max-new-tokens", "4"])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "served 4 requests / 16 tokens" in p.stdout


@pytest.mark.slow
def test_train_launcher_dcn_with_lambda(tmp_path):
    p = _run(["repro.launch.train", "--arch", "resnet50_dcn_bounded",
              "--steps", "4", "--ckpt", str(tmp_path / "ck2"),
              "--global-batch", "2", "--lam", "0.1"])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "'step': 0" in p.stdout
