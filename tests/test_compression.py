"""Error-feedback int8 compression: quantization error bounded, error
feedback contracts (time-averaged gradient preserved), psum form works."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # fallback: deterministic parametrize shim
    from _propshim import given, settings, st

from repro.distributed.compression import (compressed_psum, ef_compress_grads,
                                           init_ef_state)


@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
@settings(max_examples=20, deadline=None)
def test_single_step_quantization_error_bounded(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    tree = {"g": g}
    ef = init_ef_state(tree)
    deq, ef2 = ef_compress_grads(tree, ef)
    err = jnp.max(jnp.abs(deq["g"] - g))
    step = jnp.max(jnp.abs(g)) / 127.0
    assert float(err) <= float(step) * 0.51 + 1e-6


def test_error_feedback_preserves_average_gradient():
    """Sum over T steps of dequantized grads ~= sum of true grads —
    the EF contraction property that keeps training unbiased."""
    key = jax.random.PRNGKey(0)
    g_const = jax.random.normal(key, (32,)) * 0.01   # small => coarse quant
    tree = {"g": g_const}
    ef = init_ef_state(tree)
    total = jnp.zeros_like(g_const)
    for t in range(50):
        deq, ef = ef_compress_grads(tree, ef)
        total = total + deq["g"]
    avg = total / 50
    np.testing.assert_allclose(avg, g_const, rtol=0.02, atol=1e-5)
    # and the residual is bounded (no drift)
    assert float(jnp.max(jnp.abs(ef["g"]))) <= \
        float(jnp.max(jnp.abs(g_const))) + 1e-6


def test_compressed_psum_on_mesh():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(1), (8,))

    f = shard_map(
        functools.partial(compressed_psum, axis_name="data"),
        mesh=mesh, in_specs=P(None), out_specs=P(None))
    got = f(x)
    # 1 device: psum is identity; error is pure quantization
    err = jnp.max(jnp.abs(got - x))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0 * 0.51 + 1e-6


# ---------------------------------------------------------------------------
# PR 6: shared-grid determinism / shard symmetry
# ---------------------------------------------------------------------------

def test_compressed_psum_shard_symmetric_and_deterministic():
    """All shards quantize onto the pmax-agreed grid BEFORE the int32
    psum, so the collective is invariant to which shard holds the
    largest gradient and to reduction grouping.  (vmap with an axis
    name runs the real pmax/psum collectives across the stacked axis.)"""
    from repro.distributed.compression import _psum_int8

    big = jnp.array([10.0, -5.0, 2.5, 0.1])
    small = jnp.array([0.01, -0.02, 0.005, 0.0])
    shards = jnp.stack([big, small])

    out = jax.vmap(lambda x: compressed_psum(x, "i"), axis_name="i")(shards)
    # every shard sees the identical replicated sum
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))

    # matches the shared-grid math exactly
    scale = float(jnp.max(jnp.abs(shards)) / 127.0 + 1e-12)
    q = np.clip(np.round(np.asarray(shards) / scale), -127, 127)
    expected = (q[0] + q[1]) * scale
    np.testing.assert_allclose(np.asarray(out[0]), expected, rtol=1e-6)

    # shard order must not matter (symmetry)
    out_rev = jax.vmap(lambda x: compressed_psum(x, "i"),
                       axis_name="i")(shards[::-1])
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out_rev[0]))

    # error vs the true sum is bounded by one shared-grid LSB per shard
    true = np.asarray(big + small)
    assert np.max(np.abs(expected - true)) <= 2 * scale * 0.51 + 1e-6

    # and the payload path really is the int8 collective helper
    direct = jax.vmap(
        lambda x: _psum_int8(
            jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8),
            jnp.float32(scale), "i"),
        axis_name="i")(shards)
    np.testing.assert_allclose(np.asarray(direct[0]), expected, rtol=1e-6)
