"""Per-architecture smoke tests: a REDUCED same-family config runs one
forward + one train step on CPU, asserting shapes and finiteness.
The full configs are touched only by the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry as reg
from repro.models.registry import reduced_config
from repro.models.resnet_dcn import ResNetDCNConfig
from repro.optim import adamw, constant

ARCHS = reg.names()


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward_and_train_step(name):
    arch = reg.get(name)
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    B, S = 2, 24

    if isinstance(cfg, ResNetDCNConfig):
        from repro.models import resnet_dcn as R
        from repro.data import DetectionDataConfig, detection_batch
        params = R.init_params(key, cfg)
        dcfg = DetectionDataConfig(img_size=cfg.img_size, global_batch=B,
                                   num_classes=cfg.num_classes)
        batch = {k: jnp.asarray(v) for k, v in
                 detection_batch(dcfg, 0).items()}
        out, o_maxes = R.forward(params, cfg, batch["images"])
        hc = cfg.img_size // 32
        assert out["cls"].shape == (B, hc, hc, cfg.num_classes + 1)
        assert out["box"].shape == (B, hc, hc, 4)
        assert len(o_maxes) == cfg.num_dcn
        lam = 0.005 if cfg.offset_bound is not None else 0.0
        (loss, m), grads = jax.value_and_grad(
            lambda p: R.train_loss(p, cfg, batch, lam=lam),
            has_aux=True)(params)
        assert np.isfinite(float(loss))
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in
                          jax.tree_util.tree_leaves(grads)))
        assert np.isfinite(float(gn))
        return

    from repro.models.transformer import init_params, loss_fn, forward
    params = init_params(key, cfg)
    tok_shape = (B, S) if cfg.codebooks == 1 else (B, S, cfg.codebooks)
    toks = jax.random.randint(key, tok_shape, 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    if cfg.frontend_embeds:
        batch["frontend"] = jax.random.normal(
            key, (B, 4, cfg.d_model), jnp.float32)

    # forward shapes
    logits, _, _ = forward(params, cfg, tokens=toks,
                           frontend=batch.get("frontend"), mode="train")
    exp_s = S + (4 if cfg.frontend_embeds else 0)
    if cfg.codebooks > 1:
        assert logits.shape == (B, exp_s, cfg.codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, exp_s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one train step
    opt = adamw(constant(1e-3))
    state = opt.init(params)
    (loss, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    new_params, _ = opt.update(grads, state, params, jnp.asarray(0))
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(new_params),
                                jax.tree_util.tree_leaves(params)))
    assert np.isfinite(delta) and delta > 0


@pytest.mark.parametrize("name", [n for n in ARCHS
                                  if reg.get(n).long_context_ok])
def test_long_context_archs_decode(name):
    """The two long_500k archs must decode at positions >> cache."""
    arch = reg.get(name)
    cfg = reduced_config(arch)
    from repro.models.transformer import (init_params, init_cache,
                                          decode_step)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 32
    caches = init_cache(cfg, B, L)
    pos = jnp.asarray([500, 700], jnp.int32)      # far beyond cache_len
    tok = jnp.zeros((B,), jnp.int32)
    logits, caches = decode_step(params, cfg, tok, caches, pos)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_registry_cells_accounting():
    """40 LM cells = 32 runnable + 8 recorded skips; + 4 CNN cells."""
    cells = reg.runnable_cells()
    skips = reg.skipped_cells()
    lm = [c for c in cells if not c[0].startswith("resnet50")]
    cnn = [c for c in cells if c[0].startswith("resnet50")]
    assert len(lm) + len(skips) == 40
    assert len(cnn) == 4
    assert all(s[1] == "long_500k" for s in skips)
