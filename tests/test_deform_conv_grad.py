"""Gradient parity for the custom-VJP bounded deform_conv.

``jax.grad`` through the fused Pallas kernel path
(``ops.deform_conv`` -> ``kernels.deform_conv_bwd``) must match
``jax.grad`` through the pure-XLA gather reference for all three
cotangents (input, offsets, weights), across the same edge-geometry
matrix the forward parity suite uses: ragged tiles, stride=2,
dilation=2, and offsets that saturate the Eq. 5 clamp.

Also gates this PR's modeled-traffic acceptance criterion: combined
fwd+bwd HBM traffic of the zero-copy dataflow >= 2x below the
materialized-band training baseline on the reference 3x3 layer.
"""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# (name, H, W, C, M, K, stride, dil, bound, tile_h, tile_w, off_scale)
# — same matrix as tests/test_kernel_geometry.py, plus a multi-C-chunk
# case (tile_c < C exercises the backward C-step accumulators).
EDGE_CASES = [
    ("ragged_h", 13, 16, 4, 8, 3, 1, 1, 2.0, 4, 8, 1.0),
    ("ragged_w", 16, 18, 4, 8, 3, 1, 1, 2.0, 4, 8, 1.0),
    ("ragged_hw", 11, 13, 4, 4, 3, 1, 1, 1.5, 4, 8, 1.0),
    ("stride2", 16, 16, 4, 8, 3, 2, 1, 2.0, 4, 4, 1.0),
    ("dilation2", 16, 16, 4, 8, 3, 1, 2, 2.0, 4, 8, 1.0),
    ("clamp_hit", 12, 12, 4, 8, 3, 1, 1, 1.0, 4, 8, 4.0),
    ("stride2_ragged_clamp", 15, 13, 4, 4, 3, 2, 1, 1.5, 4, 4, 4.0),
]


def _case_arrays(name, h, w, c, m, k, s, d, off_scale):
    # crc32, not hash(): str hashing is PYTHONHASHSEED-salted, and a
    # parity failure must be reproducible across processes.
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % (2 ** 31))
    x = jax.random.normal(key, (2, h, w, c), jnp.float32)
    pad = d * (k // 2)
    ho = (h + 2 * pad - d * (k - 1) - 1) // s + 1
    wo = (w + 2 * pad - d * (k - 1) - 1) // s + 1
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (2, ho, wo, 2 * k * k), jnp.float32) * off_scale
    wgt = jax.random.normal(jax.random.fold_in(key, 2),
                            (k * k, c, m), jnp.float32) * 0.2
    return x, offs, wgt


def _grads(forward, x, offs, wgt):
    # sin() makes the cotangent position-dependent, so a transposed or
    # mis-scattered d_input cannot cancel out.
    loss = lambda a, b, c_: jnp.sum(jnp.sin(forward(a, b, c_)))  # noqa: E731
    return jax.grad(loss, argnums=(0, 1, 2))(x, offs, wgt)


@pytest.mark.parametrize("dataflow", ["zero_copy", "banded"])
@pytest.mark.parametrize("case", EDGE_CASES, ids=lambda c: c[0])
def test_grad_edge_geometry_parity(case, dataflow):
    name, h, w, c, m, k, s, d, bound, th, tw, off_scale = case
    x, offs, wgt = _case_arrays(name, h, w, c, m, k, s, d, off_scale)
    got = _grads(
        lambda a, b, c_: ops.deform_conv(
            a, b, c_, kernel_size=k, stride=s, dilation=d,
            offset_bound=bound, tile_h=th, tile_w=tw, dataflow=dataflow),
        x, offs, wgt)
    want = _grads(
        lambda a, b, c_: ref.deform_conv_fused_ref(
            a, b, c_, kernel_size=k, stride=s, dilation=d,
            offset_bound=bound),
        x, offs, wgt)
    for name_, g, r in zip(("d_input", "d_offsets", "d_weights"), got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-4, err_msg=name_)


def test_grad_multi_c_chunk():
    """tile_c < C: the backward per-chunk d_weights accumulator and the
    chunked d_input RMW flushes must compose to the full gradients."""
    x, offs, wgt = _case_arrays("csteps", 16, 16, 8, 8, 3, 1, 1, 1.0)
    got = _grads(
        lambda a, b, c_: ops.deform_conv(
            a, b, c_, offset_bound=2.0, tile_h=4, tile_w=8, tile_c=2),
        x, offs, wgt)
    want = _grads(
        lambda a, b, c_: ref.deform_conv_fused_ref(a, b, c_,
                                                   offset_bound=2.0),
        x, offs, wgt)
    for name_, g, r in zip(("d_input", "d_offsets", "d_weights"), got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-4, err_msg=name_)


def test_grad_auto_tiles():
    """Chooser-resolved tiles (the training hot path) differentiate."""
    x, offs, wgt = _case_arrays("auto", 12, 12, 6, 10, 3, 1, 1, 1.0)
    got = _grads(
        lambda a, b, c_: ops.deform_conv(a, b, c_, offset_bound=2.0),
        x, offs, wgt)
    want = _grads(
        lambda a, b, c_: ref.deform_conv_fused_ref(a, b, c_,
                                                   offset_bound=2.0),
        x, offs, wgt)
    for name_, g, r in zip(("d_input", "d_offsets", "d_weights"), got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-4, err_msg=name_)


def test_grad_through_dcl_apply_matches_reference():
    """models/layers.dcl_apply(use_kernel=True) end-to-end: the full
    layer gradient (offset conv + kernel + bias) matches the pure-JAX
    dcl_forward path parameter-for-parameter."""
    from repro.models.layers import dcl_apply, dcl_def, init_tree

    key = jax.random.PRNGKey(7)
    params = init_tree(key, dcl_def(6, 8))
    params["w_offset"] = 0.1 * jax.random.normal(
        jax.random.fold_in(key, 1), params["w_offset"].shape, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 12, 12, 6),
                          jnp.float32)

    def loss(p, use_kernel):
        y, o_max = dcl_apply(p, x, offset_bound=1.5, use_kernel=use_kernel)
        return jnp.sum(jnp.sin(y)) + 0.1 * o_max

    gk = jax.grad(lambda p: loss(p, True))(params)
    gr = jax.grad(lambda p: loss(p, False))(params)
    for name_ in gk:
        np.testing.assert_allclose(np.asarray(gk[name_]),
                                   np.asarray(gr[name_]),
                                   rtol=1e-4, atol=1e-4, err_msg=name_)


def test_train_step_kernel_path_matches_reference():
    """One value_and_grad step of the miniature ResNet-DCN detector:
    the kernel-path config and the XLA-reference config produce the
    same loss and the same full parameter gradient."""
    import dataclasses

    from jax.flatten_util import ravel_pytree
    from repro.data import DetectionDataConfig, detection_batch
    from repro.models import resnet_dcn as R

    cfg_ref = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=32, offset_bound=2.0)
    cfg_k = dataclasses.replace(cfg_ref, use_kernel=True)
    data = DetectionDataConfig(img_size=32, global_batch=2, num_classes=4,
                               seed=3)
    params = R.init_params(jax.random.PRNGKey(0), cfg_ref)
    batch = {k: jnp.asarray(v) for k, v in detection_batch(data, 0).items()}

    def step(cfg):
        return jax.value_and_grad(
            lambda p: R.train_loss(p, cfg, batch, lam=0.1)[0])(params)

    l_ref, g_ref = step(cfg_ref)
    l_k, g_k = step(cfg_k)
    np.testing.assert_allclose(float(l_k), float(l_ref), rtol=1e-5)
    flat_ref, _ = ravel_pytree(g_ref)
    flat_k, _ = ravel_pytree(g_k)
    np.testing.assert_allclose(np.asarray(flat_k), np.asarray(flat_ref),
                               rtol=1e-3, atol=1e-4)


def test_dw_flush_cadence_parity():
    """Satellite (PR 3): the compiled d_weights cadence — accumulator
    mirrored to the output block only on the LAST spatial grid step —
    must produce the same cotangents as the interpret-safe every-step
    flush (the final revisit of each C-chunk block carries the complete
    fp32 sum either way)."""
    from repro.kernels.deform_conv_bwd import deform_conv_bwd_zerocopy
    from repro.kernels.ops import _pad_zerocopy, tile_weights

    x, offs, wgt = _case_arrays("dwflush", 16, 16, 8, 8, 3, 1, 1, 1.0)
    g = jax.random.normal(jax.random.PRNGKey(11), (2, 16, 16, 8),
                          jnp.float32)
    xp = _pad_zerocopy(x, kernel_size=3, stride=1, dilation=1,
                       offset_bound=2.0, tile_h=4, tile_w=8, ho=16, wo=16)
    wt = tile_weights(wgt, 2)
    outs = {}
    for every_step in (True, False):
        outs[every_step] = deform_conv_bwd_zerocopy(
            xp, offs, g, wt, kernel_size=3, stride=1, dilation=1,
            offset_bound=2.0, tile_h=4, tile_w=8, tile_c=2,
            interpret=True, dw_flush_every_step=every_step)
    for name, a, b in zip(("dx", "doff", "dw"), outs[True], outs[False]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_bwd_megacore_split_parity():
    """Tentpole (PR 4): the Megacore-split backward (cores>1, per-core
    d_weights partials + reduce epilogue) vs the sequential kernel —
    d_input and d_offsets are batch-indexed and must be BIT-identical
    (disjoint per-core HBM regions); d_weights differs only in fp32
    partial-sum order."""
    from repro.kernels.deform_conv_bwd import deform_conv_bwd_zerocopy
    from repro.kernels.ops import _pad_zerocopy, tile_weights

    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (4, 16, 16, 8), jnp.float32)
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (4, 16, 16, 18), jnp.float32) * 2
    wgt = jax.random.normal(jax.random.fold_in(key, 2), (9, 8, 8),
                            jnp.float32) * 0.2
    g = jax.random.normal(jax.random.fold_in(key, 3), (4, 16, 16, 8),
                          jnp.float32)
    xp = _pad_zerocopy(x, kernel_size=3, stride=1, dilation=1,
                       offset_bound=2.0, tile_h=4, tile_w=8, ho=16, wo=16)
    wt = tile_weights(wgt, 4)
    outs = {}
    for cores in (1, 2, 4):
        outs[cores] = deform_conv_bwd_zerocopy(
            xp, offs, g, wt, kernel_size=3, stride=1, dilation=1,
            offset_bound=2.0, tile_h=4, tile_w=8, tile_c=4, cores=cores,
            interpret=True)
    for cores in (2, 4):
        np.testing.assert_array_equal(np.asarray(outs[cores][0]),
                                      np.asarray(outs[1][0]),
                                      err_msg=f"dx cores={cores}")
        np.testing.assert_array_equal(np.asarray(outs[cores][1]),
                                      np.asarray(outs[1][1]),
                                      err_msg=f"doff cores={cores}")
        np.testing.assert_allclose(np.asarray(outs[cores][2]),
                                   np.asarray(outs[1][2]),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"dw cores={cores}")


def test_megacore_grad_matches_reference():
    """jax.grad through ops.deform_conv(cores=2) (the public dispatch +
    custom VJP + split kernel + reduce epilogue) matches the XLA
    reference on the standard parity case."""
    x, offs, wgt = _case_arrays("mc", 16, 16, 8, 8, 3, 1, 1, 1.0)
    got = _grads(
        lambda a, b, c_: ops.deform_conv(
            a, b, c_, offset_bound=2.0, tile_h=4, tile_w=8, cores=2),
        x, offs, wgt)
    want = _grads(
        lambda a, b, c_: ref.deform_conv_fused_ref(a, b, c_,
                                                   offset_bound=2.0),
        x, offs, wgt)
    for name_, g, r in zip(("d_input", "d_offsets", "d_weights"), got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-4, err_msg=name_)


def test_core_split_value_error():
    """Satellite: a core count that doesn't divide the batch raises the
    friendly ValueError naming the offending sizes (not a deep Pallas
    grid assert)."""
    x, offs, wgt = _case_arrays("mcerr", 16, 16, 4, 4, 3, 1, 1, 1.0)
    with pytest.raises(ValueError, match=r"cores=3 does not divide.*N=2"):
        ops.deform_conv(x, offs, wgt, offset_bound=2.0, cores=3)
    with pytest.raises(ValueError, match="cores=0"):
        ops.deform_conv(x, offs, wgt, offset_bound=2.0, cores=0)
    # paths without the Megacore backward reject cores instead of
    # silently ignoring it
    with pytest.raises(ValueError, match="cores=2 applies to"):
        ops.deform_conv(x, offs, wgt, cores=2)
    with pytest.raises(ValueError, match="cores=2 applies to"):
        ops.deform_conv(x, offs, wgt, offset_bound=2.0, precision="int8",
                        cores=2)


def test_bwd_per_core_traffic_drops_cores_x():
    """Acceptance (PR 4): per-core backward HBM traffic drops exactly
    cores x for the dw-stationary (batch-indexed) terms; only the
    per-core partial-d_weights flush stays whole."""
    from repro.core.tiling import (LayerShape, TileConfig,
                                   dcl_backward_hbm_bytes)
    shape = LayerShape(h=64, w=64, c_in=128, c_out=128, offset_bound=2.0)
    t = TileConfig(t_h=8, t_w=64, t_n=128, t_m=128)
    dw = 9 * 128 * 128 * 4
    base = dcl_backward_hbm_bytes(shape, t, batch=8)
    for cores in (2, 4):
        pc = dcl_backward_hbm_bytes(shape, t, batch=8, cores=cores,
                                    per_core=True)
        assert pc - dw == (base - dw) // cores, (cores, pc, base)
        # aggregate honesty: the split only ADDs the partial flushes +
        # reduce epilogue, it never shrinks total traffic
        tot = dcl_backward_hbm_bytes(shape, t, batch=8, cores=cores)
        assert tot == base + 2 * cores * dw, (cores, tot, base)
    # the report the benches/EXPERIMENTS carry
    from repro.core.perf_model import dataflow_traffic_report
    rep = dataflow_traffic_report(h=64, w=64, c=128, m=128, batch=4,
                                  tile_h=8, offset_bound=2.0, cores=2)
    assert rep["bwd_per_core_ratio"] >= 1.9, rep["bwd_per_core_ratio"]


def test_modeled_train_traffic_acceptance_gate():
    """PR-2 acceptance: combined fwd+bwd modeled HBM traffic for the
    bounded 3x3 reference layer (H=W=64, C=M=128, batch=4, tile_h=8)
    drops >= 2x under zero-copy vs the materialized-band training
    baseline."""
    from repro.core.perf_model import dataflow_traffic_report
    rep = dataflow_traffic_report(h=64, w=64, c=128, m=128, batch=4,
                                  tile_h=8, offset_bound=2.0)
    assert rep["train_ratio"] >= 2.0, rep
    assert rep["bwd_ratio"] >= 2.0, rep
    # the PR-1 forward gate must not regress
    assert rep["ratio"] >= 2.0, rep
