"""Calibrated analytic model vs the paper's published numbers."""
import pytest

from repro.core import perf_model as pm


def test_stall_free_capacity_matches_paper():
    # "the input buffer needs at least 13.8MB when lambda is zero"
    assert pm.stall_free_capacity(0.0) == pytest.approx(13.8e6, rel=0.05)
    # regularized: a few percent of that ("only 3% input buffer capacity")
    frac = pm.stall_free_capacity(0.005) / pm.stall_free_capacity(0.0)
    assert frac < 0.05


def test_rf_compression_matches_paper():
    # "compress the maximum size of the receptive field by 12.6 times"
    assert pm.rf_compression(0.005) == pytest.approx(12.6, rel=0.03)


def test_speedup_matches_paper():
    # Fig. 8: 5.28x (N=128) ... 17.25x (N=512)
    s128 = pm.speedup(128, 0.005)
    s512 = pm.speedup(512, 0.005)
    assert s128 == pytest.approx(5.28, rel=0.08)
    assert s512 == pytest.approx(17.25, rel=0.05)
    # monotone in N (the paper's data-reuse narrative)
    assert s128 < pm.speedup(256, 0.005) < s512


def test_energy_matches_paper():
    # Fig. 9: combination saves ~1.39x
    ratios = [pm.energy_ratio(n, 0.005) for n in (128, 256, 512)]
    assert ratios[-1] == pytest.approx(1.39, rel=0.08)
    assert all(r > 1.0 for r in ratios)
    # lambda=0 ours still beats conventional but by less (bigger buffer)
    r0 = pm.energy_ratio(512, 0.0)
    assert 1.0 < r0 < ratios[-1]


def test_buffer_efficiency_curve_shape():
    # Fig. 3: efficiency rises with capacity; regularized saturates early
    caps = [64 << 10, 512 << 10, 4 << 20, 16 << 20]
    eff0 = [pm.buffer_efficiency(c, 0.0) for c in caps]
    eff5 = [pm.buffer_efficiency(c, 0.005) for c in caps]
    assert all(a <= b + 1e-9 for a, b in zip(eff0, eff0[1:]))
    assert eff5[1] > 0.99          # tiny buffer suffices after Eq. 5
    assert eff0[1] < 0.6           # same buffer starves at lambda=0
    assert eff0[-1] > 0.97         # 13.8MB-class buffer ~stall-free
