"""DCL semantics (paper Eq. 1-4) — unit + hypothesis property tests."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # fallback: deterministic parametrize shim
    from _propshim import given, settings, st

from repro.core.deform_conv import (DCLConfig, conv2d, dcl_forward,
                                    init_dcl_params, offset_abs_max,
                                    receptive_field, sample_patches)


def _x(key, n=1, h=10, w=10, c=4):
    return jax.random.normal(key, (n, h, w, c), jnp.float32)


def test_zero_offsets_equal_standard_conv():
    """Property: with o == 0, the DCL *is* a standard convolution."""
    key = jax.random.PRNGKey(0)
    cfg = DCLConfig(in_channels=4, out_channels=8)
    params = init_dcl_params(key, cfg)
    x = _x(jax.random.fold_in(key, 1))
    # w_offset init is zeros => offsets are exactly zero at init
    y, stats = dcl_forward(params, x, cfg)
    y_ref = conv2d(x, params["w_deform"], padding=cfg.pad) \
        + params["b_deform"]
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    assert float(stats["o_max"]) == 0.0


def test_integer_offsets_equal_shifted_gather():
    """Integer offsets sample exact pixels (bilinear degenerates)."""
    key = jax.random.PRNGKey(1)
    cfg = DCLConfig(in_channels=2, out_channels=2)
    x = _x(key, h=8, w=8, c=2)
    # constant offset (dy, dx) = (1, -1) for every tap
    offs = jnp.zeros((1, 8, 8, 9, 2)).at[..., 0].set(1.0).at[..., 1].set(-1.0)
    got = sample_patches(x, offs, cfg)
    zero = jnp.zeros_like(offs)
    base = sample_patches(x, zero, cfg)
    # got[n, i, j] should equal base[n, i+1, j-1] where in range
    np.testing.assert_allclose(got[0, 2:-2, 2:-2], base[0, 3:-1, 1:-3],
                               rtol=1e-5, atol=1e-5)


def test_outside_image_is_zero():
    cfg = DCLConfig(in_channels=1, out_channels=1)
    x = jnp.ones((1, 4, 4, 1))
    offs = jnp.full((1, 4, 4, 9, 2), 100.0)    # way outside
    patches = sample_patches(x, offs, cfg)
    np.testing.assert_allclose(patches, 0.0)


def test_clamping_bounds_receptive_field():
    """offset_bound clamps what the layer actually samples (Eq. 4)."""
    key = jax.random.PRNGKey(2)
    cfg = DCLConfig(in_channels=2, out_channels=2, offset_bound=1.0)
    params = init_dcl_params(key, cfg)
    # force huge offsets through the offset conv bias
    params["b_offset"] = jnp.full_like(params["b_offset"], 50.0)
    x = _x(key, c=2)
    y_bounded, stats = dcl_forward(params, x, cfg)
    # reference: clamp offsets manually to [-1, 1] then sample
    o = conv2d(x, params["w_offset"], padding=cfg.pad) + params["b_offset"]
    offs = jnp.clip(o.reshape(1, 10, 10, 9, 2), -1.0, 1.0)
    patches = sample_patches(x, offs, cfg)
    w = params["w_deform"].reshape(9, 2, 2)
    y_ref = jnp.einsum("nhwkc,kcm->nhwm", patches, w) + params["b_deform"]
    np.testing.assert_allclose(y_bounded, y_ref, rtol=1e-4, atol=1e-4)
    # Eq. 3 stat reports the UNCLAMPED max (what the Eq. 5 loss sees)
    assert float(stats["o_max"]) == 50.0


@given(k=st.sampled_from([1, 3, 5, 7]),
       o=st.floats(min_value=0.0, max_value=64.0,
                   allow_nan=False, allow_infinity=False))
@settings(max_examples=50, deadline=None)
def test_rf_algebra(k, o):
    """Eq. 4: RF = K + 2*ceil(o_max); monotone, >= K, odd-preserving."""
    rf = receptive_field(k, o)
    assert rf == k + 2 * math.ceil(o)
    assert rf >= k
    assert (rf - k) % 2 == 0
    assert receptive_field(k, o + 1.0) >= rf


@given(st.lists(st.floats(min_value=-8, max_value=8), min_size=1,
                max_size=16))
@settings(max_examples=50, deadline=None)
def test_offset_abs_max(vals):
    arr = jnp.asarray(vals, jnp.float32)
    assert float(offset_abs_max(arr)) == pytest.approx(
        max(abs(v) for v in vals), rel=1e-6, abs=1e-6)


def test_gradients_flow_through_offsets():
    """Bilinear sampling must be differentiable w.r.t. offsets — that is
    what makes the Eq. 5 regularizer trainable."""
    key = jax.random.PRNGKey(3)
    cfg = DCLConfig(in_channels=2, out_channels=2)
    params = init_dcl_params(key, cfg)
    # non-degenerate offsets (grad of floor is zero at integers)
    params["b_offset"] = jnp.full_like(params["b_offset"], 0.3)
    x = _x(key, c=2)

    def loss(p):
        y, stats = dcl_forward(p, x, cfg)
        return jnp.sum(jnp.square(y)) + stats["o_max"]

    g = jax.grad(loss)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(v))
                         for v in jax.tree_util.tree_leaves(g)))
    assert jnp.isfinite(gnorm) and gnorm > 0
    assert float(jnp.max(jnp.abs(g["w_offset"]))) > 0
