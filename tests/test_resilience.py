"""Fault injection and recovery (PR 6).

Single-device coverage of the resilience subsystem: the seeded
``FaultPlan`` chaos harness, the Trainer's non-finite sentinels /
retry / backoff / SIGTERM paths, and the graceful degradation of the
bounded kernel dispatch to the XLA reference path.  The sharded chaos
integration run lives in ``tests/test_chaos.py``.
"""
import logging
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.optim import constant, sgd
from repro.resilience import (ChaosHooks, DeviceLost, FaultEvent,
                              FaultInjected, FaultPlan,
                              KernelDispatchFault)
from repro.train import NonFiniteDivergence, Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_fault_plan_seeded_and_ordered():
    kinds = ("nonfinite_grads", "ckpt_corrupt", "step_crash", "data_hiccup")
    a = FaultPlan.random(7, total_steps=20, kinds=kinds, min_step=2)
    b = FaultPlan.random(7, total_steps=20, kinds=kinds, min_step=2)
    assert a == b                        # reproducible from the seed
    assert a.kinds() == set(kinds)
    # kinds keep their listed order over the step range (the corrupt
    # event always lands before the crash that needs it)
    steps = [e.step for e in a.events]
    assert steps == sorted(steps)
    assert all(e.step >= 2 for e in a.events)
    corrupt = [e for e in a.events if e.kind == "ckpt_corrupt"][0]
    assert corrupt.mode in ("truncate_leaf", "bad_manifest")
    c = FaultPlan.random(8, total_steps=20, kinds=kinds, min_step=2)
    assert c != a                        # seed actually matters


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(step=1, kind="meteor_strike")
    with pytest.raises(ValueError, match="window per fault kind"):
        FaultPlan.random(0, total_steps=3,
                         kinds=("step_crash", "data_hiccup",
                                "nonfinite_grads"))


# ---------------------------------------------------------------------------
# Trainer hardening
# ---------------------------------------------------------------------------

def _loss_fn(p, b):
    pred = b["x"] @ p["w"]
    return jnp.mean((pred - b["y"]) ** 2), {}


def _batch_fn(step):
    k = jax.random.PRNGKey(step)
    x = jax.random.normal(k, (4, 3))
    return {"x": x, "y": x @ np.ones((3, 2), np.float32)}


def _make_trainer(ckpt_dir, *, total=6, hooks=None, fault_hook=None,
                  batch_hook=None, **cfg_kw):
    # fresh param buffers per trainer: the step donates its inputs, so
    # sharing one params tree across trainers would pass deleted buffers
    params = {"w": jax.random.normal(jax.random.PRNGKey(42), (3, 2)) * 0.1}
    cfg = TrainerConfig(total_steps=total, ckpt_every=1,
                        ckpt_dir=str(ckpt_dir), log_every=1, **cfg_kw)
    if hooks is not None:
        fault_hook = hooks.fault_hook
        batch_hook = hooks.batch_hook
    tr = Trainer(loss_fn=_loss_fn, params=params,
                 optimizer=sgd(constant(0.1)), mesh=None, param_specs=None,
                 batch_fn=_batch_fn, config=cfg, fault_hook=fault_hook,
                 batch_hook=batch_hook)
    if hooks is not None:
        hooks.bind(tr)
    return tr


def test_nonfinite_step_skipped_and_logged(tmp_path):
    hooks = ChaosHooks(FaultPlan(
        events=(FaultEvent(step=2, kind="nonfinite_grads"),)))
    tr = _make_trainer(tmp_path, hooks=hooks)
    hist = tr.run()
    assert tr.step == 6                  # every step completed
    assert tr.telemetry["skipped"] == 1
    assert bool(jnp.all(jnp.isfinite(tr.params["w"])))   # sentinel held
    skip = [h for h in hist if "event" in h and "skipped" in h["event"]]
    assert len(skip) == 1 and skip[0]["step"] == 2
    # grad_norm health telemetry rides in the logged entries
    logged = [h for h in hist if "loss" in h]
    assert all("grad_norm" in h and np.isfinite(h["grad_norm"])
               for h in logged)
    # the skipped step was a no-op: the run matches a fault-free run
    # that also skipped step 2's update only in that one step's effect


def test_nonfinite_divergence_raises_not_retries(tmp_path):
    """A persistent NaN source exhausts max_skips and raises
    NonFiniteDivergence — NOT the generic retry path (restore-and-replay
    of a deterministic divergence would loop forever)."""
    poison = lambda step, batch: jax.tree_util.tree_map(  # noqa: E731
        lambda x: jnp.full_like(x, jnp.nan)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x, batch)
    tr = _make_trainer(tmp_path, batch_hook=poison, max_skips=3)
    with pytest.raises(NonFiniteDivergence, match="3 consecutive"):
        tr.run()
    assert tr.telemetry["skipped"] == 3
    assert tr.telemetry["retries"] == 0  # never entered the retry path


def test_retry_exhaustion_reraises(tmp_path):
    """Satellite: a fault that keeps firing exhausts max_retries and the
    original exception propagates."""
    def always_crash(step):
        if step >= 2:
            raise DeviceLost(f"persistent failure at step {step}")
    tr = _make_trainer(tmp_path, fault_hook=always_crash, max_retries=3)
    with pytest.raises(DeviceLost, match="persistent failure"):
        tr.run()
    # at least max_retries+1 attempts were made, and exactly one of the
    # failures went unrecovered: the final re-raise
    assert tr.telemetry["retries"] >= 4
    assert tr.telemetry["retries"] - tr.telemetry["recovered"] == 1


def test_crash_before_first_checkpoint_reraises(tmp_path):
    def crash_at_zero(step):
        raise DeviceLost("no checkpoint exists yet")
    tr = _make_trainer(tmp_path, fault_hook=crash_at_zero)
    with pytest.raises(DeviceLost):
        tr.run()


def test_restore_and_replay_bit_exact(tmp_path):
    """Satellite: a mid-run device loss recovers from the checkpoint and
    replays to a final state bit-identical to the fault-free run (the
    data pipeline is stateless per step)."""
    tr_free = _make_trainer(tmp_path / "free", total=8)
    tr_free.run()
    hooks = ChaosHooks(FaultPlan(
        events=(FaultEvent(step=5, kind="step_crash"),)))
    tr = _make_trainer(tmp_path / "chaos", total=8, hooks=hooks)
    tr.run()
    assert tr.telemetry["recovered"] == 1
    np.testing.assert_array_equal(np.asarray(tr.params["w"]),
                                  np.asarray(tr_free.params["w"]))


def test_retry_backoff_is_exponential(tmp_path, monkeypatch):
    import repro.train.trainer as trainer_mod
    sleeps = []
    monkeypatch.setattr(trainer_mod.time, "sleep",
                        lambda s: sleeps.append(s))
    calls = {"n": 0}
    holder = {}

    def crash_twice(step):
        if step == 3 and calls["n"] < 2:
            calls["n"] += 1
            # wait for the async publish so the resume lands on THIS
            # step's checkpoint and the two failures are consecutive
            holder["tr"].ckpt.wait()
            raise DeviceLost("flaky")
    tr = _make_trainer(tmp_path, fault_hook=crash_twice,
                       retry_backoff=0.05, max_retries=3)
    holder["tr"] = tr
    tr.run()
    assert tr.step == 6
    assert sleeps == [0.05, 0.1]         # doubles per consecutive retry
    assert tr.telemetry["recovered"] == 2


def test_sigterm_save_and_exit_then_resume(tmp_path):
    sent = {"done": False}

    def preempt(step):
        if step == 3 and not sent["done"]:
            sent["done"] = True
            os.kill(os.getpid(), signal.SIGTERM)
            time.sleep(0.01)             # let the handler run

    tr = _make_trainer(tmp_path, total=10, fault_hook=preempt)
    hist = tr.run()
    assert tr.telemetry["preempted"] is True
    assert tr.step < 10                  # exited early...
    assert tr.ckpt.latest_step() == tr.step   # ...but saved first
    assert any("preempted" in h.get("event", "") for h in hist)
    # the handler was restored
    assert signal.getsignal(signal.SIGTERM) is not tr._on_sigterm

    tr2 = _make_trainer(tmp_path, total=10)
    assert tr2.try_resume() and tr2.step == tr.step
    tr2.run()
    assert tr2.step == 10


def test_health_telemetry_in_history(tmp_path):
    tr = _make_trainer(tmp_path, total=3)
    hist = tr.run()
    health = [h for h in hist if h.get("event") == "health"]
    assert len(health) == 1
    for k in ("skipped", "recovered", "retries", "preempted"):
        assert k in health[0]


# ---------------------------------------------------------------------------
# Graceful kernel-path degradation
# ---------------------------------------------------------------------------

@pytest.fixture
def clean_dispatch():
    prev_hook = ops.set_dispatch_hook(None)
    prev_deg = ops.set_degradation(True)
    ops.reset_fallback_warnings()
    yield
    ops.set_dispatch_hook(prev_hook)
    ops.set_degradation(prev_deg)
    ops.reset_fallback_warnings()


def _dcl_args(seed=0, h=8, c=4, m=8):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, h, h, c))
    off = jax.random.normal(jax.random.fold_in(key, 1),
                            (1, h, h, 18)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 2), (9, c, m)) * 0.1
    return x, off, w


def test_dispatch_fault_degrades_to_reference_one_warning(
        clean_dispatch, caplog):
    """Acceptance: forced dispatch failure -> XLA reference output
    (identical — the fallback IS the reference) + exactly one warning
    even across repeated calls."""
    x, off, w = _dcl_args()
    y_ref = ref.deform_conv_fused_ref(x, off, w, offset_bound=2.0)

    def always_fail(context):
        assert context["op"] == "deform_conv"
        raise KernelDispatchFault("injected")
    ops.set_dispatch_hook(always_fail)
    with caplog.at_level(logging.WARNING, logger="repro.resilience"):
        y1 = ops.deform_conv(x, off, w, offset_bound=2.0)
        y2 = ops.deform_conv(x, off, w, offset_bound=2.0)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y_ref))
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y_ref))
    warned = [r for r in caplog.records if "degrading" in r.message]
    assert len(warned) == 1


def test_emitter_failure_degrades_with_parity(clean_dispatch, monkeypatch,
                                              caplog):
    """A failure INSIDE the bounded path (plan/emitter, not the hook
    seam) also lands on the reference, parity-close to the kernel."""
    from repro.kernels import plan as plan_mod
    x, off, w = _dcl_args(seed=3, h=10, c=4, m=4)   # fresh jit cache key
    y_kernel = ops.deform_conv(x, off, w, offset_bound=2.0)

    def boom(*a, **k):
        raise RuntimeError("emitter exploded")
    monkeypatch.setattr(plan_mod, "bounded_forward", boom)
    x2 = x + 0.0                                   # same math, new call
    with caplog.at_level(logging.WARNING, logger="repro.resilience"):
        y_deg = ops.deform_conv(x2, off, w, offset_bound=2.0,
                                tile_h=5)          # new static key
    assert any("degrading" in r.message for r in caplog.records)
    np.testing.assert_allclose(np.asarray(y_deg), np.asarray(y_kernel),
                               rtol=1e-5, atol=1e-5)


def test_degradation_disabled_reraises(clean_dispatch):
    x, off, w = _dcl_args()
    ops.set_dispatch_hook(
        lambda ctx: (_ for _ in ()).throw(KernelDispatchFault("boom")))
    ops.set_degradation(False)
    with pytest.raises(KernelDispatchFault):
        ops.deform_conv(x, off, w, offset_bound=2.0)


def test_validation_still_raises_never_degrades(clean_dispatch):
    """Bad explicit arguments are caller bugs: they raise the friendly
    ValueError BEFORE the hook/degradation machinery is consulted."""
    consulted = []
    ops.set_dispatch_hook(lambda ctx: consulted.append(ctx))
    x, off, w = _dcl_args()
    with pytest.raises(ValueError, match="tile_c=3 does not divide"):
        ops.deform_conv(x, off, w, offset_bound=2.0, tile_c=3)
    with pytest.raises(ValueError, match="requires a trained offset_bound"):
        ops.deform_conv(x, off, w, precision="int8")
    with pytest.raises(ValueError, match="unknown precision"):
        ops.deform_conv(x, off, w, offset_bound=2.0, precision="int4")
    with pytest.raises(ValueError, match="unknown dataflow"):
        ops.deform_conv(x, off, w, offset_bound=2.0, dataflow="warp")
    assert consulted == []


def test_int8_dispatch_fault_degrades(clean_dispatch):
    x, off, w = _dcl_args(seed=5)
    y_kernel = ops.deform_conv(x, off, w, offset_bound=2.0,
                               precision="int8")
    ops.set_dispatch_hook(
        lambda ctx: (_ for _ in ()).throw(KernelDispatchFault("boom")))
    y_deg = ops.deform_conv(x, off, w, offset_bound=2.0, precision="int8")
    # fallback is the fake-quant oracle: parity within ~1 LSB
    lsb = float(jnp.max(jnp.abs(y_kernel))) / 127.0
    assert float(jnp.max(jnp.abs(y_deg - y_kernel))) <= 2 * lsb + 1e-6


def test_chain_dispatch_fault_degrades(clean_dispatch):
    key = jax.random.PRNGKey(9)
    c, m, k2 = 4, 4, 9
    x = jax.random.normal(key, (1, 8, 8, c))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k2, c, m)) * 0.1
    w_off = jax.random.normal(jax.random.fold_in(key, 2),
                              (k2, c, 2 * k2)) * 0.05
    b_off = jnp.zeros((2 * k2,))
    kw = dict(offset_bound=2.0, x_scale=jnp.float32(0.05),
              y_scale=jnp.float32(0.05))
    y_kernel = ops.deform_conv_chain(x, w, w_off, b_off, **kw)
    ops.set_dispatch_hook(
        lambda ctx: (_ for _ in ()).throw(KernelDispatchFault("boom")))
    y_deg = ops.deform_conv_chain(x, w, w_off, b_off, **kw)
    assert y_deg.dtype == jnp.int8 and y_deg.shape == y_kernel.shape
    # int8 emission grids agree to <= 1 LSB
    diff = np.abs(np.asarray(y_deg, np.int32) -
                  np.asarray(y_kernel, np.int32))
    assert diff.max() <= 1, diff.max()


def test_unbounded_path_has_no_fallback(clean_dispatch):
    """offset_bound=None is already the XLA reference path — the hook is
    not consulted and failures are not masked."""
    consulted = []
    ops.set_dispatch_hook(lambda ctx: consulted.append(ctx))
    x, off, w = _dcl_args()
    ops.deform_conv(x, off, w)           # unbounded baseline
    assert consulted == []


# ---------------------------------------------------------------------------
# Bound-saturation health metric
# ---------------------------------------------------------------------------

def test_bound_saturation_counts_clamped_fraction():
    from repro.core.perf_model import bound_saturation
    offs = np.array([0.1, -0.2, 2.0, -2.0, 1.9999999, 0.5, 3.1, -7.0])
    # 2.0, -2.0, 1.9999999 (within atol), 3.1, -7.0 -> 5/8
    assert bound_saturation(offs, 2.0) == pytest.approx(5 / 8)
    assert bound_saturation(np.zeros((0,)), 2.0) == 0.0
    with pytest.raises(ValueError, match="positive offset_bound"):
        bound_saturation(offs, None)


def test_bound_saturation_gate_at_reference_layer():
    """Acceptance: the clamp-fraction gate evaluated on offsets the
    reference layer actually produces — a trained in-distribution model
    stays healthy, a drifted input distribution trips the gate."""
    from repro.core.perf_model import runtime_health_report
    key = jax.random.PRNGKey(0)
    bound = 2.0
    # Eq.5-trained regime: half-normal-ish offsets well inside B
    trained = jax.random.normal(key, (2, 8, 8, 18)) * (bound / 3.9)
    rep = runtime_health_report(trained, bound, threshold=0.05)
    assert rep["healthy"], rep
    # drifted inputs: offsets routinely at the clamp
    drifted = jax.random.normal(key, (2, 8, 8, 18)) * (3 * bound)
    rep2 = runtime_health_report(drifted, bound, threshold=0.05)
    assert not rep2["healthy"], rep2
    assert rep2["bound_saturation"] > rep["bound_saturation"]
    # and the metric matches what the reference clamp actually does:
    # exactly the components the reference clips to +-B count as clamped
    clipped = jnp.clip(drifted, -bound, bound)
    frac_ref = float(jnp.mean(jnp.abs(clipped) >= bound - 1e-6))
    assert rep2["bound_saturation"] == pytest.approx(frac_ref)


# ---------------------------------------------------------------------------
# ChaosHooks seams
# ---------------------------------------------------------------------------

def test_chaos_hooks_one_shot_and_telemetry(tmp_path):
    plan = FaultPlan(events=(FaultEvent(step=1, kind="data_hiccup"),
                             FaultEvent(step=1, kind="nonfinite_grads")))
    hooks = ChaosHooks(plan, ckpt_dir=tmp_path)
    with pytest.raises(FaultInjected):
        hooks.fault_hook(1)
    hooks.fault_hook(1)                  # consumed: no second raise
    batch = {"x": jnp.ones((2, 2)), "i": jnp.ones((2,), jnp.int32)}
    poisoned = hooks.batch_hook(1, batch)
    assert bool(jnp.all(jnp.isnan(poisoned["x"])))
    assert poisoned["i"].dtype == jnp.int32          # ints untouched
    again = hooks.batch_hook(1, batch)               # consumed
    assert bool(jnp.all(jnp.isfinite(again["x"])))
    assert {f["kind"] for f in hooks.fired} == {"data_hiccup",
                                                "nonfinite_grads"}
    out = tmp_path / "telemetry.json"
    hooks.dump_telemetry(out, extra={"note": "t"})
    import json
    rec = json.loads(out.read_text())
    assert rec["note"] == "t" and len(rec["fired"]) == 2


def test_dump_telemetry_coerces_numpy_round_trip(tmp_path):
    """The module-level sink: numpy scalars/arrays come back as plain
    JSON values after a json.loads round-trip."""
    import json

    from repro.resilience import dump_telemetry

    record = {"steps": np.int64(7), "loss": np.float32(0.5),
              "ratios": np.arange(3, dtype=np.float64) / 2,
              "nested": {"count": np.int32(2)}}
    path = dump_telemetry(tmp_path / "t.json", record,
                          extra={"seed": np.uint32(9)})
    rec = json.loads(path.read_text())
    assert rec == {"steps": 7, "loss": 0.5, "ratios": [0.0, 0.5, 1.0],
                   "nested": {"count": 2}, "seed": 9}
    with pytest.raises(TypeError, match="not JSON-serializable"):
        dump_telemetry(tmp_path / "bad.json", {"x": object()})


def test_serve_fault_kinds_validate_and_serve_seams(tmp_path):
    """PR 7 serve kinds are legal FaultEvent kinds, and the serve seams
    behave: slow_step stalls one-shot through the injectable sleep;
    admission events are consumed in plan order with the storm burst."""
    from repro.resilience import FAULT_KINDS

    for kind in ("slow_step", "malformed_request", "bucket_miss_storm"):
        assert kind in FAULT_KINDS
        FaultEvent(step=0, kind=kind)    # does not raise

    plan = FaultPlan(events=(
        FaultEvent(step=2, kind="slow_step", mode="0.25"),
        FaultEvent(step=0, kind="malformed_request"),
        FaultEvent(step=0, kind="bucket_miss_storm", mode="2")))
    slept = []
    hooks = ChaosHooks(plan, sleep=slept.append)
    hooks.serve_step_hook(1)
    assert slept == []                   # not its step yet
    hooks.serve_step_hook(2, {"bucket": 32})
    hooks.serve_step_hook(2)             # consumed: one-shot
    assert slept == [0.25]

    class Req:
        def __init__(self, image):
            self.image = image
            self.uid = 0
    img = np.zeros((32, 32, 3), np.float32)
    r1 = hooks.admit_hook(Req(img))      # plan order: malformed first
    assert r1.image.ndim == 1 and np.isnan(r1.image).all()
    r2 = hooks.admit_hook(Req(img))      # storm head...
    r3 = hooks.admit_hook(Req(img))      # ...and its burst tail
    for r in (r2, r3):
        h, w = r.image.shape[:2]
        assert (h, w) != (32, 32) and h % 2 == 1 and w % 2 == 1
    r4 = hooks.admit_hook(Req(img))      # storm exhausted
    assert r4.image is img
    assert [f["kind"] for f in hooks.fired] == [
        "slow_step", "malformed_request", "bucket_miss_storm"]
