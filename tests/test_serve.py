"""Serving engine: continuous batching == pure greedy; slot lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelConfig, forward, init_params
from repro.serve import Request, ServeConfig, ServingEngine


def _cfg():
    return ModelConfig(name="t", n_layers=3, d_model=32, n_heads=4,
                       kv_heads=2, d_ff=64, vocab=32, dtype=jnp.float32)


def _greedy(params, cfg, prompt, n):
    cur = jnp.asarray(prompt, jnp.int32)[None]
    outs = []
    for _ in range(n):
        lg, _, _ = forward(params, cfg, tokens=cur, mode="train")
        nxt = int(jnp.argmax(lg[0, -1]))
        outs.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]], jnp.int32)], 1)
    return outs


def test_continuous_batching_matches_greedy():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(slots=3, cache_len=64))
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i, prompt=rng.randint(0, 32, rng.randint(3, 10))
                    .astype(np.int32), max_new_tokens=6) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 7
    for r in done:
        assert r.output == _greedy(params, cfg, r.prompt, 6), r.uid


def test_eos_terminates_early():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(slots=2, cache_len=64))
    prompt = np.asarray([1, 2, 3], np.int32)
    first = _greedy(params, cfg, prompt, 2)
    r = Request(uid=0, prompt=prompt, max_new_tokens=10, eos_id=first[1])
    eng.submit(r)
    done = eng.run_until_drained()
    assert done[0].output == first[:2]


def test_slots_reused_under_load():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(slots=2, cache_len=32))
    rng = np.random.RandomState(1)
    for i in range(6):
        eng.submit(Request(uid=i,
                           prompt=rng.randint(0, 32, 4).astype(np.int32),
                           max_new_tokens=3))
    done = eng.run_until_drained()
    assert sorted(r.uid for r in done) == list(range(6))
    assert all(len(r.output) == 3 for r in done)
