"""Eq. 5 regularizer: formula, smooth-max bound, and the paper's core
claim — training with lambda > 0 shrinks o_max without hurting the task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # fallback: deterministic parametrize shim
    from _propshim import given, settings, st

from repro.core.rf_regularizer import (OffsetStats, network_offset_max,
                                       regularized_loss)


@given(task=st.floats(0, 10, allow_nan=False),
       omax=st.lists(st.floats(0, 50), min_size=1, max_size=12),
       lam=st.floats(0, 0.99))
@settings(max_examples=60, deadline=None)
def test_eq5_formula(task, omax, lam):
    t = jnp.asarray(task, jnp.float32)
    o = [jnp.asarray(v, jnp.float32) for v in omax]
    loss = regularized_loss(t, o, lam)
    expect = (1 - lam) * task + lam * max(omax)
    assert float(loss) == pytest.approx(expect, rel=1e-5, abs=1e-5)


def test_lambda_range_validated():
    with pytest.raises(ValueError):
        regularized_loss(jnp.asarray(1.0), [jnp.asarray(1.0)], 1.0)
    with pytest.raises(ValueError):
        regularized_loss(jnp.asarray(1.0), [jnp.asarray(1.0)], -0.1)


@given(st.lists(st.floats(0, 20), min_size=2, max_size=8),
       st.sampled_from([0.1, 0.5, 1.0]))
@settings(max_examples=40, deadline=None)
def test_smooth_max_upper_bounds_hard_max(vals, t):
    o = jnp.asarray(vals, jnp.float32)
    hard = float(network_offset_max(o))
    smooth = float(network_offset_max(o, smoothness=t))
    assert smooth >= hard - 1e-5
    tighter = float(network_offset_max(o, smoothness=t / 10))
    assert tighter <= smooth + 1e-5


def test_training_with_lambda_shrinks_offsets():
    """Miniature of the paper's Table I/Fig 7 experiment: same tiny DCN
    detector, lambda in {0, 0.05}; the regularized run must end with a
    much smaller o_max at a comparable task loss."""
    from repro.models import resnet_dcn as R
    from repro.data import DetectionDataConfig, detection_batch
    from repro.optim import sgd, constant

    cfg = R.ResNetDCNConfig(stage_sizes=(1, 1, 1, 1),
                            widths=(16, 32, 64, 128), stem_width=8,
                            num_dcn=2, num_classes=4, img_size=64)
    dcfg = DetectionDataConfig(img_size=64, global_batch=4, num_classes=4,
                               seed=3)
    results = {}
    for lam in (0.0, 0.2):
        params = R.init_params(jax.random.PRNGKey(0), cfg)
        # start from a model with LARGE learned offsets (as after normal
        # DCN training): bias the offset convs.
        for name, blk in params.items():
            if isinstance(blk, dict) and "dcl" in blk:
                blk["dcl"]["b_offset"] = jnp.full_like(
                    blk["dcl"]["b_offset"], 4.0)
        opt = sgd(constant(0.05), momentum=0.9)
        state = opt.init(params)

        # Smooth-max variant: the hard max's single-coordinate subgradient
        # is too noisy for a 40-step miniature under momentum (transient
        # o_max spikes); logsumexp spreads the pull over all near-maximal
        # offsets (the EXPERIMENTS.md trainability note).
        smoothness = 0.5 if lam else 0.0

        @jax.jit
        def step(p, s, batch, i, lam=lam, smoothness=smoothness):
            (loss, m), g = jax.value_and_grad(
                lambda pp: R.train_loss(pp, cfg, batch, lam=lam,
                                        smoothness=smoothness),
                has_aux=True)(p)
            p2, s2 = opt.update(g, s, p, i)
            task = m["bce"] + m["ce"] + 0.5 * m["l1"]
            return p2, s2, task, m["o_max"]

        o_max = None
        tasks = []
        for i in range(40):
            batch = {k: jnp.asarray(v) for k, v in
                     detection_batch(dcfg, i).items()}
            params, state, task, o_max = step(
                params, state, batch, jnp.asarray(i))
            tasks.append(float(task))
        # average the task loss over the last 10 steps: each step's value
        # is a single global_batch=4 draw, so the final-step sample alone
        # swings by tens of percent between otherwise-identical runs
        results[lam] = dict(task=float(np.mean(tasks[-10:])),
                            o_max=float(o_max))

    # offsets collapse (paper: 12.6x over 12 epochs; ~3x in 40 steps)
    assert results[0.2]["o_max"] < results[0.0]["o_max"] * 0.5, results
    # task quality preserved (paper: AP 39.9 -> 39.4).  Eq. 5 scales the
    # task gradient by (1 - lambda), so at a FIXED 40-step budget the
    # lam=0.2 run has taken only 0.8x the effective task learning — its
    # task loss systematically trails the baseline by roughly that
    # factor (it is behind on the same descent curve, not diverged; the
    # paper's comparison is at convergence, where the gap closes to
    # 39.9 -> 39.4 AP).  Allow the 35% miniature slack on top of the
    # 1/(1 - lambda) schedule handicap.
    lam = 0.2
    slack = 1.35 / (1.0 - lam)
    assert results[0.2]["task"] < results[0.0]["task"] * slack, results


def test_offset_stats_histogram_and_compression():
    a = OffsetStats()
    b = OffsetStats()
    for v in (30.0, 35.0, 37.5):
        a.update({"l1": jnp.asarray(v), "l2": jnp.asarray(v / 2)})
    for v in (1.0, 1.5, 1.6):
        b.update({"l1": jnp.asarray(v), "l2": jnp.asarray(v / 2)})
    assert a.network_max() == pytest.approx(37.5)
    assert b.network_max() == pytest.approx(1.6, rel=1e-6)
    edges, counts = a.histogram(bins=4)
    assert sum(counts) == 3
    comp = b.compression_vs(a)        # RF(a) / RF(b)
    assert comp == pytest.approx(
        (3 + 2 * 38) / (3 + 2 * 2), rel=1e-6)
