"""MoE dispatch invariants (capacity, gates, drops) + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import init_tree
from repro.models.moe import MoEConfig, moe_apply, moe_def, _capacity


def _setup(e=4, k=2, d=16, f=32, cap=1.25):
    cfg = MoEConfig(d_model=d, d_ff=f, num_experts=e, top_k=k,
                    capacity_factor=cap)
    params = init_tree(jax.random.PRNGKey(0), moe_def(cfg))
    return cfg, params


def test_output_shape_and_grad():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))

    def loss(p):
        y, aux = moe_apply(p, x, cfg)
        return jnp.sum(jnp.square(y)) + 0.01 * aux

    g = jax.grad(loss)(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(v))
                      for v in jax.tree_util.tree_leaves(g)))
    assert np.isfinite(float(gn)) and float(gn) > 0
    # router must receive gradient (it's the load-balance control)
    assert float(jnp.max(jnp.abs(g["w_router"]))) > 0


def test_capacity_formula():
    cfg, _ = _setup(e=8, k=2, cap=1.25)
    assert _capacity(1024, cfg) == int(1024 * 2 * 1.25 / 8)
    # floor: at least top_k
    assert _capacity(1, cfg) >= cfg.top_k


def test_uniform_router_no_drops():
    """With a zero router every expert gets equal probability; top-k is
    deterministic and the capacity (>= tokens*k/e * 1.25) holds all."""
    cfg, params = _setup(e=4, k=1, cap=4.0)
    params["w_router"] = jnp.zeros_like(params["w_router"])
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 16))
    y, aux = moe_apply(params, x, cfg)
    # every token routed (no drop) -> output nonzero for ~all tokens
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert float(jnp.min(norms)) > 0


def test_tiny_capacity_drops_tokens():
    """capacity_factor ~0 forces drops; dropped tokens output zero."""
    cfg, params = _setup(e=4, k=1, cap=0.01)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 16))
    y, _ = moe_apply(params, x, cfg)
    norms = jnp.linalg.norm(y[0], axis=-1)
    # capacity = max(1, ...) = 1 per expert -> at most 4 tokens survive
    assert int(jnp.sum(norms > 1e-6)) <= 4


def test_moe_flops_scale_with_active_params():
    """HLO FLOPs of the MoE block ~ capacity * d * f * experts (active),
    NOT tokens * experts * capacity * d (the one-hot einsum blowup)."""
    cfg, params = _setup(e=4, k=1, d=32, f=64, cap=1.0)
    x = jnp.ones((1, 256, 32))
    c = jax.jit(lambda p, x: moe_apply(p, x, cfg)[0]) \
        .lower(params, x).compile()
    cost = c.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = cost.get("flops", 0)
    t = 256
    expert_flops = 2 * 3 * t * 1.0 * 32 * 64     # dispatch-capacity matmuls
    router_flops = 2 * t * 32 * 4
    # generous envelope: within 8x of active compute (bwd not included)
    assert flops < 8 * (expert_flops + router_flops), flops
