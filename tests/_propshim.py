"""Fallback property-test shim for environments without ``hypothesis``.

Importing test modules must not fail when the package is absent, so the
hypothesis-using files do::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _propshim import given, settings, st

The shim maps each strategy to a small deterministic sample set and
``@given`` to a plain ``pytest.mark.parametrize`` over (a capped stride
sample of) their cross product — the key property cases still run, just
without shrinking/fuzzing.
"""
from __future__ import annotations

import inspect
import itertools

import pytest

MAX_COMBOS = 12


class _St:
    """Deterministic stand-ins for the hypothesis strategies we use."""

    @staticmethod
    def integers(min_value=0, max_value=100):
        return [min_value, (min_value + max_value) // 2, max_value]

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        if lo > 0 and hi / max(lo, 1e-30) > 1e3:   # wide range: log-mid
            mid = (lo * hi) ** 0.5
        else:
            mid = (lo + hi) / 2.0
        return [lo, mid, hi]

    @staticmethod
    def sampled_from(values):
        return list(values)

    @staticmethod
    def booleans():
        return [False, True]

    @staticmethod
    def lists(elements, min_size=0, max_size=None, **_kw):
        elements = list(elements)
        max_size = max_size if max_size is not None else min_size + 3
        short = list(itertools.islice(itertools.cycle(elements),
                                      max(min_size, 1)))
        long = list(itertools.islice(itertools.cycle(reversed(elements)),
                                     max_size))
        out = [short, long] if len(long) >= min_size else [short]
        return [x for x in out if min_size <= len(x) <= max_size] or [short]


st = _St()


def given(*pos_strategies, **kw_strategies):
    def deco(fn):
        names = [p for p in inspect.signature(fn).parameters]
        mapping = dict(zip(names, pos_strategies))
        mapping.update(kw_strategies)
        argnames = [n for n in names if n in mapping]
        pools = [list(mapping[n]) for n in argnames]
        combos = list(itertools.product(*pools))
        if len(combos) > MAX_COMBOS:
            step = -(-len(combos) // MAX_COMBOS)
            combos = combos[::step]
        if len(argnames) == 1:
            params = [c[0] for c in combos]
            return pytest.mark.parametrize(argnames[0], params)(fn)
        return pytest.mark.parametrize(",".join(argnames), combos)(fn)
    return deco


def settings(**_kw):
    def deco(fn):
        return fn
    return deco
