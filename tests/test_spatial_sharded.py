"""Height-sharded DCL parity on a forced multi-device mesh (ISSUE 10).

The bounded halo exchange (``distributed.spatial``) must reproduce the
unsharded zero-copy kernels: bit-for-bit under pinned tiles (fp32 AND
int8 — same tiles => same arithmetic, per the module's slab-geometry
argument), allclose under default tiles (which resolve at the LOCAL
shard height), with backward parity through the halo-gradient return
and the d_weights psum, composing with batch data-parallelism on a 2-D
mesh, and surfacing friendly errors for ragged / halo-thin splits.
The serving engine's per-bucket ``spatial_shards`` ride the same path
end-to-end.

Heavy lifting in ``tests/_spatial_checks.py`` — in-process when this
pytest already sees >= 4 devices (the CI ``spatial-4dev`` job), else
once in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (jax locks the
device count at first init; skipping would hide the coverage from
plain tier-1 boxes).
"""
import functools
import json
import os
import subprocess
import sys

import jax

HERE = os.path.dirname(os.path.abspath(__file__))


@functools.lru_cache(maxsize=1)
def _results() -> dict:
    if jax.device_count() >= 4:
        sys.path.insert(0, HERE)
        try:
            import _spatial_checks
        finally:
            sys.path.remove(HERE)
        return _spatial_checks.run_checks()
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(HERE), "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_spatial_checks.py")],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-4000:])
    return json.loads(proc.stdout.splitlines()[-1])


def test_spatial_really_multi_device():
    assert _results()["device_count"] >= 4


def test_spatial_fp32_pinned_tiles_bitwise():
    """Acceptance: with identical explicit tiles on both sides the
    height-sharded forward equals the unsharded kernel bit-for-bit at
    2 and 4 shards — the halo-extended slab IS the global padded slab's
    local rows."""
    r = _results()
    assert r["fp32_pinned_bitwise_2shard"] is True
    assert r["fp32_pinned_bitwise_4shard"] is True


def test_spatial_fp32_default_tiles_allclose():
    """Default tiles resolve at the LOCAL height, so fp32 accumulation
    order differs — parity is allclose (the unsharded kernel shows the
    same ~4e-6 spread across its own tile_h choices)."""
    assert _results()["fp32_default_diff_4shard"] <= 1e-5


def test_spatial_int8_pinned_tiles_lsb():
    """Acceptance: the int8 path (global scales hoisted outside the
    shard_map, s8 x s8 -> s32 accumulation) is within 1 LSB of the
    unsharded kernel — measured exactly bitwise here."""
    r = _results()
    assert r["int8_pinned_diff_4shard"] <= 1e-6, r["int8_pinned_diff_4shard"]
    assert r["int8_pinned_bitwise_4shard"] is True


def test_spatial_backward_grad_parity():
    """Acceptance: d_input (halo-gradient rows ppermuted back and
    added), d_offsets (local), d_weights (psummed) match the unsharded
    custom-VJP kernel under rtol=1e-4/atol=2e-4."""
    r = _results()
    for k in ("grad_dx_tol_excess", "grad_doff_tol_excess",
              "grad_dw_tol_excess"):
        assert r[k] <= 0.0, (k, r[k])


def test_spatial_stride2():
    assert _results()["stride2_diff_4shard"] <= 1e-5


def test_spatial_composes_with_batch_sharding():
    """spatial x data 2-D mesh: batch rides 'data', height rides
    'model', one shard_map — bitwise under pinned tiles."""
    assert _results()["batch_spatial_2d_bitwise"] is True


def test_spatial_friendly_errors():
    r = _results()
    assert "does not evenly divide height H=30" in r["ragged_error"]
    assert "thinner than the 4-row halo" in r["thin_error"]
    assert "use fewer shards" in r["thin_error"]


def test_engine_spatial_bucket_end_to_end():
    """Satellite: a serving bucket configured with spatial_shards=2
    serves through the height-sharded int8 rung, warms per-shard
    (local-height) plans with '@2shard' provenance, and matches the
    unsharded engine on the same rung."""
    r = _results()
    assert r["engine_outcome"] == "ok"
    assert r["engine_ladder"] == "int8"
    assert all(v.endswith("@2shard")
               for v in r["engine_plan_sources"].values())
    assert r["engine_telemetry_shards"] == [[32, 2]]
    assert r["engine_cls_diff"] <= 1e-3, r["engine_cls_diff"]


def test_engine_overshard_rejected_at_construction():
    """spatial_shards beyond the real device count fails at engine
    construction, not on the first sharded request."""
    msg = _results()["engine_overshard_error"]
    assert "spatial_shards=8" in msg and "4 available device" in msg
