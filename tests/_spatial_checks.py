"""Shared body of the spatial-sharding parity tests (ISSUE 10).

Same two entry modes as ``_sharded_checks.py``: in-process when the
pytest process already sees >= 4 devices (the CI ``spatial-4dev`` job),
else ONCE in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so tier-1 boxes
still get the coverage instead of a skip.

The checks cover the ISSUE 10 acceptance criteria: height-sharded
forward parity vs the unsharded zero-copy kernel (bit-exact fp32 under
pinned tiles, bounded int8 diff), backward grad parity through the
halo-gradient return + dw psum, stride-2, the spatial x batch 2-D mesh
composition, the friendly ragged/halo-thin errors at the public entry,
and the serving engine's spatial buckets end-to-end (per-shard plan
provenance, the int8 ladder entry, shards > devices rejected at
construction).

Note the pinned-tile discipline: the zero-copy kernels are tile-shape
sensitive at the 1e-5 (fp32) / 1e-2 (int8 after dequant) level — the
per-tile revisit order changes fp32 accumulation — and the sharded
path resolves tiles at the LOCAL height.  Bitwise assertions therefore
pin identical explicit tiles on both sides; default-tile parity is
allclose territory by design, not a sharding defect.
"""
from __future__ import annotations

import dataclasses
import json
import os

if __name__ == "__main__":       # subprocess mode: force the devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np


def _max_diff(a, b) -> float:
    return float(jnp.max(jnp.abs(jnp.asarray(a) - jnp.asarray(b))))


def _tol_excess(a, b, *, rtol: float = 1e-4, atol: float = 2e-4) -> float:
    a, b = jnp.asarray(a), jnp.asarray(b)
    return float(jnp.max(jnp.abs(a - b) - (atol + rtol * jnp.abs(b))))


def _inputs(n, h, w, c, m, k=3, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, h, w, c), jnp.float32)
    offs = 2.0 * jax.random.uniform(k2, (n, h, w, 2 * k * k),
                                    jnp.float32) - 1.0
    wgt = 0.1 * jax.random.normal(k3, (k * k, c, m), jnp.float32)
    return x, offs, wgt


def run_checks() -> dict:
    assert jax.device_count() >= 4, jax.devices()
    from jax.sharding import Mesh
    from repro.distributed.sharding import use_rules
    from repro.kernels import ops

    B = 2.0
    out: dict = {"device_count": jax.device_count()}
    x, offs, wgt = _inputs(1, 32, 32, 8, 8)
    c = m = 8
    mesh2 = Mesh(np.asarray(jax.devices()[:2]), ("model",))
    mesh4 = Mesh(np.asarray(jax.devices()[:4]), ("model",))

    # -- 1. fp32 forward parity, pinned tiles => bitwise ---------------
    pin = dict(tile_h=4, tile_w=8, tile_c=c, tile_m=m)
    ref_pin = ops.deform_conv(x, offs, wgt, offset_bound=B, **pin)
    for label, mesh in (("2shard", mesh2), ("4shard", mesh4)):
        with use_rules(mesh=mesh):
            y = ops.deform_conv(x, offs, wgt, offset_bound=B,
                                shard_spatial=True, **pin)
        out[f"fp32_pinned_bitwise_{label}"] = bool(jnp.all(y == ref_pin))

    # -- 2. fp32 default tiles: local-height tile resolution => allclose
    ref = ops.deform_conv(x, offs, wgt, offset_bound=B)
    with use_rules(mesh=mesh4):
        y4 = ops.deform_conv(x, offs, wgt, offset_bound=B,
                             shard_spatial=True)
    out["fp32_default_diff_4shard"] = _max_diff(y4, ref)

    # -- 3. int8 parity, pinned tiles ----------------------------------
    yq_ref = ops.deform_conv(x, offs, wgt, offset_bound=B,
                             precision="int8", **pin)
    with use_rules(mesh=mesh4):
        yq4 = ops.deform_conv(x, offs, wgt, offset_bound=B,
                              precision="int8", shard_spatial=True, **pin)
    out["int8_pinned_bitwise_4shard"] = bool(jnp.all(yq4 == yq_ref))
    out["int8_pinned_diff_4shard"] = _max_diff(yq4, yq_ref)

    # -- 4. backward: halo-gradient return + dw psum -------------------
    def grads(fn):
        f = lambda a, b, c_: jnp.sum(jnp.sin(fn(a, b, c_)))  # noqa: E731
        return jax.grad(f, argnums=(0, 1, 2))(x, offs, wgt)

    g_ref = grads(lambda a, b, c_: ops.deform_conv(
        a, b, c_, offset_bound=B, **pin))
    with use_rules(mesh=mesh4):
        g_sh = grads(lambda a, b, c_: ops.deform_conv(
            a, b, c_, offset_bound=B, shard_spatial=True, **pin))
    for name, a, b in zip(("dx", "doff", "dw"), g_sh, g_ref):
        out[f"grad_{name}_tol_excess"] = _tol_excess(a, b)

    # -- 5. stride-2 (H % (stride*shards) at the entry) ----------------
    offs2 = offs[:, ::2, ::2]
    ref2 = ops.deform_conv(x, offs2, wgt, offset_bound=B, stride=2)
    with use_rules(mesh=mesh4):
        y2 = ops.deform_conv(x, offs2, wgt, offset_bound=B, stride=2,
                             shard_spatial=True)
    out["stride2_diff_4shard"] = _max_diff(y2, ref2)

    # -- 6. spatial x batch 2-D mesh composition -----------------------
    xb, offb, wgtb = _inputs(2, 16, 16, 8, 8, seed=3)
    refb = ops.deform_conv(xb, offb, wgtb, offset_bound=B, **pin)
    mesh2d = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
                  ("data", "model"))
    with use_rules(mesh=mesh2d):
        yb = ops.deform_conv(xb, offb, wgtb, offset_bound=B,
                             shard_batch=True, shard_spatial=True, **pin)
    out["batch_spatial_2d_bitwise"] = bool(jnp.all(yb == refb))

    # -- 7. friendly errors at the public entry ------------------------
    with use_rules(mesh=mesh4):
        try:
            ops.deform_conv(x[:, :30], offs[:, :30], wgt, offset_bound=B,
                            shard_spatial=True)
            out["ragged_error"] = ""
        except ValueError as e:
            out["ragged_error"] = str(e)
        try:
            ops.deform_conv(x[:, :12], offs[:, :12], wgt, offset_bound=B,
                            shard_spatial=True)
            out["thin_error"] = ""
        except ValueError as e:
            out["thin_error"] = str(e)

    # -- 8. serving engine spatial buckets end-to-end ------------------
    from repro.models import resnet_dcn as R
    from repro.quant.calibrate import calibrate_resnet_dcn
    from repro.serve import DCLServeConfig, DCLServingEngine

    # Shallow on purpose: every DCL height must satisfy
    # h/shards >= halo (=4 at B=2, K=3) — dims are s0b0 h=8 s1,
    # s1b0 h=8 s2, both fine at 2 shards.
    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1), widths=(16, 32), stem_width=8, num_dcn=2,
        num_classes=4, img_size=32, offset_bound=2.0, use_kernel=True)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    table = calibrate_resnet_dcn(
        params, cfg, [rng.randn(2, 32, 32, 3).astype(np.float32)])
    img = np.random.RandomState(7).randn(32, 32, 3).astype(np.float32)

    try:
        DCLServingEngine(params, cfg,
                         DCLServeConfig(buckets=(32,), slots=2,
                                        spatial_shards=((32, 8),)),
                         scale_table=table)
        out["engine_overshard_error"] = ""
    except ValueError as e:
        out["engine_overshard_error"] = str(e)

    results = {}
    for label, shards in (("flat", ()), ("spatial", ((32, 2),))):
        # Both engines serve the "int8" rung (the flat default would be
        # int8_chain; the spatial bucket enters one rung down anyway),
        # so the diff below isolates the height split + local tiles.
        eng = DCLServingEngine(
            params, cfg,
            DCLServeConfig(buckets=(32,), slots=2, quant="int8",
                           spatial_shards=shards),
            scale_table=table)
        r = eng.submit(img)
        eng.run_until_drained()
        results[label] = r
        if label == "spatial":
            tel = eng.telemetry()
            out["engine_outcome"] = r.outcome
            out["engine_ladder"] = r.ladder
            out["engine_plan_sources"] = tel["plan_sources"]["32"]
            out["engine_telemetry_shards"] = \
                tel["engine"]["spatial_shards"]
    # The flat engine serves the chained rung; the spatial bucket
    # enters at "int8" and resolves local-height tiles — parity is
    # model-level approximate, not bitwise.
    out["engine_cls_diff"] = _max_diff(results["spatial"].result["cls"],
                                       results["flat"].result["cls"])
    return out


if __name__ == "__main__":
    print(json.dumps(run_checks()))
