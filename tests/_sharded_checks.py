"""Shared body of the mesh-sharded training tests (PR 4).

Two entry modes, one implementation:

* **In-process** — when the pytest process already sees >= 4 devices
  (the CI job sets ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
  + ``REPRO_KEEP_XLA_FLAGS=1`` so ``conftest.py`` keeps the override),
  ``tests/test_sharded_training.py`` imports this module and calls
  :func:`run_checks` directly.
* **Subprocess** — on a plain 1-device box the test file spawns
  ``python tests/_sharded_checks.py`` with the same env override (the
  device count is locked at first jax init, so it cannot be raised
  in-process) and asserts on the JSON this prints.  Tier-1 therefore
  PASSES everywhere instead of skipping.

The checks cover this PR's acceptance criteria: full-param grad parity
of the shard_map kernel path (fp32 and QAT) vs the single-device
reference, a data-parallel ``Trainer`` run end-to-end through the
zero-copy kernels, jaxpr evidence that the sharded step really routes
through ``shard_map`` + the custom-VJP kernels (with the d_weights
psum epilogue), and the friendly batch-divisibility ``ValueError``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

if __name__ == "__main__":       # subprocess mode: force the devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np


def _max_diff(a, b) -> float:
    return float(jnp.max(jnp.abs(jnp.asarray(a) - jnp.asarray(b))))


def _tol_excess(a, b, *, rtol: float = 1e-4, atol: float = 1e-4) -> float:
    """max(|a-b| - (atol + rtol*|b|)): <= 0 iff allclose under the
    repo's standard parity tolerances (psum tree-sums reorder fp32
    adds, so large-magnitude grads carry proportionally large noise)."""
    a, b = jnp.asarray(a), jnp.asarray(b)
    return float(jnp.max(jnp.abs(a - b) - (atol + rtol * jnp.abs(b))))


def _grads(forward, x, offs, wgt):
    loss = lambda a, b, c: jnp.sum(jnp.sin(forward(a, b, c)))  # noqa: E731
    return jax.grad(loss, argnums=(0, 1, 2))(x, offs, wgt)


def run_checks() -> dict:
    assert jax.device_count() >= 4, jax.devices()
    from jax.flatten_util import ravel_pytree
    from repro.data import DetectionDataConfig, detection_batch
    from repro.distributed.sharding import use_rules
    from repro.kernels import ops, ref
    from repro.models import resnet_dcn as R
    from repro.models.layers import dcl_apply, dcl_def, init_tree

    mesh = jax.make_mesh((4,), ("data",))
    out: dict = {"device_count": jax.device_count()}

    # -- 1. raw kernel-path grad parity, sharded vs XLA reference ------
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 12, 12, 4), jnp.float32)
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (4, 12, 12, 18), jnp.float32)
    wgt = jax.random.normal(jax.random.fold_in(key, 2),
                            (9, 4, 8), jnp.float32) * 0.2
    g_ref = _grads(lambda a, b, c: ref.deform_conv_fused_ref(
        a, b, c, offset_bound=2.0), x, offs, wgt)
    with use_rules(mesh=mesh):
        out["shard_active"] = ops.resolve_batch_shard(4) is not None
        g_sh = _grads(lambda a, b, c: ops.deform_conv(
            a, b, c, offset_bound=2.0, shard_batch=True), x, offs, wgt)
    for name, a, b in zip(("dx", "doff", "dw"), g_sh, g_ref):
        out[f"dconv_{name}_diff"] = _max_diff(a, b)

    # -- 2. QAT layer grad parity under the mesh -----------------------
    params = init_tree(jax.random.PRNGKey(7), dcl_def(4, 8))
    params["w_offset"] = 0.1 * jax.random.normal(
        jax.random.fold_in(key, 3), params["w_offset"].shape, jnp.float32)

    def qat_loss(p, shard):
        y, o_max = dcl_apply(p, x, offset_bound=2.0, quant="qat",
                             use_kernel=True, shard_batch=shard)
        return jnp.sum(jnp.sin(y)) + 0.1 * o_max

    # Same kernel path with and without the mesh: isolates the
    # shard_map + dw-psum machinery (kernel-vs-reference QAT parity is
    # tier-1 test_quant territory).
    gq_ref = jax.grad(lambda p: qat_loss(p, False))(params)
    with use_rules(mesh=mesh):
        gq_sh = jax.grad(lambda p: qat_loss(p, True))(params)
    out["qat_grad_tol_excess"] = max(
        _tol_excess(gq_sh[k], gq_ref[k]) for k in gq_ref)

    # -- 3. full-model step grad parity + jaxpr evidence ---------------
    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=32, offset_bound=2.0,
        use_kernel=True, shard_batch=True)
    cfg_ref = dataclasses.replace(cfg, use_kernel=False, shard_batch=None)
    data = DetectionDataConfig(img_size=32, global_batch=4, num_classes=4,
                               seed=3)
    mparams = R.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in detection_batch(data, 0).items()}

    def step(c):
        return jax.value_and_grad(
            lambda p: R.train_loss(p, c, batch, lam=0.1)[0])(mparams)

    l_ref, grad_ref = step(cfg_ref)
    with use_rules(mesh=mesh):
        l_sh, grad_sh = step(cfg)
        jaxpr = str(jax.make_jaxpr(
            jax.grad(lambda p: R.train_loss(p, cfg, batch, lam=0.1)[0]))(
            mparams))
    out["model_loss_diff"] = abs(float(l_sh) - float(l_ref))
    out["model_grad_diff"] = _max_diff(ravel_pytree(grad_sh)[0],
                                       ravel_pytree(grad_ref)[0])
    out["jaxpr_shard_map"] = "shard_map" in jaxpr
    out["jaxpr_psum"] = "psum" in jaxpr
    out["jaxpr_custom_vjp"] = "custom_vjp" in jaxpr

    # -- 4. Trainer end-to-end on the mesh -----------------------------
    import tempfile
    from repro.distributed.sharding import use_rules as _ur
    from repro.models.layers import spec_tree
    from repro.optim import constant, sgd
    from repro.train import Trainer, TrainerConfig

    finals = {}
    for label, c, m in (("single", dataclasses.replace(cfg, shard_batch=None),
                         None),
                        ("sharded", cfg, mesh)):
        param_specs = None
        if m is not None:
            with _ur(mesh=m):
                param_specs = spec_tree(R.model_def(cfg))
        with tempfile.TemporaryDirectory() as tmp:
            tr = Trainer(
                loss_fn=lambda p, b, _c=c: R.train_loss(p, _c, b, lam=0.1),
                params=R.init_params(jax.random.PRNGKey(0), cfg),
                optimizer=sgd(constant(0.05), momentum=0.9), mesh=m,
                param_specs=param_specs,
                batch_fn=lambda s: {k: jnp.asarray(v) for k, v in
                                    detection_batch(data, s).items()},
                config=TrainerConfig(total_steps=3, ckpt_every=100,
                                     ckpt_dir=tmp, log_every=100))
            tr.run()
        finals[label] = np.asarray(ravel_pytree(tr.params)[0])
        if m is not None:
            out["trainer_steps"] = len(tr.step_seconds)
    out["trainer_param_diff"] = float(
        np.max(np.abs(finals["sharded"] - finals["single"])))

    # -- 5. friendly divisibility error --------------------------------
    with use_rules(mesh=mesh):
        try:
            ops.deform_conv(x[:3], offs[:3], wgt, offset_bound=2.0,
                            shard_batch=True)
            out["mesh_divide_error"] = ""
        except ValueError as e:
            out["mesh_divide_error"] = str(e)
    return out


if __name__ == "__main__":
    print(json.dumps(run_checks()))
