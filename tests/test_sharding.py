"""Logical-axis sharding rules: divisibility fallbacks, spec/param
structure agreement — the invariants behind the 40-cell dry-run."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DEFAULT_RULES, logical_spec,
                                        use_rules)
from repro.models import registry as reg
from repro.models.registry import reduced_config
from repro.models.resnet_dcn import ResNetDCNConfig


class FakeMesh:
    """Mesh stand-in exposing axis_names/devices.shape only."""
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as np
        self.devices = np.zeros(shape)


MESH1 = FakeMesh((16, 16), ("data", "model"))
MESH2 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard():
    s = logical_spec((256, 22528), ("embed", "ff"), mesh=MESH1)
    assert s == P("data", "model")


def test_non_divisible_dims_stay_replicated():
    # 24 heads don't divide the 16-way model axis (musicgen case)
    s = logical_spec((2048, 24, 64), ("embed", "heads", None), mesh=MESH1)
    assert s == P("data", None, None)


def test_partial_composite_axes():
    # batch -> ('pod', 'data'): single-pod mesh has no 'pod' axis
    s1 = logical_spec((256, 4096), ("batch", "seq"), mesh=MESH1)
    assert s1 == P("data", None)
    s2 = logical_spec((256, 4096), ("batch", "seq"), mesh=MESH2)
    assert s2 == P(("pod", "data"), None)
    # batch=2 divides pod(2) but not data(16): only pod used
    s3 = logical_spec((2, 4096), ("batch", "seq"), mesh=MESH2)
    assert s3 == P("pod", None)


def test_mesh_axis_used_once_per_spec():
    # both dims map to 'model': the second one must stay unsharded
    s = logical_spec((64, 32), ("heads", "kv"), mesh=MESH1)
    assert s == P("model", None)


def test_no_mesh_is_fully_replicated():
    s = logical_spec((8, 8), ("embed", "ff"), mesh=None)
    assert s == P(None, None)


@pytest.mark.parametrize("name", reg.names())
def test_param_specs_match_param_structure(name):
    """Every param leaf must have exactly one PartitionSpec of the same
    tree path and rank — for ALL architectures (full-size configs; no
    allocation: abstract trees only)."""
    arch = reg.get(name)
    with use_rules(rules=DEFAULT_RULES, mesh=MESH2):
        if isinstance(arch.config, ResNetDCNConfig):
            from repro.models import resnet_dcn as R
            from repro.models.layers import abstract_tree, spec_tree
            defs = R.model_def(arch.config)
            absd, specs = abstract_tree(defs), spec_tree(defs)
        else:
            from repro.models.transformer import abstract_params, param_specs
            absd = abstract_params(arch.config)
            specs = param_specs(arch.config)
    flat_a, tda = jax.tree_util.tree_flatten(absd)
    flat_s, tds = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_a) == len(flat_s)
    for a, s in zip(flat_a, flat_s):
        assert len(s) <= len(a.shape), (a.shape, s)
        # every named axis divides its dim
        sizes = dict(zip(MESH2.axis_names, MESH2.devices.shape))
        for dim, entry in zip(a.shape, tuple(s) + (None,) * 8):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for ax in axes:
                n *= sizes[ax]
            assert dim % n == 0, (name, a.shape, s)


@pytest.mark.parametrize("name", reg.names())
def test_cache_specs_match_cache_structure(name):
    arch = reg.get(name)
    if isinstance(arch.config, ResNetDCNConfig):
        pytest.skip("CNN has no decode cache")
    from repro.models.transformer import abstract_cache, cache_specs
    with use_rules(rules=DEFAULT_RULES, mesh=MESH2):
        absd = abstract_cache(arch.config, 128, 1024)
        specs = cache_specs(arch.config, 128, 1024)
    na = len(jax.tree_util.tree_leaves(absd))
    ns = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert na == ns
