"""Chaos integration test (PR 6 capstone).

Runs ``tests/_chaos_checks.py`` — the miniature sharded QAT
``resnet_dcn`` trained fault-free, then under a seeded random
``FaultPlan`` spanning four fault classes — and asserts the recovery
contract: every step completes, at least three fault classes actually
fire, and the chaos trajectory is BIT-EXACT to the skip-only oracle
(fault-free except the same one skipped non-finite step), i.e. crash
recovery, checkpoint-corruption fallback, and data-hiccup retry inject
zero numeric drift.
"""
import functools
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import numpy as np
import pytest

_CHECKS = Path(__file__).parent / "_chaos_checks.py"
# honour a pre-set path (the CI chaos job uploads it as an artifact)
_TELEMETRY = os.environ.get("REPRO_CHAOS_TELEMETRY") or os.path.join(
    tempfile.gettempdir(), f"repro_chaos_telemetry_{os.getpid()}.json")


@functools.lru_cache(maxsize=1)
def _results() -> dict:
    env = {**os.environ, "REPRO_CHAOS_TELEMETRY": _TELEMETRY}
    if jax.device_count() >= 4:          # CI chaos job: devices forced
        os.environ["REPRO_CHAOS_TELEMETRY"] = _TELEMETRY
        sys.path.insert(0, str(_CHECKS.parent))
        try:
            from _chaos_checks import run_checks
            return run_checks()
        finally:
            sys.path.pop(0)
    env.setdefault("PYTHONPATH",
                   str(Path(__file__).resolve().parents[1] / "src"))
    proc = subprocess.run([sys.executable, str(_CHECKS)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.splitlines()[-1])


def test_chaos_run_completes_all_steps():
    r = _results()
    assert r["steps_completed"] == r["total_steps"]
    assert all(np.isfinite(r["losses_chaos"]))


def test_at_least_three_fault_classes_fired():
    r = _results()
    fired = set(r["fired_kinds"])
    assert len(fired) >= 3, fired
    # the three classes the acceptance gate names must be among them
    assert {"nonfinite_grads", "ckpt_corrupt", "step_crash"} <= fired


def test_recovery_events_recorded():
    r = _results()
    events = r["events"]
    assert any(e.startswith("skipped") for e in events)
    assert sum(e.startswith("recovered") for e in events) >= 2
    assert any("corrupt" in e for e in events)  # CRC fallback engaged
    t = r["telemetry"]
    assert t["skipped"] >= 1
    assert t["recovered"] >= 2
    assert t["retries"] >= t["recovered"]


def test_chaos_loss_parity_with_skip_oracle():
    """Crash/corruption/hiccup recovery is numerically FREE: the chaos
    run's final loss equals the skip-only oracle's to the last bit (the
    skipped non-finite step is the sole legitimate divergence from the
    plain fault-free run)."""
    r = _results()
    assert r["final_loss_chaos"] == pytest.approx(
        r["final_loss_oracle"], rel=1e-6, abs=0.0)
    # and the trajectory stayed in the fault-free run's loss envelope
    lo, hi = min(r["losses_free"]), max(r["losses_free"])
    span = hi - lo
    assert lo - span <= r["final_loss_chaos"] <= hi + span


def test_replayed_steps_are_bit_exact():
    """Every recovery replays steps from the restored checkpoint; the
    replayed losses must reproduce the originals exactly, so duplicate
    values appear in the chaos trajectory."""
    r = _results()
    losses = r["losses_chaos"]
    replays = len(losses) - len(set(losses))
    assert replays >= r["telemetry"]["recovered"] - 1


def test_telemetry_artifact_written():
    _results()
    assert os.path.exists(_TELEMETRY)
    rec = json.loads(Path(_TELEMETRY).read_text())
    for key in ("seed", "plan", "fired", "trainer_telemetry",
                "losses_chaos", "steps_completed", "metrics"):
        assert key in rec, key
    assert rec["seed"] == 20260808
    # the attached registry snapshot agrees with the health telemetry
    counters = rec["metrics"]["counters"]
    skipped = sum(v["value"] for v in
                  counters["train_steps_skipped_total"]["values"])
    assert skipped == rec["trainer_telemetry"]["skipped"]


def test_fault_events_traced():
    """Every fired fault class appears as a fault/* instant event in the
    chaos run's trace, nested among train/step spans — while the
    bit-exactness assertions above still hold (tracing is inert)."""
    r = _results()
    expected = {f"fault/{k}" for k in r["fired_kinds"]}
    assert expected <= set(r["trace_event_names"]), (
        expected, r["trace_event_names"])
    assert {"train/step", "train/data", "train/compute",
            "ckpt/save"} <= set(r["trace_span_names"])
    # the companion trace JSONL rode along with the telemetry artifact
    root, _ = os.path.splitext(_TELEMETRY)
    assert os.path.exists(root + "-trace.jsonl")
