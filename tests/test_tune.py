"""Measured-time autotuner (ISSUE 9): cache round-trip, resilience
fallback (corrupt/version-mismatch files warn once and go analytic),
platform-key isolation, resolve_tiles/bounded-backward consumption,
the platform switch, and the serving engine reading tuned plans."""
import json
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tiling import (KernelTiles, LayerShape, choose_kernel_tiles,
                               neighbor_kernel_tiles, zerocopy_vmem_bytes)
from repro.kernels import ops, plan
from repro.launch.platform import (current_platform, platform_scope,
                                   set_platform)
from repro.tune import (CACHE_VERSION, TileCache, TileCacheError,
                        active_tile_cache, entry_key, install_tile_cache,
                        load_tile_cache, reset_cache_warnings,
                        tile_cache_scope, tune_deform_conv)


@pytest.fixture(autouse=True)
def clean_tune_state():
    reset_cache_warnings()
    install_tile_cache(None)
    plan.reset_tuned_stats()
    yield
    reset_cache_warnings()
    install_tile_cache(None)
    plan.reset_tuned_stats()


def _entry(tiles, **extra):
    return {"tiles": list(tiles), "cores": 1, **extra}


def _key(**over):
    kw = dict(h=8, w=8, c=8, m=8, offset_bound=2.0, objective="forward",
              dtype=None, cores=1, platform="interpret")
    kw.update(over)
    return kw


# ---------------------------------------------------------------------------
# Cache file round-trip + resilience contract
# ---------------------------------------------------------------------------

def test_cache_round_trip(tmp_path):
    cache = TileCache()
    cache.put(_entry((1, 8, 4, 4), dw_flush_every_step=False), **_key())
    path = str(tmp_path / "TUNED_tiles.json")
    assert cache.save(path) == path
    loaded = TileCache.load(path)
    assert len(loaded) == 1
    got = loaded.lookup(**_key())
    assert got["tiles"] == [1, 8, 4, 4]
    assert got["dw_flush_every_step"] is False
    # missing key -> None, not a raise
    assert loaded.lookup(**_key(cores=2)) is None
    # on-disk payload is versioned
    payload = json.loads((tmp_path / "TUNED_tiles.json").read_text())
    assert payload["version"] == CACHE_VERSION


def test_missing_file_is_cold_and_silent(tmp_path, caplog):
    with caplog.at_level(logging.WARNING, logger="repro.tune"):
        assert load_tile_cache(str(tmp_path / "nope.json")) is None
    assert not caplog.records


def test_corrupt_cache_warns_once_and_falls_back(tmp_path, caplog):
    path = tmp_path / "TUNED_tiles.json"
    path.write_text("{not json")
    with pytest.raises(TileCacheError):
        TileCache.load(str(path))
    with caplog.at_level(logging.WARNING, logger="repro.tune"):
        assert load_tile_cache(str(path)) is None
        assert load_tile_cache(str(path)) is None      # second load silent
    warned = [r for r in caplog.records if "falling back" in r.message]
    assert len(warned) == 1
    # reset re-arms the warning (tests / long-lived processes)
    reset_cache_warnings()
    with caplog.at_level(logging.WARNING, logger="repro.tune"):
        assert load_tile_cache(str(path)) is None
    assert len([r for r in caplog.records
                if "falling back" in r.message]) == 2
    # installing a corrupt path installs no cache — analytic fallback
    install_tile_cache(str(path))
    assert active_tile_cache() is None


def test_version_mismatch_falls_back(tmp_path, caplog):
    path = tmp_path / "TUNED_tiles.json"
    path.write_text(json.dumps({"version": CACHE_VERSION + 1,
                                "entries": {}}))
    with caplog.at_level(logging.WARNING, logger="repro.tune"):
        assert load_tile_cache(str(path)) is None
    assert any("version" in r.message for r in caplog.records)


def test_schema_without_entries_mapping_raises(tmp_path):
    path = tmp_path / "TUNED_tiles.json"
    path.write_text(json.dumps({"version": CACHE_VERSION, "entries": []}))
    with pytest.raises(TileCacheError, match="entries"):
        TileCache.load(str(path))


# ---------------------------------------------------------------------------
# Platform keys + the platform switch
# ---------------------------------------------------------------------------

def test_platform_key_isolation():
    cache = TileCache()
    cache.put(_entry((1, 8, 4, 4)), **_key(platform="xla_ref"))
    with tile_cache_scope(cache):
        assert current_platform() == "interpret"
        # an xla_ref-keyed entry is never served under interpret
        assert plan.tile_source(8, 8, 8, 8, offset_bound=2.0,
                                objective="forward") == "analytic"
        with platform_scope("xla_ref"):
            assert plan.tile_source(8, 8, 8, 8, offset_bound=2.0,
                                    objective="forward") == "tuned"


def test_set_platform_validates():
    with pytest.raises(ValueError, match="unknown platform"):
        set_platform("gpu")
    if jax.default_backend() != "tpu":
        with pytest.raises(ValueError, match="Mosaic"):
            set_platform("tpu")


def test_xla_ref_platform_parity():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (1, 8, 8, 8), jnp.float32)
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, 8, 8, 18), jnp.float32) * 2
    wgt = jax.random.normal(jax.random.fold_in(key, 2),
                            (9, 8, 8), jnp.float32) * 0.1

    def loss(a, b, ww):
        return jnp.sum(ops.deform_conv(a, b, ww, offset_bound=2.0))

    y_interp = ops.deform_conv(x, offs, wgt, offset_bound=2.0)
    g_interp = jax.grad(loss, argnums=(0, 1, 2))(x, offs, wgt)
    with platform_scope("xla_ref"):
        y_ref = ops.deform_conv(x, offs, wgt, offset_bound=2.0)
        g_ref = jax.grad(loss, argnums=(0, 1, 2))(x, offs, wgt)
    np.testing.assert_allclose(np.asarray(y_interp), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    for gi, gr in zip(g_interp, g_ref):
        np.testing.assert_allclose(np.asarray(gi), np.asarray(gr),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# resolve_tiles consumption
# ---------------------------------------------------------------------------

def test_resolve_tiles_reads_tuned_entry():
    cache = TileCache()
    cache.put(_entry((1, 8, 4, 4)), **_key())
    with tile_cache_scope(cache):
        got = plan.resolve_tiles(8, 8, 8, 8, kernel_size=3, stride=1,
                                 dilation=1, offset_bound=2.0,
                                 tile_h=None, tile_w=None, tile_c=None,
                                 tile_m=None, objective="forward")
        assert got == (1, 8, 4, 4)
        info = plan.tile_cache_info()
        assert info["tuned_hits"] == 1
        assert info["tuned_cache"]["installed"]
    # scope restored: the same resolution goes analytic again
    plan.reset_tuned_stats()
    analytic = plan.resolve_tiles(8, 8, 8, 8, kernel_size=3, stride=1,
                                  dilation=1, offset_bound=2.0,
                                  tile_h=None, tile_w=None, tile_c=None,
                                  tile_m=None, objective="forward")
    kt = choose_kernel_tiles(LayerShape(h=8, w=8, c_in=8, c_out=8,
                                        offset_bound=2.0),
                             objective="forward")
    assert analytic == (kt.tile_h, kt.tile_w, kt.tile_c, kt.tile_m)
    assert plan.tile_cache_info()["analytic_resolves"] == 1


def test_explicit_tiles_beat_tuned_entry():
    cache = TileCache()
    cache.put(_entry((1, 8, 4, 4)), **_key())
    with tile_cache_scope(cache):
        got = plan.resolve_tiles(8, 8, 8, 8, kernel_size=3, stride=1,
                                 dilation=1, offset_bound=2.0,
                                 tile_h=2, tile_w=8, tile_c=8, tile_m=8,
                                 objective="forward")
    assert got == (2, 8, 8, 8)


def test_incompatible_entry_warns_once_and_goes_analytic(caplog):
    cache = TileCache()
    # tile_c=3 does not divide C=8 — a stale/foreign entry
    cache.put(_entry((1, 8, 3, 4)), **_key())
    with tile_cache_scope(cache), \
            caplog.at_level(logging.WARNING, logger="repro.tune"):
        for _ in range(2):
            plan.resolve_tiles.cache_clear()
            got = plan.resolve_tiles(8, 8, 8, 8, kernel_size=3, stride=1,
                                     dilation=1, offset_bound=2.0,
                                     tile_h=None, tile_w=None, tile_c=None,
                                     tile_m=None, objective="forward")
        kt = choose_kernel_tiles(LayerShape(h=8, w=8, c_in=8, c_out=8,
                                            offset_bound=2.0),
                                 objective="forward")
        assert got == (kt.tile_h, kt.tile_w, kt.tile_c, kt.tile_m)
        assert plan.tile_cache_info()["tuned_incompatible"] == 2
    assert len([r for r in caplog.records
                if "incompatible" in r.message]) == 1


def test_bounded_backward_reads_tuned_cadence():
    # A tuned training entry pins dw_flush_every_step=False; the
    # cadence-parity property (test_deform_conv_grad) makes that a pure
    # perf knob, so the pullback must stay bit-compatible with the
    # default-cadence pullback.
    cache = TileCache()
    cache.put(_entry((4, 8, 2, 2), dw_flush_every_step=False),
              **_key(objective="training"))
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (1, 8, 8, 8), jnp.float32)
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, 8, 8, 18), jnp.float32) * 2
    wgt = jax.random.normal(jax.random.fold_in(key, 2),
                            (9, 8, 8), jnp.float32) * 0.1

    def grads(a, b, ww):
        return jax.grad(lambda xx, oo, wv: jnp.sum(ops.deform_conv(
            xx, oo, wv, offset_bound=2.0)), argnums=(0, 1, 2))(a, b, ww)

    g_default = grads(x, offs, wgt)
    with tile_cache_scope(cache):
        g_tuned = grads(x, offs, wgt)
        assert plan.tile_cache_info()["tuned_hits"] >= 1
    for gd, gt in zip(g_default, g_tuned):
        np.testing.assert_allclose(np.asarray(gd), np.asarray(gt),
                                   rtol=1e-4, atol=1e-4)


def test_deform_conv_rejects_cadence_off_kernel_path():
    x = jnp.zeros((1, 8, 8, 8), jnp.float32)
    offs = jnp.zeros((1, 8, 8, 18), jnp.float32)
    wgt = jnp.zeros((9, 8, 8), jnp.float32)
    with pytest.raises(ValueError, match="dw_flush_every_step"):
        ops.deform_conv(x, offs, wgt, dw_flush_every_step=True)


# ---------------------------------------------------------------------------
# Candidate enumeration + the tuner end-to-end
# ---------------------------------------------------------------------------

def test_neighbor_kernel_tiles_properties():
    shape = LayerShape(h=16, w=16, c_in=32, c_out=32, offset_bound=2.0)
    seed = choose_kernel_tiles(shape, objective="training")
    cands = neighbor_kernel_tiles(shape, seed, objective="training")
    assert cands[0] == KernelTiles(seed.tile_h, seed.tile_w,
                                   seed.tile_c, seed.tile_m)
    assert len(cands) == len(set(cands)) > 1
    from repro.core.tiling import TileConfig, V5E_VMEM_BYTES, \
        zerocopy_bwd_vmem_bytes
    for kt in cands:
        assert 32 % kt.tile_c == 0 and 32 % kt.tile_m == 0
        t = TileConfig(kt.tile_h, kt.tile_w, kt.tile_c, kt.tile_m)
        assert max(zerocopy_vmem_bytes(shape, t),
                   zerocopy_bwd_vmem_bytes(shape, t)) <= V5E_VMEM_BYTES


def test_tune_deform_conv_end_to_end():
    cache = TileCache()
    res = tune_deform_conv(h=8, w=8, c=8, m=8, batch=1, offset_bound=2.0,
                           objective="forward", reps=1, max_candidates=2,
                           cache=cache)
    assert res["tuned_vs_analytic_ratio"] >= 1.0     # argmin incl. seed
    assert res["platform"] == current_platform()
    assert res["n_candidates"] >= 1
    entry = cache.lookup(**_key(platform=current_platform()))
    assert entry is not None and len(entry["tiles"]) == 4
    # the persisted winner round-trips into the resolver
    with tile_cache_scope(cache):
        got = plan.resolve_tiles(8, 8, 8, 8, kernel_size=3, stride=1,
                                 dilation=1, offset_bound=2.0,
                                 tile_h=None, tile_w=None, tile_c=None,
                                 tile_m=None, objective="forward")
    assert list(got) == entry["tiles"]


# ---------------------------------------------------------------------------
# Serving engine consumption
# ---------------------------------------------------------------------------

def test_engine_warmup_reads_tuned_cache():
    from repro.models import resnet_dcn as R
    from repro.serve import (DCLServeConfig, DCLServingEngine,
                             bucket_layer_dims)

    bucket = 32
    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=bucket, offset_bound=2.0,
        use_kernel=True)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    dims = bucket_layer_dims(cfg, bucket)
    assert dims                                   # model has DCL layers
    cache = TileCache()
    tuned = {}
    for name, d in dims.items():
        shape = LayerShape(h=d["h"], w=d["w"], c_in=d["c"], c_out=d["m"],
                           stride=d.get("stride", 1), offset_bound=2.0)
        kt = choose_kernel_tiles(shape, objective="forward")
        # a deliberately non-analytic (but valid) tile: halve tile_h
        th = max(1, kt.tile_h // 2)
        tuned[name] = (th, kt.tile_w, kt.tile_c, kt.tile_m)
        cache.put(_entry(tuned[name]),
                  **_key(h=d["h"], w=d["w"], c=d["c"], m=d["m"],
                         stride=d.get("stride", 1)))
    with tile_cache_scope(cache):
        plan.reset_tuned_stats()
        eng = DCLServingEngine(
            params, cfg, DCLServeConfig(buckets=(bucket,), slots=2,
                                        quant="fp32_kernel"))
        tel = eng.telemetry()
    assert eng.plans[bucket] == tuned             # warm-up read the cache
    assert tel["plan_cache"]["tuned_hits"] >= len(dims)
    assert tel["plan_cache"]["tuned_cache"]["installed"]
    srcs = tel["plan_sources"][str(bucket)]
    assert srcs and set(srcs.values()) == {"tuned"}


def test_forward_tune_sweeps_quant_modes():
    """ISSUE 10 satellite: a forward-objective tune sweeps the int8 and
    chained-int8 datapaths by default, writing quant-keyed cache
    entries, so serving buckets find measured plans on every rung."""
    cache = TileCache()
    res = tune_deform_conv(h=8, w=8, c=8, m=8, offset_bound=2.0,
                           objective="forward", reps=1, max_candidates=2,
                           cache=cache)
    assert set(res["quant_sweep"]) == {"int8", "int8_chain"}
    for dt in (None, "int8", "int8_chain"):
        assert cache.lookup(**_key(dtype=dt)) is not None
    # chain entries pin tile_c == C: the fused offset stage stages the
    # full input depth per band
    assert cache.lookup(**_key(dtype="int8_chain"))["tiles"][2] == 8
    # training keeps the fp32-only sweep (the swept rungs are
    # inference-only datapaths)
    cache2 = TileCache()
    res2 = tune_deform_conv(h=8, w=8, c=8, m=8, offset_bound=2.0,
                            objective="training", reps=1,
                            max_candidates=2, cache=cache2)
    assert res2.get("quant_sweep", {}) == {}
    assert cache2.lookup(**_key(objective="training")) is not None
    assert cache2.lookup(**_key(objective="training",
                                dtype="int8")) is None


def test_tune_rejects_bad_quant_combos():
    with pytest.raises(ValueError):
        tune_deform_conv(h=8, w=8, c=8, m=8, offset_bound=2.0,
                         objective="training", dtype="int8_chain")
    with pytest.raises(ValueError):
        tune_deform_conv(h=8, w=8, c=8, m=8, offset_bound=2.0,
                         objective="forward", sweep_quant=("fp8",))
