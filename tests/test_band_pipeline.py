"""Golden-parity suite for the band-pipeline emitter refactor.

The four legacy kernels (``deform_sample``, ``deform_conv_fused``,
``deform_conv_q``, ``deform_conv_bwd``) were rebuilt on the unified
``kernels/band_pipeline.py`` emitter (``BandSpec``/``DCLPlan`` + the
shared double-buffered band stager).  The rewrite must be *provably*
behavior-preserving:

* fp32 forward outputs and all three gradients are **bit-identical** to
  the pre-refactor kernels — the golden CRCs below were captured from
  the original hand-written kernels (commit ``ebe2ce7``) across the
  ragged/stride-2/dilation-2/clamp matrix and both ``cores`` settings
  of the Megacore backward split;
* the int8 kernel stays within 1 LSB of the fake-quant oracle across
  the same matrix (``tests/test_quant.py`` carries that gate; the
  structural checks here make sure it runs through the emitter too).

The structural tests pin the acceptance criterion directly: no
duplicated band-DMA/double-buffer code remains outside the emitter.
"""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

# (name, H, W, C, M, K, stride, dil, bound, tile_h, tile_w, tile_c,
#  off_scale) — explicit tiles so the goldens are chooser-independent;
# multi_c_chunk exercises the double-buffered C-step pipeline.
CASES = [
    ("ragged_h", 13, 16, 4, 8, 3, 1, 1, 2.0, 4, 8, None, 1.0),
    ("ragged_w", 16, 18, 4, 8, 3, 1, 1, 2.0, 4, 8, None, 1.0),
    ("ragged_hw", 11, 13, 4, 4, 3, 1, 1, 1.5, 4, 8, None, 1.0),
    ("stride2", 16, 16, 4, 8, 3, 2, 1, 2.0, 4, 4, None, 1.0),
    ("dilation2", 16, 16, 4, 8, 3, 1, 2, 2.0, 4, 8, None, 1.0),
    ("clamp_hit", 12, 12, 4, 8, 3, 1, 1, 1.0, 4, 8, None, 4.0),
    ("stride2_ragged_clamp", 15, 13, 4, 4, 3, 2, 1, 1.5, 4, 4, None, 4.0),
    ("multi_c_chunk", 16, 16, 8, 8, 3, 1, 1, 2.0, 4, 8, 4, 1.0),
]

# CRC32 of the raw fp32 bytes, captured from the pre-refactor kernels
# in this container (deterministic interpret-mode CPU execution).
GOLDEN = {
    "ragged_h": {"fwd": 3181181901, "grad_c1": 3654088940,
                 "grad_c2": 194592340},
    "ragged_w": {"fwd": 2125914819, "grad_c1": 1238844957,
                 "grad_c2": 1232594153},
    "ragged_hw": {"fwd": 2372151340, "grad_c1": 528650090,
                  "grad_c2": 118858786},
    "stride2": {"fwd": 4177988687, "grad_c1": 446050605,
                "grad_c2": 3394195259},
    "dilation2": {"fwd": 1226145903, "grad_c1": 2895363362,
                  "grad_c2": 3514083084},
    "clamp_hit": {"fwd": 149951776, "grad_c1": 2547651994,
                  "grad_c2": 3553168569},
    "stride2_ragged_clamp": {"fwd": 1107151245, "grad_c1": 3973991461,
                             "grad_c2": 3273117865},
    "multi_c_chunk": {"fwd": 1400854126, "grad_c1": 2036671525,
                      "grad_c2": 718438290},
}

SAMPLE_GOLDEN = {
    "ragged_h": 3451016736,
    "ragged_w": 3845078537,
    "ragged_hw": 3471438705,
    "stride2": 2922421343,
    "dilation2": 227332633,
    "clamp_hit": 4051626774,
    "stride2_ragged_clamp": 2853833911,
    "multi_c_chunk": 1052282055,
}


def _case_arrays(name, h, w, c, m, k, s, d, off_scale):
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % (2 ** 31))
    x = jax.random.normal(key, (2, h, w, c), jnp.float32)
    pad = d * (k // 2)
    ho = (h + 2 * pad - d * (k - 1) - 1) // s + 1
    wo = (w + 2 * pad - d * (k - 1) - 1) // s + 1
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (2, ho, wo, 2 * k * k), jnp.float32) * off_scale
    wgt = jax.random.normal(jax.random.fold_in(key, 2),
                            (k * k, c, m), jnp.float32) * 0.2
    return x, offs, wgt


def _digest(*arrs):
    h = 0
    for a in arrs:
        h = zlib.crc32(np.ascontiguousarray(np.asarray(a)).tobytes(), h)
    return h


@pytest.mark.parametrize("case", CASES, ids=lambda c: c[0])
def test_forward_bit_identical_to_pre_refactor(case):
    name, h, w, c, m, k, s, d, bound, th, tw, tc, off_scale = case
    x, offs, wgt = _case_arrays(name, h, w, c, m, k, s, d, off_scale)
    y = ops.deform_conv(x, offs, wgt, kernel_size=k, stride=s, dilation=d,
                        offset_bound=bound, tile_h=th, tile_w=tw, tile_c=tc)
    assert _digest(y) == GOLDEN[name]["fwd"], name


@pytest.mark.parametrize("cores", [1, 2])
@pytest.mark.parametrize("case", CASES, ids=lambda c: c[0])
def test_grads_bit_identical_to_pre_refactor(case, cores):
    name, h, w, c, m, k, s, d, bound, th, tw, tc, off_scale = case
    x, offs, wgt = _case_arrays(name, h, w, c, m, k, s, d, off_scale)
    grads = jax.grad(
        lambda xx, oo, ww: jnp.sum(ops.deform_conv(
            xx, oo, ww, kernel_size=k, stride=s, dilation=d,
            offset_bound=bound, tile_h=th, tile_w=tw, tile_c=tc,
            cores=cores)), argnums=(0, 1, 2))(x, offs, wgt)
    assert _digest(*grads) == GOLDEN[name][f"grad_c{cores}"], (name, cores)


@pytest.mark.parametrize("case", CASES, ids=lambda c: c[0])
def test_sample_bit_identical_to_pre_refactor(case):
    name, h, w, c, m, k, s, d, bound, th, tw, tc, off_scale = case
    x, offs, _ = _case_arrays(name, h, w, c, m, k, s, d, off_scale)
    p = ops.deform_sample(x, offs, kernel_size=k, stride=s, dilation=d,
                          offset_bound=bound, tile_h=th, tile_w=tw,
                          tile_c=tc)
    assert _digest(p) == SAMPLE_GOLDEN[name], name


def test_sample_int8_band_emits_requantized_patches():
    """Sample-only plans accept int8 inputs: the emitter routes them
    through the int8 bilinear gather (round-to-nearest onto the
    activation grid — the quantized-datapath convention) and reshapes
    its MXU-flat return onto the patch block."""
    from repro.kernels.ref import deform_sample_ref
    key = jax.random.PRNGKey(3)
    x = jnp.clip(jnp.round(jax.random.normal(key, (1, 12, 12, 4)) * 40),
                 -127, 127).astype(jnp.int8)
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, 12, 12, 18), jnp.float32)
    got = ops.deform_sample(x, offs, offset_bound=2.0, tile_h=4, tile_w=4)
    assert got.dtype == jnp.int8
    want = jnp.round(deform_sample_ref(x.astype(jnp.float32), offs,
                                       offset_bound=2.0))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=1)


# ---------------------------------------------------------------------------
# Structural: one emitter, no duplicated band-DMA/double-buffer code
# ---------------------------------------------------------------------------

def _kernel_source(module_name):
    import importlib
    import inspect
    return inspect.getsource(importlib.import_module(module_name))


def test_band_dma_lives_only_in_the_emitter():
    """Acceptance criterion: all four kernels are emitted through
    band_pipeline — no kernel module carries its own band-DMA /
    double-buffer implementation.  The backward's d_input
    read-modify-write is the one non-band DMA allowed outside."""
    for mod in ("repro.kernels.deform_sample",
                "repro.kernels.deform_conv_fused",
                "repro.kernels.deform_conv_q"):
        src = _kernel_source(mod)
        assert "make_async_copy(" not in src, mod
        assert "N_BUFFERS =" not in src, mod
    bwd = _kernel_source("repro.kernels.deform_conv_bwd")
    assert "make_band_dma" not in bwd.replace(
        "from .band_pipeline import", ""), \
        "bwd should stage bands via the shared BandStager"
    assert "N_BUFFERS =" not in bwd
    # the rmw DMA (d_input scatter flush) is the only raw async copy left
    assert bwd.count("pltpu.make_async_copy(") == 2


def test_all_forward_kernels_share_one_emitter():
    """The sample, fused-fp32, int8 and chain kernels are all
    ``band_pipeline.forward_call`` instantiations."""
    import repro.kernels.band_pipeline as bp
    import repro.kernels.deform_conv_fused as fused
    import repro.kernels.deform_conv_q as q
    import repro.kernels.deform_sample as ds
    assert ds.forward_call is bp.forward_call
    assert fused.forward_call is bp.forward_call
    assert q.forward_call is bp.forward_call


def test_plan_validation():
    from repro.kernels.band_pipeline import BandSpec, DCLPlan
    band = BandSpec(kernel_size=3, stride=1, dilation=1, offset_bound=2.0,
                    tile_h=4, tile_w=8)
    assert band.band_h == 4 - 1 + 2 + 2 * 2 + 2
    with pytest.raises(AssertionError):
        DCLPlan(band=band, tile_c=4, epilogue="nope")
    with pytest.raises(ValueError, match="unsupported band dtype"):
        DCLPlan(band=band, tile_c=4, band_dtype="int4")
    # fp16 inputs stage like bf16 (previously accepted — keep it so)
    assert DCLPlan(band=band, tile_c=4,
                   band_dtype="float16").jnp_band_dtype() == jnp.float16
    plan = DCLPlan(band=band, tile_c=4, tile_m=8, band_dtype="int8",
                   acc_dtype="int32", epilogue="requant")
    assert plan.contract and plan.jnp_acc_dtype() == jnp.int32
