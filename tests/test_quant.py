"""repro.quant: int8 datapath — QTensor primitives, the int8 zero-copy
kernel vs the fake-quant reference (<= 1 LSB of the output scale across
the edge-geometry matrix), dtype-aware tile budgets, calibration
observers, QAT through the Trainer, and this PR's modeled-traffic
acceptance gate (int8 >= 3x below fp32 zero-copy)."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.quant import (AbsMaxObserver, PercentileObserver, QMAX,
                         calibrate_resnet_dcn, compute_scale, fake_quant,
                         fake_quant_dcl_reference, quantize)

# (name, H, W, C, M, K, stride, dil, bound, off_scale) — the same
# geometry matrix as tests/test_kernel_geometry.py.  Offsets are drawn
# on a 1/8 grid: eighths are exact in fp32 in any coordinate frame, so
# the kernel's band-local bilinear and the reference's global-frame
# bilinear produce bit-identical pre-round patch values and the 1-LSB
# gate measures the datapaths, not knife-edge rounding of ties.
EDGE_CASES = [
    ("ragged_h", 13, 16, 4, 8, 3, 1, 1, 2.0, 1.0),
    ("ragged_w", 16, 18, 4, 8, 3, 1, 1, 2.0, 1.0),
    ("ragged_hw", 11, 13, 4, 4, 3, 1, 1, 1.5, 1.0),
    ("stride2", 16, 16, 4, 8, 3, 2, 1, 2.0, 1.0),
    ("dilation2", 16, 16, 4, 8, 3, 1, 2, 2.0, 1.0),
    ("clamp_hit", 12, 12, 4, 8, 3, 1, 1, 1.0, 4.0),
    ("stride2_ragged_clamp", 15, 13, 4, 4, 3, 2, 1, 1.5, 4.0),
    ("multi_c_chunk", 16, 16, 8, 8, 3, 1, 1, 2.0, 1.0),
]


def _case_arrays(name, h, w, c, m, k, s, d, off_scale):
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % (2 ** 31))
    x = jax.random.normal(key, (2, h, w, c), jnp.float32)
    pad = d * (k // 2)
    ho = (h + 2 * pad - d * (k - 1) - 1) // s + 1
    wo = (w + 2 * pad - d * (k - 1) - 1) // s + 1
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (2, ho, wo, 2 * k * k), jnp.float32) * off_scale
    offs = jnp.round(offs * 8) / 8
    wgt = jax.random.normal(jax.random.fold_in(key, 2),
                            (k * k, c, m), jnp.float32) * 0.2
    return x, offs, wgt


# ---------------------------------------------------------------------------
# qtypes
# ---------------------------------------------------------------------------

def test_qtensor_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8, 16), jnp.float32)
    for axis in (None, -1):
        q = quantize(x, axis=axis)
        assert q.values.dtype == jnp.int8
        err = jnp.abs(q.dequantize() - x)
        # round-to-nearest onto the grid: error <= scale/2 everywhere
        assert float(jnp.max(err / q.scale)) <= 0.5 + 1e-6


def test_per_channel_beats_per_tensor():
    """Per-channel scales adapt to channel magnitude spread."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (64, 8), jnp.float32) \
        * jnp.logspace(-2, 0, 8)[None, :]
    e_t = float(jnp.mean(jnp.abs(quantize(x).dequantize() - x)))
    e_c = float(jnp.mean(jnp.abs(quantize(x, axis=-1).dequantize() - x)))
    assert e_c < e_t


def test_fake_quant_ste_gradients():
    scale = jnp.float32(0.1)
    x = jnp.array([0.03, -1.0, 12.8, -12.8, 5.0], jnp.float32)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, scale)))(x)
    # pass-through inside [-127*s, 127*s] = [-12.7, 12.7], zero outside
    np.testing.assert_allclose(np.asarray(g), [1, 1, 0, 0, 1])


# ---------------------------------------------------------------------------
# int8 kernel vs fake-quant reference (<= 1 LSB of the output scale)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", EDGE_CASES, ids=lambda c: c[0])
def test_int8_kernel_matches_fake_quant_reference(case):
    name, h, w, c, m, k, s, d, bound, off_scale = case
    x, offs, wgt = _case_arrays(name, h, w, c, m, k, s, d, off_scale)
    got = ops.deform_conv(x, offs, wgt, kernel_size=k, stride=s,
                          dilation=d, offset_bound=bound, precision="int8")
    want = fake_quant_dcl_reference(x, offs, wgt, kernel_size=k, stride=s,
                                    dilation=d, offset_bound=bound)
    # 1 LSB of the per-output-channel dequant scale s_x * s_w[m]
    lsb = (np.asarray(compute_scale(x))
           * np.asarray(compute_scale(wgt, axis=-1)).reshape(-1))
    err = np.abs(np.asarray(got) - np.asarray(want)) / lsb
    assert float(err.max()) <= 1.0, (name, float(err.max()))


def test_int8_kernel_close_to_fp32():
    """End-to-end sanity: the quantized kernel tracks the fp32 kernel to
    quantization accuracy (not bit parity — an 8-bit grid)."""
    x, offs, wgt = _case_arrays("vs_fp32", 16, 16, 8, 8, 3, 1, 1, 1.0)
    yq = ops.deform_conv(x, offs, wgt, offset_bound=2.0, precision="int8")
    yf = ops.deform_conv(x, offs, wgt, offset_bound=2.0)
    rel = float(jnp.linalg.norm(yq - yf) / jnp.linalg.norm(yf))
    assert rel < 0.05, rel


def test_int8_calibrated_scales_override():
    """Explicit (calibrated) scales are honored: quantizing with a 2x
    coarser activation scale changes the output accordingly."""
    x, offs, wgt = _case_arrays("scales", 12, 12, 4, 8, 3, 1, 1, 1.0)
    sx = float(compute_scale(x))
    sw = np.asarray(compute_scale(wgt, axis=-1)).reshape(-1)
    got = ops.deform_conv(x, offs, wgt, offset_bound=2.0, precision="int8",
                          x_scale=jnp.float32(2 * sx),
                          w_scale=jnp.asarray(sw))
    want = fake_quant_dcl_reference(x, offs, wgt, offset_bound=2.0,
                                    x_scale=jnp.float32(2 * sx),
                                    w_scale=jnp.asarray(sw))
    lsb = 2 * sx * sw
    err = np.abs(np.asarray(got) - np.asarray(want)) / lsb
    assert float(err.max()) <= 1.0


def test_int8_requires_bound_and_zero_copy():
    x, offs, wgt = _case_arrays("errs", 12, 12, 4, 8, 3, 1, 1, 1.0)
    with pytest.raises(ValueError, match="offset_bound"):
        ops.deform_conv(x, offs, wgt, precision="int8")
    with pytest.raises(ValueError, match="zero-copy"):
        ops.deform_conv(x, offs, wgt, offset_bound=2.0, precision="int8",
                        dataflow="banded")
    with pytest.raises(ValueError, match="precision"):
        ops.deform_conv(x, offs, wgt, offset_bound=2.0, precision="int4")


# ---------------------------------------------------------------------------
# Satellite: clear ValueError on indivisible channel chunks
# ---------------------------------------------------------------------------

def test_channel_chunk_value_error():
    x, offs, wgt = _case_arrays("chunks", 12, 12, 6, 8, 3, 1, 1, 1.0)
    for kwargs in ({"tile_c": 4}, {"dataflow": "banded", "tile_c": 4}):
        with pytest.raises(ValueError, match="tile_c=4 does not divide C=6"):
            ops.deform_conv(x, offs, wgt, offset_bound=2.0, tile_h=4,
                            tile_w=4, **kwargs)
    with pytest.raises(ValueError, match="tile_m=3 does not divide M=8"):
        ops.deform_conv(x, offs, wgt, offset_bound=2.0, tile_h=4,
                        tile_w=4, tile_m=3)
    with pytest.raises(ValueError, match="tile_c=4 does not divide C=6"):
        ops.deform_sample(x, offs, offset_bound=2.0, tile_h=4, tile_w=4,
                          tile_c=4)


# ---------------------------------------------------------------------------
# Dtype-aware tile budgets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [1 << 20, 2 << 20])
def test_dtype_budget_monotone_tiles(budget):
    """Under a binding VMEM budget the chooser's Eq. 6 band working set
    (tile_h * tile_w * tile_c elements) must widen monotonically as
    bytes-per-element shrink — int8 packs 4x the band of fp32 into the
    same VMEM."""
    from repro.core.tiling import LayerShape, choose_kernel_tiles
    shape = LayerShape(h=64, w=64, c_in=128, c_out=128, offset_bound=2.0)
    elems = {}
    for dtype in ("fp32", "bf16", "int8"):
        kt = choose_kernel_tiles(shape, dtype=dtype, objective="forward",
                                 vmem_budget=budget)
        elems[dtype] = kt.tile_h * kt.tile_w * kt.tile_c
    assert elems["fp32"] <= elems["bf16"] <= elems["int8"], elems
    assert elems["int8"] > elems["fp32"], elems


def test_dtype_budget_unconstrained_agree():
    """With VMEM unconstrained the traffic argmin is dtype-independent
    (traffic scales uniformly), so the chosen tiles coincide."""
    from repro.core.tiling import LayerShape, choose_kernel_tiles
    shape = LayerShape(h=32, w=32, c_in=64, c_out=64, offset_bound=2.0)
    tiles = {d: choose_kernel_tiles(shape, dtype=d, objective="forward")
             for d in ("fp32", "int8")}
    assert tiles["fp32"] == tiles["int8"], tiles


def test_dtype_bytes_helper():
    from repro.core.tiling import dtype_bytes
    assert dtype_bytes("int8") == 1
    assert dtype_bytes("bf16") == 2
    assert dtype_bytes("fp32") == 4
    assert dtype_bytes(jnp.int8) == 1
    with pytest.raises(ValueError):
        dtype_bytes(None)


# ---------------------------------------------------------------------------
# Acceptance gate: modeled int8 traffic
# ---------------------------------------------------------------------------

def test_int8_traffic_acceptance_gate():
    """This PR's acceptance: modeled zero-copy HBM input traffic for the
    bounded 3x3 reference layer (H=W=64, C=M=128, batch=4, tile_h=8)
    drops >= 3x under int8 vs fp32 — and the PR-1/2 fp32 gates must not
    regress (the dw-flush cadence fix only lowers zero-copy bwd)."""
    from repro.core.perf_model import dataflow_traffic_report
    rep = dataflow_traffic_report(h=64, w=64, c=128, m=128, batch=4,
                                  tile_h=8, offset_bound=2.0)
    assert rep["q_ratio"] >= 3.0, rep
    assert rep["q_total_ratio"] >= 2.0, rep
    assert rep["ratio"] >= 2.0, rep
    assert rep["bwd_ratio"] >= 2.0, rep
    assert rep["train_ratio"] >= 2.0, rep


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def test_observers():
    key = jax.random.PRNGKey(3)
    xs = [jax.random.normal(jax.random.fold_in(key, i), (1024,)) * 3
          for i in range(4)]
    am, pc = AbsMaxObserver(), PercentileObserver(99.0)
    for x in xs:
        am.update(x)
        pc.update(x)
    s_am, s_pc = am.scale(), pc.scale()
    amax = max(float(jnp.max(jnp.abs(x))) for x in xs)
    assert s_am == pytest.approx(amax / QMAX)
    # clipping the top 1% of mass gives a strictly finer grid
    assert 0 < s_pc < s_am
    with pytest.raises(ValueError):
        from repro.quant import make_observer
        make_observer("minmax")


def _mini_model():
    from repro.models import resnet_dcn as R
    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=32, offset_bound=2.0)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    return R, cfg, params


def _mini_batches(n=2):
    from repro.data import DetectionDataConfig, detection_batch
    data = DetectionDataConfig(img_size=32, global_batch=2, num_classes=4,
                               seed=3)
    return [detection_batch(data, i) for i in range(n)]


def test_calibrate_resnet_dcn_scale_table(tmp_path):
    from repro.quant import load_scale_table, save_scale_table
    R, cfg, params = _mini_model()
    batches = _mini_batches()
    table = calibrate_resnet_dcn(params, cfg, batches)
    layers = [k for k in table if k != "_meta"]
    assert len(layers) == cfg.num_dcn, table.keys()
    for name in layers:
        assert table[name]["x_scale"] > 0
        cout = params[name]["dcl"]["w_deform"].shape[-1]
        assert len(table[name]["w_scale"]) == cout
    # percentile observer clips outliers -> scale no larger than absmax
    table_p = calibrate_resnet_dcn(params, cfg, batches,
                                   observer="percentile", percentile=99.0)
    for name in layers:
        assert table_p[name]["x_scale"] <= table[name]["x_scale"] + 1e-12
    path = tmp_path / "scales.json"
    save_scale_table(table, str(path))
    assert load_scale_table(str(path))[layers[0]]["x_scale"] \
        == pytest.approx(table[layers[0]]["x_scale"])


# ---------------------------------------------------------------------------
# QAT + PTQ through the model / Trainer
# ---------------------------------------------------------------------------

def test_qat_grads_flow_through_kernel_path():
    """cfg.quant='qat' + use_kernel: fake-quant STE composes with the
    custom-VJP zero-copy backward — full-parameter gradient is finite
    and non-zero, and the QAT loss sits near the fp32 loss."""
    import dataclasses

    from jax.flatten_util import ravel_pytree
    R, cfg, params = _mini_model()
    batch = {k: jnp.asarray(v) for k, v in _mini_batches(1)[0].items()}
    cfg_qat = dataclasses.replace(cfg, quant="qat", use_kernel=True)
    l_fp = R.train_loss(params, cfg, batch, lam=0.1)[0]
    l_q, g = jax.value_and_grad(
        lambda p: R.train_loss(p, cfg_qat, batch, lam=0.1)[0])(params)
    flat, _ = ravel_pytree(g)
    assert bool(jnp.all(jnp.isfinite(flat)))
    assert float(jnp.linalg.norm(flat)) > 0
    assert float(jnp.abs(l_q - l_fp)) < 0.2 * float(jnp.abs(l_fp))


def test_qat_trains_through_trainer():
    """The production Trainer runs QAT end-to-end (fake-quant DCLs over
    the custom-VJP kernel path) — steps complete, loss stays finite."""
    import dataclasses
    import tempfile

    from repro.optim import constant, sgd
    from repro.train import Trainer, TrainerConfig
    R, cfg, params = _mini_model()
    cfg_qat = dataclasses.replace(cfg, quant="qat", use_kernel=True)
    batches = _mini_batches(3)
    with tempfile.TemporaryDirectory() as tmp:
        tr = Trainer(
            loss_fn=lambda p, b: R.train_loss(p, cfg_qat, b, lam=0.1),
            params=params,
            optimizer=sgd(constant(0.05), momentum=0.9), mesh=None,
            param_specs=None,
            batch_fn=lambda s: {k: jnp.asarray(v) for k, v in
                                batches[s % len(batches)].items()},
            config=TrainerConfig(total_steps=3, ckpt_every=100,
                                 ckpt_dir=tmp, log_every=1))
        history = tr.run()
    losses = [h["loss"] for h in history if "loss" in h]
    assert len(tr.step_seconds) == 3
    assert all(np.isfinite(losses)), losses


def test_ptq_int8_model_matches_fp32_closely():
    """Post-training int8 (calibrated scales, kernel datapath) tracks
    the fp32 model output; kernel and fake-quant reference paths agree
    far tighter (same quantization grid)."""
    import dataclasses
    R, cfg, params = _mini_model()
    batches = _mini_batches()
    table = calibrate_resnet_dcn(params, cfg, batches)
    images = jnp.asarray(batches[0]["images"])
    out_fp, _ = R.forward(params, cfg, images)
    cfg_q = dataclasses.replace(cfg, quant="int8", use_kernel=True)
    out_q, _ = R.forward(params, cfg_q, images, quant_scales=table)
    rel = float(jnp.linalg.norm(out_q["cls"] - out_fp["cls"])
                / jnp.linalg.norm(out_fp["cls"]))
    assert rel < 0.05, rel
    cfg_qr = dataclasses.replace(cfg, quant="int8", use_kernel=False)
    out_qr, _ = R.forward(params, cfg_qr, images, quant_scales=table)
    rel_kernel_vs_ref = float(
        jnp.linalg.norm(out_qr["cls"] - out_q["cls"])
        / jnp.linalg.norm(out_q["cls"]))
    assert rel_kernel_vs_ref < 1e-4, rel_kernel_vs_ref
