"""Eq. 6/7 buffer algebra + the VMEM-aware tile chooser."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # fallback: deterministic parametrize shim
    from _propshim import given, settings, st

from repro.core import tiling as T


def test_eq6_paper_point():
    """Paper Fig. 3: ~13.8 MB input buffer at lambda=0 (RF 79, their
    tiling T_W=8, T_N=512, fp32)."""
    size = T.input_buffer_size(79, 1, 8, 512, bytes_per_elem=4)
    assert size == 79 * (8 + 79 - 1) * 512 * 4
    assert 13.0e6 < size < 14.5e6


def test_eq7_output_buffer():
    assert T.output_buffer_size(8, 512, 3, bytes_per_elem=4) \
        == 8 * 512 * 2 * 9 * 4


@given(rf=st.integers(3, 81), s=st.sampled_from([1, 2]),
       tw=st.sampled_from([4, 8, 16]), tn=st.sampled_from([64, 256, 512]))
@settings(max_examples=60, deadline=None)
def test_eq6_monotone(rf, s, tw, tn):
    base = T.input_buffer_size(rf, s, tw, tn)
    assert T.input_buffer_size(rf + 2, s, tw, tn) > base
    assert T.input_buffer_size(rf, s, tw + 1, tn) > base
    assert T.input_buffer_size(rf, s, tw, tn + 1) > base


def test_chooser_fits_vmem_and_beats_paper_point():
    shape = T.LayerShape(h=56, w=56, c_in=512, c_out=512, offset_bound=2.0)
    choice = T.choose_tiles(shape)
    assert choice.vmem_bytes <= T.V5E_VMEM_BYTES
    paper = T.evaluate_tile(shape, T.PAPER_TILES)
    # VMEM is ~100x BRAM: the chooser must find a much higher-CTC point
    assert choice.ctc > paper.ctc * 2


def test_chooser_rejects_unbounded_rf():
    shape = T.LayerShape(h=56, w=56, c_in=512, c_out=512,
                         offset_bound=4096.0)
    with pytest.raises(ValueError):
        T.choose_tiles(shape, vmem_budget=1 << 20)


def test_fused_beats_two_stage_ctc():
    """The beyond-paper fusion removes the Eq. 7 patch round-trip, so its
    CTC must be strictly higher for every tile point."""
    shape = T.LayerShape(h=56, w=56, c_in=256, c_out=256, offset_bound=2.0)
    for t in [T.PAPER_TILES, T.TileConfig(4, 16, 256, 128)]:
        fused = T.evaluate_tile(shape, t, fused=True)
        two = T.evaluate_tile(shape, t, fused=False)
        assert fused.ctc > two.ctc


def test_choose_kernel_tiles_memoized():
    """The chooser's full candidate sweep is memoized per layer shape
    (``lru_cache``): repeated un-jitted ``deform_conv`` calls at the
    same shape must hit the cache instead of re-running the sweep —
    and distinct shapes/dtypes must not collide."""
    T.choose_kernel_tiles.cache_clear()
    shape = T.LayerShape(h=48, w=48, c_in=96, c_out=96, offset_bound=2.0)
    first = T.choose_kernel_tiles(shape)
    base = T.choose_kernel_tiles.cache_info()
    assert base.misses >= 1
    for _ in range(5):
        assert T.choose_kernel_tiles(shape) == first
    info = T.choose_kernel_tiles.cache_info()
    assert info.hits == base.hits + 5
    assert info.misses == base.misses          # no re-sweep
    # different arguments are distinct cache entries, not stale hits
    T.choose_kernel_tiles(shape, dtype="int8", objective="forward")
    assert T.choose_kernel_tiles.cache_info().misses == base.misses + 1


def test_resolve_tiles_memoized_end_to_end():
    """``kernels.plan.resolve_tiles`` (what every un-jitted
    ``deform_conv`` call goes through) caches the resolved call too —
    the chooser sweep runs at most once per layer shape."""
    from repro.kernels.plan import resolve_tiles
    resolve_tiles.cache_clear()
    T.choose_kernel_tiles.cache_clear()
    kw = dict(kernel_size=3, stride=1, dilation=1, offset_bound=2.0,
              tile_h=None, tile_w=None, tile_c=None, tile_m=None)
    a = resolve_tiles(40, 40, 64, 64, **kw)
    sweep = T.choose_kernel_tiles.cache_info()
    for _ in range(3):
        assert resolve_tiles(40, 40, 64, 64, **kw) == a
    assert resolve_tiles.cache_info().hits >= 3
    # the repeated calls never even consulted the chooser again
    assert T.choose_kernel_tiles.cache_info() == sweep


@given(b=st.floats(0.5, 16.0))
@settings(max_examples=30, deadline=None)
def test_inverse_bound(b):
    """max_offset_bound_fitting inverts Eq. 6 w.r.t. the bound."""
    k, s, tw, tn = 3, 1, 8, 256
    budget = T.input_buffer_size(T.receptive_field(k, b), s, tw, tn,
                                 bytes_per_elem=2)
    got = T.max_offset_bound_fitting(k, s, tw, tn, vmem_budget=budget)
    # the returned bound must fit, and bound+1 must not
    assert T.input_buffer_size(T.receptive_field(k, got), s, tw, tn,
                               bytes_per_elem=2) <= budget
    assert T.input_buffer_size(T.receptive_field(k, got + 1), s, tw, tn,
                               bytes_per_elem=2) > budget
