"""Unified observability subsystem (ISSUE 8): tracer spans, metric
histograms, Prometheus round-trip, the dispatch recorder + divergence
report, and the obs_report renderers."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (DEFAULT_LATENCY_BUCKETS, DispatchRecorder,
                       DivergenceTracker, Histogram, MetricsRegistry,
                       NOOP_SPAN, Tracer, dump_telemetry,
                       modeled_dispatch_bytes, parse_prometheus_text,
                       tracer_scope)
from repro.obs.divergence import key_from_context

from _fakeclock import FakeClock


# -- tracer ------------------------------------------------------------

def test_span_nesting_and_ordering_with_fake_clock():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("outer", layer=1) as outer:
        clock.advance(1.0)
        with tr.span("inner") as inner:
            clock.advance(0.25)
        clock.advance(1.0)
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert inner.duration == pytest.approx(0.25)
    assert outer.duration == pytest.approx(2.25)
    # end order: inner closes first
    assert [s.name for s in tr.spans] == ["inner", "outer"]
    assert outer.attrs == {"layer": 1}


def test_events_parent_to_open_span():
    tr = Tracer(clock=FakeClock())
    with tr.span("parent") as p:
        tr.event("ping", n=1)
    tr.event("orphan")
    assert tr.events[0]["parent_id"] == p.span_id
    assert tr.events[1]["parent_id"] is None


def test_disabled_tracer_is_noop_and_allocates_no_spans():
    tr = Tracer(enabled=False)
    s1 = tr.span("a", x=1)
    s2 = tr.span("b")
    assert s1 is NOOP_SPAN and s2 is NOOP_SPAN  # one shared instance
    with s1:
        tr.event("nothing")
    assert tr.spans == [] and tr.events == []


def test_tracer_scope_restores_previous():
    from repro.obs import get_tracer
    prev = get_tracer()
    inner = Tracer(enabled=True)
    with tracer_scope(inner):
        assert get_tracer() is inner
    assert get_tracer() is prev


def test_trace_exports(tmp_path):
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("work", kind="demo"):
        clock.advance(0.5)
        tr.event("mark", at="mid")
    p = tr.export_jsonl(tmp_path / "trace.jsonl")
    recs = [json.loads(line) for line in p.read_text().splitlines()]
    assert {r["type"] for r in recs} == {"span", "event"}
    chrome = tr.to_chrome()
    phs = {e["ph"] for e in chrome["traceEvents"]}
    assert phs == {"X", "i"}
    x = next(e for e in chrome["traceEvents"] if e["ph"] == "X")
    assert x["dur"] == pytest.approx(0.5e6)   # microseconds


# -- metrics -----------------------------------------------------------

def test_histogram_quantiles_within_one_bucket_width():
    rng = np.random.default_rng(7)
    samples = np.abs(rng.lognormal(mean=-4.0, sigma=1.5, size=500))
    h = Histogram("lat")
    for s in samples:
        h.observe(float(s))
    exact = sorted(samples)
    for q in (0.5, 0.9, 0.99):
        idx = min(len(exact) - 1, max(0, math.ceil(q * len(exact)) - 1))
        ex = exact[idx]
        got = h.quantile(q)
        assert abs(got - ex) <= h.bucket_width(ex) + 1e-12, (q, got, ex)


def test_histogram_edge_cases():
    h = Histogram("lat")
    assert math.isnan(h.quantile(0.5))
    h.observe(1e9)                               # overflow bucket
    assert h.quantile(0.99) == h.bounds[-1]      # clamped
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_counter_and_gauge_labels():
    reg = MetricsRegistry()
    c = reg.counter("req_total")
    c.inc(outcome="ok")
    c.inc(outcome="ok")
    c.inc(outcome="shed")
    assert c.value(outcome="ok") == 2
    assert c.value(outcome="missing") == 0
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(3, q="a")
    assert g.value(q="a") == 3
    with pytest.raises(ValueError):
        reg.gauge("req_total")                   # kind conflict


def test_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("jobs_total", "jobs").inc(5, kind="batch")
    reg.gauge("depth").set(2.5)
    h = reg.histogram("lat_seconds", "latency")
    for v in (0.001, 0.002, 0.002, 0.4):
        h.observe(v, op="fwd")
    text = reg.prometheus_text()
    parsed = parse_prometheus_text(text)
    assert parsed[("jobs_total", (("kind", "batch"),))] == 5
    assert parsed[("depth", ())] == 2.5
    assert parsed[("lat_seconds_count", (("op", "fwd"),))] == 4
    assert parsed[("lat_seconds_sum", (("op", "fwd"),))] == \
        pytest.approx(0.405)
    # cumulative bucket counts are monotone and end at the total
    buckets = sorted(
        ((float(dict(k[1])["le"]), v) for k, v in parsed.items()
         if k[0] == "lat_seconds_bucket" and dict(k[1])["le"] != "+Inf"))
    counts = [v for _, v in buckets]
    assert counts == sorted(counts) and counts[-1] == 4
    assert parsed[("lat_seconds_bucket",
                   (("le", "+Inf"), ("op", "fwd")))] == 4


def test_snapshot_and_dump_telemetry_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("n").inc()
    reg.histogram("lat").observe(0.01, op="x")
    rec = {"arr": np.arange(3), "scalar": np.float32(1.5),
           "i": np.int64(7)}
    p = dump_telemetry(tmp_path / "t.json", rec, extra={"k": 1},
                       registry=reg)
    loaded = json.loads(p.read_text())
    assert loaded["arr"] == [0, 1, 2]
    assert loaded["scalar"] == 1.5 and loaded["i"] == 7 and loaded["k"] == 1
    snap = loaded["metrics"]
    assert snap["counters"]["n"]["values"][0]["value"] == 1
    hv = snap["histograms"]["lat"]["values"][0]
    assert hv["count"] == 1 and hv["labels"] == {"op": "x"}
    assert hv["p50"] == pytest.approx(0.01, rel=0.3)


def test_serve_bench_percentiles_match_histogram_at_bucket_resolution():
    """The serving bench now reports p50/p99 from the fixed-bucket
    histogram; parity with the retained-sample percentile it replaced
    is one bucket width (satellite of ISSUE 8)."""
    from benchmarks.serve_bench import _percentile

    rng = np.random.default_rng(3)
    lats = sorted(float(v) for v in
                  np.abs(rng.normal(0.05, 0.02, size=48)) + 1e-4)
    h = Histogram("serve_bench_latency_seconds")
    for v in lats:
        h.observe(v, bucket="32", quant="int8_chain")
    for q in (0.50, 0.99):
        exact = _percentile(lats, q)
        got = h.quantile(q, bucket="32", quant="int8_chain")
        assert abs(got - exact) <= h.bucket_width(exact), (q, got, exact)


# -- divergence + dispatch recorder ------------------------------------

def test_key_from_context_and_modeled_bytes():
    ctx = dict(op="deform_conv", precision="fp32", dataflow="zero_copy",
               shape=(1, 16, 16, 32), offset_bound=2.0, kernel_size=3,
               stride=1, dilation=1, m=32, cores=1)
    key = key_from_context(ctx)
    assert key.dtype == "fp32" and key.quant == "none" and key.cores == 1
    assert "deform_conv[1x16x16x32]" in key.label()
    b_fp32 = modeled_dispatch_bytes(ctx)
    assert b_fp32 and b_fp32 > 0
    b_int8 = modeled_dispatch_bytes({**ctx, "precision": "int8"})
    assert b_int8 and b_int8 < b_fp32        # int8 band is cheaper
    assert modeled_dispatch_bytes({"op": "x"}) is None  # unpriceable
    assert key_from_context({"op": "x", "shape": (1, 2)}) is None


def test_divergence_pair_flags_model_inversion():
    t = DivergenceTracker()
    ok = t.record_pair("fwd", modeled_ratio=1.8, measured_ratio=1.5)
    bad = t.record_pair("bwd_mc_128c", modeled_ratio=1.92,
                        measured_ratio=0.8, note="ROADMAP anomaly")
    assert not ok["anomalous"]
    assert bad["anomalous"] and bad["divergence"] == pytest.approx(2.4)
    rep = t.report()
    assert [p["name"] for p in rep["pairs"]] == ["fwd", "bwd_mc_128c"]


def test_dispatch_recorder_times_real_kernel_dispatch():
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 8, 8), jnp.float32)
    offs = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 8, 18),
                             jnp.float32)
    wgt = jax.random.normal(jax.random.fold_in(key, 2), (9, 8, 8),
                            jnp.float32) * 0.1
    reg = MetricsRegistry()
    tracer = Tracer(enabled=True)
    tracker = DivergenceTracker()
    rec = DispatchRecorder(registry=reg, tracer=tracer, tracker=tracker)
    with ops.dispatch_hook_scope(rec):
        out = ops.deform_conv(x, offs, wgt, offset_bound=2.0)
    assert out.shape == (1, 8, 8, 8)
    c = reg.counter("kernel_dispatch_total")
    assert c.value(op="deform_conv", quant="none", outcome="ok") == 1
    h = reg.histogram("kernel_dispatch_seconds")
    assert h.count(op="deform_conv", quant="none") == 1
    assert h.sum(op="deform_conv", quant="none") > 0
    spans = [s for s in tracer.spans if s.name == "kernel/dispatch"]
    assert len(spans) == 1 and spans[0].attrs["outcome"] == "ok"
    rows = tracker.report()["dispatches"]
    assert len(rows) == 1
    assert rows[0]["modeled_bytes"] and rows[0]["implied_gbps"] > 0


def test_dispatch_recorder_chains_and_survives_chaos_raise():
    """next_hook (the chaos seam) runs FIRST; its raise aborts the
    dispatch before any timing starts, and ops degrades as before."""
    from repro.kernels import ops

    calls = []

    def chaos_hook(context):
        calls.append(context["op"])
        raise RuntimeError("injected")

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 8, 8), jnp.float32)
    offs = jnp.zeros((1, 8, 8, 18), jnp.float32)
    wgt = jax.random.normal(jax.random.fold_in(key, 2), (9, 8, 8),
                            jnp.float32) * 0.1
    reg = MetricsRegistry()
    rec = DispatchRecorder(registry=reg, next_hook=chaos_hook)
    ops._FALLBACK_WARNED.discard(("deform_conv", "fp32"))
    try:
        with ops.dispatch_hook_scope(rec):
            out = ops.deform_conv(x, offs, wgt, offset_bound=2.0)
    finally:
        ops._FALLBACK_WARNED.discard(("deform_conv", "fp32"))
    assert out.shape == (1, 8, 8, 8)         # degraded, not crashed
    assert calls == ["deform_conv"]
    # the injected abort happened before timing: nothing recorded
    assert reg.histogram("kernel_dispatch_seconds").count(
        op="deform_conv", quant="none") == 0


# -- trainer clock seam ------------------------------------------------

def test_trainer_step_timing_on_fake_clock(tmp_path):
    from repro.optim import constant, sgd
    from repro.train import Trainer, TrainerConfig

    class TickClock(FakeClock):
        """Advances 1s per read: each step's (t0, t1) pair -> dt == 1."""

        def __call__(self):
            v = self.t
            self.t += 1.0
            return v

    tr = Trainer(
        loss_fn=lambda p, b: (jnp.sum((p["w"] - b) ** 2), {}),
        params={"w": jnp.zeros((2,))},
        optimizer=sgd(constant(0.1)), mesh=None, param_specs=None,
        batch_fn=lambda s: jnp.ones((2,)),
        config=TrainerConfig(total_steps=3, ckpt_every=100,
                             ckpt_dir=str(tmp_path), log_every=1),
        clock=TickClock())
    tr.run()
    assert tr.step_seconds == [1.0, 1.0, 1.0]
    assert tr.median_step_sec(skip_first=1) == 1.0
    # telemetry is now a registry view with the legacy dict shape
    assert tr.telemetry == {"skipped": 0, "recovered": 0, "retries": 0,
                            "preempted": False}
    h = tr.metrics.histogram("train_step_seconds")
    assert h.count() == 3 and h.sum() == pytest.approx(3.0)


# -- obs_report --------------------------------------------------------

def test_obs_report_renders_all_three_artifacts(tmp_path):
    from repro.launch import obs_report

    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("serve/step", bucket=32):
        clock.advance(0.2)
        tr.event("fault/slow_step")
    trace_path = tr.export_jsonl(tmp_path / "trace.jsonl")

    reg = MetricsRegistry()
    reg.counter("serve_requests_total").inc(5, outcome="ok", bucket="32")
    h = reg.histogram("serve_latency_seconds")
    for v in (0.01, 0.02, 0.03):
        h.observe(v, bucket="32", outcome="ok")
    metrics_path = dump_telemetry(tmp_path / "tel.json", {"x": 1},
                                  registry=reg)

    t = DivergenceTracker()
    t.record_pair("dcl_bwd_megacore_128c/bwd_megacore_split",
                  modeled_ratio=1.92, measured_ratio=0.8)
    div_path = tmp_path / "div.json"
    div_path.write_text(json.dumps({"divergence": t.report()}))

    rows = obs_report.summarize_trace(obs_report.load_trace(trace_path))
    assert any("serve/step" in r for r in rows)
    assert any("fault/slow_step" in r for r in rows)

    rows = obs_report.summarize_metrics(
        obs_report.load_metrics(metrics_path))
    assert any("serve_requests_total" in r and "5" in r for r in rows)
    assert any("serve_latency_seconds" in r for r in rows)

    rows = obs_report.summarize_divergence(
        obs_report.load_divergence(div_path))
    anomaly = [r for r in rows if "dcl_bwd_megacore_128c" in r]
    assert anomaly and "ANOMALOUS" in anomaly[0]

    assert obs_report.main(["--trace", str(trace_path),
                            "--metrics", str(metrics_path),
                            "--divergence", str(div_path)]) == 0


def test_obs_report_loads_engine_telemetry_with_legacy_counters(tmp_path):
    """Engine telemetry dumps carry a legacy top-level ``counters``
    view ({outcome: n}); load_metrics must descend into the embedded
    ``metrics`` snapshot rather than mistake the doc for a bare one."""
    from repro.launch import obs_report

    reg = MetricsRegistry()
    reg.counter("serve_requests_total").inc(6, outcome="ok", bucket="32")
    path = dump_telemetry(tmp_path / "serve-tel.json",
                          {"counters": {"ok": 6}, "steps": 2},
                          registry=reg)
    rows = obs_report.summarize_metrics(obs_report.load_metrics(path))
    assert any("serve_requests_total" in r and "6" in r for r in rows)


def test_kernel_bench_divergence_records_flag_mc128_anomaly():
    """The known-bad 128c Megacore backward configuration produces an
    anomalous divergence pair from the bench records (satellite)."""
    from benchmarks.kernel_bench import divergence_records

    recs = [{"name": "dcl_bwd_megacore_128c",
             "us_bwd_mc_zero_copy": 1000.0, "us_bwd_mc_baseline": 800.0,
             "hbm_bwd_per_core_ratio": 1.92}]
    rep = divergence_records(recs)
    pair = rep["pairs"][0]
    assert pair["name"] == "dcl_bwd_megacore_128c/bwd_megacore_split"
    assert pair["anomalous"]
