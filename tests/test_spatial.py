"""Spatial height-sharding (ISSUE 10), single-device tier: the halo
algebra and its traffic model, split validation, strict opt-in
semantics, per-shard plan warming, and 1-shard bit-identity (a size-1
'spatial' mesh must route through the halo-exchange path and reproduce
the unsharded kernel exactly — the empty ppermute perm delivers the
global zero padding).  Multi-device parity lives in
``test_spatial_sharded.py``."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import perf_model
from repro.core.tiling import (LayerShape, TileConfig, choose_kernel_tiles,
                               dcl_spatial_hbm_bytes, dcl_total_hbm_bytes,
                               spatial_halo_bytes, spatial_halo_rows)
from repro.distributed import spatial
from repro.distributed.sharding import use_rules
from repro.kernels import ops, plan
from repro.models.layers import dcl_apply, dcl_def, init_tree

B = 2.0


def _mesh1() -> Mesh:
    return Mesh(np.asarray(jax.devices()[:1]), ("model",))


def _inputs(n=1, h=16, w=16, c=8, m=8, k=3, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, h, w, c), jnp.float32)
    offs = 2.0 * jax.random.uniform(k2, (n, h, w, 2 * k * k),
                                    jnp.float32) - 1.0
    wgt = 0.1 * jax.random.normal(k3, (k * k, c, m), jnp.float32)
    return x, offs, wgt


# -- halo algebra ---------------------------------------------------------

def test_halo_rows_is_paper_bound():
    """ISSUE 10 property: for dilation=1 (any odd K) the exchanged halo
    is exactly ceil(B) + ceil(K/2) rows — the Eq. 6 band bound applied
    across devices."""
    for k in (1, 3, 5, 7):
        for bound in (0.5, 1.0, 2.0, 2.5, 3.7):
            assert spatial_halo_rows(kernel_size=k, offset_bound=bound) \
                == math.ceil(bound) + math.ceil(k / 2)


def test_halo_rows_general_formula_and_validation():
    for k in (1, 2, 3, 5):
        for d in (1, 2, 3):
            for bound in (0.0, 1.0, 2.0):
                assert spatial_halo_rows(kernel_size=k, dilation=d,
                                         offset_bound=bound) \
                    == d * (k // 2) + math.ceil(bound) + 1
    with pytest.raises(ValueError):
        spatial_halo_rows(kernel_size=0, offset_bound=1.0)
    # runtime and traffic model share one source
    assert spatial.halo_rows(kernel_size=3, offset_bound=B) \
        == spatial_halo_rows(kernel_size=3, offset_bound=B)


def test_check_height_split_errors_name_sizes():
    spatial.check_height_split(32, shards=4)                # fine
    with pytest.raises(ValueError) as ei:
        spatial.check_height_split(30, shards=4)
    assert "shards=4" in str(ei.value) and "H=30" in str(ei.value)
    with pytest.raises(ValueError) as ei:
        spatial.check_height_split(30, shards=2, stride=2)
    assert "stride=2" in str(ei.value)
    with pytest.raises(ValueError) as ei:
        spatial.check_height_split(12, shards=4, min_rows=4)
    assert "thinner than the 4-row halo" in str(ei.value)
    with pytest.raises(ValueError):
        spatial.check_height_split(32, shards=0)


# -- traffic model --------------------------------------------------------

def test_spatial_halo_bytes():
    shape = LayerShape(h=32, w=32, c_in=8, c_out=8, offset_bound=B)
    assert spatial_halo_bytes(shape, shards=1) == 0
    # 2 * halo * W * C * 4B, halo = ceil(2) + ceil(3/2) = 4
    assert spatial_halo_bytes(shape, shards=2) == 2 * 4 * 32 * 8 * 4
    with pytest.raises(ValueError):
        spatial_halo_bytes(shape, shards=0)


def test_dcl_spatial_hbm_bytes_splits_traffic():
    shape = LayerShape(h=64, w=64, c_in=32, c_out=32, offset_bound=B)
    kt = choose_kernel_tiles(shape, objective="forward")
    t = TileConfig(t_h=kt.tile_h, t_w=kt.tile_w, t_n=kt.tile_c,
                   t_m=kt.tile_m)
    single = dcl_total_hbm_bytes(shape, t)
    per_dev = dcl_spatial_hbm_bytes(shape, t, shards=2)
    import dataclasses
    local = dataclasses.replace(shape, h=32)
    assert per_dev == dcl_total_hbm_bytes(local, t) \
        + spatial_halo_bytes(shape, shards=2)
    assert per_dev < single          # the split wins despite the halo
    with pytest.raises(ValueError) as ei:
        dcl_spatial_hbm_bytes(shape, t, shards=3)
    assert "shards=3" in str(ei.value)


def test_spatial_sharding_report_megapixel_gate():
    """Acceptance: the modeled per-device forward traffic and latency
    improve >= 1.5x at 2 shards on the megapixel default shape."""
    rep = perf_model.spatial_sharding_report()
    assert rep["halo_rows"] == 4
    assert rep["traffic_ratio_2shard"] >= 1.5
    assert rep["modeled_speedup_2shard"] >= 1.5
    assert rep["modeled_speedup_4shard"] > rep["modeled_speedup_2shard"]
    assert rep["halo_bytes_2shard"] \
        == spatial_halo_bytes(LayerShape(h=1024, w=1024, c_in=64,
                                         c_out=64, offset_bound=B),
                              shards=2)


# -- opt-in resolution ----------------------------------------------------

def test_resolve_is_strictly_opt_in():
    assert spatial.spatial_mesh_axes() is None      # no active mesh
    assert spatial.resolve_spatial_shard(32) is None
    assert spatial.resolve_spatial_shard(32, shard_spatial=False) is None
    with use_rules(mesh=_mesh1()):
        # even under a live spatial mesh, off means off
        assert spatial.resolve_spatial_shard(32) is None
        got = spatial.resolve_spatial_shard(32, shard_spatial=True,
                                            offset_bound=B)
        assert got is not None and got.shards == 1
        assert got.axis == "model" and got.psum_axes == ("model",)
        assert got.pspec(4) == jax.sharding.PartitionSpec(
            None, "model", None, None)


def test_resolve_without_mesh_raises():
    with pytest.raises(ValueError) as ei:
        spatial.resolve_spatial_shard(32, shard_spatial=True,
                                      offset_bound=B)
    assert "no mesh maps the 'spatial' logical axis" in str(ei.value)


def test_deform_conv_validation():
    x, offs, wgt = _inputs()
    with pytest.raises(ValueError) as ei:
        ops.deform_conv(x, offs, wgt, offset_bound=None,
                        shard_spatial=True)
    assert "trained offset_bound" in str(ei.value)
    with pytest.raises(ValueError) as ei:
        ops.deform_conv(x, offs, wgt, offset_bound=B, dataflow="banded",
                        shard_spatial=True)
    assert "zero-copy" in str(ei.value)


def test_dcl_apply_rejects_chain_and_reference_paths():
    params = init_tree(jax.random.PRNGKey(0), dcl_def(8, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 8))
    with pytest.raises(ValueError) as ei:
        dcl_apply(params, x, offset_bound=B, use_kernel=True,
                  quant="int8_chain", shard_spatial=True)
    assert "chained int8 datapath" in str(ei.value)
    assert "use quant='int8'" in str(ei.value)
    with pytest.raises(ValueError) as ei:
        dcl_apply(params, x, offset_bound=B, use_kernel=False,
                  shard_spatial=True)
    assert "bounded kernel path" in str(ei.value)


# -- per-shard plan warming ----------------------------------------------

def test_warm_tile_cache_resolves_local_height_plans():
    dims = {"l0": dict(h=32, w=32, c=8, m=8)}
    warmed = plan.warm_tile_cache(dims, offset_bound=B,
                                  objective="forward", spatial_shards=2)
    assert warmed["l0"] == plan.resolve_tiles(
        16, 32, 8, 8, kernel_size=3, stride=1, dilation=1,
        offset_bound=B, tile_h=None, tile_w=None, tile_c=None,
        tile_m=None, objective="forward")
    # provenance queries the same local-height entry
    assert plan.tile_source(32, 32, 8, 8, offset_bound=B,
                            spatial_shards=2) \
        == plan.tile_source(16, 32, 8, 8, offset_bound=B)


def test_warm_tile_cache_split_errors_name_the_layer():
    with pytest.raises(ValueError) as ei:
        plan.warm_tile_cache({"s2b0": dict(h=30, w=30, c=8, m=8)},
                             offset_bound=B, spatial_shards=4)
    assert "layer 's2b0'" in str(ei.value)
    with pytest.raises(ValueError) as ei:
        plan.warm_tile_cache({"s3b0": dict(h=8, w=8, c=8, m=8)},
                             offset_bound=B, spatial_shards=4)
    assert "thinner than" in str(ei.value)


# -- 1-shard bit-identity -------------------------------------------------

def test_one_shard_forward_is_bit_identical():
    """A size-1 spatial mesh still routes through exchange_halo +
    _shard_slab (spatial_mesh_axes keeps size-1 axes on purpose); the
    local slab then IS the global pad_zerocopy slab, bit for bit."""
    x, offs, wgt = _inputs()
    ref = ops.deform_conv(x, offs, wgt, offset_bound=B)
    with use_rules(mesh=_mesh1()):
        y1 = ops.deform_conv(x, offs, wgt, offset_bound=B,
                             shard_spatial=True)
    assert bool(jnp.all(y1 == ref))


def test_one_shard_int8_is_bit_identical():
    x, offs, wgt = _inputs()
    ref = ops.deform_conv(x, offs, wgt, offset_bound=B, precision="int8")
    with use_rules(mesh=_mesh1()):
        y1 = ops.deform_conv(x, offs, wgt, offset_bound=B,
                             precision="int8", shard_spatial=True)
    assert bool(jnp.all(y1 == ref))


def test_one_shard_grads_are_bit_identical():
    x, offs, wgt = _inputs()

    def grads(shard):
        def f(a, b, c_):
            y = ops.deform_conv(a, b, c_, offset_bound=B,
                                shard_spatial=shard)
            return jnp.sum(jnp.sin(y))
        return jax.grad(f, argnums=(0, 1, 2))(x, offs, wgt)

    g_ref = grads(None)
    with use_rules(mesh=_mesh1()):
        g_sh = grads(True)
    for a, b in zip(g_sh, g_ref):
        assert bool(jnp.all(a == b))
