"""End-to-end system tests: training convergence, fault recovery,
resume-exactness, serving, gradient compression, data determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (DetectionDataConfig, LMDataConfig, detection_batch,
                        lm_batch)
from repro.models.transformer import ModelConfig, init_params, loss_fn
from repro.optim import adamw, constant
from repro.train import Trainer, TrainerConfig


def _cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, kv_heads=2,
                d_ff=64, vocab=32, dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


def _trainer(tmp, cfg, dcfg, *, steps=25, fault_hook=None, micro=1,
             compression=None, seed=0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    return Trainer(
        loss_fn=lambda p, b: loss_fn(p, cfg, b), params=params,
        optimizer=adamw(constant(3e-3)), mesh=None, param_specs=None,
        batch_fn=lambda s: lm_batch(dcfg, s),
        config=TrainerConfig(total_steps=steps, ckpt_every=5, ckpt_dir=tmp,
                             log_every=5, microbatches=micro,
                             grad_compression=compression),
        fault_hook=fault_hook)


def test_training_converges(tmp_path):
    cfg = _cfg()
    dcfg = LMDataConfig(vocab=32, seq_len=32, global_batch=8, seed=1)
    tr = _trainer(str(tmp_path), cfg, dcfg, steps=30)
    hist = tr.run()
    losses = [h["loss"] for h in hist if "loss" in h]
    assert losses[-1] < losses[0] * 0.9, losses


def test_fault_recovery_and_resume(tmp_path):
    cfg = _cfg()
    dcfg = LMDataConfig(vocab=32, seq_len=32, global_batch=8, seed=1)
    armed = {"on": True}

    def hook(step):
        if step == 12 and armed["on"]:
            armed["on"] = False
            raise RuntimeError("injected failure")

    tr = _trainer(str(tmp_path), cfg, dcfg, steps=20, fault_hook=hook)
    hist = tr.run()
    assert any("recovered" in str(h.get("event", "")) for h in hist)
    assert tr.step == 20

    # a NEW trainer auto-resumes at the last checkpoint
    tr2 = _trainer(str(tmp_path), cfg, dcfg, steps=25, seed=99)
    assert tr2.try_resume()
    assert tr2.step == 20


def test_resume_is_exact(tmp_path):
    """20 straight steps == (10 steps, checkpoint, restore, 10 steps)."""
    cfg = _cfg()
    dcfg = LMDataConfig(vocab=32, seq_len=16, global_batch=4, seed=7)

    trA = _trainer(str(tmp_path / "a"), cfg, dcfg, steps=20)
    trA.run()

    trB1 = _trainer(str(tmp_path / "b"), cfg, dcfg, steps=10)
    trB1.run()
    trB2 = _trainer(str(tmp_path / "b"), cfg, dcfg, steps=20, seed=123)
    assert trB2.try_resume() and trB2.step == 10
    trB2.run()

    for a, b in zip(jax.tree_util.tree_leaves(trA.params),
                    jax.tree_util.tree_leaves(trB2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_grad_accumulation_matches_large_batch(tmp_path):
    """microbatches=2 over batch 8 ~= single batch 8 (same data)."""
    cfg = _cfg()
    dcfg = LMDataConfig(vocab=32, seq_len=16, global_batch=8, seed=5)
    tr1 = _trainer(str(tmp_path / "m1"), cfg, dcfg, steps=6, micro=1)
    tr2 = _trainer(str(tmp_path / "m2"), cfg, dcfg, steps=6, micro=2)
    tr1.run()
    tr2.run()
    # same final loss magnitude (not bit-exact: loss-mean vs grad-mean)
    l1 = tr1.last_loss
    l2 = tr2.last_loss
    assert abs(l1 - l2) < 0.35, (l1, l2)


def test_int8_ef_compression_trains(tmp_path):
    cfg = _cfg()
    dcfg = LMDataConfig(vocab=32, seq_len=32, global_batch=8, seed=1)
    tr = _trainer(str(tmp_path), cfg, dcfg, steps=30,
                  compression="int8_ef")
    hist = tr.run()
    losses = [h["loss"] for h in hist if "loss" in h]
    assert losses[-1] < losses[0] * 0.92, losses


def test_data_determinism_and_host_sharding():
    dcfg = LMDataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    b1 = lm_batch(dcfg, 5)
    b2 = lm_batch(dcfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = lm_batch(dcfg, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host shards partition the batch deterministically
    h0 = lm_batch(dcfg, 5, host_id=0, num_hosts=2)
    h1 = lm_batch(dcfg, 5, host_id=1, num_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_detection_data_targets_consistent():
    dcfg = DetectionDataConfig(img_size=64, global_batch=2, num_classes=4)
    b = detection_batch(dcfg, 0)
    assert b["images"].shape == (2, 64, 64, 3)
    hc = 64 // 32
    assert b["obj"].shape == (2, hc, hc)
    pos = b["obj"] > 0
    assert pos.sum() >= 2
    assert (b["box"][pos][:, 2:] > 0).all()


def test_sharded_training_on_host_mesh(tmp_path):
    """Train under a real mesh with the logical rules active —
    exercises the pjit path end to end on CPU."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import param_specs
    from repro.distributed.sharding import use_rules

    cfg = _cfg()
    mesh = make_host_mesh()
    with use_rules(mesh=mesh):
        specs = param_specs(cfg)
        params = init_params(jax.random.PRNGKey(0), cfg)
        dcfg = LMDataConfig(vocab=32, seq_len=16, global_batch=4, seed=2)
        tr = Trainer(
            loss_fn=lambda p, b: loss_fn(p, cfg, b), params=params,
            optimizer=adamw(constant(3e-3)), mesh=mesh, param_specs=specs,
            batch_fn=lambda s: lm_batch(dcfg, s),
            config=TrainerConfig(total_steps=8, ckpt_every=4,
                                 ckpt_dir=str(tmp_path), log_every=2))
        hist = tr.run()
    losses = [h["loss"] for h in hist if "loss" in h]
    assert np.isfinite(losses).all()
