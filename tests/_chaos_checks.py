"""Shared body of the chaos integration test (PR 6 capstone).

Trains the miniature sharded QAT ``resnet_dcn`` twice on a forced
4-device mesh — once fault-free, once under a seeded random
:class:`FaultPlan` covering four fault classes (non-finite gradients, a
corrupted latest checkpoint, an injected device loss, a data-pipeline
hiccup) — and reports both loss trajectories plus the injection
telemetry.  The chaos run must complete every step with no unhandled
exception and land within tolerance of the fault-free run (the only
legitimate divergence is the one skipped non-finite step).

Entry modes mirror ``tests/_sharded_checks.py``: in-process when the
pytest process already sees >= 4 devices (the CI ``chaos`` job),
otherwise once in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` printing JSON on
the last stdout line.  If ``REPRO_CHAOS_TELEMETRY`` is set, the chaos
telemetry (plan, fired injections, trainer health counters, losses) is
also written there as JSON — the artifact the CI job uploads.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

if __name__ == "__main__":       # subprocess mode: force the devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp

CHAOS_SEED = 20260808
TOTAL_STEPS = 8


def _losses(history):
    return [h["loss"] for h in history if "loss" in h]


def run_checks() -> dict:
    assert jax.device_count() >= 4, jax.devices()
    from repro.data import DetectionDataConfig, detection_batch
    from repro.distributed.sharding import use_rules
    from repro.models import resnet_dcn as R
    from repro.models.layers import spec_tree
    from repro.optim import constant, sgd
    from repro.resilience import ChaosHooks, FaultPlan
    from repro.train import Trainer, TrainerConfig

    mesh = jax.make_mesh((4,), ("data",))
    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=32, offset_bound=2.0,
        use_kernel=True, shard_batch=True, quant="qat")
    data = DetectionDataConfig(img_size=32, global_batch=4, num_classes=4,
                               seed=5)
    with use_rules(mesh=mesh):
        param_specs = spec_tree(R.model_def(cfg))

    def make_trainer(ckpt_dir, hooks=None):
        tr = Trainer(
            loss_fn=lambda p, b: R.train_loss(p, cfg, b, lam=0.1),
            params=R.init_params(jax.random.PRNGKey(0), cfg),
            optimizer=sgd(constant(0.05), momentum=0.9), mesh=mesh,
            param_specs=param_specs,
            batch_fn=lambda s: {k: jnp.asarray(v) for k, v in
                                detection_batch(data, s).items()},
            config=TrainerConfig(total_steps=TOTAL_STEPS, ckpt_every=1,
                                 ckpt_dir=ckpt_dir, log_every=1,
                                 max_retries=5),
            fault_hook=hooks.fault_hook if hooks else None,
            batch_hook=hooks.batch_hook if hooks else None)
        if hooks is not None:
            hooks.bind(tr)
        return tr

    out: dict = {"device_count": jax.device_count(),
                 "total_steps": TOTAL_STEPS}

    # -- fault-free oracle --------------------------------------------
    with tempfile.TemporaryDirectory() as tmp:
        tr_free = make_trainer(tmp)
        hist_free = tr_free.run()
    out["losses_free"] = _losses(hist_free)

    # -- skip-only oracle ---------------------------------------------
    # The one legitimate numeric divergence under chaos is the skipped
    # non-finite step: every other fault (device loss, corrupt
    # checkpoint, data hiccup) must recover bit-exactly.  So the parity
    # target is a run that sees ONLY the nonfinite_grads event — the
    # chaos run must land on the same trajectory to the last bit.
    nf_events = tuple(e for e in FaultPlan.random(
        CHAOS_SEED, total_steps=TOTAL_STEPS,
        kinds=("nonfinite_grads", "ckpt_corrupt", "step_crash",
               "data_hiccup"), min_step=2).events
        if e.kind == "nonfinite_grads")
    oracle_hooks = ChaosHooks(FaultPlan(events=nf_events, seed=CHAOS_SEED))
    with tempfile.TemporaryDirectory() as tmp:
        tr_oracle = make_trainer(tmp, oracle_hooks)
        hist_oracle = tr_oracle.run()
    out["losses_oracle"] = _losses(hist_oracle)

    # -- chaos run: same model/data/optimizer, seeded fault schedule --
    # min_step=2 guarantees >= 2 complete checkpoints exist before the
    # corruption event (ckpt_every=1), so the CRC fallback has a
    # previous step to land on.
    plan = FaultPlan.random(
        CHAOS_SEED, total_steps=TOTAL_STEPS,
        kinds=("nonfinite_grads", "ckpt_corrupt", "step_crash",
               "data_hiccup"),
        min_step=2)
    # The chaos run is traced (ISSUE 8): fault firings land as fault/*
    # instant events among the train/step spans, and the trainer's
    # health counters live in its metrics registry — both are asserted
    # on by test_chaos.py and uploaded as CI artifacts below.
    from repro.obs import Tracer, tracer_scope

    hooks = ChaosHooks(plan)
    tracer = Tracer(enabled=True)
    with tempfile.TemporaryDirectory() as tmp:
        tr = make_trainer(tmp, hooks)
        with tracer_scope(tracer):
            hist = tr.run()
    out["losses_chaos"] = _losses(hist)
    out["trace_event_names"] = sorted({e["name"] for e in tracer.events})
    out["trace_span_names"] = sorted({s.name for s in tracer.spans})
    out["steps_completed"] = tr.step
    out["plan"] = plan.summary()
    out["fired"] = hooks.fired
    out["fired_kinds"] = sorted({f["kind"] for f in hooks.fired})
    out["telemetry"] = dict(tr.telemetry)
    out["events"] = [h["event"] for h in hist if "event" in h]
    out["final_loss_free"] = out["losses_free"][-1]
    out["final_loss_chaos"] = out["losses_chaos"][-1]
    out["final_loss_oracle"] = out["losses_oracle"][-1]

    path = os.environ.get("REPRO_CHAOS_TELEMETRY")
    if path:
        from repro.obs import dump_telemetry as _dump
        # registry= attaches the trainer's metric snapshot; the span
        # trace rides in a companion JSONL (both CI artifacts, rendered
        # by repro.launch.obs_report).
        _dump(path, hooks.telemetry(), extra={
            "seed": CHAOS_SEED,
            "trainer_telemetry": out["telemetry"],
            "losses_free": out["losses_free"],
            "losses_chaos": out["losses_chaos"],
            "steps_completed": tr.step}, registry=tr.metrics)
        root, _ = os.path.splitext(path)
        tracer.export_jsonl(root + "-trace.jsonl")
    return out


if __name__ == "__main__":
    print(json.dumps(run_checks()))
