"""Flash-attention Pallas kernel vs the dense oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _qkv(key, b, sq, sk, kv, g, dh, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, kv, g, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, sk, kv, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, sk, kv, dh), jnp.float32).astype(dtype)
    return q, k, v


CASES = [
    # (b, sq, sk, kv, g, dh, block_q, block_k, causal, softcap)
    (1, 128, 128, 1, 1, 32, 64, 64, True, None),
    (2, 64, 64, 2, 2, 16, 32, 32, True, None),
    (1, 100, 100, 1, 2, 16, 32, 32, True, None),     # ragged vs blocks
    (1, 64, 64, 2, 1, 32, 64, 64, False, None),      # non-causal
    (1, 96, 96, 1, 1, 16, 32, 32, True, 8.0),        # softcap (grok-style)
    (1, 32, 160, 1, 1, 16, 32, 32, False, None),     # Sq != Sk (cross)
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_dense(case, dtype):
    b, sq, sk, kv, g, dh, bq, bk, causal, cap = case
    q, k, v = _qkv(jax.random.PRNGKey(hash(case) % (2**31)),
                   b, sq, sk, kv, g, dh, dtype)
    got = flash_attention(q, k, v, causal=causal, softcap=cap,
                          block_q=bq, block_k=bk)
    want = flash_attention_ref(q, k, v, causal=causal, softcap=cap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_block_shape_invariance():
    q, k, v = _qkv(jax.random.PRNGKey(0), 1, 128, 128, 1, 2, 16,
                   jnp.float32)
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(32, 32), (64, 128), (128, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)


def test_matches_model_attention_path():
    """The kernel must agree with the model library's dense attention
    (the XLA path used by the dry-run) — same math, different engine."""
    from repro.models import layers as L
    b, s, kv, g, dh = 2, 64, 2, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(3), b, s, s, kv, g, dh, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    want = L.attention(q, k, v, pos, pos, window=None, softcap=None,
                       impl="dense")
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
