"""int8 layer-chaining datapath (``quant="int8_chain"``): the fused
offset-conv stage, int8 output emission with per-channel requant, the
two-layer int8 -> int8 handoff, the friendly incompatibility errors,
the modeled-traffic acceptance gate, and chain-mode training through
the production Trainer."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import deform_sample_ref
from repro.quant import QMAX, compute_scale, fake_quant_dcl_chain_reference

# (name, H, W, C, M, K, stride, dil, bound) — chain needs C == M only
# across the handoff; single-layer cases may differ.
GEOMS = [
    ("base", 16, 16, 8, 8, 3, 1, 1, 2.0),
    ("ragged", 13, 15, 4, 8, 3, 1, 1, 2.0),
    ("stride2", 16, 16, 4, 8, 3, 2, 1, 2.0),
    ("dilation2", 16, 16, 4, 4, 3, 1, 2, 1.5),
]


def _layer(name, c, m, k, scale=0.2):
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % (2 ** 31))
    k2 = k * k
    return {
        "w": jax.random.normal(key, (k2, c, m), jnp.float32) * scale,
        "w_off": jax.random.normal(jax.random.fold_in(key, 1),
                                   (k2, c, 2 * k2), jnp.float32) * 0.1,
        "b_off": jax.random.normal(jax.random.fold_in(key, 2),
                                   (2 * k2,), jnp.float32) * 0.5,
        "b": jax.random.normal(jax.random.fold_in(key, 3),
                               (m,), jnp.float32) * 0.1,
    }


def _int_reference(x, lay, *, k, s, d, bound, sx, sy=None):
    """Exact-integer oracle of the chain kernel: integer-valued fp32
    arithmetic (|q| <= 127, K^2*C-term sums < 2^24 — exact in fp32)
    mirrors the kernel's int32 MXU accumulation bit-for-bit."""
    from repro.core.deform_conv import conv2d
    k2 = k * k
    c, m = lay["w"].shape[1], lay["w"].shape[2]
    sw = np.asarray(compute_scale(lay["w"], axis=-1)).reshape(-1)
    swo = np.asarray(compute_scale(lay["w_off"], axis=-1)).reshape(-1)
    xq = jnp.clip(jnp.round(x / sx), -QMAX, QMAX)
    wq = jnp.clip(jnp.round(lay["w"] / sw.reshape(1, 1, -1)), -QMAX, QMAX)
    woq = jnp.clip(jnp.round(lay["w_off"] / swo.reshape(1, 1, -1)),
                   -QMAX, QMAX)
    offs = conv2d(xq, woq.reshape(k, k, c, 2 * k2), stride=s, dilation=d,
                  padding=d * (k // 2))
    offs = offs * (sx * swo.reshape(1, 1, 1, -1)) + lay["b_off"]
    patches = jnp.round(deform_sample_ref(
        xq, offs, kernel_size=k, stride=s, dilation=d, offset_bound=bound))
    acc = jnp.einsum("nhwkc,kcm->nhwm", patches, wq,
                     preferred_element_type=jnp.float32)
    if sy is None:
        return acc * (sx * sw).reshape(1, 1, 1, -1) + lay["b"]
    return jnp.clip(jnp.round(acc * (sx * sw / sy).reshape(1, 1, 1, -1)
                              + (lay["b"] / sy).reshape(1, 1, 1, -1)),
                    -127, 127)


@pytest.mark.parametrize("geom", GEOMS, ids=lambda g: g[0])
def test_chain_kernel_matches_integer_reference(geom):
    """The fused offset-conv stage + requant epilogue reproduce the
    exact-integer oracle bit-for-bit (both accumulate int32-exactly and
    dequant/requant through the same fp32 expression)."""
    name, h, w, c, m, k, s, d, bound = geom
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % (2 ** 31))
    x = jax.random.normal(key, (2, h, w, c), jnp.float32)
    lay = _layer(name, c, m, k)
    sx = float(compute_scale(x))
    sy = 0.9 * sx
    sw = np.asarray(compute_scale(lay["w"], axis=-1)).reshape(-1)
    swo = np.asarray(compute_scale(lay["w_off"], axis=-1)).reshape(-1)
    got = ops.deform_conv_chain(
        x, lay["w"], lay["w_off"], lay["b_off"], lay["b"], kernel_size=k,
        stride=s, dilation=d, offset_bound=bound, x_scale=sx,
        w_scale=jnp.asarray(sw), w_offset_scale=jnp.asarray(swo),
        y_scale=sy, emit="int8")
    want = _int_reference(x, lay, k=k, s=s, d=d, bound=bound, sx=sx, sy=sy)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want))


def test_chain_kernel_m_tiled_reuses_staged_band():
    """With tile_m < M the chained kernel revisits each spatial tile
    once per M-tile; the staged band and the fused offsets are computed
    at mm == 0 and reused (VMEM scratch persists across the sequential
    M axis) — the M-tiled emission must equal the untiled one exactly."""
    name, h, w, c, m, k, s, d, bound = GEOMS[0]
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (2, h, w, c), jnp.float32)
    lay = _layer(name, c, m, k)
    sx = float(compute_scale(x))
    kw = dict(kernel_size=k, stride=s, dilation=d, offset_bound=bound,
              x_scale=sx, y_scale=0.8 * sx, emit="int8")
    full = ops.deform_conv_chain(x, lay["w"], lay["w_off"], lay["b_off"],
                                 lay["b"], tile_m=m, **kw)
    tiled = ops.deform_conv_chain(x, lay["w"], lay["w_off"], lay["b_off"],
                                  lay["b"], tile_m=m // 2, **kw)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(tiled))


def test_chain_int8_input_consumed_verbatim():
    """An int8 input on the x_scale grid produces the same emission as
    the fp32 head quantized in-op — the handoff is lossless."""
    name, h, w, c, m, k, s, d, bound = GEOMS[0]
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, h, w, c), jnp.float32)
    lay = _layer(name, c, m, k)
    sx = float(compute_scale(x))
    kw = dict(kernel_size=k, stride=s, dilation=d, offset_bound=bound,
              x_scale=sx, y_scale=0.5 * sx, emit="int8")
    y_fp_head = ops.deform_conv_chain(x, lay["w"], lay["w_off"],
                                      lay["b_off"], lay["b"], **kw)
    x_q = jnp.clip(jnp.round(x / sx), -QMAX, QMAX).astype(jnp.int8)
    y_q_head = ops.deform_conv_chain(x_q, lay["w"], lay["w_off"],
                                     lay["b_off"], lay["b"], **kw)
    np.testing.assert_array_equal(np.asarray(y_fp_head),
                                  np.asarray(y_q_head))


def _two_layer_setup(h=16, w=16, c=8, k=3, bound=2.0):
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (2, h, w, c), jnp.float32)
    lays = [_layer(f"lay{i}", c, c, k) for i in range(2)]
    params = [{"w_deform": lay["w"].reshape(k, k, c, c),
               "w_offset": lay["w_off"].reshape(k, k, c, 2 * k * k),
               "b_offset": lay["b_off"], "b_deform": lay["b"]}
              for lay in lays]
    # Calibrate the exchange grid from the STE reference sweep: layer
    # 0's output observer IS layer 1's input scale.
    sx0 = float(compute_scale(x))
    y0, _ = fake_quant_dcl_chain_reference(
        x, lays[0]["w"], lays[0]["w_off"], lays[0]["b_off"], lays[0]["b"],
        kernel_size=k, offset_bound=bound, x_scale=sx0)
    sy0 = float(compute_scale(y0))
    scales = [{"x_scale": sx0,
               "w_scale": [float(v) for v in np.asarray(
                   compute_scale(lays[0]["w"], axis=-1)).reshape(-1)],
               "w_offset_scale": [float(v) for v in np.asarray(
                   compute_scale(lays[0]["w_off"], axis=-1)).reshape(-1)],
               "y_scale": sy0},
              {"x_scale": sy0,
               "w_scale": [float(v) for v in np.asarray(
                   compute_scale(lays[1]["w"], axis=-1)).reshape(-1)],
               "w_offset_scale": [float(v) for v in np.asarray(
                   compute_scale(lays[1]["w_off"], axis=-1)).reshape(-1)]}]
    return x, lays, params, scales, bound


def test_two_layer_chain_matches_fake_quant_reference():
    """int8 -> int8 two-layer parity: the kernel chain (layer 0 emits a
    QTensor consumed verbatim by layer 1) tracks the STE fake-quant
    reference to <= 1 LSB of the final per-channel output grid."""
    from repro.models.layers import dcl_chain_apply
    x, lays, params, scales, bound = _two_layer_setup()
    y_k, o_k = dcl_chain_apply(params, x, scales_seq=scales,
                               offset_bound=bound, use_kernel=True)
    y_r, o_r = dcl_chain_apply(params, x, scales_seq=scales,
                               offset_bound=bound, use_kernel=False)
    assert y_k.dtype == jnp.float32         # tail has no y_scale
    assert o_k == [None, None]              # fused offsets stay in VMEM
    assert all(v is not None for v in o_r)  # reference observes o_max
    lsb = (scales[1]["x_scale"]
           * np.asarray(scales[1]["w_scale"]).reshape(1, 1, 1, -1))
    err = np.abs(np.asarray(y_k) - np.asarray(y_r)) / lsb
    assert float(err.max()) <= 1.0, float(err.max())


def test_two_layer_chain_intermediate_is_int8():
    """The inter-layer tensor really is the int8 emission: feeding
    layer 1 the QTensor layer 0 emitted equals the monolithic chain."""
    from repro.models.layers import dcl_apply
    from repro.quant.qtypes import QTensor
    x, lays, params, scales, bound = _two_layer_setup()
    y0, _ = dcl_apply(params[0], x, offset_bound=bound, use_kernel=True,
                      quant="int8_chain", quant_scales=scales[0])
    assert isinstance(y0, QTensor) and y0.values.dtype == jnp.int8
    y1, _ = dcl_apply(params[1], y0, offset_bound=bound, use_kernel=True,
                      quant="int8_chain", quant_scales=scales[1])
    from repro.models.layers import dcl_chain_apply
    y_chain, _ = dcl_chain_apply(params, x, scales_seq=scales,
                                 offset_bound=bound, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y_chain))


# ---------------------------------------------------------------------------
# Friendly incompatibility errors
# ---------------------------------------------------------------------------

def test_chain_value_errors():
    x, lays, params, scales, bound = _two_layer_setup()
    lay = lays[0]
    with pytest.raises(ValueError, match="y_scale"):
        ops.deform_conv_chain(x, lay["w"], lay["w_off"], lay["b_off"],
                              offset_bound=bound, x_scale=1.0, emit="int8")
    with pytest.raises(ValueError, match="x_scale"):
        ops.deform_conv_chain(x, lay["w"], lay["w_off"], lay["b_off"],
                              offset_bound=bound, x_scale=None, emit="fp32")
    with pytest.raises(ValueError, match="tile_c=4 is incompatible"):
        ops.deform_conv_chain(x, lay["w"], lay["w_off"], lay["b_off"],
                              offset_bound=bound, x_scale=1.0, tile_c=4,
                              emit="fp32")
    with pytest.raises(ValueError, match="offset_bound"):
        ops.deform_conv_chain(x, lay["w"], lay["w_off"], lay["b_off"],
                              offset_bound=None, x_scale=1.0, emit="fp32")


def test_chain_layer_compat_errors():
    from repro.models.layers import check_chain_compat, dcl_chain_apply
    x, lays, params, scales, bound = _two_layer_setup()
    # producer without an emission grid
    broken = [dict(scales[0]), dict(scales[1])]
    del broken[0]["y_scale"]
    with pytest.raises(ValueError, match="has no y_scale"):
        dcl_chain_apply(params, x, scales_seq=broken, offset_bound=bound)
    # producer/consumer grid mismatch, named in the error
    broken = [dict(scales[0]), dict(scales[1])]
    broken[1]["x_scale"] = broken[1]["x_scale"] * 2
    with pytest.raises(ValueError, match="disagree on the exchange grid"):
        dcl_chain_apply(params, x, scales_seq=broken, offset_bound=bound)
    # channel handoff mismatch
    with pytest.raises(ValueError, match="C_out=8 channels .* C_in=4"):
        check_chain_compat(scales, couts=[8, 8], cins=[8, 4])
    # table length mismatch
    with pytest.raises(ValueError, match="scale-table entries"):
        dcl_chain_apply(params, x, scales_seq=scales[:1],
                        offset_bound=bound)
    # chain mode without calibration
    from repro.models.layers import dcl_apply
    with pytest.raises(ValueError, match="quant_scales"):
        dcl_apply(params[0], x, offset_bound=bound, quant="int8_chain")
    with pytest.raises(ValueError, match="offset_bound"):
        dcl_apply(params[0], x, quant="int8_chain",
                  quant_scales=scales[0])
    # configuration the chained datapath cannot honor fails loudly
    with pytest.raises(ValueError, match="zero-copy"):
        dcl_apply(params[0], x, offset_bound=bound, quant="int8_chain",
                  quant_scales=scales[0], dataflow="banded")
    with pytest.raises(ValueError, match="shard_batch"):
        dcl_apply(params[0], x, offset_bound=bound, quant="int8_chain",
                  quant_scales=scales[0], shard_batch=True)
    with pytest.raises(ValueError, match="cores"):
        dcl_apply(params[0], x, offset_bound=bound, quant="int8_chain",
                  quant_scales=scales[0], cores=2)
    # a handed-over QTensor on the wrong grid (eager call — under jit
    # the static check_chain_compat guard covers this instead)
    from repro.quant.qtypes import QTensor
    wrong = QTensor(values=jnp.zeros(x.shape, jnp.int8),
                    scale=jnp.float32(2 * scales[0]["x_scale"]))
    with pytest.raises(ValueError, match="emitted on scale"):
        dcl_apply(params[0], wrong, offset_bound=bound, use_kernel=True,
                  quant="int8_chain", quant_scales=scales[0])


# ---------------------------------------------------------------------------
# Modeled-traffic acceptance gate
# ---------------------------------------------------------------------------

def test_chain_traffic_acceptance_gate():
    """PR acceptance: the modeled two-layer HBM traffic of the chained
    int8 datapath sits >= 1.3x below per-layer int8 at the bounded 3x3
    reference layer — and the earlier fp32/int8 gates must not
    regress."""
    from repro.core.perf_model import dataflow_traffic_report
    rep = dataflow_traffic_report(h=64, w=64, c=128, m=128, batch=4,
                                  tile_h=8, offset_bound=2.0)
    assert rep["chain_ratio"] >= 1.3, rep
    assert rep["chain_bytes"] < rep["chain_per_layer_bytes"]
    # single-layer kernel-only view: fusing the offsets + int8 emission
    # strictly reduces the whole-layer total
    assert rep["total_bytes_q_fused_offsets"] \
        < rep["zero_copy_total_bytes_q"]
    # earlier acceptance gates stay intact
    assert rep["q_ratio"] >= 3.0, rep
    assert rep["ratio"] >= 2.0 and rep["train_ratio"] >= 2.0, rep


def test_chain_requires_square_channels():
    from repro.core.tiling import (LayerShape, TileConfig,
                                   dcl_chain_hbm_bytes)
    shape = LayerShape(h=32, w=32, c_in=32, c_out=64, offset_bound=2.0)
    with pytest.raises(ValueError, match="C_in"):
        dcl_chain_hbm_bytes(shape, TileConfig(8, 8, 32, 64))


# ---------------------------------------------------------------------------
# Model + Trainer threading
# ---------------------------------------------------------------------------

def _mini_model():
    from repro.models import resnet_dcn as R
    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=32, offset_bound=2.0)
    return R, cfg, R.init_params(jax.random.PRNGKey(0), cfg)


def _mini_batches(n=2):
    from repro.data import DetectionDataConfig, detection_batch
    data = DetectionDataConfig(img_size=32, global_batch=2, num_classes=4,
                               seed=3)
    return [detection_batch(data, i) for i in range(n)]


def test_calibration_records_chain_scales():
    from repro.quant import calibrate_resnet_dcn
    R, cfg, params = _mini_model()
    table = calibrate_resnet_dcn(params, cfg, _mini_batches())
    layers = [k for k in table if k != "_meta"]
    assert len(layers) == cfg.num_dcn
    for name in layers:
        entry = table[name]
        assert entry["y_scale"] > 0                  # output observer
        k2 = 9
        assert len(entry["w_offset_scale"]) == 2 * k2
        assert "/out" not in name                    # folded, not split


def test_resnet_chain_mode_kernel_vs_reference():
    import dataclasses
    from repro.quant import calibrate_resnet_dcn
    R, cfg, params = _mini_model()
    batches = _mini_batches()
    table = calibrate_resnet_dcn(params, cfg, batches)
    images = jnp.asarray(batches[0]["images"])
    out_fp, _ = R.forward(params, cfg, images)
    cfg_k = dataclasses.replace(cfg, quant="int8_chain", use_kernel=True)
    out_k, o_maxes = R.forward(params, cfg_k, images, quant_scales=table)
    assert o_maxes == {}        # fused offsets never leave VMEM
    rel = float(jnp.linalg.norm(out_k["cls"] - out_fp["cls"])
                / jnp.linalg.norm(out_fp["cls"]))
    assert rel < 0.05, rel      # quantization accuracy, not bit parity
    cfg_r = dataclasses.replace(cfg, quant="int8_chain", use_kernel=False)
    out_r, o_ref = R.forward(params, cfg_r, images, quant_scales=table)
    assert len(o_ref) == cfg.num_dcn
    rel_kr = float(jnp.linalg.norm(out_r["cls"] - out_k["cls"])
                   / jnp.linalg.norm(out_k["cls"]))
    assert rel_kr < 1e-3, rel_kr


def test_chain_mode_trains_through_trainer():
    """quant='int8_chain' threads through the production Trainer: the
    differentiable STE chain reference (use_kernel=False) trains under
    the Eq. 5 objective — steps complete, loss stays finite."""
    import dataclasses
    import tempfile

    from repro.optim import constant, sgd
    from repro.quant import calibrate_resnet_dcn
    from repro.train import Trainer, TrainerConfig
    R, cfg, params = _mini_model()
    batches = _mini_batches(3)
    table = calibrate_resnet_dcn(params, cfg, batches)
    cfg_c = dataclasses.replace(cfg, quant="int8_chain", use_kernel=False)
    with tempfile.TemporaryDirectory() as tmp:
        tr = Trainer(
            loss_fn=lambda p, b: R.train_loss(p, cfg_c, b, lam=0.1,
                                              quant_scales=table),
            params=params,
            optimizer=sgd(constant(0.05), momentum=0.9), mesh=None,
            param_specs=None,
            batch_fn=lambda s: {k: jnp.asarray(v) for k, v in
                                batches[s % len(batches)].items()},
            config=TrainerConfig(total_steps=3, ckpt_every=100,
                                 ckpt_dir=tmp, log_every=1))
        history = tr.run()
    losses = [h["loss"] for h in history if "loss" in h]
    assert len(tr.step_seconds) == 3
    assert all(np.isfinite(losses)), losses
