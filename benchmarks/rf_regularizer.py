"""Paper Table I / Fig. 6 / Fig. 7 — receptive-field regularization sweep.

Trains the reduced ResNet-DCN detector at several lambda values on the
synthetic detection task and reports: final task loss (AP proxy), the
network o_max, the Eq. 4 receptive field, the RF compression vs
lambda=0, and the Eq. 6 stall-free buffer size.  Also sweeps the
beyond-paper smooth-max variant.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.tiling import input_buffer_size, receptive_field
from repro.data import DetectionDataConfig, detection_batch
from repro.models import resnet_dcn as R
from repro.optim import constant, sgd

LAMBDAS = [0.0, 0.05, 0.1, 0.2]
STEPS = 40


def _train(lam: float, smoothness: float = 0.0, steps: int = STEPS):
    cfg = R.ResNetDCNConfig(stage_sizes=(1, 1, 1, 1),
                            widths=(16, 32, 64, 128), stem_width=8,
                            num_dcn=2, num_classes=4, img_size=64)
    dcfg = DetectionDataConfig(img_size=64, global_batch=4, num_classes=4,
                               seed=3)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    for blk in params.values():
        if isinstance(blk, dict) and "dcl" in blk:
            blk["dcl"]["b_offset"] = jnp.full_like(
                blk["dcl"]["b_offset"], 4.0)
    opt = sgd(constant(0.05), momentum=0.9)
    state = opt.init(params)

    @jax.jit
    def step(p, s, batch, i):
        (loss, m), g = jax.value_and_grad(
            lambda pp: R.train_loss(pp, cfg, batch, lam=lam,
                                    smoothness=smoothness),
            has_aux=True)(p)
        p2, s2 = opt.update(g, s, p, i)
        task = m["bce"] + m["ce"] + 0.5 * m["l1"]
        return p2, s2, task, m["o_max"]

    t0 = time.time()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in
                 detection_batch(dcfg, i).items()}
        params, state, task, o_max = step(params, state, batch,
                                          jnp.asarray(i))
    dt = (time.time() - t0) / steps
    return float(task), float(o_max), dt


def run() -> list[str]:
    rows = []
    base_rf = None
    for lam in LAMBDAS:
        task, o_max, dt = _train(lam)
        rf = receptive_field(3, o_max)
        if base_rf is None:
            base_rf = 3 + 2 * o_max
        comp = base_rf / (3 + 2 * o_max)
        buf = input_buffer_size(rf, 1, 8, 512)
        rows.append(
            f"rf_regularizer/lam={lam},{dt * 1e6:.0f},"
            f"task={task:.3f};o_max={o_max:.2f};RF={rf};"
            f"compression={comp:.2f}x;eq6_buffer={buf / 1e6:.2f}MB")
    # beyond-paper: smooth-max variant at the strongest lambda
    task, o_max, dt = _train(LAMBDAS[-1], smoothness=0.5)
    rf = receptive_field(3, o_max)
    rows.append(
        f"rf_regularizer/smooth_lam={LAMBDAS[-1]},{dt * 1e6:.0f},"
        f"task={task:.3f};o_max={o_max:.2f};RF={rf}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
