"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * rf_regularizer      — Table I / Fig. 6 / Fig. 7 (lambda sweep)
  * buffer_efficiency   — Fig. 3
  * accelerator_speed   — Fig. 8
  * energy              — Fig. 9
  * kernel              — Pallas kernels + Eq. 6/7 tile model (Table II
                          analogue: on TPU the "resources" are VMEM/CTC)
  * roofline            — summary of the dry-run §Roofline table if the
                          dry-run artifacts exist (run dryrun.py first)

Also writes ``BENCH_kernels.json`` under ``bench-out/`` at the repo
root (the single canonical artifact location — CI uploads it from
there): machine-readable per-kernel wall time (forward and backward) +
modeled HBM bytes under both DCL dataflows, so the perf trajectory is
tracked across PRs.

The driver gates the zero-copy regressions, forward and backward: for
every ``deform_conv_fused_*`` record, zero-copy wall time must be <=
banded (PR 2), the fused backward pullback <= the XLA-autodiff
reference, and the Megacore-split backward <= the sequential kernel
(PR 4) — all best-of-N with the same noise tolerance.  A gate failure
exits non-zero.

``--smoke`` runs only the kernel section at reduced shapes (< 1 min);
``--out DIR`` redirects the JSON artifacts.

``--tune`` (ISSUE 9) runs the measured-time autotuner on the bench
configs: ``tuned_us_*`` / ``tuned_vs_analytic_ratio`` records land in
``BENCH_kernels.json``, the winner cache is persisted to
``<out>/TUNED_tiles.json`` (what ``repro.tune.install_tile_cache``
consumes), the anomalous Megacore divergence pair is re-recorded
post-tuning, and the tuned-vs-analytic gate runs (see
``TUNE_GATE_NOISE_TOLERANCE``).

``--serve`` runs the serving-engine benchmark instead (PR 7): per-bucket
p50/p99 latency and QPS for the int8_chain vs per-layer-fp32 engines,
written to ``BENCH_serve.json``, with the >= 1.3x chained-int8
throughput gate (modeled HBM ratio exact; measured QPS bounded only by
``SERVE_GATE_NOISE_TOLERANCE`` — see ``serve_bench.py``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


def write_kernel_json(path: str, recs: list[dict], *, smoke: bool,
                      precision: str = "both", chain: bool = False,
                      divergence: dict | None = None) -> None:
    payload = {
        "smoke": smoke,
        "precision": precision,
        "chain": chain,
        "note": "wall times are interpret-mode (CPU, best-of-N) — scaling "
                "only; us_bwd_* time one fwd+vjp pullback; hbm_bytes_* are "
                "the analytic dataflow model (tile_h=8 convention); "
                "us_q_*/hbm_bytes_q_* are the int8 zero-copy datapath; "
                "us_chain_*/hbm_bytes_chain_* are the chained two-layer "
                "int8 datapath vs per-layer int8; divergence pairs the "
                "modeled ratios with the measured ones (repro.obs)",
        "kernels": recs,
    }
    if divergence is not None:
        payload["divergence"] = divergence
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"bench/json,0,wrote {path} ({len(recs)} kernels)")


# Shared CI boxes are right-tailed even under best-of-N; a genuine
# dataflow regression (the PR-1 128c record was 1.6x) clears this
# margin, scheduler jitter on a ~20% win does not.
GATE_NOISE_TOLERANCE = 1.2

# The backward gates compare whole fwd+vjp pullbacks whose true margin
# is thin (~5% vs the XLA-ref pullback; ~0 for the serialized-interpret
# Megacore split) and whose interpret-mode wall time swings ±40% on
# shared boxes — a 1.2x band would flake on noise.  What these gates
# exist to catch is losing the custom VJP (differentiating through the
# interpret grid loop) or a broken core split, both order-of-magnitude
# blowups that clear any sane band.
BWD_GATE_NOISE_TOLERANCE = 1.6

# The tuner's pick is an argmin over candidates that always include the
# analytic pick, so tuned_vs_analytic_ratio >= 1 by construction — up
# to the re-measure noise between the analytic candidate's timing and a
# later run of the same config.  The gate therefore exists to catch the
# tuner APPLYING the wrong plan (a broken cache key / stale entry
# served to the dispatcher), not to certify a speedup margin; 1.3x
# bounds interpret-mode jitter the same way BWD_GATE_NOISE_TOLERANCE
# does for the pullback gates.
TUNE_GATE_NOISE_TOLERANCE = 1.3


def gate_tuned(recs: list[dict]) -> int:
    """Tuned >= analytic gate on every ``tuned_*`` record (ISSUE 9).
    Returns #failures."""
    failures = 0
    floor = 1.0 / TUNE_GATE_NOISE_TOLERANCE
    for r in recs:
        if "tuned_vs_analytic_ratio" not in r:
            continue
        ratio = r["tuned_vs_analytic_ratio"]
        ok = ratio >= floor
        print(f"bench/gate_tuned_{r['name']},0,"
              f"tuned_vs_analytic={ratio:.2f}x"
              f"{'>=' if ok else '<'}{floor:.2f}x"
              f"(tol={TUNE_GATE_NOISE_TOLERANCE})"
              f"{'' if ok else ';REGRESSION'}")
        failures += 0 if ok else 1
    return failures


def gate_zero_copy_regression(recs: list[dict]) -> int:
    """Zero-copy regression gates, forward AND backward.  Returns
    #failures.

    * PR-2 forward gate: zero-copy wall time <= banded on every
      measured deform_conv layer (the 128c regression of
      BENCH_kernels.json rev. PR-1), modulo the CI noise tolerance.
    * PR-4 backward gates: the fused zero-copy backward
      (``us_bwd_zero_copy``, one fwd+vjp pullback) must not fall
      behind the XLA-autodiff reference pullback, and the
      Megacore-split backward (``us_bwd_mc_zero_copy``, cores=2) must
      not regress vs the sequential cores=1 kernel at the same batch —
      interpret mode serializes the cores, so equal time is the
      expectation and a blowup means the split broke the kernel.
    """
    failures = 0

    def gate(label, fast, slow, fast_name, slow_name,
             tol=GATE_NOISE_TOLERANCE):
        nonlocal failures
        ok = fast <= slow * tol
        print(f"bench/{label},{fast:.0f},"
              f"{fast_name}{'<=' if fast <= slow else '>'}{slow_name}"
              f"({slow:.0f}us;tol={tol}x)"
              f"{'' if ok else ';REGRESSION'}")
        failures += 0 if ok else 1

    for r in recs:
        if not r.get("name", "").startswith("deform_conv_fused_"):
            continue
        if "us_zero_copy" not in r:      # int8-only record: no fp32 pair
            continue
        gate(f"gate_{r['name']}", r["us_zero_copy"], r["us_banded"],
             "zero_copy", "banded")
        if "us_bwd_zero_copy" in r:
            gate(f"gate_bwd_{r['name']}", r["us_bwd_zero_copy"],
                 r["us_bwd_xla_ref"], "bwd_zero_copy", "bwd_xla_ref",
                 tol=BWD_GATE_NOISE_TOLERANCE)
        if "us_bwd_mc_zero_copy" in r:
            gate(f"gate_bwd_mc_{r['name']}", r["us_bwd_mc_zero_copy"],
                 r["us_bwd_mc_baseline"], "bwd_mc", "bwd_seq",
                 tol=BWD_GATE_NOISE_TOLERANCE)
    return failures


def gate_chain_traffic(recs: list[dict]) -> int:
    """Chained-layer acceptance gate: the MODELED two-layer HBM traffic
    of the chained int8 datapath must sit >= 1.3x below the per-layer
    int8 datapath on every measured shape (``hbm_chain_traffic_ratio``
    from ``tiling.dcl_chain_hbm_bytes`` — an analytic number, so no
    noise tolerance).  Returns #failures."""
    from benchmarks.kernel_bench import CHAIN_TRAFFIC_GATE
    failures = 0
    for r in recs:
        if "hbm_chain_traffic_ratio" not in r:
            continue
        ratio = r["hbm_chain_traffic_ratio"]
        ok = ratio >= CHAIN_TRAFFIC_GATE
        print(f"bench/gate_chain_{r['name']},0,"
              f"modeled_chain_ratio={ratio:.2f}x"
              f"{'>=' if ok else '<'}{CHAIN_TRAFFIC_GATE}x"
              f"{'' if ok else ';REGRESSION'}")
        failures += 0 if ok else 1
    return failures


def gate_spatial(recs: list[dict]) -> int:
    """Spatial-sharding acceptance gate (ISSUE 10): on the modeled
    megapixel-class record, both the per-device forward traffic ratio
    AND the modeled speedup (halo charged at ICI bandwidth) must be >=
    SPATIAL_MODELED_GATE at 2 shards — analytic numbers, no noise
    tolerance.  Returns #failures."""
    from benchmarks.kernel_bench import SPATIAL_MODELED_GATE
    failures = 0
    for r in recs:
        if r.get("name") != "dcl_spatial_modeled_megapixel":
            continue
        for metric in ("traffic_ratio_2shard", "modeled_speedup_2shard"):
            v = r[metric]
            ok = v >= SPATIAL_MODELED_GATE
            print(f"bench/gate_spatial_{metric},0,"
                  f"{metric}={v:.2f}x"
                  f"{'>=' if ok else '<'}{SPATIAL_MODELED_GATE}x"
                  f"{'' if ok else ';REGRESSION'}")
            failures += 0 if ok else 1
    return failures


def gate_serve(payload: dict) -> int:
    """Serving throughput gates (PR 7).  Returns #failures.

    * modeled: per-request HBM traffic ratio (fp32 per-layer / chained
      int8 at the engine's resolved tile plans) must be >=
      SERVE_THROUGHPUT_GATE on every bucket — analytic, no tolerance.
    * measured: the QPS ratio only has to clear
      GATE / SERVE_GATE_NOISE_TOLERANCE — interpret mode does not
      realize the HBM win (see the serve_bench.py comment), so this
      bound exists to catch order-of-magnitude collapses of the chained
      path, not to certify the speedup.
    """
    from benchmarks.serve_bench import (SERVE_GATE_NOISE_TOLERANCE,
                                        SERVE_THROUGHPUT_GATE)
    failures = 0
    floor = SERVE_THROUGHPUT_GATE / SERVE_GATE_NOISE_TOLERANCE
    for bucket, rec in payload["buckets"].items():
        modeled = rec["throughput_ratio_modeled"]
        ok = modeled >= SERVE_THROUGHPUT_GATE
        print(f"bench/gate_serve_modeled_bucket{bucket},0,"
              f"modeled_ratio={modeled:.2f}x"
              f"{'>=' if ok else '<'}{SERVE_THROUGHPUT_GATE}x"
              f"{'' if ok else ';REGRESSION'}")
        failures += 0 if ok else 1
        measured = rec["throughput_ratio_measured"]
        ok = measured >= floor
        print(f"bench/gate_serve_measured_bucket{bucket},0,"
              f"measured_ratio={measured:.2f}x"
              f"{'>=' if ok else '<'}{floor:.2f}x"
              f"(gate={SERVE_THROUGHPUT_GATE}/"
              f"tol={SERVE_GATE_NOISE_TOLERANCE})"
              f"{'' if ok else ';REGRESSION'}")
        failures += 0 if ok else 1
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="kernel section only, reduced shapes (< 1 min)")
    ap.add_argument("--precision", default="both",
                    choices=("fp32", "int8", "both"),
                    help="DCL datapaths to bench: the fp32 kernels, the "
                         "int8 quantized kernel, or both (default)")
    ap.add_argument("--chain", action="store_true",
                    help="add the chained two-layer int8 records "
                         "(us_chain_*/hbm_bytes_chain_*) and the modeled "
                         ">= 1.3x chained-traffic gate")
    ap.add_argument("--serve", action="store_true",
                    help="run the serving-engine bench instead: per-bucket "
                         "p50/p99/QPS -> BENCH_serve.json + the >= 1.3x "
                         "chained-int8 throughput gate")
    ap.add_argument("--spatial", action="store_true",
                    help="add the spatial-sharding records (ISSUE 10): "
                         "us_spatial_{1,2,4}shard + halo bytes (shard "
                         "counts above the device count are skipped with "
                         "a note) and the modeled megapixel record with "
                         "the >= 1.5x 2-shard gate")
    ap.add_argument("--tune", action="store_true",
                    help="run the measured-time autotuner (repro.tune): "
                         "tuned_us_*/tuned_vs_analytic_ratio records, the "
                         "TUNED_tiles.json winner cache, and the tuned >= "
                         "analytic gate")
    ap.add_argument("--out",
                    default=os.path.join(
                        os.path.dirname(os.path.dirname(
                            os.path.abspath(__file__))), "bench-out"),
                    help="directory for the JSON artifacts (default: the "
                         "canonical bench-out/ at the repo root)")
    args = ap.parse_args(argv)

    if args.serve:
        from benchmarks import serve_bench
        print("name,us_per_call,derived")
        failures = 0
        try:
            payload = serve_bench.records(smoke=args.smoke)
            for row in serve_bench.run(smoke=args.smoke, payload=payload):
                print(row)
            os.makedirs(args.out, exist_ok=True)
            path = os.path.join(args.out, "BENCH_serve.json")
            with open(path, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"bench/json,0,wrote {path} "
                  f"({len(payload['buckets'])} buckets)")
            failures += gate_serve(payload)
        except Exception:  # noqa: BLE001
            failures += 1
            print("serve,nan,ERROR")
            traceback.print_exc()
        if failures:
            sys.exit(1)
        return

    from benchmarks import (accelerator_speed, buffer_efficiency, energy,
                            kernel_bench, rf_regularizer)
    # One records() call feeds both the CSV section and the JSON artifact.
    kernel_recs: list[dict] = []

    def kernel_section():
        kernel_recs.extend(kernel_bench.records(smoke=args.smoke,
                                                precision=args.precision,
                                                chain=args.chain))
        kernel_recs.append(kernel_bench.obs_overhead_record())
        if args.spatial:
            kernel_recs.extend(kernel_bench.spatial_records(
                smoke=args.smoke))
        if args.tune:
            os.makedirs(args.out, exist_ok=True)
            kernel_recs.extend(kernel_bench.tune_records(
                smoke=args.smoke,
                cache_path=os.path.join(args.out, "TUNED_tiles.json")))
        if not args.smoke:
            kernel_recs.extend(kernel_bench.train_step_records())
        return kernel_bench.run(smoke=args.smoke, precision=args.precision,
                                chain=args.chain,
                                kernel_records=kernel_recs)

    if args.smoke:
        sections = [("kernel", kernel_section)]
    else:
        sections = [
            ("rf_regularizer", rf_regularizer.run),
            ("buffer_efficiency", buffer_efficiency.run),
            ("accelerator_speed", accelerator_speed.run),
            ("energy", energy.run),
            ("kernel", kernel_section),
        ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections:
        try:
            for row in fn():
                print(row)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR")
            traceback.print_exc()

    try:
        if not kernel_recs:
            kernel_recs = kernel_bench.records(smoke=args.smoke,
                                               precision=args.precision,
                                               chain=args.chain)
        divergence = kernel_bench.divergence_records(kernel_recs)
        for p in divergence["pairs"]:
            post = ""
            if "measured_ratio_post_tuning" in p:
                resolved = (";ANOMALY-RESOLVED-BY-TUNER"
                            if p["anomalous"] else "")
                post = (f";post_tuning="
                        f"{p['measured_ratio_post_tuning']:.2f}x;"
                        f"tuned_cores={p['tuned_recommended_cores']}"
                        f"{resolved}")
            print(f"bench/divergence_{p['name']},0,"
                  f"modeled={p['modeled_ratio']:.2f}x;"
                  f"measured={p['measured_ratio']:.2f}x;"
                  f"divergence={p['divergence']:.2f}x"
                  f"{';ANOMALOUS' if p['anomalous'] else ''}{post}")
        os.makedirs(args.out, exist_ok=True)
        write_kernel_json(os.path.join(args.out, "BENCH_kernels.json"),
                          kernel_recs, smoke=args.smoke,
                          precision=args.precision, chain=args.chain,
                          divergence=divergence)
        failures += gate_zero_copy_regression(kernel_recs)
        failures += gate_chain_traffic(kernel_recs)
        failures += gate_tuned(kernel_recs)
        failures += gate_spatial(kernel_recs)
    except Exception:  # noqa: BLE001
        failures += 1
        print("bench/json,nan,ERROR")
        traceback.print_exc()

    # roofline summary (optional: requires dry-run artifacts)
    if not args.smoke:
        try:
            from repro.launch.roofline import load_all
            rows = load_all("single")
            ok = [r for r in rows if "error" not in r]
            if ok:
                worst = min(ok, key=lambda r: r["roofline_fraction"])
                best = max(ok, key=lambda r: r["roofline_fraction"])
                doms = {}
                for r in ok:
                    doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
                print(f"roofline/cells,0,n={len(ok)};dominant_counts={doms}")
                print(f"roofline/worst,0,{worst['arch']}x{worst['shape']}="
                      f"{worst['roofline_fraction']:.3f}")
                print(f"roofline/best,0,{best['arch']}x{best['shape']}="
                      f"{best['roofline_fraction']:.3f}")
        except Exception:  # noqa: BLE001
            print("roofline/summary,nan,SKIPPED (run repro.launch.dryrun "
                  "first)")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
