"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * rf_regularizer      — Table I / Fig. 6 / Fig. 7 (lambda sweep)
  * buffer_efficiency   — Fig. 3
  * accelerator_speed   — Fig. 8
  * energy              — Fig. 9
  * kernel              — Pallas kernels + Eq. 6/7 tile model (Table II
                          analogue: on TPU the "resources" are VMEM/CTC)
  * roofline            — summary of the dry-run §Roofline table if the
                          dry-run artifacts exist (run dryrun.py first)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (accelerator_speed, buffer_efficiency, energy,
                            kernel_bench, rf_regularizer)
    sections = [
        ("rf_regularizer", rf_regularizer.run),
        ("buffer_efficiency", buffer_efficiency.run),
        ("accelerator_speed", accelerator_speed.run),
        ("energy", energy.run),
        ("kernel", kernel_bench.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections:
        try:
            for row in fn():
                print(row)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR")
            traceback.print_exc()

    # roofline summary (optional: requires dry-run artifacts)
    try:
        from repro.launch.roofline import load_all
        rows = load_all("single")
        ok = [r for r in rows if "error" not in r]
        if ok:
            worst = min(ok, key=lambda r: r["roofline_fraction"])
            best = max(ok, key=lambda r: r["roofline_fraction"])
            doms = {}
            for r in ok:
                doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
            print(f"roofline/cells,0,n={len(ok)};dominant_counts={doms}")
            print(f"roofline/worst,0,{worst['arch']}x{worst['shape']}="
                  f"{worst['roofline_fraction']:.3f}")
            print(f"roofline/best,0,{best['arch']}x{best['shape']}="
                  f"{best['roofline_fraction']:.3f}")
    except Exception:  # noqa: BLE001
        print("roofline/summary,nan,SKIPPED (run repro.launch.dryrun first)")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
