"""Paper Fig. 9 — energy of the conventional accelerator over ours."""
from __future__ import annotations

from repro.core import perf_model as pm


def run() -> list[str]:
    rows = []
    for n in (128, 256, 512):
        wl = pm.DCLWorkload(n=n, m=n)
        ours5 = pm.energy_ours(wl, 0.005)
        ours0 = pm.energy_ours(wl, 0.0)
        conv = pm.energy_conventional(wl, 0.0)
        rows.append(
            f"energy/N={n},0,"
            f"ours_lam005={ours5 / 1e9:.2f}mJ;ours_lam0={ours0 / 1e9:.2f}mJ;"
            f"conv={conv / 1e9:.2f}mJ;"
            f"saving={pm.energy_ratio(n, 0.005):.2f}x")
    rows.append("energy/paper_claim,0,1.39x saving (combination)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
