"""EXPERIMENTS.md §Quantization: QAT-vs-PTQ sweep on the miniature task.

Trains the miniature ResNet-DCN detector twice (fp32 and QAT fake-quant,
both through the Pallas kernel path), calibrates scale tables (absmax +
percentile observers), and reports held-out detection loss under fp32
and int8 evaluation for each recipe — the numbers in the §Quantization
table.  Run with:

    PYTHONPATH=src:. python benchmarks/quant_experiment.py [--steps 30]

Interpret-mode, a few minutes on CPU.  Not part of ``run.py``'s smoke
path (training is too slow for the < 1 min budget).
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--eval-batches", type=int, default=4)
    args = ap.parse_args(argv)

    from repro.data import DetectionDataConfig, detection_batch
    from repro.models import resnet_dcn as R
    from repro.optim import constant, sgd
    from repro.quant import calibrate_resnet_dcn
    from repro.train import Trainer, TrainerConfig

    cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=32, offset_bound=2.0,
        use_kernel=True)
    data = DetectionDataConfig(img_size=32, global_batch=2, num_classes=4,
                               seed=3)

    def train(cfg_train, tag):
        params = R.init_params(jax.random.PRNGKey(0), cfg)
        with tempfile.TemporaryDirectory() as tmp:
            tr = Trainer(
                loss_fn=lambda p, b: R.train_loss(p, cfg_train, b, lam=0.1),
                params=params,
                optimizer=sgd(constant(0.05), momentum=0.9),
                mesh=None, param_specs=None,
                batch_fn=lambda s: {k: jnp.asarray(v) for k, v in
                                    detection_batch(data, s).items()},
                config=TrainerConfig(total_steps=args.steps,
                                     ckpt_every=10_000, ckpt_dir=tmp,
                                     log_every=10))
            tr.run()
        print(f"quant/train_{tag},{tr.median_step_sec() * 1e6:.0f},"
              f"median_step_us over {args.steps} steps")
        return tr.params

    def eval_loss(params, cfg_eval, scales=None):
        losses = []
        for i in range(1000, 1000 + args.eval_batches):
            b = {k: jnp.asarray(v) for k, v in
                 detection_batch(data, i).items()}
            out, _ = R.forward(params, cfg_eval, b["images"],
                               quant_scales=scales)
            losses.append(float(R.detection_loss(out, b)[0]))
        return float(np.mean(losses))

    cfg_qat = dataclasses.replace(cfg, quant="qat")
    cfg_int8 = dataclasses.replace(cfg, quant="int8")
    p_fp = train(cfg, "fp32")
    p_qat = train(cfg_qat, "qat")

    cal = [detection_batch(data, i)["images"] for i in range(4)]
    tab_fp = calibrate_resnet_dcn(p_fp, cfg, cal)
    tab_fp_p = calibrate_resnet_dcn(p_fp, cfg, cal, observer="percentile",
                                    percentile=99.9)
    tab_qat = calibrate_resnet_dcn(p_qat, cfg, cal)

    rows = [
        ("fp32_trained_fp32_eval", eval_loss(p_fp, cfg)),
        ("fp32_trained_ptq_int8_absmax", eval_loss(p_fp, cfg_int8, tab_fp)),
        ("fp32_trained_ptq_int8_p99.9", eval_loss(p_fp, cfg_int8, tab_fp_p)),
        ("qat_trained_fp32_eval", eval_loss(p_qat, cfg)),
        ("qat_trained_int8_eval_absmax", eval_loss(p_qat, cfg_int8,
                                                   tab_qat)),
    ]
    for name, loss in rows:
        print(f"quant/{name},0,heldout_detection_loss={loss:.4f}")
    print("quant/calibration,0," + ";".join(
        f"{k}:absmax={tab_fp[k]['x_scale']:.5f}:"
        f"p99.9={tab_fp_p[k]['x_scale']:.5f}"
        for k in sorted(tab_fp) if k != "_meta"))


if __name__ == "__main__":
    main()
