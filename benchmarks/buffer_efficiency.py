"""Paper Fig. 3 — input-buffer efficiency vs capacity at several lambda."""
from __future__ import annotations

from repro.core import perf_model as pm

CAPS = [64 << 10, 128 << 10, 256 << 10, 512 << 10, 1 << 20, 4 << 20,
        8 << 20, 14 << 20]


def run() -> list[str]:
    rows = []
    for lam in (0.0, 0.0075, 0.005, 0.01):
        effs = ";".join(f"{c >> 10}KiB={pm.buffer_efficiency(c, lam):.3f}"
                        for c in CAPS)
        rows.append(f"buffer_efficiency/lam={lam},0,{effs}")
    rows.append(
        "buffer_efficiency/stall_free,0,"
        f"lam0={pm.stall_free_capacity(0.0) / 1e6:.1f}MB;"
        f"lam005={pm.stall_free_capacity(0.005) / 1e6:.2f}MB;"
        f"ratio={pm.stall_free_capacity(0.005) / pm.stall_free_capacity(0.0):.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
