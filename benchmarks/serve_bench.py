"""Serving-engine benchmark: per-bucket latency/QPS + the chained-int8
throughput gate (``BENCH_serve.json``).

Drives the real ``repro.serve.DCLServingEngine`` (miniature calibrated
resnet_dcn, CPU interpret mode) through each configured shape bucket
twice — once on the production ``int8_chain`` datapath, once on the
per-layer fp32 kernel datapath — and records:

* p50/p99 request latency and QPS per bucket and datapath (measured
  wall clock: scaling signal only on this container);
* the MODELED per-request HBM traffic of both datapaths over the
  bucket's DCL layers (``tiling.dcl_chain_hbm_bytes`` /
  ``dcl_total_hbm_bytes`` at the engine's own resolved tile configs) —
  the analytic number the >= 1.3x throughput win is gated on, exactly
  like the ``hbm_chain_traffic_ratio`` gate of ``kernel_bench``.

The fp32 side is charged honestly per layer: the XLA offset pass (fp32
input read + offset write), then the zero-copy fp32 kernel traffic
(band + offsets re-read + weight blocks + fp32 emission).  The chain
side prices one fused layer (head quantize once, int8 band + int8
weight/offset-weight blocks, int8 emission, fp32 tail) — the model's
DCL blocks each emit int8 to their GroupNorm consumer, so ``layers=1``
per block is the deployed configuration.
"""
from __future__ import annotations

import time

import numpy as np

SERVE_THROUGHPUT_GATE = 1.3     # modeled chained-int8 win — analytic

# The measured QPS ratio does NOT realize the HBM win in interpret
# mode: there is no real band DMA to save, and the int8 path pays
# extra interpret-mode quantize/requant work, so chained wall clock on
# this container sits near (often below) the fp32 kernel path — the
# measured ratio here is ~0.9x where the modeled ratio is ~2.4x.  Like
# BWD_GATE_NOISE_TOLERANCE, the measured gate exists only to catch
# order-of-magnitude collapses of the chained serving path (a broken
# plan cache recompiling per request, an accidental per-step fallback
# to the reference ladder rung), so it allows the full interpret-mode
# inversion plus scheduler noise: measured >= GATE / TOLERANCE.
SERVE_GATE_NOISE_TOLERANCE = 3.0


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Exact sample percentile — kept as the parity reference for the
    histogram quantiles the bench now reports (tests assert the two
    agree to one bucket width)."""
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1,
              max(0, int(np.ceil(q * len(sorted_vals))) - 1))
    return sorted_vals[idx]


def _modeled_bytes(model_cfg, bucket: int, tiles: dict) -> tuple[int, int]:
    """(fp32_per_layer_bytes, chained_bytes) per request at ``bucket``,
    summed over the bucket's DCL layers at the engine's resolved tile
    configs."""
    from repro.core.tiling import (LayerShape, TileConfig,
                                   dcl_chain_hbm_bytes,
                                   dcl_total_hbm_bytes, out_hw)
    from repro.serve import bucket_layer_dims

    fp32 = chain = 0
    for name, d in bucket_layer_dims(model_cfg, bucket).items():
        th, tw, tc, tm = tiles[name]
        shape = LayerShape(h=d["h"], w=d["w"], c_in=d["c"], c_out=d["m"],
                           stride=d["stride"],
                           offset_bound=model_cfg.offset_bound)
        t = TileConfig(t_h=th, t_w=tw, t_n=tc, t_m=tm)
        ho, wo = out_hw(d["h"], d["w"], kernel_size=3, stride=d["stride"])
        plane = d["h"] * d["w"] * d["c"]
        offs = ho * wo * 18
        # offset pass (read input, write offsets) + fp32 kernel traffic
        fp32 += plane * 4 + offs * 4 \
            + dcl_total_hbm_bytes(shape, t, bytes_per_elem=4)
        chain += dcl_chain_hbm_bytes(shape, t, layers=1, chained=True)
    return fp32, chain


def records(*, smoke: bool = False) -> dict:
    """Run the serve benchmark; returns the ``BENCH_serve.json`` payload."""
    import jax

    from repro.models import resnet_dcn as R
    from repro.obs import Histogram
    from repro.quant.calibrate import calibrate_resnet_dcn
    from repro.serve import DCLServeConfig, DCLServingEngine

    buckets = (32,) if smoke else (32, 48)
    n_requests = 6 if smoke else 12
    slots = 2 if smoke else 4

    model_cfg = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=buckets[0], offset_bound=2.0,
        use_kernel=True)
    params = R.init_params(jax.random.PRNGKey(0), model_cfg)
    rng = np.random.RandomState(0)
    table = calibrate_resnet_dcn(
        params, model_cfg,
        [rng.randn(2, b, b, 3).astype(np.float32) for b in buckets])

    payload: dict = {
        "smoke": smoke,
        "slots": slots,
        "n_requests_per_bucket": n_requests,
        "quant_default": "int8_chain",
        "note": "wall times are interpret-mode (CPU) — scaling only; the "
                "gated throughput win is the modeled per-request HBM "
                "traffic ratio (fp32 per-layer / chained int8) at the "
                "engine's resolved tile plans; measured QPS is bounded "
                "only by SERVE_GATE_NOISE_TOLERANCE (see serve_bench.py)",
        "buckets": {},
    }

    # p50/p99 via the fixed-bucket obs histogram (no sample retention) —
    # a standalone instrument over the served requests, NOT the engine's
    # own serve_latency_seconds, which also counts the warm-up request.
    hist = Histogram("serve_bench_latency_seconds")

    ratios_modeled = []
    qps = {"int8_chain": {}, "fp32_kernel": {}}
    for bucket in buckets:
        rec: dict = {}
        imgs = [rng.randn(bucket, bucket, 3).astype(np.float32)
                for _ in range(n_requests)]
        for quant in ("int8_chain", "fp32_kernel"):
            eng = DCLServingEngine(
                params, model_cfg,
                DCLServeConfig(buckets=(bucket,), slots=slots, quant=quant),
                scale_table=table)
            eng.submit(imgs[0])
            eng.run_until_drained()          # warm the jit caches
            for im in imgs:
                eng.submit(im)
            t0 = time.perf_counter()
            eng.run_until_drained()
            dt = time.perf_counter() - t0
            served = [r for r in eng.completed[1:] if r.outcome == "ok"]
            assert len(served) == n_requests, eng.counters
            for r in served:
                hist.observe(r.latency_s(), bucket=str(bucket), quant=quant)
            key = "chain" if quant == "int8_chain" else "fp32"
            labels = dict(bucket=str(bucket), quant=quant)
            rec[f"p50_ms_{key}"] = hist.quantile(0.50, **labels) * 1e3
            rec[f"p99_ms_{key}"] = hist.quantile(0.99, **labels) * 1e3
            rec[f"qps_{key}"] = n_requests / dt
            qps[quant][bucket] = n_requests / dt
            if quant == "int8_chain":
                fp32_b, chain_b = _modeled_bytes(model_cfg, bucket,
                                                 eng.plans[bucket])
                rec["hbm_bytes_fp32_per_layer"] = fp32_b
                rec["hbm_bytes_chained"] = chain_b
                rec["throughput_ratio_modeled"] = fp32_b / chain_b
                ratios_modeled.append(fp32_b / chain_b)
                # Plan provenance (ISSUE 9): whether each DCL layer's
                # tiles came from the installed autotuner cache or the
                # analytic Sec. 3.2 chooser — cold caches are visible,
                # not silent.
                sources = eng.plan_sources.get(bucket, {})
                rec["plan_sources"] = dict(sources)
                rec["plans_tuned"] = sum(
                    1 for s in sources.values() if s == "tuned")
                rec["plans_total"] = len(sources)
                rec["plan_from_tuned_cache"] = bool(
                    rec["plans_tuned"])
        rec["throughput_ratio_measured"] = \
            rec["qps_chain"] / rec["qps_fp32"]
        payload["buckets"][str(bucket)] = rec

    payload["throughput_ratio_modeled_min"] = min(ratios_modeled)
    payload["throughput_ratio_measured_min"] = min(
        payload["buckets"][str(b)]["throughput_ratio_measured"]
        for b in buckets)
    payload["gate"] = SERVE_THROUGHPUT_GATE
    payload["gate_noise_tolerance"] = SERVE_GATE_NOISE_TOLERANCE
    return payload


def run(*, smoke: bool = False, payload: dict | None = None):
    """CSV rows for the driver (``name,us_per_call,derived``)."""
    payload = payload or records(smoke=smoke)
    rows = []
    for bucket, rec in payload["buckets"].items():
        rows.append(
            f"serve/bucket{bucket},"
            f"{rec['p50_ms_chain'] * 1e3:.0f},"
            f"p50={rec['p50_ms_chain']:.1f}ms;p99={rec['p99_ms_chain']:.1f}"
            f"ms;qps={rec['qps_chain']:.1f};modeled_ratio="
            f"{rec['throughput_ratio_modeled']:.2f}x;"
            f"plans_tuned={rec.get('plans_tuned', 0)}/"
            f"{rec.get('plans_total', 0)}")
    return rows
