"""Paper Fig. 8 — speedup of the bounded-RF accelerator over the
conventional systolic accelerator [22], for N in {128, 256, 512}."""
from __future__ import annotations

from repro.core import perf_model as pm


def run() -> list[str]:
    rows = []
    for n in (128, 256, 512):
        wl = pm.DCLWorkload(n=n, m=n)
        ours = pm.cycles_ours(wl, 0.005)
        conv = pm.cycles_conventional(wl, 0.0)
        s = pm.speedup(n, 0.005)
        rows.append(
            f"accelerator_speed/N={n},0,"
            f"cycles_ours={ours:.3e};cycles_conv={conv:.3e};"
            f"speedup={s:.2f}x")
    rows.append("accelerator_speed/paper_claim,0,"
                "5.28x(N=128)..17.25x(N=512)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
