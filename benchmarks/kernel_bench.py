"""Kernel micro-benchmarks (CPU wall-time is NOT the target metric —
interpret-mode timings validate the algorithmic scaling only; TPU perf
is covered by the §Roofline dry-run).  Also reports the analytic VMEM
footprints / CTC from the Eq. 6/7 tile model and the modeled HBM bytes
of the two DCL dataflows (materialized-band vs zero-copy) so the perf
trajectory is tracked across PRs (see ``run.py`` / BENCH_kernels.json).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.perf_model import dataflow_traffic_report
from repro.core.tiling import (LayerShape, PAPER_TILES, choose_kernel_tiles,
                               choose_tiles, evaluate_tile)
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.time() - t0) / reps * 1e6


def records(*, smoke: bool = False) -> list[dict]:
    """Structured per-kernel records: wall time (interpret mode) and the
    modeled HBM traffic of both DCL dataflows for the measured shape."""
    out: list[dict] = []
    key = jax.random.PRNGKey(0)
    shapes = [(16, 16, 32, 32)] if smoke else \
        [(32, 32, 64, 64), (32, 32, 128, 128)]
    for (h, w, c, m) in shapes:
        x = jax.random.normal(key, (1, h, w, c), jnp.float32)
        offs = jax.random.normal(jax.random.fold_in(key, 1),
                                 (1, h, w, 18), jnp.float32) * 2
        wgt = jax.random.normal(jax.random.fold_in(key, 2),
                                (9, c, m), jnp.float32) * 0.1
        t_zero = _time(lambda a, b, ww: ops.deform_conv(
            a, b, ww, offset_bound=2.0, tile_h=8,
            dataflow="zero_copy"), x, offs, wgt)
        t_banded = _time(lambda a, b, ww: ops.deform_conv(
            a, b, ww, offset_bound=2.0, tile_h=8,
            dataflow="banded"), x, offs, wgt)
        t_unbounded = _time(lambda a, b, ww: ops.deform_conv(
            a, b, ww), x, offs, wgt)
        rep = dataflow_traffic_report(h=h, w=w, c=c, m=m, batch=1,
                                      tile_h=8, offset_bound=2.0)
        out.append({
            "name": f"deform_conv_fused_{c}c",
            "us_zero_copy": t_zero,
            "us_banded": t_banded,
            "us_unbounded_xla": t_unbounded,
            "hbm_bytes_zero_copy": rep["zero_copy_bytes"],
            "hbm_bytes_materialized_band": rep["materialized_band_bytes"],
            "hbm_traffic_ratio": rep["ratio"],
            "tiles": str(rep["tiles"]),
        })
    return out


def run(*, smoke: bool = False,
        kernel_records: list[dict] | None = None) -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    # deformable conv: zero-copy vs banded vs unbounded XLA-gather path
    # (pass kernel_records to avoid re-timing — run.py shares one
    # records() call between the CSV rows and BENCH_kernels.json)
    for r in kernel_records if kernel_records is not None \
            else records(smoke=smoke):
        rows.append(
            f"kernel/{r['name']},{r['us_zero_copy']:.0f},"
            f"interpret-mode; banded={r['us_banded']:.0f}us;"
            f"unbounded_xla={r['us_unbounded_xla']:.0f}us;"
            f"hbm_model_zero_copy={r['hbm_bytes_zero_copy'] / 1e6:.2f}MB;"
            f"hbm_model_banded="
            f"{r['hbm_bytes_materialized_band'] / 1e6:.2f}MB;"
            f"traffic_ratio={r['hbm_traffic_ratio']:.2f}x")
    # flash attention kernel (interpret) vs dense reference
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    for s in (128,) if smoke else (128, 256):
        q = jax.random.normal(key, (1, s, 2, 2, 32), jnp.float32)
        kk = jax.random.normal(jax.random.fold_in(key, 4), (1, s, 2, 32),
                               jnp.float32)
        vv = jax.random.normal(jax.random.fold_in(key, 5), (1, s, 2, 32),
                               jnp.float32)
        t_fl = _time(lambda a, b_, c_: flash_attention(
            a, b_, c_, block_q=64, block_k=64), q, kk, vv)
        t_dn = _time(lambda a, b_, c_: flash_attention_ref(a, b_, c_),
                     q, kk, vv)
        # HBM bytes the dense path writes for scores vs flash (never):
        score_mb = 4 * 2 * 2 * s * s * 4 / 1e6
        rows.append(f"kernel/flash_attn_s{s},{t_fl:.0f},"
                    f"dense_ref={t_dn:.0f}us;score_traffic_saved="
                    f"{score_mb:.1f}MB")
    # matmul kernel
    for mkn in [(256, 256, 256)] if smoke else \
            [(256, 256, 256), (512, 512, 512)]:
        a = jax.random.normal(key, mkn[:2], jnp.float32)
        b = jax.random.normal(key, mkn[1:], jnp.float32)
        t = _time(lambda x_, y_: ops.matmul(x_, y_), a, b)
        t_ref = _time(lambda x_, y_: ref.matmul_ref(x_, y_), a, b)
        rows.append(f"kernel/matmul_{mkn[0]},{t:.0f},xla_ref={t_ref:.0f}us")
    # tile model summary for the DCL hot spots (ResNet-50 stages)
    for n in (128,) if smoke else (128, 256, 512):
        s = LayerShape(h=56, w=56, c_in=n, c_out=n, offset_bound=2.0)
        c_ = choose_tiles(s)
        p = evaluate_tile(s, PAPER_TILES)
        kt = choose_kernel_tiles(s)
        rows.append(
            f"kernel/tile_model_N={n},0,"
            f"chosen={c_.tile};ctc={c_.ctc:.1f};vmem={c_.vmem_bytes >> 20}MiB;"
            f"attainable={c_.attainable_flops / 1e12:.0f}TF;"
            f"paper_tile_ctc={p.ctc:.1f};"
            f"kernel_tiles=({kt.tile_h},{kt.tile_w},{kt.tile_c},{kt.tile_m})")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
