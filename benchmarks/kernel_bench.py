"""Kernel micro-benchmarks (CPU wall-time is NOT the target metric —
interpret-mode timings validate the algorithmic scaling only; TPU perf
is covered by the §Roofline dry-run).  Also reports the analytic VMEM
footprints / CTC from the Eq. 6/7 tile model for the shipped kernels."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.tiling import LayerShape, choose_tiles, evaluate_tile, PAPER_TILES
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.time() - t0) / reps * 1e6


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    # deformable conv: bounded Pallas path vs unbounded XLA-gather path
    for (h, w, c, m) in [(32, 32, 64, 64), (32, 32, 128, 128)]:
        x = jax.random.normal(key, (1, h, w, c), jnp.float32)
        offs = jax.random.normal(jax.random.fold_in(key, 1),
                                 (1, h, w, 18), jnp.float32) * 2
        wgt = jax.random.normal(jax.random.fold_in(key, 2),
                                (9, c, m), jnp.float32) * 0.1
        t_bounded = _time(lambda a, b, ww: ops.deform_conv(
            a, b, ww, offset_bound=2.0, tile_h=8), x, offs, wgt)
        t_unbounded = _time(lambda a, b, ww: ops.deform_conv(
            a, b, ww), x, offs, wgt)
        rows.append(f"kernel/deform_conv_fused_{c}c,{t_bounded:.0f},"
                    f"interpret-mode; unbounded_xla={t_unbounded:.0f}us")
    # flash attention kernel (interpret) vs dense reference
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    for s in (128, 256):
        q = jax.random.normal(key, (1, s, 2, 2, 32), jnp.float32)
        kk = jax.random.normal(jax.random.fold_in(key, 4), (1, s, 2, 32),
                               jnp.float32)
        vv = jax.random.normal(jax.random.fold_in(key, 5), (1, s, 2, 32),
                               jnp.float32)
        t_fl = _time(lambda a, b_, c_: flash_attention(
            a, b_, c_, block_q=64, block_k=64), q, kk, vv)
        t_dn = _time(lambda a, b_, c_: flash_attention_ref(a, b_, c_),
                     q, kk, vv)
        # HBM bytes the dense path writes for scores vs flash (never):
        score_mb = 4 * 2 * 2 * s * s * 4 / 1e6
        rows.append(f"kernel/flash_attn_s{s},{t_fl:.0f},"
                    f"dense_ref={t_dn:.0f}us;score_traffic_saved="
                    f"{score_mb:.1f}MB")
    # matmul kernel
    for mkn in [(256, 256, 256), (512, 512, 512)]:
        a = jax.random.normal(key, mkn[:2], jnp.float32)
        b = jax.random.normal(key, mkn[1:], jnp.float32)
        t = _time(lambda x_, y_: ops.matmul(x_, y_), a, b)
        t_ref = _time(lambda x_, y_: ref.matmul_ref(x_, y_), a, b)
        rows.append(f"kernel/matmul_{mkn[0]},{t:.0f},xla_ref={t_ref:.0f}us")
    # tile model summary for the DCL hot spots (ResNet-50 stages)
    for n in (128, 256, 512):
        s = LayerShape(h=56, w=56, c_in=n, c_out=n, offset_bound=2.0)
        c_ = choose_tiles(s)
        p = evaluate_tile(s, PAPER_TILES)
        rows.append(
            f"kernel/tile_model_N={n},0,"
            f"chosen={c_.tile};ctc={c_.ctc:.1f};vmem={c_.vmem_bytes >> 20}MiB;"
            f"attainable={c_.attainable_flops / 1e12:.0f}TF;"
            f"paper_tile_ctc={p.ctc:.1f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
