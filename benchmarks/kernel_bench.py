"""Kernel micro-benchmarks (CPU wall-time is NOT the target metric —
interpret-mode timings validate the algorithmic scaling only; TPU perf
is covered by the §Roofline dry-run).  Also reports the analytic VMEM
footprints / CTC from the Eq. 6/7 tile model and the modeled HBM bytes
of the two DCL dataflows (materialized-band vs zero-copy), forward AND
backward, so the perf trajectory is tracked across PRs (see ``run.py``
/ BENCH_kernels.json).

Timing is best-of-N (min): interpret-mode wall time on a shared CI
machine is heavily right-tailed, and the PR-2 "128-channel zero-copy
regression" turned out to be mean-of-3 noise on top of a hand-pinned
``tile_h=8`` — the chooser's own tiles (taller row tiles, fewer grid
steps) win once timed robustly.  ``run.py`` gates zero-copy <= banded
on these records.

Backward entries (``us_bwd_*``) time one full ``jax.vjp`` pullback —
forward + the fused backward kernel of ``kernels.deform_conv_bwd`` for
the bounded path, forward + XLA autodiff for the unbounded gather
reference — i.e. the per-layer cost a training step actually pays.

int8 entries (``us_q_*`` / ``hbm_bytes_q_*``) time the quantized
zero-copy kernel (``ops.deform_conv(precision="int8")``, dynamic
absmax scales + fused dequant included in the timed call) and record
the modeled quantized-dataflow traffic — the trajectory JSON carries
both precisions so the >= 3x int8 traffic drop is tracked across PRs.

Chain entries (``--chain`` / ``chain=True``: ``us_chain_*`` /
``hbm_bytes_chain_*``) run two back-to-back DCLs through the chained
datapath (``ops.deform_conv_chain`` — fused in-kernel offset conv,
int8 emission consumed verbatim by the second layer) vs the per-layer
int8 datapath (XLA offset pass + ``precision="int8"`` kernel + fp32
output each), and record the modeled 2-layer traffic of both —
``run.py`` gates the modeled chained reduction >= 1.3x.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.perf_model import dataflow_traffic_report
from repro.core.tiling import (LayerShape, PAPER_TILES, choose_kernel_tiles,
                               choose_tiles, evaluate_tile)
from repro.kernels import ops, ref

BANDED_TILE_H = 8     # the legacy banded path's hand-tiled default
CHAIN_TRAFFIC_GATE = 1.3   # modeled chained-vs-per-layer reduction floor


def _time(fn, *args, reps=5):
    """Best-of-``reps`` wall time in us (first call warms the cache)."""
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        out = fn(*args)
        jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
        best = min(best, time.time() - t0)
    return best * 1e6


def _grad_fn(forward):
    """loss = sum(y): the pullback cotangent is all-ones, exercising
    every (d_input, d_offsets, d_weights) path."""
    return jax.jit(jax.grad(
        lambda x, o, w: jnp.sum(forward(x, o, w)), argnums=(0, 1, 2)))


def records(*, smoke: bool = False, precision: str = "both",
            chain: bool = False) -> list[dict]:
    """Structured per-kernel records: forward and backward wall time
    (interpret mode, best-of-N) and the modeled HBM traffic of both DCL
    dataflows for the measured shape.

    ``precision`` selects the datapaths: ``"fp32"`` (the PR-1/2
    records), ``"int8"`` (the quantized zero-copy kernel — ``us_q_*``
    wall time and ``hbm_bytes_q_*`` modeled traffic, dequant epilogue
    included in the timed call), or ``"both"`` (one record carrying
    both sets, the default — what CI uploads).  ``chain`` adds the
    chained two-layer records (``us_chain_*`` / ``hbm_bytes_chain_*``,
    needs an int8 precision).
    """
    if precision not in ("fp32", "int8", "both"):
        raise ValueError(f"unknown precision {precision!r}")
    if chain and precision == "fp32":
        raise ValueError("--chain records run the int8 chained datapath; "
                         "pass --precision int8 or both")
    out: list[dict] = []
    key = jax.random.PRNGKey(0)
    shapes = [(16, 16, 32, 32)] if smoke else \
        [(32, 32, 64, 64), (32, 32, 128, 128)]
    for (h, w, c, m) in shapes:
        x = jax.random.normal(key, (1, h, w, c), jnp.float32)
        offs = jax.random.normal(jax.random.fold_in(key, 1),
                                 (1, h, w, 18), jnp.float32) * 2
        wgt = jax.random.normal(jax.random.fold_in(key, 2),
                                (9, c, m), jnp.float32) * 0.1
        # Batch-2 pair for the Megacore-split backward records (the
        # cores=2 grid needs an even batch).
        x2 = jax.random.normal(jax.random.fold_in(key, 3), (2, h, w, c),
                               jnp.float32)
        offs2 = jax.random.normal(jax.random.fold_in(key, 4),
                                  (2, h, w, 18), jnp.float32) * 2
        # Traffic model at the PR-1 tile_h=8 convention so the recorded
        # ratios stay comparable across BENCH_kernels.json revisions
        # (wall times use the chooser's own tiles — recorded
        # separately as tiles_timed_zero_copy).
        rep = dataflow_traffic_report(h=h, w=w, c=c, m=m, batch=1,
                                      tile_h=BANDED_TILE_H, offset_bound=2.0)
        # Megacore records model the same shape at the mc bench batch
        # (cores=2 needs an even batch; at batch=1 per-core == total).
        rep_mc = dataflow_traffic_report(h=h, w=w, c=c, m=m, batch=2,
                                         tile_h=BANDED_TILE_H,
                                         offset_bound=2.0, cores=2)
        kt = choose_kernel_tiles(
            LayerShape(h=h, w=w, c_in=c, c_out=m, offset_bound=2.0), batch=1)
        rec: dict = {
            "name": f"deform_conv_fused_{c}c",
            "tiles_traffic_model": str(rep["tiles"]),
            "tiles_timed_zero_copy":
                f"({kt.tile_h},{kt.tile_w},{kt.tile_c},{kt.tile_m})",
            "tiles_timed_banded": f"tile_h={BANDED_TILE_H}",
        }
        if precision in ("fp32", "both"):
            # zero-copy runs at the Sec. 3.2 chooser's own tiles (the
            # product path); banded keeps its legacy hand-tiled default.
            # reps=7: these two records feed run.py's regression gate.
            rec.update({
                "us_zero_copy": _time(lambda a, b, ww: ops.deform_conv(
                    a, b, ww, offset_bound=2.0, dataflow="zero_copy"),
                    x, offs, wgt, reps=7),
                "us_banded": _time(lambda a, b, ww: ops.deform_conv(
                    a, b, ww, offset_bound=2.0, tile_h=BANDED_TILE_H,
                    dataflow="banded"), x, offs, wgt, reps=7),
                "us_unbounded_xla": _time(lambda a, b, ww: ops.deform_conv(
                    a, b, ww), x, offs, wgt),
                # reps=7: the bwd records feed run.py's backward gates.
                "us_bwd_zero_copy": _time(
                    _grad_fn(lambda a, b, ww: ops.deform_conv(
                        a, b, ww, offset_bound=2.0, dataflow="zero_copy")),
                    x, offs, wgt, reps=7),
                "us_bwd_xla_ref": _time(
                    _grad_fn(lambda a, b, ww: ref.deform_conv_fused_ref(
                        a, b, ww, offset_bound=2.0)), x, offs, wgt,
                    reps=7),
                # Megacore split (PR 4): cores=2 backward on a batch-2
                # input vs the cores=1 baseline at the SAME batch —
                # interpret mode serializes the core subgrids, so equal
                # wall time here just validates the split adds no
                # overhead; the per-core traffic drop is the modeled
                # number.
                "us_bwd_mc_zero_copy": _time(
                    _grad_fn(lambda a, b, ww: ops.deform_conv(
                        a, b, ww, offset_bound=2.0, cores=2)),
                    x2, offs2, wgt, reps=7),
                "us_bwd_mc_baseline": _time(
                    _grad_fn(lambda a, b, ww: ops.deform_conv(
                        a, b, ww, offset_bound=2.0, cores=1)),
                    x2, offs2, wgt, reps=7),
                "bwd_mc_batch": 2,
                "bwd_mc_cores": 2,
                "hbm_bytes_zero_copy": rep["zero_copy_bytes"],
                "hbm_bytes_materialized_band":
                    rep["materialized_band_bytes"],
                "hbm_traffic_ratio": rep["ratio"],
                "hbm_bytes_bwd_zero_copy": rep["zero_copy_bwd_bytes"],
                "hbm_bytes_bwd_materialized_band":
                    rep["materialized_band_bwd_bytes"],
                "hbm_bwd_traffic_ratio": rep["bwd_ratio"],
                "hbm_train_traffic_ratio": rep["train_ratio"],
                "hbm_bytes_bwd_per_core_mc":
                    rep_mc["zero_copy_bwd_bytes_per_core"],
                "hbm_bwd_per_core_ratio": rep_mc["bwd_per_core_ratio"],
            })
        if precision in ("int8", "both"):
            ktq = choose_kernel_tiles(
                LayerShape(h=h, w=w, c_in=c, c_out=m, offset_bound=2.0),
                batch=1, dtype="int8", objective="forward")
            rec.update({
                "us_q_zero_copy": _time(lambda a, b, ww: ops.deform_conv(
                    a, b, ww, offset_bound=2.0, precision="int8"),
                    x, offs, wgt),
                "hbm_bytes_q_zero_copy": rep["zero_copy_bytes_q"],
                "hbm_bytes_q_total": rep["zero_copy_total_bytes_q"],
                "hbm_q_traffic_ratio_vs_fp32": rep["q_ratio"],
                "hbm_q_total_ratio_vs_fp32": rep["q_total_ratio"],
                "tiles_timed_int8":
                    f"({ktq.tile_h},{ktq.tile_w},{ktq.tile_c},"
                    f"{ktq.tile_m})",
            })
        if chain:
            rec.update(_chain_records(x, wgt, h=h, w=w, c=c, m=m, rep=rep))
        out.append(rec)
    if smoke and precision in ("fp32", "both"):
        out.append(_mc128_smoke_record(key))
    return out


def _mc128_smoke_record(key) -> dict:
    """The known-bad 128-channel Megacore backward configuration at
    smoke scale: the traffic model prices the cores=2 split ~1.92x
    better per core, but measured wall time runs SLOWER (ROADMAP
    anomaly) — the smoke bench carries the pair so the divergence
    report always includes it.  The name deliberately does NOT start
    with ``deform_conv_fused_``: this record feeds the divergence
    report, not the regression gates (the full bench's 128c record is
    gated)."""
    h, w, c, m = 16, 16, 128, 128
    x2 = jax.random.normal(jax.random.fold_in(key, 7), (2, h, w, c),
                           jnp.float32)
    offs2 = jax.random.normal(jax.random.fold_in(key, 8),
                              (2, h, w, 18), jnp.float32) * 2
    wgt = jax.random.normal(jax.random.fold_in(key, 9),
                            (9, c, m), jnp.float32) * 0.1
    rep_mc = dataflow_traffic_report(h=h, w=w, c=c, m=m, batch=2,
                                     tile_h=BANDED_TILE_H,
                                     offset_bound=2.0, cores=2)
    return {
        "name": "dcl_bwd_megacore_128c",
        "us_bwd_mc_zero_copy": _time(
            _grad_fn(lambda a, b, ww: ops.deform_conv(
                a, b, ww, offset_bound=2.0, cores=2)),
            x2, offs2, wgt, reps=3),
        "us_bwd_mc_baseline": _time(
            _grad_fn(lambda a, b, ww: ops.deform_conv(
                a, b, ww, offset_bound=2.0, cores=1)),
            x2, offs2, wgt, reps=3),
        "bwd_mc_batch": 2,
        "bwd_mc_cores": 2,
        "hbm_bwd_per_core_ratio": rep_mc["bwd_per_core_ratio"],
    }


def tune_records(*, smoke: bool = False,
                 cache_path: str | None = None) -> list[dict]:
    """ISSUE 9 (``run.py --tune``): run the measured-time autotuner on
    the bench configs, persist the winner cache, and verify end-to-end
    that a cold dispatch (tiles=None) resolved under the installed
    cache actually reads the tuned entries.

    Two configs: the flagged ``dcl_bwd_megacore_128c`` backward (the
    acceptance target — the analytic chooser dispatches cores=2 there,
    the tuner measures the cores sweep + dw-flush cadence and wins),
    and the smoke forward shape the serving engine's plans resolve.
    ``tuned_vs_analytic_ratio`` >= 1 up to re-measure noise by
    construction (the analytic pick is always a candidate); ``run.py``
    gates it with ``TUNE_GATE_NOISE_TOLERANCE``.
    """
    from repro.kernels import plan
    from repro.tune import TileCache, tile_cache_scope, tune_deform_conv

    reps = 2 if smoke else 3
    max_candidates = 4 if smoke else 8
    cache = TileCache()
    out: list[dict] = []

    # -- the acceptance config: the flagged Megacore 128c backward ----
    mc = tune_deform_conv(
        h=16, w=16, c=128, m=128, batch=2, offset_bound=2.0,
        objective="training", cores=2, sweep_cores=(1, 2), reps=reps,
        max_candidates=max_candidates, cache=cache)
    # -- the smoke forward shape (what the serving plans resolve) -----
    fwd = tune_deform_conv(
        h=16, w=16, c=32, m=32, batch=1, offset_bound=2.0,
        objective="forward", cores=1, reps=reps,
        max_candidates=max_candidates, cache=cache)

    if cache_path:
        cache.save(cache_path)

    # -- applied end-to-end: dispatch with tiles=None under the cache
    # and confirm resolve_tiles served the tuned entries (tuned_hits).
    key = jax.random.PRNGKey(23)
    h, w, c, m = 16, 16, 128, 128
    x2 = jax.random.normal(jax.random.fold_in(key, 7), (2, h, w, c),
                           jnp.float32)
    offs2 = jax.random.normal(jax.random.fold_in(key, 8),
                              (2, h, w, 18), jnp.float32) * 2
    wgt = jax.random.normal(jax.random.fold_in(key, 9),
                            (9, c, m), jnp.float32) * 0.1
    rec_cores = mc["best"]["cores"]
    with tile_cache_scope(cache):
        plan.reset_tuned_stats()
        applied_us = _time(
            _grad_fn(lambda a, b, ww: ops.deform_conv(
                a, b, ww, offset_bound=2.0, cores=rec_cores)),
            x2, offs2, wgt, reps=reps)
        stats = plan.tile_cache_info()
    applied_hits = stats.get("tuned_hits", 0)

    out.append({
        "name": "tuned_dcl_bwd_megacore_128c",
        "tuned_us_bwd": mc["best"]["us"],
        "analytic_us_bwd": mc["analytic"]["us"],
        "tuned_vs_analytic_ratio": mc["tuned_vs_analytic_ratio"],
        "tuned_tiles": mc["best"]["tiles"],
        "tuned_cores": mc["best"]["cores"],
        "tuned_dw_flush_every_step": mc["best"]["dw_flush_every_step"],
        "analytic_tiles": mc["analytic"]["tiles"],
        "analytic_cores": mc["analytic"]["cores"],
        "per_cores": mc["per_cores"],
        "applied_us_bwd": applied_us,
        "applied_tuned_hits": applied_hits,
        "platform": mc["platform"],
        "n_candidates": mc["n_candidates"],
        "reps": reps,
        "tuner_cache_path": cache_path,
    })
    out.append({
        "name": "tuned_deform_conv_fused_32c",
        "tuned_us_fwd": fwd["best"]["us"],
        "analytic_us_fwd": fwd["analytic"]["us"],
        "tuned_vs_analytic_ratio": fwd["tuned_vs_analytic_ratio"],
        "tuned_tiles": fwd["best"]["tiles"],
        "analytic_tiles": fwd["analytic"]["tiles"],
        "platform": fwd["platform"],
        "n_candidates": fwd["n_candidates"],
        "reps": reps,
        "tuner_cache_path": cache_path,
    })
    # The forward tuner sweeps the serving quant modes by default
    # (ISSUE 10 satellite) — record each quant-keyed winner so the
    # trajectory JSON carries the int8/int8_chain tuned plans and the
    # tuned >= analytic gate covers them too.
    for qmode, qrec in fwd.get("quant_sweep", {}).items():
        out.append({
            "name": f"tuned_deform_conv_fused_32c_{qmode}",
            "quant": qmode,
            "tuned_us_fwd": qrec["best"]["us"],
            "analytic_us_fwd": qrec["analytic"]["us"],
            "tuned_vs_analytic_ratio": qrec["tuned_vs_analytic_ratio"],
            "tuned_tiles": qrec["best"]["tiles"],
            "analytic_tiles": qrec["analytic"]["tiles"],
            "platform": qrec["platform"],
            "n_candidates": qrec["n_candidates"],
            "reps": reps,
            "tuner_cache_path": cache_path,
        })
    return out


SPATIAL_MODELED_GATE = 1.5   # modeled per-device win floor at 2 shards


def spatial_records(*, smoke: bool = False,
                    shards: tuple[int, ...] = (1, 2, 4)) -> list[dict]:
    """ISSUE 10 (``run.py --spatial``): spatial-sharding records.

    One *measured* record — the bounded forward at 1/2/4 height shards
    over real (or ``--xla_force_host_platform_device_count``-virtual)
    devices, ``us_spatial_{s}shard`` wall time plus the exchanged
    ``halo_bytes_{s}shard`` — shard counts exceeding the available
    device count are skipped with a note, never faked.  And one
    *modeled* record: ``perf_model.spatial_sharding_report`` on the
    megapixel-class default shape, carrying the per-device traffic
    ratio and modeled speedup that ``run.py`` gates at
    ``SPATIAL_MODELED_GATE`` for 2 shards.
    """
    import numpy as np
    from jax.sharding import Mesh

    from repro.core.perf_model import spatial_sharding_report
    from repro.core.tiling import spatial_halo_bytes, spatial_halo_rows
    from repro.distributed.sharding import use_rules

    h, w, c, m = (16, 16, 16, 16) if smoke else (32, 32, 64, 64)
    bound = 2.0
    key = jax.random.PRNGKey(17)
    x = jax.random.normal(key, (1, h, w, c), jnp.float32)
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, h, w, 18), jnp.float32) * 2
    wgt = jax.random.normal(jax.random.fold_in(key, 2),
                            (9, c, m), jnp.float32) * 0.1
    shape = LayerShape(h=h, w=w, c_in=c, c_out=m, offset_bound=bound)
    rec: dict = {
        "name": f"dcl_spatial_{c}c",
        "offset_bound": bound,
        "halo_rows": spatial_halo_rows(kernel_size=3, dilation=1,
                                       offset_bound=bound),
        "devices_available": jax.device_count(),
        "us_unsharded": _time(
            lambda a, b, ww: ops.deform_conv(a, b, ww, offset_bound=bound),
            x, offs, wgt),
    }
    skipped = []
    for s in shards:
        if s > jax.device_count():
            skipped.append(s)
            continue
        mesh = Mesh(np.asarray(jax.devices()[:s]), ("model",))
        with use_rules(mesh=mesh):
            rec[f"us_spatial_{s}shard"] = _time(
                lambda a, b, ww: ops.deform_conv(
                    a, b, ww, offset_bound=bound, shard_spatial=True),
                x, offs, wgt)
        rec[f"halo_bytes_{s}shard"] = spatial_halo_bytes(shape, shards=s)
    if skipped:
        rec["note"] = (
            f"shard counts {skipped} skipped: only "
            f"{jax.device_count()} device(s) — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=4 (the "
            f"spatial-4dev CI job) for the full sweep")

    modeled = spatial_sharding_report()
    mrec: dict = {"name": "dcl_spatial_modeled_megapixel"}
    for k, v in modeled.items():
        if k == "shape":
            mrec["shape"] = f"{v.h}x{v.w}x{v.c_in}->{v.c_out}" \
                            f"@B={v.offset_bound}"
        elif k.startswith("tiles_"):
            mrec[k] = str(v)
        else:
            mrec[k] = v
    return [rec, mrec]


def obs_overhead_record(*, reps: int = 7) -> dict:
    """Cost of the ISSUE-8 dispatch instrumentation on one bounded
    deform_conv call: untraced (no hook) vs a ``DispatchRecorder`` with
    an enabled tracer vs one resolving the (default, disabled) tracer —
    the no-op span path.  Overheads are expected to vanish into
    interpret-mode noise; the record exists so a future accidental
    hot-path allocation shows up in BENCH_kernels.json."""
    from repro.obs import (DispatchRecorder, MetricsRegistry, Tracer,
                           tracer_scope)

    key = jax.random.PRNGKey(11)
    h, w, c, m = 16, 16, 32, 32
    x = jax.random.normal(key, (1, h, w, c), jnp.float32)
    offs = jax.random.normal(jax.random.fold_in(key, 1),
                             (1, h, w, 18), jnp.float32) * 2
    wgt = jax.random.normal(jax.random.fold_in(key, 2),
                            (9, c, m), jnp.float32) * 0.1

    def call(a, b, ww):
        return ops.deform_conv(a, b, ww, offset_bound=2.0)

    us_untraced = _time(call, x, offs, wgt, reps=reps)

    traced = Tracer(enabled=True)
    rec_traced = DispatchRecorder(registry=MetricsRegistry(),
                                  tracer=traced)
    with ops.dispatch_hook_scope(rec_traced):
        us_traced = _time(call, x, offs, wgt, reps=reps)

    # Recorder installed but the resolved tracer is disabled — spans
    # are the shared no-op; only the histogram/counter update remains.
    rec_disabled = DispatchRecorder(registry=MetricsRegistry())
    with tracer_scope(Tracer(enabled=False)), \
            ops.dispatch_hook_scope(rec_disabled):
        us_disabled = _time(call, x, offs, wgt, reps=reps)

    return {
        "name": "obs_dispatch_overhead",
        "us_dispatch_untraced": us_untraced,
        "us_dispatch_traced": us_traced,
        "us_dispatch_disabled_tracer": us_disabled,
        "overhead_ratio_traced": us_traced / us_untraced,
        "overhead_ratio_disabled": us_disabled / us_untraced,
    }


def divergence_records(recs: list[dict]) -> dict:
    """Modeled-vs-measured divergence report over the bench records
    (``repro.obs.DivergenceTracker.record_pair``): for every measured
    shape, the forward zero-copy-vs-banded pair and the Megacore
    backward pair (modeled per-core traffic drop vs measured cores=2 /
    cores=1 speedup — the 128c case is the known anomaly, flagged
    ``anomalous``)."""
    from repro.obs import DivergenceTracker

    tracker = DivergenceTracker()
    for r in recs:
        name = r.get("name", "")
        if "us_zero_copy" in r and "hbm_traffic_ratio" in r:
            tracker.record_pair(
                f"{name}/fwd_zero_copy_vs_banded",
                modeled_ratio=r["hbm_traffic_ratio"],
                measured_ratio=r["us_banded"] / r["us_zero_copy"],
                note="modeled = banded/zero-copy HBM bytes; measured = "
                     "banded/zero-copy wall time (interpret mode)")
        if "us_bwd_mc_zero_copy" in r and "hbm_bwd_per_core_ratio" in r:
            tracker.record_pair(
                f"{name}/bwd_megacore_split",
                modeled_ratio=r["hbm_bwd_per_core_ratio"],
                measured_ratio=(r["us_bwd_mc_baseline"]
                                / r["us_bwd_mc_zero_copy"]),
                note="modeled = per-core backward traffic drop of the "
                     "cores=2 split; measured = cores=1/cores=2 wall "
                     "time — interpret mode serializes the cores, so "
                     "the 128c case measures slower (ROADMAP anomaly)")
    # ISSUE 9: when the autotuner ran (--tune), re-record the anomalous
    # Megacore pair post-tuning — same ratio semantics (cores=1 wall /
    # cores=2 wall), but at each side's TUNED plan.  The tuner resolves
    # the anomaly by *recommending* cores=1 on this platform (interpret
    # mode serializes the core subgrids, so the modeled per-core win
    # cannot materialize as wall time); the pair keeps its modeled
    # ratio and documents the resolution instead of a stale flag.
    tuned = next((r for r in recs
                  if r.get("name") == "tuned_dcl_bwd_megacore_128c"), None)
    if tuned is not None and "per_cores" in tuned:
        pc = tuned["per_cores"]
        if "1" in pc and "2" in pc:
            ratio_post = pc["1"]["us"] / pc["2"]["us"]
            for p in tracker.pairs:
                if p["name"] != "dcl_bwd_megacore_128c/bwd_megacore_split":
                    continue
                tracker.annotate_pair(
                    p["name"],
                    measured_ratio_post_tuning=ratio_post,
                    anomalous_post_tuning=bool(
                        p["modeled_ratio"] > 1.0 > ratio_post),
                    tuned_recommended_cores=tuned["tuned_cores"],
                    tuned_vs_analytic_ratio=
                        tuned["tuned_vs_analytic_ratio"],
                    tuned_note="autotuner resolution: measured best "
                               "pick is cores="
                               f"{tuned['tuned_cores']} (ratio "
                               f"{tuned['tuned_vs_analytic_ratio']:.2f}x "
                               "vs the analytic cores=2 dispatch) — on "
                               "this platform the split's modeled "
                               "per-core win cannot show up in wall "
                               "time, so the tuner sidesteps it rather "
                               "than flipping the measured ratio")
    return tracker.report()


def _chain_records(x, wgt, *, h, w, c, m, rep) -> dict:
    """Two back-to-back DCLs: chained int8 datapath vs per-layer int8.

    The chained pair runs ``ops.deform_conv_chain`` twice — layer 1
    emits int8 on layer 2's activation grid and layer 2 consumes the
    tensor verbatim (self-consistent ``y_scale = x_scale``, so the
    chain is shape- and scale-closed at c == m).  The per-layer pair
    runs the XLA offset conv + ``precision="int8"`` kernel + fp32
    emission each — exactly what a model without chaining pays twice.
    Wall times are interpret-mode scaling checks; the modeled
    ``hbm_bytes_chain_*`` numbers carry the acceptance ratio.
    """
    from repro.core.deform_conv import conv2d
    from repro.quant.qtypes import compute_scale

    if c != m:              # chaining hands the tensor over verbatim —
        return {}           # skip (don't kill the whole bench) off-square
    key = jax.random.PRNGKey(17)
    k2 = 9
    woff = jax.random.normal(key, (k2, c, 2 * k2), jnp.float32) * 0.05
    boff = jnp.zeros((2 * k2,), jnp.float32)
    bd = jnp.zeros((m,), jnp.float32)
    sx = float(compute_scale(x))

    def chain2(a):
        y1 = ops.deform_conv_chain(a, wgt, woff, boff, bd,
                                   offset_bound=2.0, x_scale=sx,
                                   y_scale=sx, emit="int8")
        return ops.deform_conv_chain(y1, wgt, woff, boff, bd,
                                     offset_bound=2.0, x_scale=sx,
                                     emit="fp32")

    def per_layer2(a):
        y = a
        for _ in range(2):
            offs = conv2d(y, woff.reshape(3, 3, c, 2 * k2), padding=1)
            y = ops.deform_conv(y, offs, wgt, offset_bound=2.0,
                                precision="int8")
        return y

    return {
        "us_chain_2layer": _time(jax.jit(chain2), x),
        "us_chain_per_layer_int8": _time(jax.jit(per_layer2), x),
        "hbm_bytes_chain_2layer": rep["chain_bytes"],
        "hbm_bytes_chain_per_layer": rep["chain_per_layer_bytes"],
        "hbm_chain_traffic_ratio": rep["chain_ratio"],
    }


def train_step_records() -> list[dict]:
    """§Training-throughput: median Trainer step time of the miniature
    ResNet-DCN detector — XLA-reference DCLs, the Pallas kernel path,
    and the mesh-sharded kernel path (``shard_map`` over the host
    mesh's data axis; on a 1-device CI box the mesh is (1, 1) and the
    record documents the sharded machinery's overhead-free baseline —
    the 4-virtual-device CI job exercises the real split).
    Full mode only — compile time would blow the --smoke budget."""
    import dataclasses as _dc
    import tempfile

    from repro.data import DetectionDataConfig, detection_batch
    from repro.launch.mesh import make_host_mesh
    from repro.models import resnet_dcn as R
    from repro.optim import constant, sgd
    from repro.train import Trainer, TrainerConfig

    cfg_ref = R.ResNetDCNConfig(
        stage_sizes=(1, 1, 1, 1), widths=(16, 32, 64, 128), stem_width=8,
        num_dcn=2, num_classes=4, img_size=32, offset_bound=2.0)
    data = DetectionDataConfig(img_size=32, global_batch=2, num_classes=4,
                               seed=3)
    host_mesh = make_host_mesh()
    out = []
    for label, cfg, mesh in [
            ("xla_ref", cfg_ref, None),
            ("kernel", _dc.replace(cfg_ref, use_kernel=True), None),
            ("sharded", _dc.replace(cfg_ref, use_kernel=True), host_mesh)]:
        param_specs = None
        if mesh is not None:
            from repro.distributed.sharding import use_rules
            from repro.models.layers import spec_tree
            with use_rules(mesh=mesh):
                param_specs = spec_tree(R.model_def(cfg_ref))
        with tempfile.TemporaryDirectory() as tmp:
            tr = Trainer(
                loss_fn=lambda p, b, _cfg=cfg: R.train_loss(
                    p, _cfg, b, lam=0.1),
                params=R.init_params(jax.random.PRNGKey(0), cfg_ref),
                optimizer=sgd(constant(0.05), momentum=0.9), mesh=mesh,
                param_specs=param_specs,
                batch_fn=lambda s: {k: jnp.asarray(v) for k, v in
                                    detection_batch(data, s).items()},
                config=TrainerConfig(total_steps=6, ckpt_every=100,
                                     ckpt_dir=tmp, log_every=100))
            tr.run()
        name = ("train_step_sharded_resnet_dcn" if label == "sharded"
                else f"train_step_resnet_dcn_{label}")
        rec = {"name": name,
               "us_median_step": tr.median_step_sec() * 1e6,
               "steps": len(tr.step_seconds)}
        if mesh is not None:
            rec["mesh_devices"] = int(mesh.devices.size)
        out.append(rec)
    return out


def run(*, smoke: bool = False, precision: str = "both",
        chain: bool = False,
        kernel_records: list[dict] | None = None) -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    # deformable conv: zero-copy vs banded vs unbounded XLA-gather path
    # (pass kernel_records to avoid re-timing — run.py shares one
    # records() call between the CSV rows and BENCH_kernels.json)
    for r in kernel_records if kernel_records is not None \
            else records(smoke=smoke, precision=precision, chain=chain):
        if "us_median_step" in r:
            rows.append(f"kernel/{r['name']},{r['us_median_step']:.0f},"
                        f"median_of_{r['steps']}_steps")
            continue
        if r.get("name") == "obs_dispatch_overhead":
            rows.append(
                f"kernel/{r['name']},{r['us_dispatch_traced']:.0f},"
                f"untraced={r['us_dispatch_untraced']:.0f}us;"
                f"disabled_tracer={r['us_dispatch_disabled_tracer']:.0f}us;"
                f"traced_ratio={r['overhead_ratio_traced']:.2f}x;"
                f"disabled_ratio={r['overhead_ratio_disabled']:.2f}x")
            continue
        if r.get("name") == "tuned_dcl_bwd_megacore_128c":
            rows.append(
                f"kernel/{r['name']},{r['tuned_us_bwd']:.0f},"
                f"analytic={r['analytic_us_bwd']:.0f}us;"
                f"ratio={r['tuned_vs_analytic_ratio']:.2f}x;"
                f"tuned_cores={r['tuned_cores']};"
                f"tuned_tiles={tuple(r['tuned_tiles'])};"
                f"dw_flush_every_step={r['tuned_dw_flush_every_step']};"
                f"applied={r['applied_us_bwd']:.0f}us;"
                f"applied_tuned_hits={r['applied_tuned_hits']};"
                f"platform={r['platform']}")
            continue
        if r.get("name") == "tuned_deform_conv_fused_32c":
            rows.append(
                f"kernel/{r['name']},{r['tuned_us_fwd']:.0f},"
                f"analytic={r['analytic_us_fwd']:.0f}us;"
                f"ratio={r['tuned_vs_analytic_ratio']:.2f}x;"
                f"tuned_tiles={tuple(r['tuned_tiles'])};"
                f"platform={r['platform']}")
            continue
        if str(r.get("name", "")).startswith("tuned_deform_conv_fused_") \
                and "quant" in r:
            rows.append(
                f"kernel/{r['name']},{r['tuned_us_fwd']:.0f},"
                f"quant={r['quant']};"
                f"analytic={r['analytic_us_fwd']:.0f}us;"
                f"ratio={r['tuned_vs_analytic_ratio']:.2f}x;"
                f"tuned_tiles={tuple(r['tuned_tiles'])};"
                f"platform={r['platform']}")
            continue
        if str(r.get("name", "")).startswith("dcl_spatial_") \
                and "us_unsharded" in r:
            parts = [f"unsharded={r['us_unsharded']:.0f}us",
                     f"halo_rows={r['halo_rows']}",
                     f"devices={r['devices_available']}"]
            shard_keys = sorted(k for k in r if k.startswith("us_spatial_"))
            for k in shard_keys:
                parts.append(f"{k.removeprefix('us_')}={r[k]:.0f}us")
            if "note" in r:
                parts.append("partial-sweep")
            first = r[shard_keys[0]] if shard_keys else r["us_unsharded"]
            rows.append(f"kernel/{r['name']},{first:.0f}," + ";".join(parts))
            continue
        if r.get("name") == "dcl_spatial_modeled_megapixel":
            rows.append(
                f"kernel/{r['name']},0,"
                f"shape={r['shape']};halo_rows={r['halo_rows']};"
                f"traffic_ratio_2shard={r['traffic_ratio_2shard']:.2f}x;"
                f"modeled_speedup_2shard="
                f"{r['modeled_speedup_2shard']:.2f}x;"
                f"modeled_speedup_4shard="
                f"{r['modeled_speedup_4shard']:.2f}x;"
                f"halo_bytes_2shard={r['halo_bytes_2shard'] / 1e6:.2f}MB")
            continue
        if r.get("name") == "dcl_bwd_megacore_128c":
            rows.append(
                f"kernel/{r['name']},{r['us_bwd_mc_zero_copy']:.0f},"
                f"bwd_seq={r['us_bwd_mc_baseline']:.0f}us;"
                f"modeled_per_core_ratio="
                f"{r['hbm_bwd_per_core_ratio']:.2f}x;"
                f"divergence-report-only (known anomaly)")
            continue
        if "us_zero_copy" in r:
            rows.append(
                f"kernel/{r['name']},{r['us_zero_copy']:.0f},"
                f"interpret-mode; banded={r['us_banded']:.0f}us;"
                f"unbounded_xla={r['us_unbounded_xla']:.0f}us;"
                f"bwd_zero_copy={r['us_bwd_zero_copy']:.0f}us;"
                f"bwd_xla_ref={r['us_bwd_xla_ref']:.0f}us;"
                f"hbm_model_zero_copy={r['hbm_bytes_zero_copy'] / 1e6:.2f}MB;"
                f"hbm_model_banded="
                f"{r['hbm_bytes_materialized_band'] / 1e6:.2f}MB;"
                f"traffic_ratio={r['hbm_traffic_ratio']:.2f}x;"
                f"bwd_traffic_ratio={r['hbm_bwd_traffic_ratio']:.2f}x;"
                f"train_traffic_ratio={r['hbm_train_traffic_ratio']:.2f}x")
        if "us_q_zero_copy" in r:
            rows.append(
                f"kernel/{r['name']}_int8,{r['us_q_zero_copy']:.0f},"
                f"interpret-mode; "
                f"hbm_model_q={r['hbm_bytes_q_zero_copy'] / 1e6:.2f}MB;"
                f"q_traffic_ratio_vs_fp32="
                f"{r['hbm_q_traffic_ratio_vs_fp32']:.2f}x;"
                f"q_total_ratio_vs_fp32="
                f"{r['hbm_q_total_ratio_vs_fp32']:.2f}x;"
                f"tiles_int8={r['tiles_timed_int8']}")
        if "us_chain_2layer" in r:
            rows.append(
                f"kernel/{r['name']}_chain2,{r['us_chain_2layer']:.0f},"
                f"interpret-mode; "
                f"per_layer_int8={r['us_chain_per_layer_int8']:.0f}us;"
                f"hbm_model_chain="
                f"{r['hbm_bytes_chain_2layer'] / 1e6:.2f}MB;"
                f"hbm_model_per_layer="
                f"{r['hbm_bytes_chain_per_layer'] / 1e6:.2f}MB;"
                f"chain_traffic_ratio="
                f"{r['hbm_chain_traffic_ratio']:.2f}x")
    # flash attention kernel (interpret) vs dense reference
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref
    for s in (128,) if smoke else (128, 256):
        q = jax.random.normal(key, (1, s, 2, 2, 32), jnp.float32)
        kk = jax.random.normal(jax.random.fold_in(key, 4), (1, s, 2, 32),
                               jnp.float32)
        vv = jax.random.normal(jax.random.fold_in(key, 5), (1, s, 2, 32),
                               jnp.float32)
        t_fl = _time(lambda a, b_, c_: flash_attention(
            a, b_, c_, block_q=64, block_k=64), q, kk, vv)
        t_dn = _time(lambda a, b_, c_: flash_attention_ref(a, b_, c_),
                     q, kk, vv)
        # HBM bytes the dense path writes for scores vs flash (never):
        score_mb = 4 * 2 * 2 * s * s * 4 / 1e6
        rows.append(f"kernel/flash_attn_s{s},{t_fl:.0f},"
                    f"dense_ref={t_dn:.0f}us;score_traffic_saved="
                    f"{score_mb:.1f}MB")
    # matmul kernel
    for mkn in [(256, 256, 256)] if smoke else \
            [(256, 256, 256), (512, 512, 512)]:
        a = jax.random.normal(key, mkn[:2], jnp.float32)
        b = jax.random.normal(key, mkn[1:], jnp.float32)
        t = _time(lambda x_, y_: ops.matmul(x_, y_), a, b)
        t_ref = _time(lambda x_, y_: ref.matmul_ref(x_, y_), a, b)
        rows.append(f"kernel/matmul_{mkn[0]},{t:.0f},xla_ref={t_ref:.0f}us")
    # tile model summary for the DCL hot spots (ResNet-50 stages)
    for n in (128,) if smoke else (128, 256, 512):
        s = LayerShape(h=56, w=56, c_in=n, c_out=n, offset_bound=2.0)
        c_ = choose_tiles(s)
        p = evaluate_tile(s, PAPER_TILES)
        kt = choose_kernel_tiles(s)
        rows.append(
            f"kernel/tile_model_N={n},0,"
            f"chosen={c_.tile};ctc={c_.ctc:.1f};vmem={c_.vmem_bytes >> 20}MiB;"
            f"attainable={c_.attainable_flops / 1e12:.0f}TF;"
            f"paper_tile_ctc={p.ctc:.1f};"
            f"kernel_tiles=({kt.tile_h},{kt.tile_w},{kt.tile_c},{kt.tile_m})")
    return rows


if __name__ == "__main__":
    import argparse
    _ap = argparse.ArgumentParser()
    _ap.add_argument("--smoke", action="store_true")
    _ap.add_argument("--precision", default="both",
                     choices=("fp32", "int8", "both"))
    _ap.add_argument("--chain", action="store_true",
                     help="add the chained two-layer int8 records "
                          "(us_chain_* / hbm_bytes_chain_*)")
    _args = _ap.parse_args()
    print("\n".join(run(smoke=_args.smoke, precision=_args.precision,
                        chain=_args.chain)))
