"""Pipeline-parallelism demo: GPipe schedule over a 'stage' mesh axis.

    PYTHONPATH=src python examples/pipeline_demo.py

Must run as its own process (needs >1 host device).  Splits a 4-layer
MLP across 2 pipeline stages, streams 8 microbatches through, and checks
the result against the sequential reference.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import pathlib  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.distributed.pipeline import bubble_fraction, gpipe_forward  # noqa: E402


def main() -> None:
    n_stages, layers_per_stage, d = 2, 2, 16
    n_micro, mb = 8, 4

    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (n_stages, layers_per_stage, d, d)) \
        / jnp.sqrt(d)

    def stage_fn(w_stage, x):
        for i in range(layers_per_stage):
            x = jnp.tanh(x @ w_stage[i])
        return x

    xs = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, mb, d))

    mesh = jax.make_mesh((n_stages,), ("stage",))
    ys = gpipe_forward(stage_fn, ws, xs, mesh=mesh)

    # sequential reference
    ref = xs
    for s in range(n_stages):
        ref = jax.vmap(lambda x: stage_fn(ws[s], x))(ref)

    np.testing.assert_allclose(np.asarray(ys), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print(f"pipeline({n_stages} stages, {n_micro} microbatches) == "
          f"sequential: OK")
    print(f"bubble fraction: {bubble_fraction(n_stages, n_micro):.2%} "
          f"(GPipe (S-1)/(M+S-1))")

    # --- pipelined TRANSFORMER (first-class model feature) -------------
    from repro.models.transformer import ModelConfig, forward, init_params
    from repro.models.pipelined import pipelined_forward

    cfg = ModelConfig(name="pp-lm", n_layers=4, d_model=32, n_heads=4,
                      kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(7), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(8), (8, 16), 0, 64)
    want, _, _ = forward(params, cfg, tokens=toks, mode="train")
    got = pipelined_forward(params, cfg, toks, mesh=mesh,
                            n_stages=n_stages, microbatches=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print(f"pipelined transformer ({cfg.n_layers} layers / {n_stages} "
          f"stages) == standard forward: OK")


if __name__ == "__main__":
    main()
