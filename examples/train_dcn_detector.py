"""End-to-end driver for the PAPER's experiment (Sec. 4.1, miniature):

train the ResNet-DCN detector on the synthetic COCO-like set twice —
lambda = 0 (baseline) and lambda = 0.2 (Eq. 5 regularizer) — and report
the offset statistics, receptive-field compression (Eq. 4), and the
Eq. 6 input-buffer requirement for both.  Finishes by running the
regularized model through the BOUNDED Pallas kernel path and checking it
against the unbounded reference output.

    PYTHONPATH=src python examples/train_dcn_detector.py [--steps 60]
"""
import argparse
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rf_regularizer import OffsetStats
from repro.core.tiling import input_buffer_size, receptive_field
from repro.data import DetectionDataConfig, detection_batch
from repro.models import resnet_dcn as R
from repro.optim import constant, sgd


def train(lam: float, steps: int):
    cfg = R.ResNetDCNConfig(stage_sizes=(1, 1, 1, 1),
                            widths=(16, 32, 64, 128), stem_width=8,
                            num_dcn=2, num_classes=4, img_size=64)
    data = DetectionDataConfig(img_size=64, global_batch=4, num_classes=4,
                               seed=3)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    for blk in params.values():
        if isinstance(blk, dict) and "dcl" in blk:
            blk["dcl"]["b_offset"] = jnp.full_like(blk["dcl"]["b_offset"], 4.0)
    opt = sgd(constant(0.05), momentum=0.9)
    state = opt.init(params)

    @jax.jit
    def step(p, s, batch, i):
        (loss, m), g = jax.value_and_grad(
            lambda pp: R.train_loss(pp, cfg, batch, lam=lam),
            has_aux=True)(p)
        p2, s2 = opt.update(g, s, p, i)
        return p2, s2, m

    stats = OffsetStats()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in detection_batch(data, i).items()}
        params, state, m = step(params, state, batch, jnp.asarray(i))
    # validation offset statistics (paper Fig. 7)
    for i in range(1000, 1008):
        batch = {k: jnp.asarray(v) for k, v in detection_batch(data, i).items()}
        _, o_maxes = R.forward(params, cfg, batch["images"])
        stats.update(o_maxes)
    task = float(m["bce"] + m["ce"] + 0.5 * m["l1"])
    return cfg, params, stats, task


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    print("=== lambda = 0 (baseline) ===")
    cfg0, params0, stats0, task0 = train(0.0, args.steps)
    print(f"task loss {task0:.3f}  o_max {stats0.network_max():.2f}")

    print("=== lambda = 0.2 (Eq. 5 regularizer) ===")
    cfg1, params1, stats1, task1 = train(0.2, args.steps)
    print(f"task loss {task1:.3f}  o_max {stats1.network_max():.2f}")

    rf0 = receptive_field(3, stats0.network_max())
    rf1 = receptive_field(3, stats1.network_max())
    print(f"\nreceptive field (Eq. 4): {rf0} -> {rf1} "
          f"({stats1.compression_vs(stats0):.2f}x compression; "
          f"paper: 12.6x over 12 COCO epochs)")
    b0 = input_buffer_size(rf0, 1, 8, 512)
    b1 = input_buffer_size(rf1, 1, 8, 512)
    print(f"Eq. 6 input buffer: {b0 / 1e6:.2f} MB -> {b1 / 1e6:.2f} MB "
          f"({100 * b1 / b0:.1f}%)")

    # serve the regularized model through the bounded Pallas kernels
    bound = float(np.ceil(stats1.network_max()))
    cfg_k = dataclasses.replace(cfg1, offset_bound=bound, use_kernel=True)
    cfg_ref = dataclasses.replace(cfg1, offset_bound=bound, use_kernel=False)
    data = DetectionDataConfig(img_size=64, global_batch=2, num_classes=4)
    batch = {k: jnp.asarray(v) for k, v in detection_batch(data, 0).items()}
    out_k, _ = R.forward(params1, cfg_k, batch["images"])
    out_r, _ = R.forward(params1, cfg_ref, batch["images"])
    err = float(jnp.max(jnp.abs(out_k["cls"] - out_r["cls"])))
    print(f"\nbounded Pallas kernel path (B={bound:.0f}): "
          f"max |delta| vs pure-JAX = {err:.2e}  "
          f"{'OK' if err < 5e-3 else 'MISMATCH'}")


if __name__ == "__main__":
    main()
