"""Elastic restart demo: checkpoint on one mesh, resume on ANOTHER.

Phase 1 (4 host devices, (data=2, model=2) mesh): train a small LM,
checkpoint.  Phase 2 (run again with 8 devices, (data=4, model=2) mesh):
auto-resume — the checkpoint carries no mesh assumptions, so the restore
reshard s onto whatever the restart sees; training continues bit-exactly
(stateless data pipeline).

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python examples/elastic_restart.py --phase 1 --ckpt /tmp/elastic
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/elastic_restart.py --phase 2 --ckpt /tmp/elastic
"""
import argparse
import os
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.data import LMDataConfig, lm_batch
from repro.distributed.sharding import use_rules
from repro.models.transformer import (ModelConfig, init_params, loss_fn,
                                      param_specs)
from repro.optim import adamw, constant
from repro.train import Trainer, TrainerConfig


def build(ckpt: str, mesh):
    cfg = ModelConfig(name="elastic", n_layers=2, d_model=32, n_heads=4,
                      kv_heads=2, d_ff=64, vocab=32, dtype=jnp.float32)
    data = LMDataConfig(vocab=32, seq_len=32, global_batch=8, seed=11)
    with use_rules(mesh=mesh):
        specs = param_specs(cfg)
        params = init_params(jax.random.PRNGKey(0), cfg)
        tr = Trainer(
            loss_fn=lambda p, b: loss_fn(p, cfg, b), params=params,
            optimizer=adamw(constant(3e-3)), mesh=mesh, param_specs=specs,
            batch_fn=lambda s: lm_batch(data, s),
            config=TrainerConfig(total_steps=20, ckpt_every=10,
                                 ckpt_dir=ckpt, log_every=5))
    return tr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--phase", type=int, required=True)
    ap.add_argument("--ckpt", required=True)
    args = ap.parse_args()

    n = len(jax.devices())
    mesh = jax.make_mesh((n // 2, 2), ("data", "model"))
    print(f"phase {args.phase}: {n} devices, mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")

    tr = build(args.ckpt, mesh)
    if args.phase == 1:
        tr.cfg.total_steps = 10
        with use_rules(mesh=mesh):
            tr.run()
        print(f"phase 1 done at step {tr.step}; loss "
              f"{tr.last_loss:.4f}")
    else:
        # try_resume reshards the whole bundle (params, optimizer state,
        # step) onto THIS mesh via CheckpointManager.restore(shardings=...)
        # — no hand-resharding needed.
        assert tr.try_resume(), "no checkpoint found"
        print(f"resumed at step {tr.step} onto the NEW mesh")
        with use_rules(mesh=mesh):
            tr.run()
        print(f"phase 2 done at step {tr.step}; loss "
              f"{tr.last_loss:.4f}")
        # oracle: a straight 20-step run must match.  NOT bit-exact:
        # phase 1 ran its first 10 steps on a different mesh, and
        # all-reduce grouping differs (fp32 reduction order) — the
        # difference is pure float non-associativity, ~1e-5.
        import numpy as np
        import tempfile
        ref = build(tempfile.mkdtemp(), mesh)
        with use_rules(mesh=mesh):
            ref.run()
        for a, b in zip(jax.tree_util.tree_leaves(tr.params),
                        jax.tree_util.tree_leaves(ref.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-5)
        print("elastic resume == straight run: OK "
              "(up to cross-mesh reduction order)")


if __name__ == "__main__":
    main()
