"""Multi-pod dry-run walkthrough for ONE cell — the minimal example of
how the production launch path works (what `repro.launch.dryrun --all`
does for every cell).

    PYTHONPATH=src python examples/multipod_dryrun.py [--arch glm4-9b]

NOTE: must run as its own process (the 512-device override must precede
any other jax usage).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.distributed.sharding import use_rules  # noqa: E402
from repro.launch.dryrun import parse_collectives  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_train_step  # noqa: E402
from repro.models import registry as reg  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--multi-pod", action="store_true", default=True)
    args = ap.parse_args()

    arch = reg.get(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} chips)")

    with use_rules(mesh=mesh):
        step, in_sh, out_sh, abstract = make_train_step(arch, mesh)
        lowered = jax.jit(step, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*abstract)
        compiled = lowered.compile()

    print("\nmemory analysis (per device):")
    mem = compiled.memory_analysis()
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes"):
        if hasattr(mem, k):
            print(f"  {k:<28} {getattr(mem, k) / 1e9:8.2f} GB")

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    print(f"\ncost analysis: flops/device={cost.get('flops', 0):.3e} "
          f"(scan body counted once; see dryrun.py extrapolation)")

    coll = parse_collectives(compiled.as_text())
    print("\ncollective schedule (per device program):")
    for k, v in coll.items():
        if isinstance(v, dict) and v["count"]:
            print(f"  {k:<20} x{v['count']:>3}  {v['bytes'] / 1e9:8.3f} GB")
    print(f"  {'TOTAL':<20} x{coll['total_count']:>3}  "
          f"{coll['total_bytes'] / 1e9:8.3f} GB")


if __name__ == "__main__":
    main()
