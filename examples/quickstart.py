"""Quickstart: train a small LM with the full production stack on CPU.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates: registry reduced configs, logical-axis sharding on the
host mesh, the fault-tolerant Trainer (checkpoint + auto-resume), and
greedy sampling from the trained model.
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.data import LMDataConfig, lm_batch
from repro.distributed.sharding import use_rules
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import (ModelConfig, forward, init_params,
                                      loss_fn, param_specs)
from repro.optim import adamw, warmup_cosine
from repro.train import Trainer, TrainerConfig


def main() -> None:
    cfg = ModelConfig(name="quickstart-lm", n_layers=4, d_model=64,
                      n_heads=4, kv_heads=2, d_ff=128, vocab=64,
                      dtype=jnp.float32)
    data = LMDataConfig(vocab=64, seq_len=64, global_batch=16, seed=0)
    mesh = make_host_mesh()
    ckpt_dir = tempfile.mkdtemp(prefix="repro_quickstart_")

    with use_rules(mesh=mesh):
        specs = param_specs(cfg)
        params = init_params(jax.random.PRNGKey(0), cfg)
        trainer = Trainer(
            loss_fn=lambda p, b: loss_fn(p, cfg, b),
            params=params,
            optimizer=adamw(warmup_cosine(3e-3, 10, 200)),
            mesh=mesh, param_specs=specs,
            batch_fn=lambda s: lm_batch(data, s),
            config=TrainerConfig(total_steps=120, ckpt_every=40,
                                 ckpt_dir=ckpt_dir, log_every=20))
        if trainer.try_resume():
            print(f"resumed at step {trainer.step}")
        history = trainer.run()

    print("\nstep  loss")
    for h in history:
        if "loss" in h:
            print(f"{h['step']:>4}  {h['loss']:.4f}")

    # greedy sample
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    cur = prompt
    for _ in range(12):
        logits, _, _ = forward(trainer.params, cfg, tokens=cur, mode="train")
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        cur = jnp.concatenate([cur, nxt], axis=1)
    print("\nprompt + sample:", cur[0].tolist())
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
