"""Continuous-batching serving demo: stream requests through the engine.

    PYTHONPATH=src python examples/serve_lm.py

Trains a small LM briefly (so generations aren't pure noise), then
serves a stream of prompts through the slot-based continuous-batching
engine and verifies one output against naive greedy decoding.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import LMDataConfig, lm_batch
from repro.models.transformer import (ModelConfig, forward, init_params,
                                      loss_fn)
from repro.optim import adamw, constant
from repro.serve import Request, ServeConfig, ServingEngine


def main() -> None:
    cfg = ModelConfig(name="serve-demo", n_layers=3, d_model=48, n_heads=4,
                      kv_heads=2, d_ff=96, vocab=64, dtype=jnp.float32)
    data = LMDataConfig(vocab=64, seq_len=48, global_batch=16, seed=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(constant(3e-3))
    state = opt.init(params)

    @jax.jit
    def step(p, s, batch, i):
        (loss, _), g = jax.value_and_grad(
            lambda pp: loss_fn(pp, cfg, batch), has_aux=True)(p)
        p2, s2 = opt.update(g, s, p, i)
        return p2, s2, loss

    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(data, i).items()}
        params, state, loss = step(params, state, batch, jnp.asarray(i))
    print(f"trained 60 steps, loss {float(loss):.3f}")

    engine = ServingEngine(params, cfg, ServeConfig(slots=4, cache_len=96))
    rng = np.random.RandomState(0)
    for uid in range(10):
        prompt = rng.randint(0, 64, rng.randint(4, 12)).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=16))

    steps = 0
    while engine.queue or any(r is not None for r in engine.active):
        n_active = engine.step()
        steps += 1
        if steps % 5 == 0:
            print(f"decode step {steps}: {n_active} active, "
                  f"{len(engine.queue)} queued, "
                  f"{len(engine.completed)} done")
    print(f"\nserved {len(engine.completed)} requests in {steps} "
          f"batched decode steps "
          f"(vs {sum(16 for _ in range(10))} sequential steps)")

    # verify continuous batching == naive greedy for one request
    req = engine.completed[0]
    cur = jnp.asarray(req.prompt, jnp.int32)[None]
    ref = []
    for _ in range(len(req.output)):
        logits, _, _ = forward(params, cfg, tokens=cur, mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        ref.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]], jnp.int32)], 1)
    assert req.output == ref, "continuous batching must match greedy"
    print("continuous batching == naive greedy decode: OK")
    print("sample output:", req.output)


if __name__ == "__main__":
    main()
