"""Deterministic, stateless synthetic data pipelines.

Every batch is a pure function of (seed, step, host_shard) — the
fault-tolerance keystone: after a crash/restore ANY host can regenerate
ANY shard of ANY step bit-exactly, so restarts are exact and stragglers
can be re-assigned without coordination.  (A real deployment swaps the
generator for a deterministic tokenized-file reader with the same
(seed, step) -> batch contract.)

Two generators:

* ``lm_batch`` — synthetic token LM with learnable structure: a noisy
  affine-mod sequence (token_{t+1} ~ a * token_t + b + noise).  A model
  that learns the transition drops loss well below uniform entropy —
  giving the e2e training example a real convergence signal.
* ``detection_batch`` — synthetic COCO-like scenes: colored rectangles
  on textured background, dense grid targets (objectness, class, box).
  This drives the paper's DCN experiments (the regularizer needs a task
  where offsets are trained, not random).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    codebooks: int = 1
    seed: int = 0
    noise: float = 0.05          # fraction of uniformly-resampled tokens


def lm_batch(cfg: LMDataConfig, step: int, *, host_id: int = 0,
             num_hosts: int = 1) -> dict[str, np.ndarray]:
    """Returns {'tokens','targets'} int32; targets are next-token."""
    assert cfg.global_batch % num_hosts == 0
    b = cfg.global_batch // num_hosts
    rng = np.random.RandomState(
        (cfg.seed * 1_000_003 + step * 7919 + host_id * 104729) % (2**31))
    a = 31 % cfg.vocab or 1
    c = 17 % cfg.vocab
    shape = (b, cfg.seq_len + 1)
    if cfg.codebooks > 1:
        shape = (b, cfg.seq_len + 1, cfg.codebooks)
    start = rng.randint(0, cfg.vocab, shape[:1] + shape[2:])
    seq = np.empty(shape, np.int64)
    seq[:, 0] = start
    for t in range(1, cfg.seq_len + 1):
        seq[:, t] = (seq[:, t - 1] * a + c) % cfg.vocab
    flip = rng.rand(*shape) < cfg.noise
    seq = np.where(flip, rng.randint(0, cfg.vocab, shape), seq)
    return {"tokens": seq[:, :-1].astype(np.int32),
            "targets": seq[:, 1:].astype(np.int32)}


@dataclasses.dataclass(frozen=True)
class DetectionDataConfig:
    img_size: int = 256
    global_batch: int = 8
    num_classes: int = 16
    max_objects: int = 4
    stride: int = 32             # head cell stride
    seed: int = 0


def detection_batch(cfg: DetectionDataConfig, step: int, *, host_id: int = 0,
                    num_hosts: int = 1) -> dict[str, np.ndarray]:
    """Synthetic scenes + dense grid targets for the detection head."""
    assert cfg.global_batch % num_hosts == 0
    b = cfg.global_batch // num_hosts
    hw, hc = cfg.img_size, cfg.img_size // cfg.stride
    rng = np.random.RandomState(
        (cfg.seed * 999_983 + step * 6007 + host_id * 31337) % (2**31))

    images = rng.rand(b, hw, hw, 3).astype(np.float32) * 0.25
    obj = np.zeros((b, hc, hc), np.float32)
    cls = np.zeros((b, hc, hc), np.int32)
    box = np.zeros((b, hc, hc, 4), np.float32)

    for i in range(b):
        for _ in range(rng.randint(1, cfg.max_objects + 1)):
            c = rng.randint(0, cfg.num_classes)
            w = rng.randint(hw // 8, hw // 2)
            h = rng.randint(hw // 8, hw // 2)
            x0 = rng.randint(0, hw - w)
            y0 = rng.randint(0, hw - h)
            color = (np.arange(3) == c % 3).astype(np.float32) * 0.5 + 0.25 \
                + rng.rand(3) * 0.25
            images[i, y0:y0 + h, x0:x0 + w] = color
            # center cell target
            cy, cx = (y0 + h // 2) // cfg.stride, (x0 + w // 2) // cfg.stride
            cy, cx = min(cy, hc - 1), min(cx, hc - 1)
            obj[i, cy, cx] = 1.0
            cls[i, cy, cx] = c
            box[i, cy, cx] = [(y0 + h / 2) / hw, (x0 + w / 2) / hw,
                              h / hw, w / hw]
    return {"images": images, "obj": obj, "cls": cls, "box": box}
