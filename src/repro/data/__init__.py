from .pipeline import (  # noqa: F401
    LMDataConfig, lm_batch, DetectionDataConfig, detection_batch)
