"""Analytic performance/energy model of the paper's FPGA evaluation.

The container has no Virtex-7 (nor a TPU); the paper's Fig. 3 / Fig. 8 /
Fig. 9 are reproduced from an analytic model of the two accelerators:

* **conventional** — Wei et al. DAC'17 [22]: a systolic CNN accelerator
  with no DCL-aware input buffering.  Bilinear samples that miss the
  on-chip buffer issue irregular DRAM reads that stall the pipeline.
* **ours** — the paper's accelerator: the Eq. 5-trained model has a
  bounded receptive field, the Eq. 6-sized input buffer provably holds
  every sample, all reads hit on-chip, and the two stages are pipelined.

The model is *calibrated* against the paper's published numbers (13.8 MB
stall-free buffer at lambda=0, 12.68x RF compression at lambda=0.005,
5.28x-17.25x speedup for N in {128, 256, 512}, 1.39x energy saving) and
is used by ``benchmarks/`` to regenerate the figures.  All constants are
module-level and documented so the calibration is auditable.

Offset-magnitude statistics are modelled as a half-normal distribution
whose scale is set by the trained ``o_max`` for each lambda (paper
Fig. 7 histogram); the buffer hit-rate of a capacity-C buffer is then
the CDF of the coverage radius that C buys via Eq. 6.
"""
from __future__ import annotations

import dataclasses
import math

from .tiling import (LayerShape, TileConfig, V5E_HBM_BW, V5E_ICI_BW,
                     choose_kernel_tiles, dcl_backward_hbm_bytes,
                     dcl_chain_hbm_bytes, dcl_dataflow_hbm_bytes,
                     dcl_spatial_hbm_bytes, dcl_total_hbm_bytes,
                     dcl_train_hbm_bytes, input_buffer_size,
                     receptive_field, spatial_halo_bytes,
                     spatial_halo_rows, PAPER_TILES)

# ---------------------------------------------------------------------------
# Calibration constants
# ---------------------------------------------------------------------------

# Trained network-max offset per lambda (paper Fig. 6/7; lambda=0 chosen so
# Eq. 6 gives the paper's 13.8 MB stall-free buffer with their tiling).
O_MAX_BY_LAMBDA: dict[float, float] = {
    0.0: 37.5,      # RF = 3 + 2*38 = 79  -> Eq.6 ~= 13.8 MB @ T_W=8, T_N=512
    0.005: 1.6,     # RF = 7; 79/6.23 ... combined with tail mass ~= 12.68x
    0.0075: 1.2,    # RF = 7
    0.01: 0.9,      # RF = 5
}

# Half-normal scale as a fraction of the observed max (tail calibration):
# P(|o| > o_max) ~ 1e-4 for the validation set => o_max ~= 3.9 sigma.
_SIGMA_FRACTION = 1.0 / 3.9

KERNEL_SIZE = 3
FREQ_HZ = 200e6                   # paper-class HLS design frequency
PE_MACS_PER_CYCLE = 2596 // 2     # ~2596 DSPs, 2 DSP per fp32 MAC
DRAM_BW_BYTES_PER_S = 12.8e9      # DDR3-1600 x1 channel
ONCHIP_BW_BYTES_PER_S = 4e9       # paper: "bandwidth between on-chip buffers was 4GB/s"
DRAM_RANDOM_LATENCY_CYCLES = 130  # queueing + tRC row-cycle penalty @200 MHz
DRAM_BURST_CYCLES = 1.0           # per 64-B burst once the row is open
DRAM_BURST_BYTES = 64
T_M_PASS = 64                     # output-channel tile (the paper's T_M)

# Energy constants (Micron TN-41-01-class DDR3 + 28 nm on-chip estimates).
E_DRAM_PJ_PER_BYTE_SEQ = 70.0
E_DRAM_PJ_PER_BYTE_RAND = 120.0   # row-miss overhead on irregular access
E_BRAM_PJ_PER_BYTE = 2.5          # at the reference 416 KiB capacity
E_MAC_PJ = 4.5                    # fp32 MAC @28nm
BRAM_REF_BYTES = 416 * 1024       # BRAM pJ/B scales ~sqrt(capacity/ref)

CONV_BUFFER_BYTES = 416 * 1024    # conventional [22] input-buffer capacity


def sigma_for_lambda(lam: float) -> float:
    if lam not in O_MAX_BY_LAMBDA:
        # interpolate in log-space of o_max over known lambdas
        ks = sorted(O_MAX_BY_LAMBDA)
        lo = max([k for k in ks if k <= lam], default=ks[0])
        hi = min([k for k in ks if k >= lam], default=ks[-1])
        if lo == hi:
            o = O_MAX_BY_LAMBDA[lo]
        else:
            t = (lam - lo) / (hi - lo)
            o = math.exp((1 - t) * math.log(O_MAX_BY_LAMBDA[lo])
                         + t * math.log(O_MAX_BY_LAMBDA[hi]))
    else:
        o = O_MAX_BY_LAMBDA[lam]
    return o * _SIGMA_FRACTION


def o_max_for_lambda(lam: float) -> float:
    return sigma_for_lambda(lam) / _SIGMA_FRACTION


def halfnormal_cdf(x: float, sigma: float) -> float:
    if sigma <= 0:
        return 1.0
    return math.erf(x / (sigma * math.sqrt(2.0)))


# ---------------------------------------------------------------------------
# Fig. 3 — input-buffer efficiency vs capacity
# ---------------------------------------------------------------------------

def rf_compression(lam: float, *, baseline_lam: float = 0.0) -> float:
    """Paper abstract: 12.6x receptive-field compression (real-valued RF,
    RF = K + 2*o_max, between lambda=0 and the given lambda)."""
    rf0 = KERNEL_SIZE + 2 * o_max_for_lambda(baseline_lam)
    rf1 = KERNEL_SIZE + 2 * o_max_for_lambda(lam)
    return rf0 / rf1


def coverage_radius(capacity_bytes: int, *, t_w: int = PAPER_TILES.t_w,
                    t_n: int = PAPER_TILES.t_n, stride: int = 1,
                    bytes_per_elem: int = 4) -> float:
    """Largest offset radius r such that the Eq. 6 buffer for
    RF = K + 2*ceil(r) fits in ``capacity_bytes``."""
    r = 0
    while True:
        rf = receptive_field(KERNEL_SIZE, r + 1)
        if input_buffer_size(rf, stride, t_w, t_n,
                             bytes_per_elem=bytes_per_elem) > capacity_bytes:
            return float(r)
        r += 1
        if r > 1 << 14:
            return float(r)


def buffer_efficiency(capacity_bytes: int, lam: float, **kw) -> float:
    """Fig. 3: % of bilinear-interpolation reads served by the buffer."""
    r = coverage_radius(capacity_bytes, **kw)
    return halfnormal_cdf(r + 0.5, sigma_for_lambda(lam))


def stall_free_capacity(lam: float, *, t_w: int = PAPER_TILES.t_w,
                        t_n: int = PAPER_TILES.t_n, stride: int = 1,
                        bytes_per_elem: int = 4) -> int:
    """Buffer bytes needed for (numerically) stall-free operation —
    paper: 13.8 MB at lambda=0, ~3% of that after regularization."""
    rf = receptive_field(KERNEL_SIZE, o_max_for_lambda(lam))
    return input_buffer_size(rf, stride, t_w, t_n,
                             bytes_per_elem=bytes_per_elem)


# ---------------------------------------------------------------------------
# Fig. 8 — cycle model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DCLWorkload:
    """One DCL invocation (paper evaluates ResNet-50 DCLs by N)."""
    h: int = 56
    w: int = 56
    n: int = 256          # input channels (the paper's N)
    m: int = 256          # output channels
    kernel_size: int = KERNEL_SIZE
    stride: int = 1

    @property
    def out_pixels(self) -> int:
        return (self.h // self.stride) * (self.w // self.stride)

    @property
    def macs(self) -> int:
        k2 = self.kernel_size ** 2
        conv = self.out_pixels * k2 * self.n * self.m
        bilinear = self.out_pixels * k2 * self.n * 4
        return conv + bilinear


def _miss_rate(wl: DCLWorkload, lam: float, capacity: int) -> float:
    """Fraction of bilinear corner reads that miss a capacity-C input
    buffer when the layer is tiled T_N = N (the coverage radius shrinks
    as N grows — deeper layers cache fewer rows)."""
    r = coverage_radius(capacity, t_n=wl.n)
    return 1.0 - halfnormal_cdf(r + 0.5, sigma_for_lambda(lam))


def cycles_ours(wl: DCLWorkload, lam: float) -> float:
    """Bounded-RF accelerator: fully pipelined; all samples hit on-chip.

    The PE array is provisioned for the paper's T_N = 512 channel tile,
    so at N < 512 it is underutilized — this is exactly why the paper's
    Fig. 8 speedup grows with N ("improved by increasing the number of
    data reuses").  Interpolated patches are computed ONCE and reused
    across every output-channel pass.
    """
    util = min(1.0, wl.n / PAPER_TILES.t_n)
    comp = wl.macs / (PE_MACS_PER_CYCLE * util)
    bytes_seq = (wl.h * wl.w * wl.n + wl.out_pixels * wl.m
                 + wl.kernel_size ** 2 * wl.n * wl.m) * 4
    mem = bytes_seq / DRAM_BW_BYTES_PER_S * FREQ_HZ
    # Residual misses for the Eq. 6-sized buffer of the trained bound
    # (numerically ~0: the buffer is sized to cover the trained o_max).
    miss = 1.0 - buffer_efficiency(stall_free_capacity(lam), lam)
    stall = wl.out_pixels * wl.kernel_size ** 2 * 4 * miss \
        * DRAM_RANDOM_LATENCY_CYCLES
    return max(comp, mem) + stall


def cycles_conventional(wl: DCLWorkload, lam: float) -> float:
    """[22]-style accelerator: no DCL-aware buffering.  Every buffer miss
    issues an irregular DRAM read (row-miss latency + channel bursts) and
    stalls the pipeline; misses recur on EVERY output-channel tile pass
    because interpolated patches are not reused (M/T_M passes)."""
    comp = wl.macs / PE_MACS_PER_CYCLE        # [22] tiles T_N to the layer
    miss = _miss_rate(wl, lam, CONV_BUFFER_BYTES)
    passes = math.ceil(wl.m / T_M_PASS)
    misses = wl.out_pixels * wl.kernel_size ** 2 * 4 * miss * passes
    bursts = math.ceil(wl.n * 4 / DRAM_BURST_BYTES)
    stall_per_miss = DRAM_RANDOM_LATENCY_CYCLES + bursts * DRAM_BURST_CYCLES
    return comp + misses * stall_per_miss


def speedup(n_channels: int, lam_ours: float, lam_conv: float = 0.0,
            **kw) -> float:
    """Fig. 8: 'combination of our algorithm and accelerator' (ours @
    lam_ours) vs the conventional accelerator running the unregularized
    model (lam_conv = 0)."""
    wl = DCLWorkload(n=n_channels, m=n_channels, **kw)
    return cycles_conventional(wl, lam_conv) / cycles_ours(wl, lam_ours)


# ---------------------------------------------------------------------------
# TPU dataflow traffic — zero-copy vs materialized-band (EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

def dataflow_traffic_report(*, h: int = 64, w: int = 64, c: int = 128,
                            m: int = 128, batch: int = 4, tile_h: int = 8,
                            tile_w: int | None = None,
                            offset_bound: float = 2.0, kernel_size: int = 3,
                            stride: int = 1,
                            bytes_per_elem: int = 4,
                            cores: int = 2) -> dict:
    """Modeled HBM traffic of one bounded DCL under both TPU dataflows.

    ``materialized_band`` is the legacy ``ops._pad_and_band`` path (full
    overlapping row bands duplicated through HBM by an XLA gather before
    the kernel runs); ``zero_copy`` is the in-kernel DMA dataflow.  When
    ``tile_w`` is None the width tile comes from the Sec. 3.2 chooser
    (``tiling.choose_kernel_tiles``), exactly as ``ops.deform_conv``
    resolves it.  Returns bytes for both dataflows plus the ratio —
    the number EXPERIMENTS.md §Perf and ``benchmarks/kernel_bench.py``
    report and that the PR-1 acceptance gate (>= 2x) checks — and since
    PR 2 the *backward* traffic of both dataflows (``bwd_ratio``, from
    ``tiling.dcl_backward_hbm_bytes``) plus the combined fwd+bwd
    training traffic (``train_ratio``, this PR's >= 2x acceptance gate).

    int8 records (``*_q`` keys): the quantized zero-copy datapath
    (``kernels/deform_conv_q.py``) streams the input band and weight
    blocks at 1 byte/elem while offsets and the dequantized output stay
    fp32.  ``q_ratio`` (input dataflow, fp32 zero-copy over int8
    zero-copy — 4x at equal tiles, the Eq. 6 band density argument) is
    this PR's >= 3x acceptance gate; ``q_total_ratio`` is the honest
    whole-layer number including the fp32 offset/output terms.
    ``tiles_int8`` reports what the dtype-aware chooser would run.

    Megacore records (PR 4): ``zero_copy_bwd_bytes_per_core`` is one
    core's backward traffic under the ``cores``-way batch split of
    ``kernels.deform_conv_bwd`` (``tiling.dcl_backward_hbm_bytes``
    ``per_core=True``) and ``bwd_per_core_ratio`` the drop vs the
    sequential kernel — ~``cores``x whenever the dw-stationary
    (batch-indexed) terms dominate, the PR-4 acceptance gate.
    ``zero_copy_bwd_bytes_mc_total`` is the aggregate including every
    core's partial-d_weights flush + the reduce epilogue (the honest
    price of the split).

    Chained-layer records (``chain_*`` keys): two back-to-back int8
    DCLs through the per-layer datapath (each layer pays the fp32
    offset pass, the fp32->int8 quantize pass, and a fp32 output that
    the next layer re-reads) vs the chained datapath
    (``quant="int8_chain"``: in-kernel offset conv over the staged
    band, int8 emission on the next layer's grid — every inter-layer
    tensor crosses HBM once at 1 byte/elem).  ``chain_ratio`` is this
    PR's >= 1.3x acceptance gate (``tiling.dcl_chain_hbm_bytes``);
    ``total_bytes_q_fused_offsets`` is the single-layer kernel-only
    view (``dcl_total_hbm_bytes(fused_offsets=True)``).
    """
    shape = LayerShape(h=h, w=w, c_in=c, c_out=m, kernel_size=kernel_size,
                       stride=stride, offset_bound=offset_bound)
    if tile_w is None:
        kt = choose_kernel_tiles(shape, batch=batch)
        tile_w = kt.tile_w
        tile_c, tile_m = kt.tile_c, kt.tile_m
    else:
        tile_c, tile_m = c, m
    t = TileConfig(t_h=tile_h, t_w=tile_w, t_n=tile_c, t_m=tile_m)
    kt_q = choose_kernel_tiles(shape, batch=batch, dtype="int8",
                               objective="forward")
    zero = dcl_dataflow_hbm_bytes(shape, t, dataflow="zero_copy",
                                  batch=batch, bytes_per_elem=bytes_per_elem)
    band = dcl_dataflow_hbm_bytes(shape, t, dataflow="materialized_band",
                                  batch=batch, bytes_per_elem=bytes_per_elem)
    zero_q = dcl_dataflow_hbm_bytes(shape, t, dataflow="zero_copy",
                                    batch=batch, bytes_per_elem=1)
    total_q = dcl_total_hbm_bytes(shape, t, dataflow="zero_copy",
                                  batch=batch, bytes_per_elem=1,
                                  offset_bytes_per_elem=4,
                                  out_bytes_per_elem=4)
    zero_total = dcl_total_hbm_bytes(shape, t, dataflow="zero_copy",
                                     batch=batch,
                                     bytes_per_elem=bytes_per_elem)
    zero_bwd = dcl_backward_hbm_bytes(shape, t, dataflow="zero_copy",
                                      batch=batch,
                                      bytes_per_elem=bytes_per_elem)
    zero_bwd_pc = dcl_backward_hbm_bytes(shape, t, dataflow="zero_copy",
                                         batch=batch,
                                         bytes_per_elem=bytes_per_elem,
                                         cores=cores, per_core=True)
    zero_bwd_mc = dcl_backward_hbm_bytes(shape, t, dataflow="zero_copy",
                                         batch=batch,
                                         bytes_per_elem=bytes_per_elem,
                                         cores=cores)
    band_bwd = dcl_backward_hbm_bytes(shape, t, dataflow="materialized_band",
                                      batch=batch,
                                      bytes_per_elem=bytes_per_elem)
    zero_train = dcl_train_hbm_bytes(shape, t, dataflow="zero_copy",
                                     batch=batch,
                                     bytes_per_elem=bytes_per_elem)
    band_train = dcl_train_hbm_bytes(shape, t, dataflow="materialized_band",
                                     batch=batch,
                                     bytes_per_elem=bytes_per_elem)
    if c == m:
        chain_shape = shape
    else:  # chaining needs C_in == C_out; model the square analogue
        chain_shape = LayerShape(h=h, w=w, c_in=c, c_out=c,
                                 kernel_size=kernel_size, stride=stride,
                                 offset_bound=offset_bound)
    chain_per_layer = dcl_chain_hbm_bytes(chain_shape, t, layers=2,
                                          batch=batch, chained=False)
    chain_fused = dcl_chain_hbm_bytes(chain_shape, t, layers=2,
                                      batch=batch, chained=True)
    total_q_fused = dcl_total_hbm_bytes(shape, t, dataflow="zero_copy",
                                        batch=batch, bytes_per_elem=1,
                                        out_bytes_per_elem=1,
                                        fused_offsets=True)
    return {
        "tiles": t,
        "zero_copy_bytes": zero,
        "materialized_band_bytes": band,
        "ratio": band / max(zero, 1),
        "zero_copy_bwd_bytes": zero_bwd,
        "materialized_band_bwd_bytes": band_bwd,
        "bwd_ratio": band_bwd / max(zero_bwd, 1),
        "cores": cores,
        "zero_copy_bwd_bytes_per_core": zero_bwd_pc,
        "zero_copy_bwd_bytes_mc_total": zero_bwd_mc,
        "bwd_per_core_ratio": zero_bwd / max(zero_bwd_pc, 1),
        "zero_copy_train_bytes": zero_train,
        "materialized_band_train_bytes": band_train,
        "train_ratio": band_train / max(zero_train, 1),
        "zero_copy_total_bytes": zero_total,
        "materialized_band_total_bytes": dcl_total_hbm_bytes(
            shape, t, dataflow="materialized_band", batch=batch,
            bytes_per_elem=bytes_per_elem),
        "zero_copy_bytes_q": zero_q,
        "q_ratio": zero / max(zero_q, 1),
        "zero_copy_total_bytes_q": total_q,
        "q_total_ratio": zero_total / max(total_q, 1),
        "tiles_int8": kt_q,
        "chain_layers": 2,
        "chain_per_layer_bytes": chain_per_layer,
        "chain_bytes": chain_fused,
        "chain_ratio": chain_per_layer / max(chain_fused, 1),
        "total_bytes_q_fused_offsets": total_q_fused,
    }


def parallel_training_report(*, h: int = 64, w: int = 64, c: int = 128,
                             m: int = 128, batch: int = 8, tile_h: int = 8,
                             tile_w: int | None = None,
                             offset_bound: float = 2.0,
                             kernel_size: int = 3, stride: int = 1,
                             cores: int = 2, devices: int = 4,
                             bytes_per_elem: int = 4) -> dict:
    """Modeled two-level parallel-training picture of one bounded DCL
    (EXPERIMENTS.md §Parallel training).

    **Core level (Megacore backward split).**  Each core owns a batch
    shard: its dw-stationary backward traffic (band recompute, d_input
    RMW, cotangent/weight fetches, d_offsets) drops exactly ``cores``x
    while it flushes one full partial-d_weights block.  The honest
    caveat is also modeled: the cores *share* HBM, so aggregate
    bandwidth demand does not drop — ``core_speedup_hbm_bound`` (~1x)
    vs ``core_speedup_compute_bound`` (= cores, the grid-step split) —
    Megacore pays off exactly when the backward is compute-bound, which
    the high-CTC chooser tiles make the common case.

    **Device level (shard_map data parallelism).**  Each device owns
    ``batch/devices`` samples with its *own* HBM, plus the d_weights
    psum over ICI each step.  ``device_speedup`` is the modeled
    HBM-time ratio t(1)/t(devices) with the psum charged at ICI
    bandwidth (2x dw bytes: reduce-scatter + all-gather halves of the
    ring all-reduce).
    """
    if batch % devices or (batch // devices) % cores:
        raise ValueError(
            f"devices={devices} must divide batch={batch}, and "
            f"cores={cores} must divide the per-device shard "
            f"({batch}//{devices}) — the same constraints "
            f"kernels.ops.check_batch_split enforces")
    shape = LayerShape(h=h, w=w, c_in=c, c_out=m, kernel_size=kernel_size,
                       stride=stride, offset_bound=offset_bound)
    if tile_w is None:
        kt = choose_kernel_tiles(shape, batch=batch)
        t = TileConfig(t_h=tile_h, t_w=kt.tile_w, t_n=kt.tile_c,
                       t_m=kt.tile_m)
    else:
        t = TileConfig(t_h=tile_h, t_w=tile_w, t_n=c, t_m=m)
    kw = dict(dataflow="zero_copy", dilation=1,
              bytes_per_elem=bytes_per_elem)
    bwd_1 = dcl_backward_hbm_bytes(shape, t, batch=batch, **kw)
    bwd_per_core = dcl_backward_hbm_bytes(shape, t, batch=batch,
                                          cores=cores, per_core=True, **kw)
    bwd_mc_total = dcl_backward_hbm_bytes(shape, t, batch=batch,
                                          cores=cores, **kw)
    dw_bytes = kernel_size ** 2 * c * m * bytes_per_elem
    per_dev_batch = batch // devices
    train_1 = dcl_train_hbm_bytes(shape, t, batch=batch, **kw)
    train_dev = dcl_train_hbm_bytes(shape, t, batch=per_dev_batch,
                                    cores=cores, **kw)
    # HBM-time model: each device streams its own shard from its own
    # HBM; the dw psum crosses ICI (ring all-reduce ~ 2x payload).
    t_single = train_1 / V5E_HBM_BW
    t_dev = train_dev / V5E_HBM_BW + 2 * dw_bytes / V5E_ICI_BW
    return {
        "tiles": t,
        "cores": cores,
        "devices": devices,
        "bwd_bytes_seq": bwd_1,
        "bwd_bytes_per_core": bwd_per_core,
        "bwd_bytes_mc_total": bwd_mc_total,
        "bwd_per_core_ratio": bwd_1 / max(bwd_per_core, 1),
        "dw_stationary_bytes": bwd_1 - dw_bytes,
        "core_speedup_compute_bound": float(cores),
        "core_speedup_hbm_bound": bwd_1 / max(bwd_mc_total, 1),
        "train_bytes_single": train_1,
        "train_bytes_per_device": train_dev,
        "dw_psum_bytes": dw_bytes,
        "modeled_step_sec_single": t_single,
        "modeled_step_sec_sharded": t_dev,
        "device_speedup": t_single / max(t_dev, 1e-30),
    }


def spatial_sharding_report(shape: LayerShape | None = None, *,
                            shards: tuple[int, ...] = (1, 2, 4),
                            dilation: int = 1,
                            bytes_per_elem: int = 4) -> dict:
    """Modeled single-image latency scaling of one bounded DCL under
    spatial (height-axis) sharding (ISSUE 10, EXPERIMENTS.md §Spatial
    sharding).

    The default shape is a megapixel-class early layer
    (1024x1024x64 -> 64, B = 2.0) — the regime the spatial path
    targets: batch parallelism has nothing to split at batch 1, but
    each of ``s`` devices streams only ``H/s`` rows from its own HBM
    plus one bounded halo exchange (``2 * halo * W * C`` bytes over
    ICI, halo = dilation*(K//2) + ceil(B) + 1 rows per edge).

    Per shard count ``s`` the report carries the per-device forward
    HBM bytes at the *locally* resolved chooser tiles
    (``fwd_hbm_bytes_{s}shard`` — includes the halo payload, matching
    ``tiling.dcl_spatial_hbm_bytes``), the exchange payload
    (``halo_bytes_{s}shard``), the single-device/per-device traffic
    ratio (``traffic_ratio_{s}shard``), and the modeled HBM-time
    speedup with the halo charged at ICI bandwidth
    (``modeled_speedup_{s}shard``).  The speedup undershoots the
    traffic ratio exactly by the ICI term — honest about the
    communication the split buys.
    """
    if shape is None:
        shape = LayerShape(h=1024, w=1024, c_in=64, c_out=64,
                           offset_bound=2.0)
    halo = spatial_halo_rows(kernel_size=shape.kernel_size,
                             dilation=dilation,
                             offset_bound=shape.offset_bound)
    kw = dict(dataflow="zero_copy", dilation=dilation,
              bytes_per_elem=bytes_per_elem)
    kt1 = choose_kernel_tiles(shape, dilation=dilation,
                              objective="forward")
    t1 = TileConfig(t_h=kt1.tile_h, t_w=kt1.tile_w, t_n=kt1.tile_c,
                    t_m=kt1.tile_m)
    base = dcl_total_hbm_bytes(shape, t1, **kw)
    t_single = base / V5E_HBM_BW
    out = {
        "shape": shape,
        "halo_rows": halo,
        "fwd_hbm_bytes_single": base,
        "modeled_us_single": t_single * 1e6,
    }
    for s in shards:
        local = dataclasses.replace(shape, h=shape.h // s)
        ktl = choose_kernel_tiles(local, dilation=dilation,
                                  objective="forward")
        tl = TileConfig(t_h=ktl.tile_h, t_w=ktl.tile_w, t_n=ktl.tile_c,
                        t_m=ktl.tile_m)
        per_dev = dcl_spatial_hbm_bytes(shape, tl, shards=s, **kw)
        halo_b = spatial_halo_bytes(shape, shards=s, dilation=dilation,
                                    bytes_per_elem=bytes_per_elem)
        t_dev = (per_dev - halo_b) / V5E_HBM_BW + halo_b / V5E_ICI_BW
        out[f"tiles_{s}shard"] = tl
        out[f"fwd_hbm_bytes_{s}shard"] = per_dev
        out[f"halo_bytes_{s}shard"] = halo_b
        out[f"traffic_ratio_{s}shard"] = base / max(per_dev, 1)
        out[f"modeled_us_{s}shard"] = t_dev * 1e6
        out[f"modeled_speedup_{s}shard"] = t_single / max(t_dev, 1e-30)
    return out


# ---------------------------------------------------------------------------
# Fig. 9 — energy model
# ---------------------------------------------------------------------------

def _common_dynamic_energy(wl: DCLWorkload) -> float:
    """Energy both designs pay: MACs, sequential in/weight/out streaming,
    and the stage-1 -> stage-2 patch round-trip through DRAM.  NOTE: the
    paper's OWN accelerator also stores interpolated inputs to DRAM
    between stages (their Fig. 4); eliminating that round-trip is this
    repo's beyond-paper fused-kernel optimization, accounted separately
    in ``tiling.two_stage_extra_bytes`` — not claimed here."""
    seq_bytes = (wl.h * wl.w * wl.n + wl.out_pixels * wl.m
                 + wl.kernel_size ** 2 * wl.n * wl.m) * 4
    patch_bytes = 2 * wl.out_pixels * wl.kernel_size ** 2 * wl.n * 4
    return (seq_bytes + patch_bytes) * E_DRAM_PJ_PER_BYTE_SEQ \
        + wl.macs * E_MAC_PJ


def _bram_pj_per_byte(capacity_bytes: int) -> float:
    """Larger SRAMs cost more per access (longer word/bit lines)."""
    return E_BRAM_PJ_PER_BYTE * math.sqrt(
        max(capacity_bytes, BRAM_REF_BYTES) / BRAM_REF_BYTES)


def energy_ours(wl: DCLWorkload, lam: float) -> float:
    """pJ for one DCL on the bounded-RF accelerator: all sampling reads
    hit the Eq. 6-sized on-chip buffer.  At lam=0 that buffer is 13.8 MB
    — the 'large on-chip buffer systems cause high energy consumption'
    the paper warns about — captured by capacity-scaled pJ/B."""
    onchip_bytes = wl.out_pixels * wl.kernel_size ** 2 * wl.n * 4 * 4
    cap = stall_free_capacity(lam)
    return _common_dynamic_energy(wl) + onchip_bytes * _bram_pj_per_byte(cap)


def energy_conventional(wl: DCLWorkload, lam: float) -> float:
    """pJ for the [22]-style dataflow.  Missed sample lines are fetched
    from DRAM with row-miss (irregular) pricing; the fetched line is
    inserted into the buffer so later output-channel passes hit on-chip
    (the ENERGY view; the TIME view in ``cycles_conventional`` still
    stalls every pass on pipeline refill)."""
    miss = _miss_rate(wl, lam, CONV_BUFFER_BYTES)
    misses = wl.out_pixels * wl.kernel_size ** 2 * 4 * miss
    rand_bytes = misses * math.ceil(wl.n * 4 / DRAM_BURST_BYTES) \
        * DRAM_BURST_BYTES
    onchip_bytes = wl.out_pixels * wl.kernel_size ** 2 * wl.n * 4 * 4
    return (_common_dynamic_energy(wl)
            + rand_bytes * E_DRAM_PJ_PER_BYTE_RAND
            + onchip_bytes * E_BRAM_PJ_PER_BYTE)


def energy_ratio(n_channels: int, lam_ours: float, lam_conv: float = 0.0,
                 **kw) -> float:
    """Fig. 9: energy of conventional (unregularized model) over ours."""
    wl = DCLWorkload(n=n_channels, m=n_channels, **kw)
    return energy_conventional(wl, lam_conv) / energy_ours(wl, lam_ours)


# ---------------------------------------------------------------------------
# Runtime health: bound saturation (PR 6)
# ---------------------------------------------------------------------------
#
# The whole dataflow is only as correct as the Eq. 5 bound: an
# out-of-distribution input whose offsets hit the clamp makes the kernel
# silently saturate where unbounded reference math would have sampled
# farther away.  The fraction of offset components clamped at B is a
# cheap health metric — compute it host-side on the (N, Ho, Wo, 2*K*K)
# offset tensor a layer already produced (numpy only; this module stays
# importable without jax).

def bound_saturation(offsets, offset_bound: float, *,
                     atol: float = 1e-6) -> float:
    """Fraction of offset components with |o| >= B (the Eq. 5 clamp).

    ``offsets`` is any array-like of raw offset-conv outputs; components
    within ``atol`` of the bound count as clamped (the kernel's clip
    makes |o| == B exactly).  0.0 for an empty tensor.
    """
    import numpy as np

    if offset_bound is None or offset_bound <= 0:
        raise ValueError(
            f"bound_saturation needs a positive offset_bound (got "
            f"{offset_bound!r}); the unbounded baseline has no clamp to "
            f"saturate")
    off = np.asarray(offsets, dtype=np.float32)
    if off.size == 0:
        return 0.0
    return float(np.mean(np.abs(off) >= (offset_bound - atol)))


def runtime_health_report(offsets, offset_bound: float, *,
                          threshold: float = 0.05) -> dict:
    """Gate ``bound_saturation`` against a deployment threshold.

    A healthy Eq. 5-trained model keeps the trained offsets well inside
    B (the half-normal tail puts ~1e-4 of the mass at o_max); a clamp
    fraction above ``threshold`` means the input distribution has
    drifted past what the bound was trained for — the signal to fall
    back down the degradation ladder (docs/robustness.md) or retrain
    the bound.
    """
    frac = bound_saturation(offsets, offset_bound)
    return {"offset_bound": float(offset_bound),
            "bound_saturation": frac,
            "threshold": float(threshold),
            "healthy": frac <= threshold}
