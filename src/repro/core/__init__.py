"""The paper's primary contribution, as a composable JAX module set:

* ``deform_conv``    — deformable convolution (Eq. 1-4) + RF algebra
* ``rf_regularizer`` — the Eq. 5 loss (hard max + smooth-max variant)
* ``tiling``         — Eq. 6/7 buffer model + VMEM-aware tile chooser
* ``perf_model``     — calibrated FPGA cycle/energy model (Figs. 3/8/9)
"""
from .deform_conv import (  # noqa: F401
    DCLConfig, dcl_forward, init_dcl_params, offset_abs_max,
    receptive_field, sample_patches)
from .rf_regularizer import (  # noqa: F401
    OffsetStats, network_offset_max, regularized_loss)
