"""Receptive-field regularization — the paper's Eq. 5.

    Loss = (1 - lambda) * L + lambda * max_{l in D} o_max^l ,  0 <= lambda < 1

where ``D`` is the set of deformable layers in the network and
``o_max^l`` is Eq. 3 evaluated on layer ``l``'s offset tensor.

The hard max is what the paper uses; its (sub)gradient flows only into
the single largest offset each step, which is exactly the mechanism that
pulls the tail of the offset histogram in (paper Fig. 7).  We also expose
a logsumexp smooth-max variant (``smoothness > 0``) as a beyond-paper
trainability option — it spreads gradient over all near-maximal offsets
and converges the RF bound faster at equal lambda (see EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def network_offset_max(o_maxes: Sequence[Array] | Array,
                       *, smoothness: float = 0.0) -> Array:
    """max_{l in D} o_max^l over the per-layer Eq. 3 statistics.

    With ``smoothness = t > 0`` uses ``t * logsumexp(o / t)`` — a smooth,
    strictly-upper bound on the hard max that tightens as t -> 0.
    """
    o = jnp.stack(list(o_maxes)) if not isinstance(o_maxes, jax.Array) else o_maxes
    if smoothness and smoothness > 0.0:
        return smoothness * jax.scipy.special.logsumexp(o / smoothness)
    return jnp.max(o)


def regularized_loss(task_loss: Array, o_maxes: Sequence[Array] | Array,
                     lam: float, *, smoothness: float = 0.0) -> Array:
    """Eq. 5.  ``lam`` must satisfy 0 <= lam < 1."""
    if not (0.0 <= lam < 1.0):
        raise ValueError(f"lambda must be in [0, 1), got {lam}")
    if lam == 0.0:
        return task_loss
    penalty = network_offset_max(o_maxes, smoothness=smoothness)
    return (1.0 - lam) * task_loss + lam * penalty


class OffsetStats:
    """Running collector for per-layer o_max statistics during eval.

    Used to reproduce the paper's Fig. 7 histogram: accumulate the Eq. 3
    max-offset value of every DCL over a validation set, then histogram
    the per-image network maxima.
    """

    def __init__(self) -> None:
        self.per_image_max: list[float] = []
        self.per_layer_max: dict[str, float] = {}

    def update(self, layer_maxes: Mapping[str, Array]) -> None:
        vals = {k: float(v) for k, v in layer_maxes.items()}
        for k, v in vals.items():
            self.per_layer_max[k] = max(self.per_layer_max.get(k, 0.0), v)
        if vals:
            self.per_image_max.append(max(vals.values()))

    def network_max(self) -> float:
        return max(self.per_layer_max.values()) if self.per_layer_max else 0.0

    def histogram(self, bins: int = 32) -> tuple[list[float], list[int]]:
        import numpy as np
        if not self.per_image_max:
            return [], []
        counts, edges = np.histogram(self.per_image_max, bins=bins)
        return list(map(float, edges)), list(map(int, counts))

    def compression_vs(self, other: "OffsetStats", kernel_size: int = 3) -> float:
        """RF compression ratio (paper: 12.6x between lambda=0 and 0.005)."""
        from .deform_conv import receptive_field
        rf_self = receptive_field(kernel_size, self.network_max())
        rf_other = receptive_field(kernel_size, other.network_max())
        return rf_other / rf_self
