"""Deformable convolution (Dai et al., ICCV'17) in pure JAX — the paper's Eq. 1-4.

This is the *reference* implementation of the deformable convolutional
layer (DCL) analysed by the paper:

    o = f(x, w_o)                      (Eq. 1)  -- offset-generating conv
    y = f(g(x, o), w_deform)           (Eq. 2)  -- conv over bilinearly
                                                   sampled inputs
    o_max = max_i |o_i|                (Eq. 3)
    RF    = K_C + 2 * ceil(o_max)      (Eq. 4)

Layout is NHWC (TPU-native).  Offsets are stored as (..., K*K, 2) with
``[..., 0] = dy`` and ``[..., 1] = dx`` relative to the regular grid tap
position.  Sampling outside the image contributes zero (standard DCN
semantics, matching bilinear interpolation against a zero-padded plane).

The hardware-friendly mode of the paper (bounded receptive field after
training with the Eq. 5 regularizer) is exposed via ``offset_bound``:
offsets are clamped to ``[-B, B]`` so the receptive field is statically
``K + 2*ceil(B)`` — this is what lets the Pallas kernels in
``repro.kernels`` use a fixed VMEM halo.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


# ---------------------------------------------------------------------------
# Receptive-field algebra (Eq. 3, Eq. 4)
# ---------------------------------------------------------------------------

def offset_abs_max(offsets: Array) -> Array:
    """Eq. 3: o_max = max over the offset tensor of |o_i| (sign = direction)."""
    return jnp.max(jnp.abs(offsets))


def receptive_field(kernel_size: int, o_max: float) -> int:
    """Eq. 4: RF = K_C + 2 * ceil(o_max)."""
    return int(kernel_size + 2 * math.ceil(float(o_max)))


def receptive_field_dynamic(kernel_size: int, o_max: Array) -> Array:
    """Traced version of Eq. 4 (used inside jitted stat collection)."""
    return kernel_size + 2 * jnp.ceil(o_max)


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DCLConfig:
    """Static configuration of one deformable convolutional layer."""

    in_channels: int
    out_channels: int
    kernel_size: int = 3
    stride: int = 1
    dilation: int = 1
    # Hardware-friendly receptive-field bound B (None = unbounded, the
    # paper's lambda=0 baseline).  With a bound, RF = K + 2*ceil(B).
    offset_bound: float | None = None
    use_bias: bool = True
    # dtype of the compute (params kept in float32 by default; cast at use).
    dtype: Any = jnp.float32

    @property
    def taps(self) -> int:
        return self.kernel_size * self.kernel_size

    @property
    def pad(self) -> int:
        # SAME-style padding for the regular grid (matches mmdetection DCN).
        return self.dilation * (self.kernel_size // 2)

    def static_rf(self) -> int | None:
        if self.offset_bound is None:
            return None
        return receptive_field(self.kernel_size, self.offset_bound)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_dcl_params(key: Array, cfg: DCLConfig) -> dict[str, Array]:
    """Init DCL params.

    Offset conv weights are initialised to zero (standard DCN practice:
    the layer starts as a plain convolution), deform weights use He init.
    """
    k_deform, = jax.random.split(key, 1)
    K, C, M = cfg.kernel_size, cfg.in_channels, cfg.out_channels
    fan_in = K * K * C
    w_deform = jax.random.normal(k_deform, (K, K, C, M), jnp.float32)
    w_deform = w_deform * jnp.sqrt(2.0 / fan_in)
    params = {
        "w_offset": jnp.zeros((K, K, C, 2 * K * K), jnp.float32),
        "w_deform": w_deform,
    }
    if cfg.use_bias:
        params["b_offset"] = jnp.zeros((2 * K * K,), jnp.float32)
        params["b_deform"] = jnp.zeros((M,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Standard convolution helper (NHWC x HWIO -> NHWC)
# ---------------------------------------------------------------------------

def conv2d(x: Array, w: Array, *, stride: int = 1, dilation: int = 1,
           padding: str | int = "SAME") -> Array:
    if isinstance(padding, int):
        pad = [(padding, padding), (padding, padding)]
    else:
        pad = padding
    return lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=pad,
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


# ---------------------------------------------------------------------------
# Bilinear sampling (the g(x, o) of Eq. 2)
# ---------------------------------------------------------------------------

def bilinear_sample(x: Array, pos_y: Array, pos_x: Array) -> Array:
    """Bilinearly sample ``x`` at float positions, zero outside the image.

    x:            (N, H, W, C)
    pos_y, pos_x: (N, P) float sample coordinates (pixel units)
    returns:      (N, P, C) in x.dtype

    Positions and interpolation coefficients are computed in fp32
    regardless of the data dtype (hardware analogue: the sampling
    controller generates addresses and coefficients at full precision
    even when the datapath is bf16); corner values accumulate in fp32
    and round once at the end.
    """
    N, H, W, C = x.shape
    pos_y = pos_y.astype(jnp.float32)
    pos_x = pos_x.astype(jnp.float32)
    y0 = jnp.floor(pos_y)
    x0 = jnp.floor(pos_x)
    ty = pos_y - y0  # in [0, 1)
    tx = pos_x - x0
    y0 = y0.astype(jnp.int32)
    x0 = x0.astype(jnp.int32)

    flat = x.reshape(N, H * W, C)

    def corner(yc: Array, xc: Array, wgt: Array) -> Array:
        valid = ((yc >= 0) & (yc < H) & (xc >= 0) & (xc < W))
        idx = jnp.clip(yc, 0, H - 1) * W + jnp.clip(xc, 0, W - 1)
        v = jnp.take_along_axis(flat, idx[..., None], axis=1)
        return v.astype(jnp.float32) \
            * (wgt * valid.astype(jnp.float32))[..., None]

    out = corner(y0, x0, (1.0 - ty) * (1.0 - tx))
    out = out + corner(y0, x0 + 1, (1.0 - ty) * tx)
    out = out + corner(y0 + 1, x0, ty * (1.0 - tx))
    out = out + corner(y0 + 1, x0 + 1, ty * tx)
    return out.astype(x.dtype)


def sample_patches(x: Array, offsets: Array, cfg: DCLConfig) -> Array:
    """g(x, o): gather bilinearly-interpolated K*K patches.

    x:       (N, H, W, C)
    offsets: (N, Ho, Wo, K*K, 2)   ([..., 0]=dy, [..., 1]=dx)
    returns: (N, Ho, Wo, K*K, C) interpolated inputs (the tensor the
             paper's stage 1 writes to its output buffer).
    """
    N, H, W, C = x.shape
    K, S, D, P = cfg.kernel_size, cfg.stride, cfg.dilation, cfg.pad
    Ho = (H + 2 * P - D * (K - 1) - 1) // S + 1
    Wo = (W + 2 * P - D * (K - 1) - 1) // S + 1
    assert offsets.shape == (N, Ho, Wo, K * K, 2), (
        f"offsets {offsets.shape} != {(N, Ho, Wo, K * K, 2)}")

    # Regular-grid base positions for every (output position, tap).
    oy = jnp.arange(Ho) * S - P            # (Ho,)
    ox = jnp.arange(Wo) * S - P            # (Wo,)
    ky, kx = jnp.meshgrid(jnp.arange(K) * D, jnp.arange(K) * D, indexing="ij")
    ky = ky.reshape(-1)                    # (K*K,)
    kx = kx.reshape(-1)

    base_y = oy[:, None, None] + ky[None, None, :]          # (Ho, 1, K*K)
    base_x = ox[None, :, None] + kx[None, None, :]          # (1, Wo, K*K)
    base_y = jnp.broadcast_to(base_y, (Ho, Wo, K * K))
    base_x = jnp.broadcast_to(base_x, (Ho, Wo, K * K))

    pos_y = base_y[None].astype(jnp.float32) + offsets[..., 0].astype(jnp.float32)
    pos_x = base_x[None].astype(jnp.float32) + offsets[..., 1].astype(jnp.float32)

    Pn = Ho * Wo * K * K
    sampled = bilinear_sample(x, pos_y.reshape(N, Pn), pos_x.reshape(N, Pn))
    return sampled.reshape(N, Ho, Wo, K * K, C)


# ---------------------------------------------------------------------------
# Full deformable convolution layer (Eq. 1 + Eq. 2)
# ---------------------------------------------------------------------------

def dcl_forward(params: dict[str, Array], x: Array, cfg: DCLConfig,
                *, return_stats: bool = True):
    """Run one DCL: offset conv -> clamp (optional) -> sample -> conv.

    Returns ``(y, stats)`` where ``stats['o_max']`` is the Eq. 3 statistic
    of the *unclamped* offsets (what the Eq. 5 regularizer penalises) and
    ``stats['offsets']`` the (possibly clamped) offsets actually used.
    """
    N, H, W, C = x.shape
    K = cfg.kernel_size
    xc = x.astype(cfg.dtype)

    # Stage 1a: offset generation (Eq. 1).
    o = conv2d(xc, params["w_offset"].astype(cfg.dtype),
               stride=cfg.stride, dilation=cfg.dilation, padding=cfg.pad)
    if "b_offset" in params:
        o = o + params["b_offset"].astype(cfg.dtype)
    Ho, Wo = o.shape[1], o.shape[2]
    offsets = o.reshape(N, Ho, Wo, K * K, 2)

    o_max = offset_abs_max(offsets)
    if cfg.offset_bound is not None:
        offsets = jnp.clip(offsets, -cfg.offset_bound, cfg.offset_bound)

    # Stage 1b: bilinear sampling (the g of Eq. 2).
    patches = sample_patches(xc, offsets, cfg)  # (N, Ho, Wo, K*K, C)

    # Stage 2: dynamic convolution == matmul over (K*K, C) taps (MXU-friendly).
    w = params["w_deform"].astype(cfg.dtype).reshape(K * K, C, cfg.out_channels)
    y = jnp.einsum("nhwkc,kcm->nhwm", patches, w,
                   preferred_element_type=jnp.float32).astype(cfg.dtype)
    if "b_deform" in params:
        y = y + params["b_deform"].astype(cfg.dtype)

    if not return_stats:
        return y
    stats = {"o_max": o_max, "rf_dynamic": receptive_field_dynamic(K, o_max)}
    return y, stats


@partial(jax.jit, static_argnames=("cfg",))
def dcl_forward_jit(params, x, cfg: DCLConfig):
    return dcl_forward(params, x, cfg)
