"""Loop-tiling buffer model (paper Eq. 6/7) and roofline-driven tile chooser.

The paper sizes its on-chip buffers for the deformable convolutional
layer (DCL) as

    Input buffer size  = RF * (S*T_W + RF - S) * T_N          (Eq. 6)
    Output buffer size = T_W * T_N * 2 * K_C^2                (Eq. 7)

where ``RF = K_C + 2*ceil(B)`` is the (bounded) receptive field, ``S``
the stride, ``T_W``/``T_N`` the tile width / input-channel tile, and the
output buffer holds offsets + interpolated inputs (the factor ``2*K^2``:
2 offset planes and the K^2-tap patch tensor share it double-buffered).

On TPU the same algebra sizes the **VMEM** working set of the Pallas
kernels in ``repro.kernels``: the input tile + halo must fit VMEM next
to the weight tile and the output accumulator.  ``choose_tiles`` solves
the paper's roofline-based tiling (Sec. 3.2, following Zhang FPGA'15)
against TPU constants instead of FPGA BRAM:

    attainable = min(peak_flops, CTC * hbm_bandwidth)

maximising compute-to-communication ratio (CTC) subject to the Eq. 6/7
VMEM bound and MXU alignment (8 sublanes x 128 lanes).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e target; the container only dry-runs these).
# ---------------------------------------------------------------------------

V5E_VMEM_BYTES = 128 * 1024 * 1024        # 128 MiB VMEM per core
V5E_PEAK_FLOPS_BF16 = 197e12              # 197 TFLOP/s bf16
V5E_HBM_BW = 819e9                        # 819 GB/s
V5E_ICI_BW = 50e9                         # ~50 GB/s per link
MXU_LANE = 128                            # lane (minor) alignment
MXU_SUBLANE = 8                           # sublane alignment (fp32)

# Datapath element widths understood by the dtype-aware budgets below.
# ``int8`` is the quantized DCL datapath (``repro.quant``): every VMEM
# byte holds 4x more of the Eq. 6 band than fp32, so the same budget
# admits wider tiles — the paper's fixed-point argument on TPU.
DTYPE_BYTES = {"int8": 1, "bf16": 2, "fp32": 4}


def dtype_bytes(dtype) -> int:
    """Bytes per element of a datapath dtype: accepts the string names
    of ``DTYPE_BYTES`` or anything ``jnp.dtype`` understands."""
    if dtype is None:
        raise ValueError("dtype is None; pass 'int8' | 'bf16' | 'fp32'")
    if isinstance(dtype, str) and dtype in DTYPE_BYTES:
        return DTYPE_BYTES[dtype]
    import numpy as np
    return int(np.dtype(dtype).itemsize)


# ---------------------------------------------------------------------------
# Paper buffer algebra
# ---------------------------------------------------------------------------

def receptive_field(kernel_size: int, offset_bound: float) -> int:
    """Eq. 4 (duplicated here so tiling is importable standalone)."""
    return int(kernel_size + 2 * math.ceil(float(offset_bound)))


def input_buffer_size(rf: int, stride: int, t_w: int, t_n: int,
                      *, bytes_per_elem: int = 4) -> int:
    """Eq. 6: bytes of input tile (+halo) needed for stall-free sampling."""
    return rf * (stride * t_w + rf - stride) * t_n * bytes_per_elem


def output_buffer_size(t_w: int, t_n: int, kernel_size: int,
                       *, bytes_per_elem: int = 4) -> int:
    """Eq. 7: bytes of output buffer (offsets + interpolated inputs)."""
    return t_w * t_n * 2 * kernel_size * kernel_size * bytes_per_elem


def weight_buffer_size(kernel_size: int, t_n: int, t_m: int,
                       *, bytes_per_elem: int = 4) -> int:
    """Weight tile for the dynamic-convolution stage (not in Eq. 6/7 —
    the paper holds all weights of the tile on chip; we account for it
    explicitly because VMEM is shared)."""
    return kernel_size * kernel_size * t_n * t_m * bytes_per_elem


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One loop-tiling point (the paper fixes T_N=512, T_M=64, T_H=1, T_W=8)."""
    t_h: int
    t_w: int
    t_n: int   # input-channel tile
    t_m: int   # output-channel tile

    def vmem_bytes(self, rf: int, stride: int, kernel_size: int,
                   *, bytes_per_elem: int = 4) -> int:
        band_h = rf + stride * (self.t_h - 1)              # Eq. 6 row extent
        inp = band_h * (stride * self.t_w + rf - stride) * self.t_n \
            * bytes_per_elem
        out = output_buffer_size(self.t_w * self.t_h, self.t_n, kernel_size,
                                 bytes_per_elem=bytes_per_elem)
        wgt = weight_buffer_size(kernel_size, self.t_n, self.t_m,
                                 bytes_per_elem=bytes_per_elem)
        acc = self.t_h * self.t_w * self.t_m * 4           # fp32 accumulator
        return inp + out + wgt + acc


PAPER_TILES = TileConfig(t_h=1, t_w=8, t_n=512, t_m=64)


# ---------------------------------------------------------------------------
# Roofline-driven tile chooser (Sec. 3.2 methodology on TPU constants)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerShape:
    """Shape of one DCL invocation used to evaluate a tiling point."""
    h: int
    w: int
    c_in: int
    c_out: int
    kernel_size: int = 3
    stride: int = 1
    offset_bound: float = 2.0

    @property
    def rf(self) -> int:
        return receptive_field(self.kernel_size, self.offset_bound)


def _align(v: int, a: int) -> int:
    return max(a, (v // a) * a)


def tile_candidates(shape: LayerShape) -> Iterable[TileConfig]:
    """Enumerate MXU-aligned tile points that divide the layer cleanly
    enough (we allow ragged edges; alignment matters more than divisibility)."""
    for t_h in (1, 2, 4, 8):
        for t_w in (8, 16, 32, 64):
            for t_n in (128, 256, 512):
                for t_m in (64, 128, 256):
                    if t_n > shape.c_in * 2 or t_m > shape.c_out * 2:
                        continue
                    yield TileConfig(t_h, t_w, min(t_n, _align(shape.c_in, MXU_LANE)),
                                     min(t_m, _align(shape.c_out, MXU_SUBLANE)))


def tile_flops(shape: LayerShape, t: TileConfig) -> int:
    """MACs*2 of one tile of the dynamic-convolution stage + bilinear stage."""
    k2 = shape.kernel_size ** 2
    conv = 2 * t.t_h * t.t_w * t.t_m * k2 * t.t_n
    bilinear = t.t_h * t.t_w * k2 * t.t_n * 8      # 4 corners * (mul+add)
    return conv + bilinear


def tile_hbm_bytes(shape: LayerShape, t: TileConfig,
                   *, bytes_per_elem: int = 2) -> int:
    """HBM traffic per tile: input band (+halo), weight tile, output tile.

    In the fused kernel the interpolated patches never travel to HBM —
    this is the beyond-paper saving; ``two_stage_extra_bytes`` accounts
    for the paper-faithful dataflow that round-trips them.
    """
    rf, s = shape.rf, shape.stride
    band_h = rf + s * (t.t_h - 1)
    inp = band_h * (s * t.t_w + rf - s) * t.t_n * bytes_per_elem
    wgt = shape.kernel_size ** 2 * t.t_n * t.t_m * bytes_per_elem
    out = t.t_h * t.t_w * t.t_m * bytes_per_elem
    return inp + wgt + out


def two_stage_extra_bytes(shape: LayerShape, t: TileConfig,
                          *, bytes_per_elem: int = 2) -> int:
    """Patches written + re-read by the paper's two-stage dataflow."""
    k2 = shape.kernel_size ** 2
    return 2 * t.t_h * t.t_w * k2 * t.t_n * bytes_per_elem


@dataclasses.dataclass(frozen=True)
class TileChoice:
    tile: TileConfig
    ctc: float                 # compute-to-communication ratio (flops/byte)
    attainable_flops: float    # roofline-attainable performance
    vmem_bytes: int

    @property
    def fits(self) -> bool:
        return self.vmem_bytes <= V5E_VMEM_BYTES


def evaluate_tile(shape: LayerShape, t: TileConfig, *, fused: bool = True,
                  vmem_budget: int = V5E_VMEM_BYTES) -> TileChoice:
    flops = tile_flops(shape, t)
    traffic = tile_hbm_bytes(shape, t)
    if not fused:
        traffic += two_stage_extra_bytes(shape, t)
    ctc = flops / max(traffic, 1)
    attainable = min(V5E_PEAK_FLOPS_BF16, ctc * V5E_HBM_BW)
    vmem = t.vmem_bytes(shape.rf, shape.stride, shape.kernel_size,
                        bytes_per_elem=2)
    return TileChoice(tile=t, ctc=ctc, attainable_flops=attainable,
                      vmem_bytes=vmem)


def choose_tiles(shape: LayerShape, *, fused: bool = True,
                 vmem_budget: int = V5E_VMEM_BYTES) -> TileChoice:
    """Pick the tiling point with the highest roofline-attainable perf
    among those whose Eq. 6/7 working set fits VMEM (paper Sec. 3.2)."""
    best: TileChoice | None = None
    for t in tile_candidates(shape):
        c = evaluate_tile(shape, t, fused=fused, vmem_budget=vmem_budget)
        if c.vmem_bytes > vmem_budget:
            continue
        if best is None or (c.attainable_flops, c.ctc) > (best.attainable_flops, best.ctc):
            best = c
    if best is None:
        raise ValueError(
            f"no tile configuration fits VMEM budget {vmem_budget} for {shape}; "
            f"receptive field {shape.rf} too large — train with a larger lambda")
    return best


# ---------------------------------------------------------------------------
# Dataflow-level HBM traffic (zero-copy vs materialized-band) and the
# kernel tile chooser used by ``repro.kernels.ops``.
# ---------------------------------------------------------------------------

def band_extent(tile: int, *, kernel_size: int, stride: int,
                dilation: int = 1, offset_bound: float) -> int:
    """Eq. 6 band extent along one axis for an output tile of ``tile``
    positions, matching ``kernels.deform_sample.band_geometry`` exactly
    (the +2 covers the bilinear x0+1 corner on each side)."""
    hb = int(math.ceil(float(offset_bound)))
    return (tile - 1) * stride + (kernel_size - 1) * dilation + 2 * hb + 2


def out_hw(h: int, w: int, *, kernel_size: int, stride: int,
           dilation: int = 1) -> tuple[int, int]:
    """'Same'-padded output spatial dims of one DCL invocation."""
    pad = dilation * (kernel_size // 2)
    ho = (h + 2 * pad - dilation * (kernel_size - 1) - 1) // stride + 1
    wo = (w + 2 * pad - dilation * (kernel_size - 1) - 1) // stride + 1
    return ho, wo


def dcl_dataflow_hbm_bytes(shape: LayerShape, t: TileConfig, *,
                           dataflow: str = "zero_copy", batch: int = 1,
                           dilation: int = 1,
                           bytes_per_elem: int = 4) -> int:
    """Input-dataflow HBM bytes for one whole DCL layer.

    ``zero_copy``: the padded input stays in HBM; the kernel DMAs one
    (band_h, band_w) window per (row-tile, width-tile, M-tile, C-chunk)
    grid step — halo rows are re-read at tile boundaries, nothing is
    duplicated.

    ``materialized_band``: the legacy XLA path reads the padded input
    once, *writes* every overlapping full-width row band back to HBM
    (a band_h/(tile_h*stride) duplication of the input), and the kernel
    re-reads those full-width bands per (M-tile, C-chunk) pass.
    """
    k, s, b = shape.kernel_size, shape.stride, shape.offset_bound
    c, m = shape.c_in, shape.c_out
    ho, wo = out_hw(shape.h, shape.w, kernel_size=k, stride=s,
                    dilation=dilation)
    h_tiles = -(-ho // t.t_h)
    w_tiles = -(-wo // t.t_w)
    m_passes = -(-m // t.t_m)
    band_h = band_extent(t.t_h, kernel_size=k, stride=s, dilation=dilation,
                         offset_bound=b)
    hb = int(math.ceil(float(b)))
    pad = dilation * (k // 2)
    # Padded full-width extent (what the legacy path stages per band).
    w_full = wo * s + band_extent(1, kernel_size=k, stride=s,
                                  dilation=dilation, offset_bound=b) - s
    if dataflow == "zero_copy":
        band_w = band_extent(t.t_w, kernel_size=k, stride=s,
                             dilation=dilation, offset_bound=b)
        reads = h_tiles * w_tiles * m_passes * band_h * band_w * c
        return batch * reads * bytes_per_elem
    if dataflow == "materialized_band":
        hp = shape.h + 2 * (pad + hb) + 1
        x_read = hp * w_full * c                      # the jnp.take source
        band_elems = h_tiles * band_h * w_full * c    # duplicated bands
        kernel_reads = band_elems * m_passes          # per M-tile pass
        return batch * (x_read + band_elems + kernel_reads) * bytes_per_elem
    raise ValueError(f"unknown dataflow {dataflow!r}")


def dcl_total_hbm_bytes(shape: LayerShape, t: TileConfig, *,
                        dataflow: str = "zero_copy", batch: int = 1,
                        dilation: int = 1, bytes_per_elem: int = 4,
                        offset_bytes_per_elem: int | None = None,
                        out_bytes_per_elem: int | None = None,
                        fused_offsets: bool = False) -> int:
    """Whole-layer HBM traffic: input dataflow + offsets + weights + out.

    Weight blocks are re-fetched per (row-tile, width-tile) because the
    C/M grid axes cycle inside each spatial tile (same for both
    dataflows); offsets and output travel once.

    ``offset_bytes_per_elem`` / ``out_bytes_per_elem`` override the
    element width of the offset planes and the output tensor — the int8
    datapath keeps both at fp32 (address generation is full precision
    and the fused dequant epilogue emits fp32) while the input band and
    weight blocks travel at 1 byte/elem.

    ``fused_offsets`` models the chained datapath's in-kernel offset
    stage (``band_pipeline.offset_conv_stage``): the offsets never
    exist in HBM (the term drops entirely) and the offset-conv weight
    blocks are re-fetched per spatial tile alongside the deform blocks
    instead.
    """
    k2 = shape.kernel_size ** 2
    off_b = offset_bytes_per_elem or bytes_per_elem
    out_b = out_bytes_per_elem or bytes_per_elem
    ho, wo = out_hw(shape.h, shape.w, kernel_size=shape.kernel_size,
                    stride=shape.stride, dilation=dilation)
    h_tiles = -(-ho // t.t_h)
    w_tiles = 1 if dataflow == "materialized_band" else -(-wo // t.t_w)
    inp = dcl_dataflow_hbm_bytes(shape, t, dataflow=dataflow, batch=batch,
                                 dilation=dilation,
                                 bytes_per_elem=bytes_per_elem)
    if fused_offsets:
        offs = batch * h_tiles * w_tiles * k2 * shape.c_in * 2 * k2 \
            * bytes_per_elem
    else:
        offs = batch * ho * wo * 2 * k2 * off_b
    wgt = batch * h_tiles * w_tiles * k2 * shape.c_in * shape.c_out \
        * bytes_per_elem
    out = batch * ho * wo * shape.c_out * out_b
    return inp + offs + wgt + out


def dcl_chain_hbm_bytes(shape: LayerShape, t: TileConfig, *,
                        layers: int = 2, batch: int = 1,
                        dilation: int = 1,
                        chained: bool = True) -> int:
    """Whole-stack HBM bytes of ``layers`` back-to-back int8 DCLs —
    the chained-layer accounting behind the ``quant="int8_chain"``
    datapath (requires ``shape.c_in == shape.c_out``: chaining hands
    the tensor over verbatim).

    ``chained=False`` models the per-layer int8 datapath, charging each
    layer everything it actually costs end to end:

    * the XLA offset pass reads the fp32 input plane and writes the
      fp32 offsets, which the kernel then re-reads;
    * the quantize pass reads the fp32 plane again and writes the int8
      plane the band DMA consumes;
    * the kernel streams int8 bands + weight blocks and emits the
      output fp32 — which is exactly the fp32 plane the NEXT layer's
      offset/quantize passes re-read (no double counting: each layer
      owns its own input prep).

    ``chained=True`` models the fused datapath: the input arrives int8
    (the head is quantized once — charged to the first layer), the
    offset conv runs in-kernel over the already-staged band (its only
    extra HBM traffic is the int8 offset-weight blocks, re-fetched per
    spatial tile like the deform blocks; the offsets themselves never
    exist in HBM), and each inter-layer tensor is emitted int8 on the
    next layer's grid — crossing HBM once, at 1 byte/elem.  The chain
    TAIL is priced honestly at fp32 (the last layer has no ``y_scale``
    and emits through the dequant epilogue — exactly the ``emit="fp32"``
    configuration the benchmarks time), so both sides of the ratio end
    in the same fp32 tensor.

    The modeled chained/per-layer ratio is gated >= 1.3x in
    ``tests/test_chain.py`` and ``benchmarks/run.py`` (the PR
    acceptance number reported by ``perf_model.dataflow_traffic_report``
    ``chain_*`` keys).
    """
    if shape.c_in != shape.c_out:
        raise ValueError(
            f"chained layers hand the tensor over verbatim, so C_in "
            f"must equal C_out (got {shape.c_in} != {shape.c_out})")
    k2 = shape.kernel_size ** 2
    c, m = shape.c_in, shape.c_out
    ho, wo = out_hw(shape.h, shape.w, kernel_size=shape.kernel_size,
                    stride=shape.stride, dilation=dilation)
    h_tiles = -(-ho // t.t_h)
    w_tiles = -(-wo // t.t_w)
    plane = shape.h * shape.w * c                  # input plane elems
    off_elems = ho * wo * 2 * k2
    band_q = dcl_dataflow_hbm_bytes(shape, t, dataflow="zero_copy",
                                    batch=batch, dilation=dilation,
                                    bytes_per_elem=1)
    wgt_q = batch * h_tiles * w_tiles * k2 * c * m            # int8 blocks
    if not chained:
        per_layer = (
            band_q
            + batch * (plane * 4                  # offset pass: fp32 read
                       + off_elems * 4            # offsets written fp32
                       + off_elems * 4            # ... re-read by kernel
                       + plane * 4 + plane        # quantize: read + write
                       + ho * wo * m * 4)         # fp32 output emission
            + wgt_q)
        return layers * per_layer
    woff_q = batch * h_tiles * w_tiles * k2 * c * 2 * k2      # int8 blocks
    head_quant = batch * (plane * 4 + plane)      # quantize once, layer 0
    per_layer = band_q + wgt_q + woff_q + batch * ho * wo * m  # int8 out
    tail_fp32 = batch * ho * wo * m * 3           # last emission 4B, not 1B
    return layers * per_layer + head_quant + tail_fp32


def spatial_halo_rows(*, kernel_size: int, dilation: int = 1,
                      offset_bound: float) -> int:
    """Input rows each height-shard neighbor must contribute for the
    spatially sharded bounded DCL (``distributed.spatial``).

    An output row ``t`` of the bounded kernel samples original input
    rows in ``[t*s - (pad + hb), t*s + pad + hb + 1]`` where
    ``pad = dilation*(K//2)`` is the 'same' conv padding, ``hb =
    ceil(B)`` the Eq. 5 offset bound, and the ``+1`` the bilinear
    ``x0+1`` corner.  The symmetric halo that covers both directions is

        halo = dilation*(K//2) + ceil(B) + 1

    which for ``dilation=1`` and odd ``K`` is exactly the paper-derived
    ``ceil(B) + ceil(K/2)`` bound — the same Eq. 6 locality argument
    that sizes the on-chip band, applied across devices.  This is the
    single source of the halo algebra: ``distributed.spatial`` and the
    traffic model below both delegate here so the exchange and its
    model can never disagree.
    """
    if kernel_size < 1 or dilation < 1:
        raise ValueError(f"kernel_size={kernel_size}/dilation={dilation} "
                         f"must be >= 1")
    return dilation * (kernel_size // 2) \
        + int(math.ceil(float(offset_bound))) + 1


def spatial_halo_bytes(shape: LayerShape, *, shards: int,
                       dilation: int = 1, bytes_per_elem: int = 4) -> int:
    """Per-device halo-exchange bytes of one height-sharded DCL layer:
    ``2 * halo_rows * W * C`` (one up + one down ``lax.ppermute`` per
    layer; edge shards receive zeros for free).  Zero at 1 shard —
    there is no exchange to pay."""
    if shards < 1:
        raise ValueError(f"shards={shards} must be >= 1")
    if shards == 1:
        return 0
    halo = spatial_halo_rows(kernel_size=shape.kernel_size,
                             dilation=dilation,
                             offset_bound=shape.offset_bound)
    return 2 * halo * shape.w * shape.c_in * bytes_per_elem


def dcl_spatial_hbm_bytes(shape: LayerShape, t: TileConfig, *,
                          shards: int, dataflow: str = "zero_copy",
                          batch: int = 1, dilation: int = 1,
                          bytes_per_elem: int = 4) -> int:
    """Per-device whole-layer traffic of the height-sharded bounded DCL:
    the per-shard layer traffic (the shard's ``H/shards`` rows through
    ``dcl_total_hbm_bytes``) plus the ``2*halo_rows*W*C`` halo-exchange
    bytes of the one up/down ``ppermute`` pair.  ``shape`` is the
    GLOBAL layer shape; divisibility follows the runtime's
    ``check_height_split`` contract (``H % (stride*shards) == 0``)."""
    if shards < 1:
        raise ValueError(f"shards={shards} must be >= 1")
    if shape.h % (shape.stride * shards) != 0:
        raise ValueError(
            f"shards={shards} does not evenly divide H={shape.h} at "
            f"stride={shape.stride}; the spatial shard_map needs equal "
            f"per-device row blocks (H % (stride*shards) == 0)")
    local = dataclasses.replace(shape, h=shape.h // shards)
    return (dcl_total_hbm_bytes(local, t, dataflow=dataflow, batch=batch,
                                dilation=dilation,
                                bytes_per_elem=bytes_per_elem)
            + spatial_halo_bytes(shape, shards=shards, dilation=dilation,
                                 bytes_per_elem=bytes_per_elem))


def dcl_backward_hbm_bytes(shape: LayerShape, t: TileConfig, *,
                           dataflow: str = "zero_copy", batch: int = 1,
                           dilation: int = 1,
                           bytes_per_elem: int = 4,
                           cores: int = 1,
                           per_core: bool = False) -> int:
    """HBM bytes of one whole-layer DCL *backward* pass.

    ``cores`` models the Megacore batch split of
    ``kernels.deform_conv_bwd`` (zero-copy only): every batch-indexed
    term — the band recompute read, the d_input read-modify-write, the
    cotangent/weight fetches, the d_offsets writes — is owned by
    exactly one core, so the *per-core* traffic (``per_core=True``) is
    those "dw-stationary" terms divided by ``cores`` plus one full
    partial-``d_weights`` flush.  The default total view charges every
    core's partial flush plus the reduce epilogue (read ``cores``
    partials, write one reduced block); the batch-indexed terms are
    unchanged in aggregate — cores split *work*, they don't shrink it.

    ``zero_copy`` models ``kernels.deform_conv_bwd``: per (row-tile,
    width-tile, C-chunk) grid step the kernel re-reads one Eq. 6
    (band_h, band_w) input band chunk (cheap recompute of the sampled
    patches — no patch residual is ever saved), reads the cotangent tile
    and weight block, read-modify-writes the same-shaped ``d_input``
    band region, and flushes the fp32 ``d_weights`` accumulator block.
    The output-channel axis is untiled in backward, so input bands are
    NOT re-read per M-pass (unlike forward).

    ``materialized_band`` models the pre-PR training path: XLA
    differentiates a two-stage (sample -> einsum) reference, which
    (a) materializes overlapping full-width row bands in HBM for the
    recompute, (b) materializes an equal-sized banded scatter buffer
    for ``d_input`` that is written, re-read, and reduced into the
    input gradient, and (c) — the dominant term — round-trips the
    (N, Ho, Wo, K^2, C) patch tensor through HBM: the einsum VJP reads
    the saved patch residual and writes+reads ``d_patches`` before the
    sampling VJP can consume it.  The fused backward kernel contracts
    both in VMEM; neither tensor ever exists in HBM.  Each dataflow is
    charged its own cotangent/weight cadence (the fused kernel
    re-fetches per tile and over-flushes d_weights; the XLA baseline
    streams those once) so the ratio reflects the dataflows, not a
    shared-term artifact.
    """
    k, s, b = shape.kernel_size, shape.stride, shape.offset_bound
    k2 = k * k
    c, m = shape.c_in, shape.c_out
    ho, wo = out_hw(shape.h, shape.w, kernel_size=k, stride=s,
                    dilation=dilation)
    h_tiles = -(-ho // t.t_h)
    w_tiles = -(-wo // t.t_w)
    c_steps = -(-c // t.t_n)
    band_h = band_extent(t.t_h, kernel_size=k, stride=s, dilation=dilation,
                         offset_bound=b)
    hb = int(math.ceil(float(b)))
    pad = dilation * (k // 2)
    w_full = wo * s + band_extent(1, kernel_size=k, stride=s,
                                  dilation=dilation, offset_bound=b) - s

    doff_writes = ho * wo * 2 * k2
    if cores < 1:
        raise ValueError(f"cores={cores} must be >= 1")
    if dataflow != "zero_copy" and (cores != 1 or per_core):
        raise ValueError(
            f"cores={cores}/per_core={per_core} model the Megacore "
            f"split of the zero-copy backward kernel only (got "
            f"dataflow={dataflow!r})")

    if dataflow == "zero_copy":
        # Per-grid-step costs of the fused backward kernel: the
        # cotangent tile is fetched once per spatial tile (its
        # BlockSpec index is constant in the C-chunk axis), the weight
        # block per C-chunk step, and the fp32 d_weights accumulator
        # flushes once per C-chunk block on the LAST spatial grid step
        # (the kernel keeps the every-step flush only under interpret
        # mode; see ``deform_conv_bwd._bwd_zerocopy_kernel``) — the
        # h_tiles*w_tiles*batch over-flush factor of the PR-2 cadence
        # is gone: the last-step condition includes the batch grid
        # axis, so dw_writes is a whole-layer constant, NOT per-batch.
        g_reads = ho * wo * m
        w_reads = h_tiles * w_tiles * k2 * c * m
        dw_writes = k2 * c * m
        band_w = band_extent(t.t_w, kernel_size=k, stride=s,
                             dilation=dilation, offset_bound=b)
        band_elems = h_tiles * w_tiles * band_h * band_w * c
        inp = band_elems          # recompute read
        dx_rmw = 2 * band_elems   # d_input band read + write per step
        batch_terms = inp + dx_rmw + g_reads + w_reads + doff_writes
        if per_core:
            # One core's share: its batch shard's terms + its own full
            # partial-d_weights flush (the dw-stationary terms drop
            # exactly cores x; dw does not — each core carries a whole
            # partial).
            return (-(-batch // cores) * batch_terms
                    + dw_writes) * bytes_per_elem
        if cores > 1:
            # Aggregate: per-core partial flushes + the sum epilogue
            # (read cores partials, write the reduced block).
            dw_total = cores * dw_writes + (cores + 1) * dw_writes
            return (batch * batch_terms + dw_total) * bytes_per_elem
        return (batch * batch_terms + dw_writes) * bytes_per_elem
    if dataflow == "materialized_band":
        # XLA autodiff of the two-stage reference is NOT spatially
        # tiled: it reads g twice (d_weights and d_patches einsums),
        # reads w and writes dw once *per layer* (the einsum VJP
        # contracts the batch axis) — charged at those once-through
        # sizes outside the batch multiplier, not the fused kernel's
        # per-tile re-fetch cadence.
        g_reads = 2 * ho * wo * m
        w_reads = k2 * c * m
        dw_writes = k2 * c * m
        hp = shape.h + 2 * (pad + hb) + 1
        x_read = hp * w_full * c
        band_elems = h_tiles * band_h * w_full * c
        # recompute staging: bands written then re-read by the kernel
        inp = x_read + 2 * band_elems
        # d_input: banded scatter buffer written, re-read, reduced
        dx_bands = 2 * band_elems + hp * w_full * c
        # two-stage patch round-trip: patch residual read + d_patches
        # written by the einsum VJP + read by the sampling VJP
        patches = 3 * ho * wo * k2 * c
        return (batch * (inp + dx_bands + patches + g_reads + doff_writes)
                + w_reads + dw_writes) * bytes_per_elem
    raise ValueError(f"unknown dataflow {dataflow!r}")


def dcl_train_hbm_bytes(shape: LayerShape, t: TileConfig, *,
                        dataflow: str = "zero_copy", batch: int = 1,
                        dilation: int = 1, bytes_per_elem: int = 4,
                        cores: int = 1) -> int:
    """Combined fwd+bwd whole-layer HBM traffic — the objective the
    kernel tile chooser minimizes for training (``objective='training'``).
    ``cores`` charges the Megacore backward's extra partial-d_weights
    flushes + reduce epilogue (zero-copy only)."""
    bwd_cores = cores if dataflow == "zero_copy" else 1
    return (dcl_total_hbm_bytes(shape, t, dataflow=dataflow, batch=batch,
                                dilation=dilation,
                                bytes_per_elem=bytes_per_elem)
            + dcl_backward_hbm_bytes(shape, t, dataflow=dataflow,
                                     batch=batch, dilation=dilation,
                                     bytes_per_elem=bytes_per_elem,
                                     cores=bwd_cores))


def zerocopy_vmem_bytes(shape: LayerShape, t: TileConfig, *,
                        dilation: int = 1, bytes_per_elem: int = 2,
                        aux_bytes_per_elem: int | None = None) -> int:
    """VMEM working set of the zero-copy fused kernel: double-buffered
    Eq. 6 (band_h, band_w) input scratch + weight block + offsets block
    + fp32/int32 accumulator + output tile.

    ``aux_bytes_per_elem`` sizes the offsets block and the output tile
    separately from the datapath — the int8 kernel keeps both fp32
    (addresses are full precision; the dequant epilogue emits fp32), so
    the dtype-aware chooser passes 4 there while the band and weight
    blocks shrink to 1 byte/elem.
    """
    k2 = shape.kernel_size ** 2
    aux_b = aux_bytes_per_elem or bytes_per_elem
    band_h = band_extent(t.t_h, kernel_size=shape.kernel_size,
                         stride=shape.stride, dilation=dilation,
                         offset_bound=shape.offset_bound)
    band_w = band_extent(t.t_w, kernel_size=shape.kernel_size,
                         stride=shape.stride, dilation=dilation,
                         offset_bound=shape.offset_bound)
    band = 2 * band_h * band_w * t.t_n * bytes_per_elem   # double buffer
    wgt = k2 * t.t_n * t.t_m * bytes_per_elem
    offs = t.t_h * t.t_w * 2 * k2 * aux_b
    acc = t.t_h * t.t_w * t.t_m * 4
    out = t.t_h * t.t_w * t.t_m * aux_b
    return band + wgt + offs + acc + out


def zerocopy_bwd_vmem_bytes(shape: LayerShape, t: TileConfig, *,
                            dilation: int = 1,
                            bytes_per_elem: int = 2) -> int:
    """VMEM working set of the fused zero-copy *backward* kernel
    (``kernels.deform_conv_bwd``): double-buffered Eq. 6 input band +
    same-shaped d_input read-modify-write band + the full fp32
    d_weights accumulator (K^2 * C * M — the M axis is untiled in
    backward) + cotangent tile + weight block + fp32 d_offsets
    accumulator."""
    k2 = shape.kernel_size ** 2
    band_h = band_extent(t.t_h, kernel_size=shape.kernel_size,
                         stride=shape.stride, dilation=dilation,
                         offset_bound=shape.offset_bound)
    band_w = band_extent(t.t_w, kernel_size=shape.kernel_size,
                         stride=shape.stride, dilation=dilation,
                         offset_bound=shape.offset_bound)
    band = 2 * band_h * band_w * t.t_n * bytes_per_elem   # double buffer
    rmw = band_h * band_w * t.t_n * bytes_per_elem        # d_input band
    dw_acc = k2 * shape.c_in * shape.c_out * 4            # fp32, all chunks
    g_tile = t.t_h * t.t_w * shape.c_out * bytes_per_elem
    wgt = k2 * t.t_n * shape.c_out * bytes_per_elem
    doff_acc = t.t_h * t.t_w * 2 * k2 * 4
    return band + rmw + dw_acc + g_tile + wgt + doff_acc


def _divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (>= 1)."""
    cap = max(1, min(cap, n))
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


@dataclasses.dataclass(frozen=True)
class KernelTiles:
    """Concrete (divisor-snapped) tile sizes for the Pallas kernels."""
    tile_h: int
    tile_w: int
    tile_c: int
    tile_m: int


@functools.lru_cache(maxsize=512)
def choose_kernel_tiles(shape: LayerShape, *, batch: int = 1,
                        dilation: int = 1,
                        objective: str = "training",
                        dtype: str | None = None,
                        cores: int = 1,
                        vmem_budget: int = V5E_VMEM_BYTES) -> KernelTiles:
    """Pick (tile_h, tile_w, tile_c, tile_m) for the zero-copy fused
    kernels: minimize modeled whole-layer HBM traffic among tile points
    whose double-buffered working set fits VMEM, then snap the channel
    tiles to divisors of (C, M) as the kernels require.

    ``objective`` selects the traffic term: ``"forward"`` minimizes the
    inference pass only; ``"training"`` (default — what
    ``ops.deform_conv`` uses, since the same resolved tiles serve the
    custom-VJP backward kernel) minimizes the combined fwd+bwd traffic
    of ``dcl_train_hbm_bytes`` and additionally requires the backward
    working set (``zerocopy_bwd_vmem_bytes``) to fit VMEM.

    ``dtype`` makes both budgets element-width-aware: ``"int8"`` sizes
    the Eq. 6 band and weight blocks at 1 byte/elem (4x the band per
    VMEM byte vs fp32 — the quantized-datapath win the paper's
    fixed-point design banks on), ``"bf16"``/``"fp32"`` at 2/4.  The
    legacy ``dtype=None`` keeps the PR-1/2 convention (bf16 VMEM
    working set, fp32 traffic) so existing chooser results are stable.

    ``cores`` evaluates the training objective with the Megacore
    backward split's traffic (extra partial-d_weights flushes + reduce
    epilogue): the dw terms grow with cores, so the chooser leans
    toward channel tiles that keep the per-core partial cheap.  Each
    core has its own VMEM on Megacore parts, so the VMEM budgets are
    already per-core and need no scaling.

    This replaces the hand-passed tile arguments of ``ops.deform_conv``
    (Sec. 3.2 methodology, evaluated on the zero-copy traffic model).
    The row-tile candidate set extends to 32: per-tile halo re-reads
    amortize with taller tiles, and the PR-2 128-channel regression
    traced to the chooser stopping at tile_h=16 while the bench pinned
    tile_h=8 (see EXPERIMENTS.md §Perf).
    """
    if objective not in ("forward", "training"):
        raise ValueError(f"unknown objective {objective!r}")
    if cores < 1:
        raise ValueError(f"cores={cores} must be >= 1")
    vmem_b = dtype_bytes(dtype) if dtype is not None else 2
    traffic_b = dtype_bytes(dtype) if dtype is not None else 4
    # The int8 kernel keeps offsets/output fp32 (address precision +
    # dequant epilogue) — size those VMEM terms at 4 bytes, not 1.
    aux_b = 4 if dtype == "int8" else None
    ho, wo = out_hw(shape.h, shape.w, kernel_size=shape.kernel_size,
                    stride=shape.stride, dilation=dilation)
    ths = sorted({min(t, max(1, ho)) for t in (1, 2, 4, 8, 16, 32)})
    tws = sorted({min(t, max(1, wo)) for t in (8, 16, 32, 64, 128)})
    tns = sorted({_divisor_at_most(shape.c_in, cap)
                  for cap in (32, 64, 128, 256, 512, shape.c_in)})
    tms = sorted({_divisor_at_most(shape.c_out, cap)
                  for cap in (32, 64, 128, 256, shape.c_out)})
    traffic_fn = (functools.partial(dcl_train_hbm_bytes, cores=cores)
                  if objective == "training" else dcl_total_hbm_bytes)
    best: tuple[tuple, TileConfig] | None = None
    for t_h in ths:
        for t_w in tws:
            for t_n in tns:
                for t_m in tms:
                    t = TileConfig(t_h, t_w, t_n, t_m)
                    vmem = zerocopy_vmem_bytes(shape, t, dilation=dilation,
                                               bytes_per_elem=vmem_b,
                                               aux_bytes_per_elem=aux_b)
                    if objective == "training":
                        vmem = max(vmem, zerocopy_bwd_vmem_bytes(
                            shape, t, dilation=dilation,
                            bytes_per_elem=vmem_b))
                    if vmem > vmem_budget:
                        continue
                    traffic = traffic_fn(
                        shape, t, dataflow="zero_copy", batch=batch,
                        dilation=dilation, bytes_per_elem=traffic_b)
                    # Minimize traffic; break ties toward bigger MXU tiles.
                    key = (float(traffic), -t_n * t_m, -t_h * t_w)
                    if best is None or key < best[0]:
                        best = (key, t)
    if best is None:
        raise ValueError(
            f"no zero-copy tile configuration fits VMEM budget "
            f"{vmem_budget} for {shape} at dtype={dtype or 'legacy'}; "
            f"receptive field {shape.rf} too large — train with a larger "
            f"lambda")
    t = best[1]
    return KernelTiles(tile_h=t.t_h, tile_w=t.t_w, tile_c=t.t_n,
                       tile_m=t.t_m)


def neighbor_kernel_tiles(shape: LayerShape, seed: KernelTiles, *,
                          dilation: int = 1,
                          objective: str = "training",
                          dtype: str | None = None,
                          vmem_budget: int = V5E_VMEM_BYTES,
                          radius: int = 1) -> list[KernelTiles]:
    """VMEM-feasible tile candidates around ``seed`` — the search space
    of the measured-time autotuner (``repro.tune``).

    Each dimension moves up to ``radius`` positions along the analytic
    chooser's own candidate ladder (spatial tiles clamped to the output
    extent, channel tiles drawn from the same divisor set), and the
    cross product is filtered by exactly the working-set feasibility
    ``choose_kernel_tiles`` enforces (forward VMEM, plus the backward
    working set under the training objective).  The seed is always the
    first candidate, so the analytic pick is measured alongside its
    neighbors and the tuner's win is never an artifact of dropping it.
    """
    if objective not in ("forward", "training"):
        raise ValueError(f"unknown objective {objective!r}")
    vmem_b = dtype_bytes(dtype) if dtype is not None else 2
    aux_b = 4 if dtype == "int8" else None
    ho, wo = out_hw(shape.h, shape.w, kernel_size=shape.kernel_size,
                    stride=shape.stride, dilation=dilation)
    ths = sorted({min(t, max(1, ho)) for t in (1, 2, 4, 8, 16, 32)})
    tws = sorted({min(t, max(1, wo)) for t in (8, 16, 32, 64, 128)})
    tns = sorted({_divisor_at_most(shape.c_in, cap)
                  for cap in (32, 64, 128, 256, 512, shape.c_in)})
    tms = sorted({_divisor_at_most(shape.c_out, cap)
                  for cap in (32, 64, 128, 256, shape.c_out)})

    def near(ladder: list[int], v: int) -> list[int]:
        i = min(range(len(ladder)), key=lambda j: abs(ladder[j] - v))
        return ladder[max(0, i - radius):i + radius + 1]

    seed_kt = KernelTiles(seed.tile_h, seed.tile_w, seed.tile_c,
                          seed.tile_m)
    out, seen = [seed_kt], {(seed.tile_h, seed.tile_w, seed.tile_c,
                             seed.tile_m)}
    for t_h in near(ths, seed.tile_h):
        for t_w in near(tws, seed.tile_w):
            for t_n in near(tns, seed.tile_c):
                for t_m in near(tms, seed.tile_m):
                    key = (t_h, t_w, t_n, t_m)
                    if key in seen:
                        continue
                    seen.add(key)
                    t = TileConfig(t_h, t_w, t_n, t_m)
                    vmem = zerocopy_vmem_bytes(shape, t, dilation=dilation,
                                               bytes_per_elem=vmem_b,
                                               aux_bytes_per_elem=aux_b)
                    if objective == "training":
                        vmem = max(vmem, zerocopy_bwd_vmem_bytes(
                            shape, t, dilation=dilation,
                            bytes_per_elem=vmem_b))
                    if vmem > vmem_budget:
                        continue
                    out.append(KernelTiles(t_h, t_w, t_n, t_m))
    return out


def max_offset_bound_fitting(kernel_size: int, stride: int, t_w: int,
                             t_n: int, vmem_budget: int = V5E_VMEM_BYTES,
                             *, bytes_per_elem: int = 2) -> float:
    """Inverse of Eq. 6: largest offset bound B whose input tile still
    fits the budget.  This is what couples the Eq. 5 regularizer strength
    to the hardware — the co-design knob of the paper."""
    b = 0
    while True:
        rf = receptive_field(kernel_size, b + 1)
        if input_buffer_size(rf, stride, t_w, t_n,
                             bytes_per_elem=bytes_per_elem) > vmem_budget:
            return float(b)
        b += 1
        if b > 4096:
            return float(b)
