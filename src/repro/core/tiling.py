"""Loop-tiling buffer model (paper Eq. 6/7) and roofline-driven tile chooser.

The paper sizes its on-chip buffers for the deformable convolutional
layer (DCL) as

    Input buffer size  = RF * (S*T_W + RF - S) * T_N          (Eq. 6)
    Output buffer size = T_W * T_N * 2 * K_C^2                (Eq. 7)

where ``RF = K_C + 2*ceil(B)`` is the (bounded) receptive field, ``S``
the stride, ``T_W``/``T_N`` the tile width / input-channel tile, and the
output buffer holds offsets + interpolated inputs (the factor ``2*K^2``:
2 offset planes and the K^2-tap patch tensor share it double-buffered).

On TPU the same algebra sizes the **VMEM** working set of the Pallas
kernels in ``repro.kernels``: the input tile + halo must fit VMEM next
to the weight tile and the output accumulator.  ``choose_tiles`` solves
the paper's roofline-based tiling (Sec. 3.2, following Zhang FPGA'15)
against TPU constants instead of FPGA BRAM:

    attainable = min(peak_flops, CTC * hbm_bandwidth)

maximising compute-to-communication ratio (CTC) subject to the Eq. 6/7
VMEM bound and MXU alignment (8 sublanes x 128 lanes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e target; the container only dry-runs these).
# ---------------------------------------------------------------------------

V5E_VMEM_BYTES = 128 * 1024 * 1024        # 128 MiB VMEM per core
V5E_PEAK_FLOPS_BF16 = 197e12              # 197 TFLOP/s bf16
V5E_HBM_BW = 819e9                        # 819 GB/s
V5E_ICI_BW = 50e9                         # ~50 GB/s per link
MXU_LANE = 128                            # lane (minor) alignment
MXU_SUBLANE = 8                           # sublane alignment (fp32)


# ---------------------------------------------------------------------------
# Paper buffer algebra
# ---------------------------------------------------------------------------

def receptive_field(kernel_size: int, offset_bound: float) -> int:
    """Eq. 4 (duplicated here so tiling is importable standalone)."""
    return int(kernel_size + 2 * math.ceil(float(offset_bound)))


def input_buffer_size(rf: int, stride: int, t_w: int, t_n: int,
                      *, bytes_per_elem: int = 4) -> int:
    """Eq. 6: bytes of input tile (+halo) needed for stall-free sampling."""
    return rf * (stride * t_w + rf - stride) * t_n * bytes_per_elem


def output_buffer_size(t_w: int, t_n: int, kernel_size: int,
                       *, bytes_per_elem: int = 4) -> int:
    """Eq. 7: bytes of output buffer (offsets + interpolated inputs)."""
    return t_w * t_n * 2 * kernel_size * kernel_size * bytes_per_elem


def weight_buffer_size(kernel_size: int, t_n: int, t_m: int,
                       *, bytes_per_elem: int = 4) -> int:
    """Weight tile for the dynamic-convolution stage (not in Eq. 6/7 —
    the paper holds all weights of the tile on chip; we account for it
    explicitly because VMEM is shared)."""
    return kernel_size * kernel_size * t_n * t_m * bytes_per_elem


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One loop-tiling point (the paper fixes T_N=512, T_M=64, T_H=1, T_W=8)."""
    t_h: int
    t_w: int
    t_n: int   # input-channel tile
    t_m: int   # output-channel tile

    def vmem_bytes(self, rf: int, stride: int, kernel_size: int,
                   *, bytes_per_elem: int = 4) -> int:
        band_h = rf + stride * (self.t_h - 1)              # Eq. 6 row extent
        inp = band_h * (stride * self.t_w + rf - stride) * self.t_n \
            * bytes_per_elem
        out = output_buffer_size(self.t_w * self.t_h, self.t_n, kernel_size,
                                 bytes_per_elem=bytes_per_elem)
        wgt = weight_buffer_size(kernel_size, self.t_n, self.t_m,
                                 bytes_per_elem=bytes_per_elem)
        acc = self.t_h * self.t_w * self.t_m * 4           # fp32 accumulator
        return inp + out + wgt + acc


PAPER_TILES = TileConfig(t_h=1, t_w=8, t_n=512, t_m=64)


# ---------------------------------------------------------------------------
# Roofline-driven tile chooser (Sec. 3.2 methodology on TPU constants)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerShape:
    """Shape of one DCL invocation used to evaluate a tiling point."""
    h: int
    w: int
    c_in: int
    c_out: int
    kernel_size: int = 3
    stride: int = 1
    offset_bound: float = 2.0

    @property
    def rf(self) -> int:
        return receptive_field(self.kernel_size, self.offset_bound)


def _align(v: int, a: int) -> int:
    return max(a, (v // a) * a)


def tile_candidates(shape: LayerShape) -> Iterable[TileConfig]:
    """Enumerate MXU-aligned tile points that divide the layer cleanly
    enough (we allow ragged edges; alignment matters more than divisibility)."""
    for t_h in (1, 2, 4, 8):
        for t_w in (8, 16, 32, 64):
            for t_n in (128, 256, 512):
                for t_m in (64, 128, 256):
                    if t_n > shape.c_in * 2 or t_m > shape.c_out * 2:
                        continue
                    yield TileConfig(t_h, t_w, min(t_n, _align(shape.c_in, MXU_LANE)),
                                     min(t_m, _align(shape.c_out, MXU_SUBLANE)))


def tile_flops(shape: LayerShape, t: TileConfig) -> int:
    """MACs*2 of one tile of the dynamic-convolution stage + bilinear stage."""
    k2 = shape.kernel_size ** 2
    conv = 2 * t.t_h * t.t_w * t.t_m * k2 * t.t_n
    bilinear = t.t_h * t.t_w * k2 * t.t_n * 8      # 4 corners * (mul+add)
    return conv + bilinear


def tile_hbm_bytes(shape: LayerShape, t: TileConfig,
                   *, bytes_per_elem: int = 2) -> int:
    """HBM traffic per tile: input band (+halo), weight tile, output tile.

    In the fused kernel the interpolated patches never travel to HBM —
    this is the beyond-paper saving; ``two_stage_extra_bytes`` accounts
    for the paper-faithful dataflow that round-trips them.
    """
    rf, s = shape.rf, shape.stride
    band_h = rf + s * (t.t_h - 1)
    inp = band_h * (s * t.t_w + rf - s) * t.t_n * bytes_per_elem
    wgt = shape.kernel_size ** 2 * t.t_n * t.t_m * bytes_per_elem
    out = t.t_h * t.t_w * t.t_m * bytes_per_elem
    return inp + wgt + out


def two_stage_extra_bytes(shape: LayerShape, t: TileConfig,
                          *, bytes_per_elem: int = 2) -> int:
    """Patches written + re-read by the paper's two-stage dataflow."""
    k2 = shape.kernel_size ** 2
    return 2 * t.t_h * t.t_w * k2 * t.t_n * bytes_per_elem


@dataclasses.dataclass(frozen=True)
class TileChoice:
    tile: TileConfig
    ctc: float                 # compute-to-communication ratio (flops/byte)
    attainable_flops: float    # roofline-attainable performance
    vmem_bytes: int

    @property
    def fits(self) -> bool:
        return self.vmem_bytes <= V5E_VMEM_BYTES


def evaluate_tile(shape: LayerShape, t: TileConfig, *, fused: bool = True,
                  vmem_budget: int = V5E_VMEM_BYTES) -> TileChoice:
    flops = tile_flops(shape, t)
    traffic = tile_hbm_bytes(shape, t)
    if not fused:
        traffic += two_stage_extra_bytes(shape, t)
    ctc = flops / max(traffic, 1)
    attainable = min(V5E_PEAK_FLOPS_BF16, ctc * V5E_HBM_BW)
    vmem = t.vmem_bytes(shape.rf, shape.stride, shape.kernel_size,
                        bytes_per_elem=2)
    return TileChoice(tile=t, ctc=ctc, attainable_flops=attainable,
                      vmem_bytes=vmem)


def choose_tiles(shape: LayerShape, *, fused: bool = True,
                 vmem_budget: int = V5E_VMEM_BYTES) -> TileChoice:
    """Pick the tiling point with the highest roofline-attainable perf
    among those whose Eq. 6/7 working set fits VMEM (paper Sec. 3.2)."""
    best: TileChoice | None = None
    for t in tile_candidates(shape):
        c = evaluate_tile(shape, t, fused=fused, vmem_budget=vmem_budget)
        if c.vmem_bytes > vmem_budget:
            continue
        if best is None or (c.attainable_flops, c.ctc) > (best.attainable_flops, best.ctc):
            best = c
    if best is None:
        raise ValueError(
            f"no tile configuration fits VMEM budget {vmem_budget} for {shape}; "
            f"receptive field {shape.rf} too large — train with a larger lambda")
    return best


def max_offset_bound_fitting(kernel_size: int, stride: int, t_w: int,
                             t_n: int, vmem_budget: int = V5E_VMEM_BYTES,
                             *, bytes_per_elem: int = 2) -> float:
    """Inverse of Eq. 6: largest offset bound B whose input tile still
    fits the budget.  This is what couples the Eq. 5 regularizer strength
    to the hardware — the co-design knob of the paper."""
    b = 0
    while True:
        rf = receptive_field(kernel_size, b + 1)
        if input_buffer_size(rf, stride, t_w, t_n,
                             bytes_per_elem=bytes_per_elem) > vmem_budget:
            return float(b)
        b += 1
        if b > 4096:
            return float(b)
