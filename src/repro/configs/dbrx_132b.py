"""dbrx-132b [hf:databricks/dbrx-base; unverified]

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16 experts
top-4 (fine-grained).  LayerNorm, SwiGLU experts, RoPE.
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    kv_heads=8,
    d_ff=10752,
    vocab=100352,
    head_dim=128,
    norm="layer",
    act="swiglu",
    use_rope=True,
    rope_theta=500_000.0,
    tie_embeddings=False,
    moe=MoEConfig(d_model=6144, d_ff=10752, num_experts=16, top_k=4,
                  capacity_factor=1.25, kind="swiglu"),
    remat="full",
)

register(ArchSpec(
    name="dbrx-132b",
    family="moe",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=False,
    source="hf:databricks/dbrx-base (unverified tier)",
    notes="long_500k skipped: pure full attention (DESIGN.md §4). "
          "16 experts divide the 16-way model axis -> EXPERT-PARALLEL "
          "sharding by default (§Perf E: +42% roofline fraction vs "
          "tensor-parallel experts).",
    rules_overrides={"experts": "model"},
))
