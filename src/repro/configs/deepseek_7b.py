"""deepseek-7b [arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-7b-base]

30L d_model=4096 32H (kv=32, i.e. full MHA) d_ff=11008 vocab=102400 —
llama-arch.
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    kv_heads=32,
    d_ff=11008,
    vocab=102400,
    head_dim=128,
    norm="rms",
    act="swiglu",
    use_rope=True,
    rope_theta=10000.0,
    tie_embeddings=False,
    remat="full",
)

register(ArchSpec(
    name="deepseek-7b",
    family="dense",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=False,
    source="arXiv:2401.02954",
    notes="long_500k skipped: pure full attention (DESIGN.md §4).",
))
