"""musicgen-medium [arXiv:2306.05284; hf:facebook/musicgen-medium]

48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048 — decoder-only
over EnCodec tokens, 4 parallel codebooks (summed embeddings, one output
head per codebook).

The EnCodec frontend is a STUB per the assignment: inputs are the token
grids themselves; ``input_specs`` provides (B, S, 4) int32.  Positional
encoding adapted from MusicGen's sinusoidal to RoPE (framework-uniform;
recorded in DESIGN.md hardware-adaptation notes).
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    kv_heads=24,
    d_ff=6144,
    vocab=2048,
    head_dim=64,
    norm="layer",
    act="gelu",
    mlp_bias=True,
    use_rope=True,
    tie_embeddings=False,
    codebooks=4,
    remat="full",
)

register(ArchSpec(
    name="musicgen-medium",
    family="audio",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=False,
    source="arXiv:2306.05284",
    notes="long_500k skipped: pure full attention (DESIGN.md §4). "
          "EnCodec frontend stubbed (token inputs).",
))
