"""tinyllama-1.1b [arXiv:2401.02385; hf]

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000 — llama2-arch small.
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    kv_heads=4,
    d_ff=5632,
    vocab=32000,
    head_dim=64,
    norm="rms",
    act="swiglu",
    use_rope=True,
    rope_theta=10000.0,
    tie_embeddings=False,
    remat="full",
)

register(ArchSpec(
    name="tinyllama-1.1b",
    family="dense",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=False,
    source="arXiv:2401.02385; hf:TinyLlama/TinyLlama-1.1B",
    notes="long_500k skipped: pure full attention (DESIGN.md §4).",
))
