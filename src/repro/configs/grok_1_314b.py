"""grok-1-314b [hf:xai-org/grok-1; unverified]

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts
top-2.  Grok specifics: embedding scale, attention + logits tanh
soft-capping (30.0), GeGLU experts, tied embeddings.
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    kv_heads=8,
    d_ff=32768,
    vocab=131072,
    head_dim=128,
    norm="rms",
    act="geglu",
    use_rope=True,
    rope_theta=10000.0,
    attn_softcap=30.0,
    logits_softcap=30.0,
    embed_scale=True,
    tie_embeddings=True,
    moe=MoEConfig(d_model=6144, d_ff=32768, num_experts=8, top_k=2,
                  capacity_factor=1.25, kind="geglu"),
    remat="full",
)

register(ArchSpec(
    name="grok-1-314b",
    family="moe",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=False,
    source="hf:xai-org/grok-1 (unverified tier)",
    notes="long_500k skipped: pure full attention (DESIGN.md §4). "
          "8 experts do not divide the 16-way model axis: tensor-parallel "
          "experts (inner dims sharded) — see DESIGN.md §5.",
))
