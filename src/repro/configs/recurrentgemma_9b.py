"""recurrentgemma-9b [arXiv:2402.19427]

38L d_model=4096 16H (GQA kv=1, MQA) d_ff=12288 vocab=256000 —
RG-LRU + local attention, 1 attention : 2 recurrent.

Pattern: (rglru, rglru, attn) x 12 periods + 2 leading recurrent layers
(= 38).  Local attention window 2048; GeGLU MLP; embeddings scaled by
sqrt(d); d_rnn (lru width) 4096.

DESIGN.md §Arch-applicability: the local-attention window is itself a
*statically bounded receptive field* — the same co-design argument the
paper makes for DCNs (bound the dynamic field so the dataflow tiles
statically).  Noted as conceptually related; the DCL technique itself is
conv-specific and not applied here.
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig
from repro.models.rglru import RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    norm="rms",
    act="geglu",
    use_rope=True,
    rope_theta=10000.0,
    window=2048,
    embed_scale=True,
    tie_embeddings=True,
    pattern=("rglru", "rglru", "attn"),
    rglru=RGLRUConfig(d_model=4096, d_rnn=4096),
    remat="full",
)

register(ArchSpec(
    name="recurrentgemma-9b",
    family="hybrid",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=True,   # windowed KV + LRU state: O(1) per step
    source="arXiv:2402.19427",
    notes="runs long_500k (windowed attention + recurrent state).",
))
