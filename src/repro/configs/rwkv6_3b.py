"""rwkv6-3b "Finch" [arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b]

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 — data-dependent
decay.  Head size 64 (40 heads); decay LoRA rank 64.

Runs ``long_500k``: the WKV state is O(1) per step.
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig
from repro.models.rwkv6 import RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    kv_heads=40,
    d_ff=8960,
    vocab=65536,
    head_dim=64,
    norm="layer",
    use_rope=False,
    tie_embeddings=False,
    pattern=("rwkv6",),
    rwkv=RWKVConfig(d_model=2560, d_ff=8960, head_dim=64,
                    decay_lora_rank=64),
    remat="full",
)

register(ArchSpec(
    name="rwkv6-3b",
    family="ssm",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=True,
    source="arXiv:2404.05892",
    notes="attention-free; constant-memory decode state; runs long_500k. "
          "Projections shard on the flat 2560 channel dim (40 heads do "
          "not divide the axis; dv-sharding was tried and rejected, "
          "§Perf F).",
))
