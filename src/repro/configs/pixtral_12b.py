"""pixtral-12b [hf:mistralai/Pixtral-12B-2409; unverified]

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072 — pixtral-ViT +
mistral-nemo backbone.  head_dim=128 (q/k/v project to 4096).

The ViT frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, 256, d_model) that are prepended to the
token sequence; loss is computed on text positions only.
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    norm="rms",
    act="swiglu",
    use_rope=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    frontend_embeds=True,
    remat="full",
)

register(ArchSpec(
    name="pixtral-12b",
    family="vlm",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=False,
    source="hf:mistralai/Pixtral-12B-2409 (unverified tier)",
    notes="long_500k skipped: pure full attention (DESIGN.md §4). "
          "ViT frontend stubbed (precomputed patch embeddings).",
))
