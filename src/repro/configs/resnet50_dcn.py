"""resnet50_dcn — the paper's own model (Sec. 4.1).

ResNet-50 backbone with 12 deformable convolutional layers (the last 12
3x3 convs: c3's last 3, all of c4, all of c5) + dense detection head.
Two variants are registered:

* ``resnet50_dcn``        — lambda=0 baseline (unbounded offsets)
* ``resnet50_dcn_bounded``— the Eq. 5-trained hardware-friendly model
                            (offset bound 2.0 -> RF = 7, Eq. 4), DCLs
                            routed through the fused Pallas kernel path
                            when serving.
"""
from repro.models.registry import ArchSpec, ShapeSpec, register
from repro.models.resnet_dcn import ResNetDCNConfig

DET_SHAPES = {
    "train_det": ShapeSpec("train_det", 0, 128, note="512x512 synthetic COCO"),
    "infer_det": ShapeSpec("infer_det", 0, 256, note="batch inference"),
}

CONFIG = ResNetDCNConfig(
    name="resnet50_dcn",
    stage_sizes=(3, 4, 6, 3),
    widths=(256, 512, 1024, 2048),
    stem_width=64,
    num_dcn=12,
    offset_bound=None,
    num_classes=80,
    img_size=512,
)

CONFIG_BOUNDED = ResNetDCNConfig(
    name="resnet50_dcn_bounded",
    stage_sizes=(3, 4, 6, 3),
    widths=(256, 512, 1024, 2048),
    stem_width=64,
    num_dcn=12,
    offset_bound=2.0,
    num_classes=80,
    img_size=512,
)

register(ArchSpec(
    name="resnet50_dcn",
    family="cnn",
    config=CONFIG,
    shapes=dict(DET_SHAPES),
    source="this paper, Sec. 4.1 (Faster R-CNN head simplified to a "
           "dense single-scale head; see DESIGN.md)",
    notes="lambda=0 baseline: unbounded offsets -> XLA gather path.",
))

register(ArchSpec(
    name="resnet50_dcn_bounded",
    family="cnn",
    config=CONFIG_BOUNDED,
    shapes=dict(DET_SHAPES),
    source="this paper, Sec. 3.1/3.2",
    notes="Eq. 5-trained bound B=2 (RF=7): bounded-halo Pallas dataflow.",
))
