"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01; unverified]

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — GQA, no-bias.
Cohere specifics: parallel attention+FFN block, LayerNorm without bias,
tied embeddings with logit scaling, full RoPE.
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=22528,
    vocab=256000,
    head_dim=128,
    norm="layer",
    act="swiglu",
    parallel_block=True,
    qkv_bias=False,
    mlp_bias=False,
    use_rope=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    logit_scale=0.0625,
    remat="full",
)

register(ArchSpec(
    name="command-r-35b",
    family="dense",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=False,
    source="hf:CohereForAI/c4ai-command-r-v01 (unverified tier)",
    notes="long_500k skipped: pure full attention (DESIGN.md §4).",
))
