"""glm4-9b [hf:THUDM/glm-4-9b]

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552 — RoPE, GQA.
GLM specifics: partial rotary (fraction 0.5), QKV bias, untied embeddings.
"""
from repro.models.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    kv_heads=2,
    d_ff=13696,
    vocab=151552,
    head_dim=128,
    norm="rms",
    act="swiglu",
    qkv_bias=True,
    use_rope=True,
    rope_theta=10000.0,
    rope_fraction=0.5,
    tie_embeddings=False,
    remat="full",
)

register(ArchSpec(
    name="glm4-9b",
    family="dense",
    config=CONFIG,
    shapes=dict(LM_SHAPES),
    long_context_ok=False,
    source="hf:THUDM/glm-4-9b",
    notes="long_500k skipped: pure full attention (DESIGN.md §4).",
))
