"""ResNet-50 backbone with deformable convolutional layers + detection head.

This is the paper's own model family: "Faster R-CNN ... which uses 12
DCLs in ResNet-50 as a backbone" (Sec. 4.1).  We reproduce the backbone
faithfully — the last ``num_dcn`` 3x3 convolutions of the c3/c4/c5
bottlenecks are replaced by DCLs (12 by default: c3's last 3, all 6 of
c4, all 3 of c5) — and attach a single-scale dense detection head
(objectness + class + box per stride-32 cell).  The two-stage Faster
R-CNN RPN/RoI machinery is out of scope for the accelerator study: the
paper's experiments hinge on the *offset statistics of the backbone
DCLs* under the Eq. 5 regularizer, which this model exposes per layer.

Norms are GroupNorm(32) (batch-stat-free, standard for detection).
Layout NHWC.  ``use_kernel=True`` routes every DCL through the Pallas
fused kernel (``repro.kernels.ops.deform_conv``) — including under
``jax.grad``: since PR 2 the bounded kernel path carries a custom VJP
(``kernels.deform_conv_bwd``), so ``train_loss`` with a kernel-path
config trains through the zero-copy Pallas dataflow in both
directions.  The pure-JAX path remains the parity reference.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.deform_conv import conv2d
from .layers import ParamDef, dcl_apply, dcl_def

Array = jax.Array

GN_GROUPS = 32


@dataclasses.dataclass(frozen=True)
class ResNetDCNConfig:
    name: str = "resnet50_dcn"
    stage_sizes: tuple[int, ...] = (3, 4, 6, 3)
    widths: tuple[int, ...] = (256, 512, 1024, 2048)
    stem_width: int = 64
    num_dcn: int = 12              # last N 3x3 convs become DCLs
    offset_bound: float | None = None   # hardware-friendly clamp (Eq. 4)
    num_classes: int = 16
    img_size: int = 256
    dtype: Any = jnp.float32
    use_kernel: bool = False       # route DCLs through the Pallas kernel
    dataflow: str = "zero_copy"    # kernel dataflow: zero_copy | banded
    # DCL datapath: none | qat | int8 | int8_chain (int8_chain fuses the
    # offset conv in-kernel and emits the DCL output int8 on the
    # calibrated y_scale grid — 1 byte/elem through HBM, dequantized by
    # the consumer; with use_kernel=False the differentiable STE chain
    # reference runs instead, so chain configs train in the Trainer).
    quant: str = "none"
    bwd_cores: int = 1             # Megacore batch split of the bwd kernel
    # Data-parallel shard_map of the kernel path over the active mesh's
    # batch axes: None = auto (shard when a mesh is live and divides the
    # batch), True = require (ValueError otherwise), False = never.
    shard_batch: bool | None = None
    # Spatial shard_map of the kernel path: split the height axis over
    # the mesh axis mapped from logical "spatial" with a bounded halo
    # exchange per DCL (distributed.spatial).  None/False = off,
    # True = require (ValueError when no mesh / ragged heights).
    shard_spatial: bool | None = None

    @property
    def total_blocks(self) -> int:
        return sum(self.stage_sizes)

    def is_dcn(self, block_index: int) -> bool:
        """block_index counts bottleneck blocks from 0 (first c2 block)."""
        return block_index >= self.total_blocks - self.num_dcn


def _conv_def(kh, kw, cin, cout, *, scale=None):
    return ParamDef((kh, kw, cin, cout), (None, None, None, "conv_out"),
                    scale=scale)


def _gn_def(c):
    return {"scale": ParamDef((c,), (None,), init="ones"),
            "bias": ParamDef((c,), (None,), init="zeros")}


def group_norm(x: Array, params, *, groups: int = GN_GROUPS,
               eps: float = 1e-5) -> Array:
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xf = x.astype(jnp.float32).reshape(n, h, w, g, c // g)
    mu = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(n, h, w, c)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def _dcl_def(cin, cout, k=3):
    return dcl_def(cin, cout, k)


def _block_def(cfg: ResNetDCNConfig, cin, width, block_index,
               *, downsample: bool):
    mid = width // 4
    d = {
        "conv1": _conv_def(1, 1, cin, mid), "gn1": _gn_def(mid),
        "gn2": _gn_def(mid),
        "conv3": _conv_def(1, 1, mid, width), "gn3": _gn_def(width),
    }
    if cfg.is_dcn(block_index):
        d["dcl"] = _dcl_def(mid, mid)
    else:
        d["conv2"] = _conv_def(3, 3, mid, mid)
    if downsample or cin != width:
        d["proj"] = _conv_def(1, 1, cin, width)
        d["gn_proj"] = _gn_def(width)
    return d


def model_def(cfg: ResNetDCNConfig) -> dict:
    defs: dict[str, Any] = {
        "stem": {"conv": _conv_def(7, 7, 3, cfg.stem_width),
                 "gn": _gn_def(cfg.stem_width)},
    }
    cin = cfg.stem_width
    bi = 0
    for s, (n_blocks, width) in enumerate(zip(cfg.stage_sizes, cfg.widths)):
        for b in range(n_blocks):
            defs[f"s{s}b{b}"] = _block_def(
                cfg, cin, width, bi, downsample=(b == 0))
            cin = width
            bi += 1
    c = cfg.widths[-1]
    defs["head"] = {
        "conv": _conv_def(3, 3, c, 256), "gn": _gn_def(256),
        "cls": _conv_def(1, 1, 256, cfg.num_classes + 1),   # +1 objectness
        "box": _conv_def(1, 1, 256, 4),
    }
    return defs


def init_params(key: Array, cfg: ResNetDCNConfig):
    from .layers import init_tree
    return init_tree(key, model_def(cfg))


def _apply_dcl(params, x: Array, cfg: ResNetDCNConfig, *, stride=1,
               quant_scales=None):
    return dcl_apply(params, x, stride=stride,
                     offset_bound=cfg.offset_bound,
                     use_kernel=cfg.use_kernel, dataflow=cfg.dataflow,
                     quant=cfg.quant, quant_scales=quant_scales,
                     cores=cfg.bwd_cores, shard_batch=cfg.shard_batch,
                     shard_spatial=cfg.shard_spatial, dtype=cfg.dtype)


def _apply_block(params, x: Array, cfg: ResNetDCNConfig, *, stride: int,
                 is_dcn: bool, name: str = "", tap=None, quant_scales=None):
    h = conv2d(x, params["conv1"].astype(x.dtype))
    h = jax.nn.relu(group_norm(h, params["gn1"]))
    o_max = None
    if is_dcn:
        if tap is not None:
            tap(name, h)
        h, o_max = _apply_dcl(params["dcl"], h, cfg, stride=stride,
                              quant_scales=quant_scales)
        if hasattr(h, "dequantize"):
            # int8_chain emission: the DCL output crossed HBM as int8
            # (QTensor); the GroupNorm consumer decodes it here — the
            # only fp32 materialization of the tensor.
            h = h.dequantize(cfg.dtype)
        if tap is not None:
            tap(f"{name}/out", h)
    else:
        h = conv2d(h, params["conv2"].astype(x.dtype), stride=stride)
    h = jax.nn.relu(group_norm(h, params["gn2"]))
    h = conv2d(h, params["conv3"].astype(x.dtype))
    h = group_norm(h, params["gn3"])
    if "proj" in params:
        x = conv2d(x, params["proj"].astype(x.dtype), stride=stride)
        x = group_norm(x, params["gn_proj"])
    return jax.nn.relu(x + h), o_max


def forward(params, cfg: ResNetDCNConfig, images: Array, *, tap=None,
            quant_scales=None):
    """images: (N, H, W, 3) -> (outputs, o_max dict per DCL).

    ``tap(name, x)`` is invoked with every DCL block's input activation
    — the calibration hook of ``repro.quant.calibrate`` (run eagerly so
    observers see concrete values).  ``quant_scales`` is a calibration
    scale table ({block_name: {"x_scale", "w_scale"}}) consumed when
    ``cfg.quant`` is ``"qat"``/``"int8"``; None = dynamic absmax.
    """
    x = images.astype(cfg.dtype)
    x = conv2d(x, params["stem"]["conv"].astype(x.dtype), stride=2,
               padding=3)
    x = jax.nn.relu(group_norm(x, params["stem"]["gn"]))
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1),
        [(0, 0), (1, 1), (1, 1), (0, 0)])

    o_maxes: dict[str, Array] = {}
    bi = 0
    for s, (n_blocks, width) in enumerate(zip(cfg.stage_sizes, cfg.widths)):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            name = f"s{s}b{b}"
            scales = quant_scales.get(name) if quant_scales else None
            x, o_max = _apply_block(params[name], x, cfg,
                                    stride=stride, is_dcn=cfg.is_dcn(bi),
                                    name=name, tap=tap,
                                    quant_scales=scales)
            if o_max is not None:
                o_maxes[name] = o_max
            bi += 1

    h = conv2d(x, params["head"]["conv"].astype(x.dtype))
    h = jax.nn.relu(group_norm(h, params["head"]["gn"]))
    cls = conv2d(h, params["head"]["cls"].astype(x.dtype))
    box = conv2d(h, params["head"]["box"].astype(x.dtype))
    return {"cls": cls, "box": box, "features": x}, o_maxes


def detection_loss(outputs: dict, targets: dict) -> tuple[Array, dict]:
    """Dense single-scale detection loss.

    targets: obj (N,Hc,Wc) {0,1}, cls (N,Hc,Wc) int, box (N,Hc,Wc,4).
    Classification: sigmoid BCE on objectness + CE on class for positive
    cells; box: L1 on positive cells.
    """
    cls_logits = outputs["cls"].astype(jnp.float32)
    box_pred = outputs["box"].astype(jnp.float32)
    obj_logit = cls_logits[..., 0]
    cls_logit = cls_logits[..., 1:]
    obj = targets["obj"].astype(jnp.float32)

    bce = jnp.mean(
        jnp.maximum(obj_logit, 0) - obj_logit * obj
        + jnp.log1p(jnp.exp(-jnp.abs(obj_logit))))

    pos = obj
    n_pos = jnp.maximum(jnp.sum(pos), 1.0)
    logp = jax.nn.log_softmax(cls_logit, axis=-1)
    gold = jnp.take_along_axis(logp, targets["cls"][..., None], axis=-1)[..., 0]
    ce = -jnp.sum(gold * pos) / n_pos
    l1 = jnp.sum(jnp.abs(box_pred - targets["box"]) * pos[..., None]) / n_pos
    loss = bce + ce + 0.5 * l1
    return loss, {"bce": bce, "ce": ce, "l1": l1}


def train_loss(params, cfg: ResNetDCNConfig, batch: dict, *,
               lam: float = 0.0, smoothness: float = 0.0,
               quant_scales=None):
    """Full paper objective: Eq. 5 over the detection loss.  With
    ``cfg.quant="qat"`` this is the quantization-aware objective —
    fake-quant DCLs (STE) under the same Eq. 5 regularizer, trainable
    by the production ``Trainer`` unchanged."""
    from repro.core.rf_regularizer import regularized_loss
    outputs, o_maxes = forward(params, cfg, batch["images"],
                               quant_scales=quant_scales)
    task, metrics = detection_loss(outputs, batch)
    if lam > 0.0 and o_maxes:
        loss = regularized_loss(task, list(o_maxes.values()), lam,
                                smoothness=smoothness)
    else:
        loss = task
    metrics = dict(metrics)
    if o_maxes:
        metrics["o_max"] = jnp.max(jnp.stack(list(o_maxes.values())))
    return loss, metrics
