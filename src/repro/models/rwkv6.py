"""RWKV-6 "Finch" blocks (arXiv:2404.05892): attention-free time mixing
with *data-dependent decay*, plus the squared-ReLU channel mix.

Faithful core mechanism; two documented simplifications:
* token-shift lerp coefficients are static (full Finch uses an extra
  LoRA on the mix weights); the decay LoRA — Finch's headline
  contribution — is implemented in full.
* log-decay is clamped to [-2.5, -1e-6] so the chunked scan's
  exp-factorized form stays in fp32 range (chunk 32 -> max exponent 80).
  Trained RWKV decays live well inside this range.

The chunked scan is the TPU-native formulation: intra-chunk work is an
MXU matmul over (chunk, chunk) decay-weighted scores, inter-chunk state
is carried by a lax.scan — O(S * chunk) instead of O(S^2), which is what
makes the ``long_500k`` dry-run cell feasible for this family.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from .layers import ParamDef, rms_norm

Array = jax.Array

LOG_DECAY_MIN = -2.5
LOG_DECAY_MAX = -1e-6
CHUNK = 32


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    d_ff: int
    head_dim: int = 64
    decay_lora_rank: int = 64

    @property
    def n_heads(self) -> int:
        assert self.d_model % self.head_dim == 0
        return self.d_model // self.head_dim


def time_mix_def(cfg: RWKVConfig) -> dict[str, ParamDef]:
    d, r = cfg.d_model, cfg.decay_lora_rank
    # Flat (d, d) projections: 2560 divides the 16-way model axis even
    # though 40 heads do not — sharding the flat channel dim wins over
    # head-dim (dv) sharding, which §Perf F measured and REJECTED
    # (collective halved but r/k replication raised the memory term).
    return {
        "mu_r": ParamDef((d,), (None,), init="zeros"),
        "mu_k": ParamDef((d,), (None,), init="zeros"),
        "mu_v": ParamDef((d,), (None,), init="zeros"),
        "mu_w": ParamDef((d,), (None,), init="zeros"),
        "mu_g": ParamDef((d,), (None,), init="zeros"),
        "w_r": ParamDef((d, d), ("embed", "heads")),
        "w_k": ParamDef((d, d), ("embed", "heads")),
        "w_v": ParamDef((d, d), ("embed", "heads")),
        "w_g": ParamDef((d, d), ("embed", "heads")),
        "w_o": ParamDef((d, d), ("heads", "embed")),
        # data-dependent decay: lw = -exp(w0 + tanh(x @ A) @ B)
        "decay_w0": ParamDef((d,), (None,), init="zeros"),
        "decay_A": ParamDef((d, r), ("embed", None), scale=0.01),
        "decay_B": ParamDef((r, d), (None, None), scale=0.01),
        "bonus_u": ParamDef((d,), (None,), init="zeros"),
        "ln_x": ParamDef((d,), (None,), init="zeros"),  # per-head norm scale
    }


def channel_mix_def(cfg: RWKVConfig) -> dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamDef((d,), (None,), init="zeros"),
        "mu_r": ParamDef((d,), (None,), init="zeros"),
        "w_k": ParamDef((d, f), ("embed", "ff")),
        "w_v": ParamDef((f, d), ("ff", "embed")),
        "w_r": ParamDef((d, d), ("embed", None)),
    }


def _token_shift(x: Array, prev: Array | None) -> Array:
    """x_{t-1} with an optional carried state for the first position."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _lerp(x: Array, x_prev: Array, mu: Array) -> Array:
    m = mu.astype(x.dtype)
    return x + (x_prev - x) * m


def _log_decay(params, xw: Array) -> Array:
    lora = jnp.einsum(
        "bsd,dr->bsr", jnp.tanh(xw @ params["decay_A"].astype(xw.dtype)),
        params["decay_B"].astype(xw.dtype))
    raw = params["decay_w0"].astype(jnp.float32) + lora.astype(jnp.float32)
    return jnp.clip(-jnp.exp(raw), LOG_DECAY_MIN, LOG_DECAY_MAX)


def wkv_chunked(r: Array, k: Array, v: Array, lw: Array, u: Array,
                state: Array | None = None,
                chunk: int = CHUNK) -> tuple[Array, Array]:
    """Chunked WKV scan.

    r,k,v: (B, S, H, Dh); lw: (B, S, H, Dh) log-decay (<=0); u: (H, Dh).
    state: (B, H, Dh, Dh) initial [key, value] state.
    Returns (out (B,S,H,Dh) fp32, final state).

    o_t = r_t @ S_{t-1} + (r_t . (u*k_t)) v_t
    S_t = diag(exp(lw_t)) S_{t-1} + k_t (x) v_t
    """
    b, s, h, dh = r.shape
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    rf = r.astype(jnp.float32).reshape(b, n, chunk, h, dh)
    kf = k.astype(jnp.float32).reshape(b, n, chunk, h, dh)
    vf = v.astype(jnp.float32).reshape(b, n, chunk, h, dh)
    lwf = lw.astype(jnp.float32).reshape(b, n, chunk, h, dh)

    c_incl = jnp.cumsum(lwf, axis=2)                 # c_j (inclusive)
    c_excl = c_incl - lwf                            # c_{j-1}
    c_tot = c_incl[:, :, -1:]                        # chunk total

    r_in = rf * jnp.exp(c_excl)                      # r'_i
    k_out = kf * jnp.exp(-c_incl)                    # k'_j  (bounded by clamp)
    k_end = kf * jnp.exp(c_tot - c_incl)             # decay to chunk end

    # intra-chunk scores: A[i, j] = r'_i . k'_j for j < i, bonus at j == i.
    scores = jnp.einsum("bnihd,bnjhd->bnhij", r_in, k_out)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
    scores = scores * tri[None, None, None]
    bonus = jnp.einsum("bnihd,hd,bnihd->bnih", rf, u.astype(jnp.float32), kf)
    o_intra = jnp.einsum("bnhij,bnjhd->bnihd", scores, vf) \
        + bonus[..., None] * vf

    # inter-chunk: carry S across chunks with a scan.
    kv_end = jnp.einsum("bnjhd,bnjhe->bnhde", k_end, vf)  # sum_j decayed k(x)v

    def step(S, inputs):
        r_in_c, kv_end_c, c_tot_c = inputs
        o_inter = jnp.einsum("bihd,bhde->bihe", r_in_c, S)
        S = jnp.exp(c_tot_c)[:, 0, :, :, None] * S + kv_end_c
        return S, o_inter

    S0 = state.astype(jnp.float32) if state is not None else \
        jnp.zeros((b, h, dh, dh), jnp.float32)
    xs = (jnp.moveaxis(r_in, 1, 0), jnp.moveaxis(kv_end, 1, 0),
          jnp.moveaxis(c_tot, 1, 0))
    S_fin, o_inter = jax.lax.scan(step, S0, xs)
    o = o_intra + jnp.moveaxis(o_inter, 0, 1)
    return o.reshape(b, s, h, dh), S_fin


def wkv_step(r: Array, k: Array, v: Array, lw: Array, u: Array,
             state: Array) -> tuple[Array, Array]:
    """Single-token recurrence (decode).  r,k,v,lw: (B, H, Dh)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    lwf = lw.astype(jnp.float32)
    out = jnp.einsum("bhd,bhde->bhe", rf, state) \
        + jnp.einsum("bhd,hd,bhd,bhe->bhe", rf, u.astype(jnp.float32), kf, vf)
    state = jnp.exp(lwf)[..., None] * state \
        + jnp.einsum("bhd,bhe->bhde", kf, vf)
    return out, state


def time_mix_apply(params, x: Array, cfg: RWKVConfig, *,
                   shift_state: Array | None = None,
                   wkv_state: Array | None = None,
                   chunk: int = CHUNK):
    """Returns (y, (new_shift_state, new_wkv_state))."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    xp = _token_shift(x, shift_state)
    xr = _lerp(x, xp, params["mu_r"])
    xk = _lerp(x, xp, params["mu_k"])
    xv = _lerp(x, xp, params["mu_v"])
    xw = _lerp(x, xp, params["mu_w"])
    xg = _lerp(x, xp, params["mu_g"])

    r = (xr @ params["w_r"].astype(x.dtype)).reshape(b, s, h, dh)
    k = (xk @ params["w_k"].astype(x.dtype)).reshape(b, s, h, dh)
    v = (xv @ params["w_v"].astype(x.dtype)).reshape(b, s, h, dh)
    g = jax.nn.silu(xg @ params["w_g"].astype(x.dtype)).reshape(b, s, h, dh)
    lw = _log_decay(params, xw).reshape(b, s, h, dh)
    u = params["bonus_u"].reshape(h, dh)

    r = logical_constraint(r, "batch", "seq", "heads", None)
    k = logical_constraint(k, "batch", "seq", "heads", None)
    v = logical_constraint(v, "batch", "seq", "heads", None)

    pad = (-s) % chunk
    if pad:
        rp = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lwp = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        rp, kp, vp, lwp = r, k, v, lw
    o, S = wkv_chunked(rp, kp, vp, lwp, u, state=wkv_state, chunk=chunk)
    o = o[:, :s]

    # per-head group norm, then gate and project out
    o = rms_norm(o.astype(x.dtype), params["ln_x"].reshape(h, dh))
    y = ((o * g).reshape(b, s, d)) @ params["w_o"].astype(x.dtype)
    y = logical_constraint(y, "batch", "seq", "embed_no_fsdp")
    return y, (x[:, -1], S)


def time_mix_step(params, x: Array, cfg: RWKVConfig, *, shift_state: Array,
                  wkv_state: Array):
    """Decode: x (B, D) one token.  Returns (y, (shift, wkv))."""
    b, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    xp = shift_state
    xr = _lerp(x, xp, params["mu_r"])
    xk = _lerp(x, xp, params["mu_k"])
    xv = _lerp(x, xp, params["mu_v"])
    xw = _lerp(x, xp, params["mu_w"])
    xg = _lerp(x, xp, params["mu_g"])
    r = (xr @ params["w_r"].astype(x.dtype)).reshape(b, h, dh)
    k = (xk @ params["w_k"].astype(x.dtype)).reshape(b, h, dh)
    v = (xv @ params["w_v"].astype(x.dtype)).reshape(b, h, dh)
    g = jax.nn.silu(xg @ params["w_g"].astype(x.dtype)).reshape(b, h, dh)
    lw = _log_decay(params, xw[:, None])[:, 0].reshape(b, h, dh)
    u = params["bonus_u"].reshape(h, dh)
    o, S = wkv_step(r, k, v, lw, u, wkv_state.astype(jnp.float32))
    o = rms_norm(o.astype(x.dtype), params["ln_x"].reshape(h, dh))
    y = ((o * g).reshape(b, d)) @ params["w_o"].astype(x.dtype)
    return y, (x, S)


def channel_mix_apply(params, x: Array, cfg: RWKVConfig, *,
                      shift_state: Array | None = None):
    xp = _token_shift(x, shift_state)
    xk = _lerp(x, xp, params["mu_k"])
    xr = _lerp(x, xp, params["mu_r"])
    k = jnp.square(jax.nn.relu(xk @ params["w_k"].astype(x.dtype)))
    k = logical_constraint(k, "batch", "seq", "ff")
    kv = k @ params["w_v"].astype(x.dtype)
    y = jax.nn.sigmoid(xr @ params["w_r"].astype(x.dtype)) * kv
    return logical_constraint(y, "batch", "seq", "embed_no_fsdp"), x[:, -1]


def channel_mix_step(params, x: Array, cfg: RWKVConfig, *,
                     shift_state: Array):
    xk = _lerp(x, shift_state, params["mu_k"])
    xr = _lerp(x, shift_state, params["mu_r"])
    k = jnp.square(jax.nn.relu(xk @ params["w_k"].astype(x.dtype)))
    kv = k @ params["w_v"].astype(x.dtype)
    y = jax.nn.sigmoid(xr @ params["w_r"].astype(x.dtype)) * kv
    return y, x
