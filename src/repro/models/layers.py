"""Shared neural-net primitives for every architecture in the registry.

Params are plain nested dicts of jnp arrays.  Each module declares its
parameters once as a ``ParamDef`` tree (shape + init + logical sharding
axes); ``init_tree`` materializes arrays and ``spec_tree`` materializes
``PartitionSpec``s from the *same* declaration, so the sharding layout
can never drift from the parameter structure.

All apply functions are pure; activations carry sharding hints via
``repro.distributed.logical_constraint`` (no-ops off-mesh).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import logical_constraint, logical_spec

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape, init scheme, logical sharding axes."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed | uniform
    scale: float | None = None    # stddev override (default: fan-in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple[int, ...]) -> int:
    return int(shape[0]) if len(shape) <= 1 else int(
        math.prod(shape[:-1]))


def init_param(key: Array, d: ParamDef) -> Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    scale = d.scale
    if d.init == "embed":
        scale = scale if scale is not None else 1.0
        return jax.random.normal(key, d.shape, d.dtype) * scale
    if d.init == "uniform":
        lim = scale if scale is not None else 1.0 / math.sqrt(_fan_in(d.shape))
        return jax.random.uniform(key, d.shape, d.dtype, -lim, lim)
    scale = scale if scale is not None else 1.0 / math.sqrt(_fan_in(d.shape))
    return jax.random.normal(key, d.shape, d.dtype) * scale


def init_tree(key: Array, defs) -> Any:
    """Materialize a ParamDef pytree into arrays (stable key derivation:
    one fold per leaf path hash, so insertion order doesn't matter)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, max(len(leaves), 1))
    arrs = [init_param(k, d) for k, d in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def spec_tree(defs) -> Any:
    """ParamDef pytree -> PartitionSpec pytree (uses the active rules)."""
    return jax.tree_util.tree_map(
        lambda d: logical_spec(d.shape, d.axes),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_tree(defs, dtype=None) -> Any:
    """ParamDef pytree -> ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype or d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, *, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array | None = None,
               *, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def norm_def(d_model: int, kind: str) -> dict[str, ParamDef]:
    if kind == "rms":
        return {"scale": ParamDef((d_model,), (None,), init="zeros")}
    return {"scale": ParamDef((d_model,), (None,), init="ones"),
            "bias": ParamDef((d_model,), (None,), init="zeros")}


def apply_norm(params: Mapping[str, Array], x: Array, kind: str) -> Array:
    if kind == "rms":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params.get("bias"))


# ---------------------------------------------------------------------------
# Rotary position embeddings (half-split / llama convention)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, *, theta: float = 10000.0,
               fraction: float = 1.0) -> Array:
    rot = int(head_dim * fraction) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: Array, positions: Array, *, theta: float = 10000.0,
               fraction: float = 1.0) -> Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    b, s, h, dh = x.shape
    inv = rope_freqs(dh, theta=theta, fraction=fraction)
    rot = inv.shape[0] * 2
    ang = positions.astype(jnp.float32)[..., None] * inv    # (B, S, rot/2)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = jnp.split(xr, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), x[..., rot:]], -1) \
        if rot < dh else out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, optional sliding window, optional KV cache)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    use_rope: bool = True
    qkv_bias: bool = False
    out_bias: bool = False
    window: int | None = None          # sliding-window size (None = full)
    softcap: float | None = None       # grok-style tanh soft-capping
    qk_norm: bool = False              # per-head RMS on q/k (stability)

    @property
    def group(self) -> int:
        return self.n_heads // self.kv_heads


def heads_tp_size() -> int:
    """Size of the mesh axis the 'heads' logical axis maps to (1 off-mesh)."""
    from repro.distributed.sharding import current_rules
    ctx = current_rules()
    if not ctx or ctx[1] is None:
        return 1
    rules, mesh = ctx
    target = rules.get("heads")
    if target is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = (target,) if isinstance(target, str) else tuple(target)
    n = 1
    for ax in axes:
        n *= sizes.get(ax, 1)
    return n


def effective_kv_heads(cfg: AttnConfig) -> int:
    """KV-head count actually carried through attention / the KV cache.

    §Perf hillclimb: with kv_heads < TP degree the (kv, group) einsum
    split loses head sharding entirely — the fp32 score tensor then
    replicates over the model axis (measured 16x memory-term inflation
    on command-r).  Megatron's fix: replicate KV per query group so the
    FLAT head dim shards.  Applied whenever kv doesn't divide TP but
    n_heads does; a pure function of (cfg, active mesh), so the cache
    layout and every mode agree.
    """
    tp = heads_tp_size()
    if tp > 1 and cfg.kv_heads % tp != 0 and cfg.n_heads % tp == 0:
        return cfg.n_heads
    return cfg.kv_heads


def seq_parallel_attention(cfg: AttnConfig) -> bool:
    """Neither kv nor n_heads shardable (e.g. musicgen's 24 heads on a
    16-way axis): fall back to sharding the QUERY-sequence dim of the
    attention computation over the model axis (sequence parallelism)."""
    tp = heads_tp_size()
    return tp > 1 and cfg.n_heads % tp != 0


def attn_def(cfg: AttnConfig) -> dict[str, ParamDef]:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    defs: dict[str, ParamDef] = {
        "wq": ParamDef((d, h, dh), ("embed", "heads", None)),
        "wk": ParamDef((d, kv, dh), ("embed", "kv", None)),
        "wv": ParamDef((d, kv, dh), ("embed", "kv", None)),
        "wo": ParamDef((h, dh, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, dh), ("heads", None), init="zeros")
        defs["bk"] = ParamDef((kv, dh), ("kv", None), init="zeros")
        defs["bv"] = ParamDef((kv, dh), ("kv", None), init="zeros")
    if cfg.out_bias:
        defs["bo"] = ParamDef((d,), (None,), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((dh,), (None,), init="zeros")
        defs["k_norm"] = ParamDef((dh,), (None,), init="zeros")
    return defs


def _qkv(params, x: Array, cfg: AttnConfig, positions: Array):
    """Returns (q, k, v) with k/v expanded to ``effective_kv_heads``
    (see above) so downstream sharding always has a shardable head dim
    when one exists."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
    ekv = effective_kv_heads(cfg)
    if ekv != cfg.kv_heads:
        rep = ekv // cfg.kv_heads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if seq_parallel_attention(cfg):
        q = logical_constraint(q, "batch", "seq_sp", None, None)
        k = logical_constraint(k, "batch", None, None, None)
        v = logical_constraint(v, "batch", None, None, None)
    else:
        q = logical_constraint(q, "batch", "seq", "heads", None)
        k = logical_constraint(k, "batch", "seq", "kv", None)
        v = logical_constraint(v, "batch", "seq", "kv", None)
    return q, k, v


def _scores_mask(q_pos: Array, k_pos: Array, window: int | None) -> Array:
    """(.., Sq, Sk) boolean keep-mask: causal (+ sliding window)."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def _sdpa(q: Array, k: Array, v: Array, mask: Array,
          softcap: float | None) -> Array:
    """q: (B,Sq,KV,G,Dh); k/v: (B,Sk,KV,Dh); mask: (B,Sq,Sk)."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dh)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def _sdpa_chunked(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
                  window: int | None, softcap: float | None,
                  block: int = 1024) -> Array:
    """Online-softmax (flash-style) attention, scanning KV blocks.

    Never materializes the (Sq, Sk) score matrix — per step only
    (B, KV, G, Sq, block) lives.  Exact (same math as `_sdpa`).
    q: (B,Sq,KV,G,Dh); k,v: (B,Sk,KV,Dh); q_pos: (B,Sq); k_pos: (B,Sk).
    """
    b, sq, kv, g, dh = q.shape
    sk = k.shape[1]
    pad = (-sk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)),
                        constant_values=jnp.iinfo(jnp.int32).max)
    nb = (sk + pad) // block
    kb = jnp.moveaxis(k.reshape(b, nb, block, kv, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, block, kv, dh), 1, 0)
    kpb = jnp.moveaxis(k_pos.reshape(b, nb, block), 1, 0)
    qf = q.astype(jnp.float32)
    scale = 1.0 / math.sqrt(dh)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, kp = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc.astype(jnp.float32))
        s = s * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        keep = _scores_mask(q_pos, kp, window)           # (B, Sq, block)
        s = jnp.where(keep[:, None, None], s, -1e30)
        bm = jnp.max(s, axis=-1)
        m2 = jnp.maximum(m, bm)
        p = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + jnp.sum(p, axis=-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
        return (m2, l2, acc2), None

    m0 = jnp.full((b, kv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)       # (B,Sq,KV,G,Dh)


def _sdpa_window_blocks(q: Array, k: Array, v: Array, q_pos: Array,
                        k_pos: Array, window: int,
                        softcap: float | None) -> Array:
    """Sliding-window attention in diagonal blocks of width `window`:
    query block i attends KV blocks (i-1, i) only — FLOPs O(S*2W)
    instead of O(S^2).  Exact for causal windows."""
    b, sq, kv, g, dh = q.shape
    assert k.shape[1] == sq, "window-block path expects self-attention"
    w = window
    pad = (-sq) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)),
                        constant_values=-1)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)),
                        constant_values=jnp.iinfo(jnp.int32).max)
    nb = q.shape[1] // w
    qb = jnp.moveaxis(q.reshape(b, nb, w, kv, g, dh), 1, 0)
    kb = jnp.moveaxis(k.reshape(b, nb, w, kv, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, w, kv, dh), 1, 0)
    qpb = jnp.moveaxis(q_pos.reshape(b, nb, w), 1, 0)
    kpb = jnp.moveaxis(k_pos.reshape(b, nb, w), 1, 0)
    # previous block (zeros/sentinel for block 0)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:1]), kb[:-1]], 0)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:1]), vb[:-1]], 0)
    kpprev = jnp.concatenate(
        [jnp.full_like(kpb[:1], jnp.iinfo(jnp.int32).max), kpb[:-1]], 0)

    def body(_, inp):
        qc, qp, kc, vc, kp, kc2, vc2, kp2 = inp
        kcat = jnp.concatenate([kc2, kc], axis=1)        # (B, 2W, KV, Dh)
        vcat = jnp.concatenate([vc2, vc], axis=1)
        kpcat = jnp.concatenate([kp2, kp], axis=1)
        mask = _scores_mask(qp, kpcat, window)
        out = _sdpa(qc, kcat, vcat, mask, softcap)
        return None, out

    _, outs = jax.lax.scan(
        body, None, (qb, qpb, kb, vb, kpb, kprev, vprev, kpprev))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nb * w, kv, g, dh)
    return out[:, :sq]


DENSE_ATTN_MAX_KV = 4096


def attention(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
              *, window: int | None, softcap: float | None,
              impl: str = "auto") -> Array:
    """Dispatch between dense, chunked (flash-style) and window-block
    attention.  All paths are exact; selection is purely a memory/FLOP
    trade (recorded per cell in EXPERIMENTS.md §Roofline)."""
    sk = k.shape[1]
    if impl == "auto":
        if window is not None and sk > 2 * window and q.shape[1] == sk:
            impl = "window"
        elif sk > DENSE_ATTN_MAX_KV:
            impl = "chunked"
        else:
            impl = "dense"
    if impl == "window":
        return _sdpa_window_blocks(q, k, v, q_pos, k_pos, window, softcap)
    if impl == "chunked":
        return _sdpa_chunked(q, k, v, q_pos, k_pos, window, softcap)
    mask = _scores_mask(q_pos, k_pos, window)
    return _sdpa(q, k, v, mask, softcap)


def attn_apply(params, x: Array, cfg: AttnConfig, *, positions: Array,
               mask: Array | None = None) -> Array:
    """Full-sequence attention (training / prefill).

    x: (B, S, D); positions: (B, S).  mask overrides the default
    causal(+window) mask when given (e.g. VLM prefix blocks).
    """
    b, s, d = x.shape
    q, k, v = _qkv(params, x, cfg, positions)
    ekv = k.shape[2]
    q = q.reshape(b, s, ekv, cfg.n_heads // ekv, cfg.head_dim)
    if mask is None:
        mask = _scores_mask(positions, positions, cfg.window)
    out = _sdpa(q, k, v, mask, cfg.softcap)
    out = out.reshape(b, s, cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    if cfg.out_bias:
        y = y + params["bo"].astype(x.dtype)
    return logical_constraint(y, "batch", "seq", "embed_no_fsdp")


def attn_decode(params, x: Array, cfg: AttnConfig, *, cache: dict,
                pos: Array) -> tuple[Array, dict]:
    """Single-token decode with a KV cache.

    x: (B, 1, D); cache: {'k','v': (B, S_cache, KV, Dh), 'offset': ()};
    pos: (B,) absolute positions of the new token.  For windowed
    attention the cache is a ring buffer of size >= window.
    """
    b, _, d = x.shape
    s_cache = cache["k"].shape[1]
    q, k, v = _qkv(params, x, cfg, pos[:, None])
    ekv = k.shape[2]
    assert cache["k"].shape[2] == ekv, (
        "cache kv-head layout disagrees with the active sharding rules; "
        "allocate it under the same mesh/rules (effective_kv_heads)")
    slot = pos % s_cache if cfg.window is not None else pos
    k_cache = jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0)))(
        cache["k"], k.astype(cache["k"].dtype), slot)
    v_cache = jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0)))(
        cache["v"], v.astype(cache["v"].dtype), slot)

    q = q.reshape(b, 1, ekv, cfg.n_heads // ekv, cfg.head_dim)
    # Absolute position of each cache slot (ring-aware).
    idx = jnp.arange(s_cache)[None, :]
    if cfg.window is not None:
        wraps = (pos[:, None] // s_cache)
        k_pos = jnp.where(idx <= (pos[:, None] % s_cache), wraps * s_cache + idx,
                          (wraps - 1) * s_cache + idx)
    else:
        k_pos = idx
    mask = _scores_mask(pos[:, None], k_pos, cfg.window)
    out = _sdpa(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                mask, cfg.softcap)
    out = out.reshape(b, 1, cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    if cfg.out_bias:
        y = y + params["bo"].astype(x.dtype)
    return y, {"k": k_cache, "v": v_cache}


def attn_cache_def(cfg: AttnConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict[str, ParamDef]:
    s = min(max_len, cfg.window) if cfg.window is not None else max_len
    ekv = effective_kv_heads(cfg)    # expanded layout shards on 'kv'
    return {
        "k": ParamDef((batch, s, ekv, cfg.head_dim),
                      ("batch", None, "kv", None), init="zeros", dtype=dtype),
        "v": ParamDef((batch, s, ekv, cfg.head_dim),
                      ("batch", None, "kv", None), init="zeros", dtype=dtype),
    }


# ---------------------------------------------------------------------------
# Deformable convolution layer (shared conv-backbone primitive)
# ---------------------------------------------------------------------------

def dcl_def(cin: int, cout: int, k: int = 3) -> dict[str, ParamDef]:
    """Parameter tree of one DCL: offset conv (zero-init, the paper's
    'offsets start at the regular grid') + deform conv weights."""
    return {
        "w_offset": ParamDef((k, k, cin, 2 * k * k), (None, None, None, None),
                             init="zeros"),
        "b_offset": ParamDef((2 * k * k,), (None,), init="zeros"),
        "w_deform": ParamDef((k, k, cin, cout),
                             (None, None, None, "conv_out")),
        "b_deform": ParamDef((cout,), (None,), init="zeros"),
    }


def dcl_apply(params: Mapping[str, Array], x: Array, *,
              kernel_size: int = 3, stride: int = 1, dilation: int = 1,
              offset_bound: float | None = None, use_kernel: bool = False,
              dataflow: str = "zero_copy", quant: str = "none",
              quant_scales: Mapping[str, Any] | None = None,
              cores: int = 1, shard_batch: bool | None = None,
              shard_spatial: bool | None = None,
              dtype: Any = jnp.float32) -> tuple[Array, Array]:
    """One DCL forward pass -> (y, o_max).

    ``use_kernel=True`` with a trained ``offset_bound`` routes through
    the fused Pallas kernel (``repro.kernels.ops.deform_conv``) under
    the requested dataflow — ``"zero_copy"`` (double-buffered in-kernel
    band DMAs, the default) or ``"banded"`` (legacy HBM-materialized
    bands).  Tile sizes come from the Sec. 3.2 chooser (combined
    fwd+bwd traffic objective).  Since PR 2 the kernel path is fully
    differentiable — ``jax.grad`` routes through the fused backward
    kernel (``kernels.deform_conv_bwd``, a ``jax.custom_vjp``), so
    bounded *training* runs zero-copy end-to-end; the pure-JAX gather
    path (``dcl_forward``) remains the parity reference.  ``o_max``
    (the Eq. 5 statistic) is computed from the raw offsets outside the
    kernel, so the regularizer gradient flows through XLA either way.

    Parallel training (PR 4): ``cores`` splits the backward kernel's
    batch grid per Megacore core, and ``shard_batch`` controls the
    data-parallel ``shard_map`` wrap of the kernel path over the active
    mesh's batch axes (None = auto when a mesh is live under
    ``distributed.sharding.use_rules``; True requires it and raises a
    clear ``ValueError`` on non-dividing batches) — both forwarded to
    ``ops.deform_conv``, including under ``quant="qat"`` (the STE
    wrappers act on replicated values outside the kernel, so the
    sharded VJP's d_weights psum is exactly the cotangent they need).

    Spatial sharding (PR 10): ``shard_spatial=True`` splits the height
    axis across the mesh axis mapped from logical ``"spatial"`` with a
    bounded halo exchange per layer (see ``distributed.spatial``) —
    forwarded to ``ops.deform_conv`` on the fp32-kernel, ``"qat"`` and
    ``"int8"`` kernel branches.  It requires the kernel path (the
    reference paths have no shard_map wrap) and is rejected by
    ``"int8_chain"``: the chained plan stages full-height fused-offset
    bands, so spatial serve buckets ladder from ``"int8"``.

    ``quant`` selects the int8 datapath modes of ``repro.quant``:

    * ``"qat"`` — fake-quantize the deform-conv operands (activation
      per-tensor, weights per-channel, STE backward) and run the
      normal fp32 machinery on the quantized grid; the offset conv and
      every gradient stay fp32, so the Trainer's custom-VJP zero-copy
      backward is untouched.  Scales are dynamic absmax unless
      ``quant_scales`` ({"x_scale": .., "w_scale": [..]}) pins them.
    * ``"int8"`` — inference: the kernel path dispatches
      ``ops.deform_conv(precision="int8")`` (int8 band DMA + int8 MXU
      with fused dequant); the non-kernel path runs the bit-level
      fake-quant reference (including the patch requantization the
      kernel performs before its MXU step).
    * ``"int8_chain"`` — the layer-chaining datapath: the offset conv
      is fused into the kernel (quantized, computed from the staged
      Eq. 6 band — no separate fp32 pass) and, when the calibration
      table carries a ``y_scale``, the output is emitted int8 on that
      grid (a ``repro.quant.QTensor``) with the deform bias folded
      into the fused requant — back-to-back DCLs chain int8 -> int8
      with no fp32 HBM round-trip (see ``dcl_chain_apply``).  Requires
      calibrated ``quant_scales`` (``calibrate_resnet_dcn`` records
      x/w/w_offset/y scales) and a trained ``offset_bound``.  The
      kernel path is inference (``o_max`` is None — the fused offsets
      never leave VMEM); with ``use_kernel=False`` the differentiable
      STE chain reference runs instead, so chained configs *train*
      through the production ``Trainer`` unchanged.
    """
    from repro.core.deform_conv import (DCLConfig, conv2d, dcl_forward,
                                        offset_abs_max)
    if quant not in ("none", "qat", "int8", "int8_chain"):
        raise ValueError(
            f"unknown quant mode {quant!r}; expected 'none', 'qat', "
            f"'int8' or 'int8_chain'")
    if quant == "int8_chain":
        # Fail loudly on configuration the chained datapath cannot
        # honor (mirroring the int8 branch's dataflow passthrough
        # contract) instead of silently running zero-copy/unsharded.
        if dataflow != "zero_copy":
            raise ValueError(
                f"quant='int8_chain' supports only the zero-copy "
                f"dataflow (got {dataflow!r}); the fused offset stage "
                f"and int8 emission are band-pipeline plans")
        if shard_batch:
            raise ValueError(
                "shard_batch=True is not supported by the chained int8 "
                "inference datapath (it partitions via GSPMD like the "
                "int8 branch); train chain configs via the STE "
                "reference (use_kernel=False)")
        if cores != 1:
            raise ValueError(
                f"cores={cores} applies to the fp32 training backward "
                f"only — the chained int8 datapath is inference, pass "
                f"cores=1")
        if shard_spatial:
            raise ValueError(
                "shard_spatial=True is not supported by the chained int8 "
                "datapath — the fused offset stage computes offsets from "
                "the staged band, so halo rows alone cannot reproduce "
                "them at shard seams; use quant='int8' for spatially "
                "sharded buckets")
        return _dcl_chain_layer(params, x, kernel_size=kernel_size,
                                stride=stride, dilation=dilation,
                                offset_bound=offset_bound,
                                use_kernel=use_kernel,
                                quant_scales=quant_scales, dtype=dtype)
    if shard_spatial and not (use_kernel and offset_bound is not None):
        raise ValueError(
            "shard_spatial=True requires the bounded kernel path "
            "(use_kernel=True with a trained offset_bound) — the "
            "reference paths have no spatial shard_map wrap")
    cin = x.shape[-1]
    cout = params["w_deform"].shape[-1]
    cfg = DCLConfig(in_channels=cin, out_channels=cout,
                    kernel_size=kernel_size, stride=stride,
                    dilation=dilation, offset_bound=offset_bound,
                    dtype=dtype)
    k = cfg.kernel_size

    if quant != "none":
        from repro.kernels import ops, ref
        from repro.quant.qat import (fake_quant_dcl_reference,
                                     qat_quantize_inputs)
        xc = x.astype(dtype)
        # Offsets always generate at full precision (the address path
        # of the accelerator — never quantized).
        offsets = conv2d(xc, params["w_offset"].astype(xc.dtype),
                         stride=stride, dilation=dilation, padding=cfg.pad)
        offsets = offsets + params["b_offset"].astype(xc.dtype)
        o_max = offset_abs_max(offsets)
        w = params["w_deform"].astype(xc.dtype).reshape(k * k, cin, cout)
        x_scale = quant_scales.get("x_scale") if quant_scales else None
        w_scale = quant_scales.get("w_scale") if quant_scales else None
        kernel_ok = use_kernel and offset_bound is not None
        if quant == "qat":
            xq, wq = qat_quantize_inputs(xc, w, x_scale=x_scale,
                                         w_scale=w_scale)
            if kernel_ok:
                y = ops.deform_conv(xq, offsets, wq, kernel_size=k,
                                    stride=stride, dilation=dilation,
                                    offset_bound=offset_bound,
                                    dataflow=dataflow, cores=cores,
                                    shard_batch=shard_batch,
                                    shard_spatial=shard_spatial)
            else:
                y = ref.deform_conv_fused_ref(xq, offsets, wq,
                                              kernel_size=k, stride=stride,
                                              dilation=dilation,
                                              offset_bound=offset_bound)
        else:  # int8 (inference datapath)
            if kernel_ok:
                ws = None if w_scale is None \
                    else jnp.asarray(w_scale, jnp.float32)
                # dataflow passes through so a banded config raises
                # ops' ValueError instead of silently running zero-copy
                y = ops.deform_conv(xc, offsets, w, kernel_size=k,
                                    stride=stride, dilation=dilation,
                                    offset_bound=offset_bound,
                                    dataflow=dataflow,
                                    precision="int8", x_scale=x_scale,
                                    w_scale=ws,
                                    shard_spatial=shard_spatial)
            else:
                y = fake_quant_dcl_reference(xc, offsets, w, kernel_size=k,
                                             stride=stride,
                                             dilation=dilation,
                                             offset_bound=offset_bound,
                                             x_scale=x_scale,
                                             w_scale=w_scale)
        return y + params["b_deform"].astype(xc.dtype), o_max

    if use_kernel and offset_bound is not None:
        from repro.kernels import ops
        offsets = conv2d(x, params["w_offset"].astype(x.dtype),
                         stride=stride, dilation=dilation, padding=cfg.pad)
        offsets = offsets + params["b_offset"].astype(x.dtype)
        o_max = offset_abs_max(offsets)
        w = params["w_deform"].astype(x.dtype).reshape(k * k, cin, cout)
        y = ops.deform_conv(x, offsets, w, kernel_size=k, stride=stride,
                            dilation=dilation, offset_bound=offset_bound,
                            dataflow=dataflow, cores=cores,
                            shard_batch=shard_batch,
                            shard_spatial=shard_spatial)
        return y + params["b_deform"].astype(x.dtype), o_max
    y, stats = dcl_forward(params, x, cfg)
    return y, stats["o_max"]


def _dcl_chain_layer(params: Mapping[str, Array], x, *, kernel_size: int,
                     stride: int, dilation: int,
                     offset_bound: float | None, use_kernel: bool,
                     quant_scales: Mapping[str, Any] | None, dtype: Any):
    """``quant="int8_chain"`` body of ``dcl_apply`` — one chained DCL.

    x may be a fp32 array (the chain head, quantized onto the table's
    ``x_scale``) or a ``QTensor`` handed over by the previous chained
    layer (consumed directly — no dequant/requant round-trip).  Returns
    ``(y, o_max)`` where y is a ``QTensor`` on the ``y_scale`` grid
    (kernel path with a calibrated ``y_scale``) or a fp32 array (the
    chain tail, or the differentiable STE reference path).
    """
    from repro.quant.qat import fake_quant_dcl_chain_reference
    from repro.quant.qtypes import QTensor

    if offset_bound is None:
        raise ValueError(
            "quant='int8_chain' requires a trained offset_bound — the "
            "fused offset-conv stage exists because Eq. 6 bounds the "
            "band (train with the Eq. 5 regularizer first)")
    scales = quant_scales or {}
    x_scale = scales.get("x_scale")
    if x_scale is None:
        raise ValueError(
            "quant='int8_chain' requires calibrated quant_scales with at "
            "least x_scale (repro.quant.calibrate_resnet_dcn records "
            "x/w/w_offset/y scales per DCL block): chained layers "
            "exchange int8 values on a pinned activation grid, so "
            "dynamic absmax would break the producer/consumer contract")
    w_scale = scales.get("w_scale")
    wo_scale = scales.get("w_offset_scale")
    y_scale = scales.get("y_scale")
    cin = x.shape[-1]
    cout = params["w_deform"].shape[-1]
    k = kernel_size
    w = params["w_deform"].astype(jnp.float32).reshape(k * k, cin, cout)
    w_off = params["w_offset"].astype(jnp.float32) \
        .reshape(k * k, cin, 2 * k * k)

    if use_kernel:
        from repro.kernels import ops
        if isinstance(x, QTensor):
            # A handed-over QTensor carries the grid it was emitted on;
            # decoding it with a different table scale would be silently
            # wrong.  The check needs a concrete value — under jit the
            # scale is a tracer and the static check_chain_compat guard
            # (dcl_chain_apply) is the line of defense instead.
            try:
                carried = float(x.scale)
            except (jax.errors.ConcretizationTypeError, TypeError):
                carried = None
            if carried is not None and not math.isclose(
                    carried, float(x_scale), rel_tol=1e-6):
                raise ValueError(
                    f"int8 input was emitted on scale {carried} but the "
                    f"layer's calibration table decodes x_scale="
                    f"{float(x_scale)} — the consumer's x_scale must BE "
                    f"the producer's y_scale (recalibrate the pair "
                    f"together)")
        xin = x.values if isinstance(x, QTensor) else x.astype(dtype)
        ws = None if w_scale is None else jnp.asarray(w_scale, jnp.float32)
        wos = None if wo_scale is None \
            else jnp.asarray(wo_scale, jnp.float32)
        emit = "int8" if y_scale is not None else "fp32"
        y = ops.deform_conv_chain(
            xin, w, w_off, params["b_offset"], params["b_deform"],
            kernel_size=k, stride=stride, dilation=dilation,
            offset_bound=offset_bound, x_scale=x_scale, w_scale=ws,
            w_offset_scale=wos, y_scale=y_scale, emit=emit)
        if emit == "int8":
            y = QTensor(values=y, scale=jnp.asarray(y_scale, jnp.float32))
        # The fused offsets never leave VMEM — there is no o_max to
        # observe on the inference datapath (training uses the STE
        # reference below, which returns the real statistic).
        return y, None

    xin = x.dequantize(dtype) if isinstance(x, QTensor) else x.astype(dtype)
    y, offsets = fake_quant_dcl_chain_reference(
        xin, w, w_off, params["b_offset"], params["b_deform"],
        kernel_size=k, stride=stride, dilation=dilation,
        offset_bound=offset_bound, x_scale=x_scale, w_scale=w_scale,
        w_offset_scale=wo_scale, y_scale=y_scale)
    from repro.core.deform_conv import offset_abs_max
    return y, offset_abs_max(offsets)


def check_chain_compat(scales_seq: Sequence[Mapping[str, Any]],
                       couts: Sequence[int] | None = None,
                       cins: Sequence[int] | None = None) -> None:
    """Validate that adjacent chained layers can actually hand each
    other int8 tensors — a clear ``ValueError`` naming the layer pair
    instead of a silent wrong-grid dequant (scales) or a deep kernel
    shape error (tiles/channels).

    Producer ``i`` emits on its ``y_scale`` grid; consumer ``i+1``
    decodes on its ``x_scale`` grid — they must be the same number.
    When channel extents are given, producer C_out must equal consumer
    C_in (the chained kernel stages the whole C extent per band).
    """
    for i in range(len(scales_seq) - 1):
        ys = scales_seq[i].get("y_scale")
        xs = scales_seq[i + 1].get("x_scale")
        if ys is None:
            raise ValueError(
                f"chained layer {i} has no y_scale: the int8 emission "
                f"grid must be calibrated (calibrate_resnet_dcn records "
                f"it from the DCL output observer) before layer {i + 1} "
                f"can consume the tensor")
        if xs is None or not math.isclose(float(ys), float(xs),
                                          rel_tol=1e-6):
            raise ValueError(
                f"adjacent chained layers disagree on the exchange "
                f"grid: layer {i} emits on y_scale={ys} but layer "
                f"{i + 1} decodes on x_scale={xs} — recalibrate the "
                f"pair together (the consumer's x_scale IS the "
                f"producer's y_scale)")
        if couts is not None and cins is not None \
                and couts[i] != cins[i + 1]:
            raise ValueError(
                f"chained layer {i} emits C_out={couts[i]} channels but "
                f"layer {i + 1} expects C_in={cins[i + 1]} — int8 "
                f"chaining hands the tensor over verbatim, so the "
                f"channel extents must match")


def dcl_chain_apply(params_seq: Sequence[Mapping[str, Array]], x: Array, *,
                    scales_seq: Sequence[Mapping[str, Any]],
                    kernel_size: int = 3, stride: int = 1,
                    dilation: int = 1, offset_bound: float | None = None,
                    use_kernel: bool = True,
                    dtype: Any = jnp.float32) -> tuple[Array, list]:
    """Run back-to-back DCLs chained int8 -> int8.

    Layer ``i`` emits a ``QTensor`` on its calibrated ``y_scale`` grid
    and layer ``i+1`` consumes it verbatim (its ``x_scale`` — validated
    equal by ``check_chain_compat``): between chained layers the
    activation touches HBM only as int8, the offsets never touch it at
    all, and the single fp32 boundary is the chain head (quantized
    once) and tail (the last layer's table has no ``y_scale``, or the
    caller dequantizes).  With ``use_kernel=False`` the differentiable
    STE chain reference runs layer by layer — the training path.

    Returns ``(y, o_maxes)``; o_maxes entries are None on the kernel
    path (the fused offsets never leave VMEM).
    """
    if len(params_seq) != len(scales_seq):
        raise ValueError(
            f"got {len(params_seq)} chained layers but "
            f"{len(scales_seq)} scale-table entries")
    check_chain_compat(
        scales_seq,
        couts=[p["w_deform"].shape[-1] for p in params_seq],
        cins=[p["w_deform"].shape[-2] for p in params_seq])
    o_maxes = []
    y = x
    for params, scales in zip(params_seq, scales_seq):
        y, o_max = dcl_apply(params, y, kernel_size=kernel_size,
                             stride=stride, dilation=dilation,
                             offset_bound=offset_bound,
                             use_kernel=use_kernel, quant="int8_chain",
                             quant_scales=scales, dtype=dtype)
        o_maxes.append(o_max)
    return y, o_maxes


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

ACTS: dict[str, Callable[[Array], Array]] = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    kind: str = "swiglu"          # swiglu | geglu | gelu | relu2
    bias: bool = False


def mlp_def(cfg: MLPConfig) -> dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    defs = {"w_out": ParamDef((f, d), ("ff", "embed"))}
    if cfg.kind in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((d, f), ("embed", "ff"))
        defs["w_up"] = ParamDef((d, f), ("embed", "ff"))
    else:
        defs["w_in"] = ParamDef((d, f), ("embed", "ff"))
    if cfg.bias:
        defs["b_in"] = ParamDef((f,), ("ff",), init="zeros")
        defs["b_out"] = ParamDef((d,), (None,), init="zeros")
    return defs


def mlp_apply(params, x: Array, cfg: MLPConfig) -> Array:
    if cfg.kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.kind == "swiglu" else jax.nn.gelu
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
        if cfg.bias:
            g = g + params["b_in"].astype(x.dtype)
        h = act(g) * u
    else:
        act = ACTS["gelu" if cfg.kind == "gelu" else "relu2"]
        h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(x.dtype))
        if cfg.bias:
            h = h + params["b_in"].astype(x.dtype)
        h = act(h)
    h = logical_constraint(h, "batch", "seq", "ff")
    y = jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(x.dtype))
    if cfg.bias:
        y = y + params["b_out"].astype(x.dtype)
    return logical_constraint(y, "batch", "seq", "embed_no_fsdp")


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def embed_def(vocab: int, d_model: int) -> dict[str, ParamDef]:
    # Sharded on vocab ONLY: FSDP-sharding the embed dim makes the token
    # gather un-partitionable (SPMD falls back to full replication of the
    # gathered activations — measured +4.3 GB/device temp at 1B scale).
    return {"embedding": ParamDef((vocab, d_model), ("vocab", None),
                                  init="embed", scale=0.02)}


def embed_apply(params, tokens: Array, dtype=jnp.bfloat16) -> Array:
    emb = params["embedding"].astype(dtype)
    out = jnp.take(emb, tokens, axis=0)
    return logical_constraint(out, "batch", "seq", "embed_no_fsdp")


def logits_apply(params, x: Array, *, softcap: float | None = None) -> Array:
    """Project to vocab with the (possibly tied) embedding matrix."""
    emb = params["embedding"].astype(x.dtype)
    logits = jnp.einsum("bsd,vd->bsv", x, emb,
                        preferred_element_type=jnp.float32)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logical_constraint(logits, "batch", "seq", "vocab")


def unembed_def(vocab: int, d_model: int) -> dict[str, ParamDef]:
    # vocab (output) dim sharded; contracting dim replicated so the logit
    # matmul partitions without a cross-'data' reduction.
    return {"unembedding": ParamDef((d_model, vocab), (None, "vocab"))}


def unembed_apply(params, x: Array, *, softcap: float | None = None) -> Array:
    w = params["unembedding"].astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logical_constraint(logits, "batch", "seq", "vocab")


def chunked_cross_entropy(x: Array, w: Array, targets: Array,
                          mask: Array | None = None, *, tied: bool,
                          logit_scale: float = 1.0,
                          softcap: float | None = None,
                          chunk: int = 1024) -> Array:
    """Mean CE without materializing (B, S, V) logits.

    Scans token chunks; each step computes a (B, chunk, V) logit block,
    reduces it to per-token NLL, and discards it (checkpointed, so the
    backward pass recomputes the block instead of saving it).  At
    command-r scale the full logits tensor is ~1 TB fp32 — this is the
    difference between compiling and not.

    x: (B, S, D) final hidden; w: embedding (V, D) if tied else (D, V).
    """
    b, s, d = x.shape
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    nb = (s + pad) // chunk
    xc = jnp.moveaxis(x.reshape(b, nb, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nb, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, nb, chunk), 1, 0)
    wt = w.astype(x.dtype)

    @jax.checkpoint
    def body(carry, inp):
        nll_sum, m_sum = carry
        xb, tb, mb = inp
        eq = "bsd,vd->bsv" if tied else "bsd,dv->bsv"
        logits = jnp.einsum(eq, xb, wt, preferred_element_type=jnp.float32)
        logits = logits * logit_scale
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        logits = logical_constraint(logits, "batch", "seq", "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, tb[..., None].astype(jnp.int32), axis=-1)[..., 0]
        mb = mb.astype(jnp.float32)
        return (nll_sum + jnp.sum((lse - gold) * mb),
                m_sum + jnp.sum(mb)), None

    (nll, m), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, tc, mc))
    return nll / jnp.maximum(m, 1.0)


def cross_entropy(logits: Array, targets: Array,
                  mask: Array | None = None) -> Array:
    """Mean CE over (possibly masked) targets; logits fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
