"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel of width d_rnn):

    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The block wraps the LRU with a width-4 temporal conv and a GeLU gate
branch (Griffin's "recurrent block").  Training uses
``jax.lax.associative_scan`` (parallel prefix — the TPU-native way to
run a linear recurrence in O(log S) depth); decode carries (h, conv
taps) as state.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from .layers import ParamDef

Array = jax.Array

LRU_C = 8.0
CONV_WIDTH = 4


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int            # lru width (RecurrentGemma-9B: 4096)


def rglru_block_def(cfg: RGLRUConfig) -> dict[str, ParamDef]:
    d, dr = cfg.d_model, cfg.d_rnn
    return {
        # Griffin recurrent block: two input branches
        "w_gate_in": ParamDef((d, dr), ("embed", "rnn")),     # GeLU branch
        "w_rec_in": ParamDef((d, dr), ("embed", "rnn")),      # conv+LRU branch
        "conv_w": ParamDef((CONV_WIDTH, dr), (None, "rnn"), scale=0.1),
        "conv_b": ParamDef((dr,), ("rnn",), init="zeros"),
        # RG-LRU gates
        "w_a": ParamDef((dr, dr), ("rnn", None)),
        "b_a": ParamDef((dr,), (None,), init="zeros"),
        "w_x": ParamDef((dr, dr), ("rnn", None)),
        "b_x": ParamDef((dr,), (None,), init="zeros"),
        "lam": ParamDef((dr,), (None,), init="ones", scale=None),
        "w_out": ParamDef((dr, d), ("rnn", "embed")),
    }


def _log_a(params, x: Array, r: Array) -> Array:
    lam = jax.nn.softplus(params["lam"].astype(jnp.float32))
    return -LRU_C * lam * r.astype(jnp.float32)


def rg_lru_scan(params, x: Array, h0: Array | None = None):
    """x: (B, S, d_rnn).  Returns (y (B,S,d_rnn), h_final (B,d_rnn))."""
    b, s, dr = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_x"].astype(jnp.float32)
                       + params["b_x"].astype(jnp.float32))
    log_a = _log_a(params, x, r)                       # (B, S, dr), <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xf)

    # h_t = a_t h_{t-1} + b_t  via associative scan on (a, b) pairs.
    if h0 is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.ones((b, 1, dr), a.dtype), a], axis=1)
        gated = jnp.concatenate([h0.astype(jnp.float32)[:, None], gated], 1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    return hh.astype(x.dtype), hh[:, -1]


def rg_lru_step(params, x: Array, h: Array):
    """Decode: x (B, d_rnn), h (B, d_rnn) -> (y, h')."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_x"].astype(jnp.float32)
                       + params["b_x"].astype(jnp.float32))
    log_a = _log_a(params, x, r)
    a = jnp.exp(log_a)
    h = a * h.astype(jnp.float32) \
        + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return h.astype(x.dtype), h


def _causal_conv(x: Array, w: Array, b: Array,
                 state: Array | None = None):
    """Width-4 depthwise causal conv.  x: (B, S, dr).
    state: (B, CONV_WIDTH-1, dr) trailing inputs from the previous call."""
    bsz, s, dr = x.shape
    if state is None:
        state = jnp.zeros((bsz, CONV_WIDTH - 1, dr), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + s] * w[i].astype(x.dtype)
              for i in range(CONV_WIDTH))
    new_state = xp[:, -(CONV_WIDTH - 1):]
    return out + b.astype(x.dtype), new_state


def rglru_block_apply(params, x: Array, cfg: RGLRUConfig, *,
                      state: dict | None = None):
    """Griffin recurrent block.  x: (B, S, D).
    state: {'h': (B, d_rnn), 'conv': (B, 3, d_rnn)} or None.
    Returns (y, new_state)."""
    gate = jax.nn.gelu(x @ params["w_gate_in"].astype(x.dtype))
    u = x @ params["w_rec_in"].astype(x.dtype)
    u = logical_constraint(u, "batch", "seq", "rnn")
    conv_state = state["conv"] if state else None
    u, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"],
                                 conv_state)
    h0 = state["h"] if state else None
    y, h = rg_lru_scan(params, u, h0)
    y = y * gate
    out = y @ params["w_out"].astype(x.dtype)
    out = logical_constraint(out, "batch", "seq", "embed_no_fsdp")
    return out, {"h": h, "conv": conv_state}


def rglru_block_step(params, x: Array, cfg: RGLRUConfig, *, state: dict):
    """Decode one token.  x: (B, D)."""
    gate = jax.nn.gelu(x @ params["w_gate_in"].astype(x.dtype))
    u = x @ params["w_rec_in"].astype(x.dtype)
    u3, conv_state = _causal_conv(u[:, None], params["conv_w"],
                                  params["conv_b"], state["conv"])
    y, h = rg_lru_step(params, u3[:, 0], state["h"])
    out = (y * gate) @ params["w_out"].astype(x.dtype)
    return out, {"h": h, "conv": conv_state}
