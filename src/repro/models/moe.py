"""Mixture-of-Experts block: top-k routing with capacity (GShard-style).

Dispatch/combine are dense one-hot einsums — the standard TPU-native
formulation (no dynamic shapes, shardable by GSPMD).  Experts are
*tensor-parallel* by default: the expert dimension is replicated and the
inner (d_model, d_ff) dims are sharded over ('embed'->data, 'ff'->model),
which works for any expert count (grok's 8 experts do not divide a
16-way axis).  An expert-parallel variant (experts on 'model', all-to-all
dispatch) is exercised in the §Perf hillclimb via the 'experts' rule.

FLOPs scale with E * capacity = top_k * tokens * capacity_factor — i.e.
with ACTIVE parameters, matching the 6*N_active*D roofline accounting.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from .layers import ParamDef

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    kind: str = "swiglu"          # expert MLP activation
    router_softcap: float | None = None


def moe_def(cfg: MoEConfig) -> dict[str, ParamDef]:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    defs = {
        "w_router": ParamDef((d, e), (None, None), scale=0.02),
        "w_out": ParamDef((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.kind in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((e, d, f), ("experts", "embed", "ff"))
        defs["w_up"] = ParamDef((e, d, f), ("experts", "embed", "ff"))
    else:
        defs["w_in"] = ParamDef((e, d, f), ("experts", "embed", "ff"))
    return defs


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(cap, cfg.top_k)


def moe_apply(params, x: Array, cfg: MoEConfig,
              *, full_capacity: bool = False) -> tuple[Array, Array]:
    """x: (B, S, D) -> (y, aux_loss).

    Scatter/gather dispatch (NOT the GShard one-hot einsum): the one-hot
    (T, E, C) dispatch tensor is O(T^2) at LM batch sizes — at train_4k
    scale (1M tokens) it would be ~3e12 elements and its einsum would
    dominate HLO FLOPs with non-model compute.  Instead each (token, k)
    choice scatter-adds its token into an (E*C, D) buffer and gathers the
    expert output back, so HLO FLOPs stay proportional to ACTIVE params
    (6*N_active*D accounting) and the roofline ratio stays honest.

    aux_loss is the standard load-balancing loss (mean over experts of
    fraction_dispatched * mean_router_prob * E).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    # GROUPED dispatch (§Perf hillclimb): each batch row routes into its
    # own (E, cap) buffer, so every scatter/gather index is LOCAL to the
    # batch-sharded shard — GSPMD partitions the whole block along
    # 'batch' with zero dispatch collectives.  The global-buffer variant
    # made SPMD replicate a (E*C, D) tensor per device: measured 2.6e13
    # collective bytes/device/step on grok-1 train_4k (544 s of ICI time
    # vs 21 s of compute).  Capacity is per group (GShard semantics).
    # decode (full_capacity): cap = s*k slots — drop-free.
    cap = s * k if full_capacity else _capacity(s, cfg)

    logits = jnp.einsum("bsd,de->bse", x, params["w_router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.router_softcap is not None:
        logits = jnp.tanh(logits / cfg.router_softcap) * cfg.router_softcap
    probs = jax.nn.softmax(logits, axis=-1)                  # (B, S, E)

    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (B, S, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Position of each (token, k) choice within its expert's capacity,
    # cumulative WITHIN the group (axis=1).
    flat_e = expert_idx.reshape(b, s * k)                    # (B, SK)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (B, SK, E)
    pos = jnp.sum((jnp.cumsum(onehot, axis=1) - onehot) * onehot,
                  axis=-1)                                   # (B, SK)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)      # drop -> last

    # Scatter tokens into each group's (E*C, D) buffer (batch-local).
    src = jnp.repeat(x, k, axis=1) * keep[..., None].astype(x.dtype)
    buf = jax.vmap(
        lambda sl, sr: jnp.zeros((e * cap + 1, d), x.dtype).at[sl].add(sr)
    )(slot, src)                                             # (B, EC+1, D)
    ein = buf[:, :-1].reshape(b, e, cap, d)
    ein = logical_constraint(ein, "batch", "experts", None, "embed_no_fsdp")

    if cfg.kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.kind == "swiglu" else jax.nn.gelu
        g = jnp.einsum("becd,edf->becf", ein,
                       params["w_gate"].astype(x.dtype))
        u = jnp.einsum("becd,edf->becf", ein,
                       params["w_up"].astype(x.dtype))
        h = act(g) * u
    else:
        h = jax.nn.gelu(
            jnp.einsum("becd,edf->becf", ein,
                       params["w_in"].astype(x.dtype)))
    h = logical_constraint(h, "batch", "experts", None, "ff")
    eout = jnp.einsum("becf,efd->becd", h, params["w_out"].astype(x.dtype))
    eout = logical_constraint(eout, "batch", "experts", None,
                              "embed_no_fsdp")

    # Gather expert outputs back to (token, k) and combine by gate.
    eflat = jnp.concatenate(
        [eout.reshape(b, e * cap, d),
         jnp.zeros((b, 1, d), eout.dtype)], axis=1)
    back = jnp.take_along_axis(eflat, slot[..., None], axis=1)  # (B, SK, D)
    gk = (gate_vals.reshape(b, s * k) * keep).astype(x.dtype)
    y = jnp.sum((back * gk[..., None]).reshape(b, s, k, d), axis=2)

    # Load-balancing aux loss (Switch/GShard).
    frac = jnp.mean(
        jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(axis=2),
        axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(frac * mean_prob) * e / k

    return logical_constraint(y, "batch", "seq", "embed_no_fsdp"), aux
