"""Pipeline-parallel transformer forward (GPipe over the layer stack).

Turns `distributed.pipeline.gpipe_forward` into a first-class model
feature: the scanned period stack is split into ``n_stages`` contiguous
chunks, each resident on one rank of a 'stage' mesh axis; microbatches
stream through with the GPipe schedule.  Embedding / final norm /
unembedding run outside the pipeline (their params are replicated and
cheap relative to the stack).

Scope: train/eval forward (no KV caches), homogeneous configs with no
prefix layers, ``n_periods % n_stages == 0``.  MoE aux-loss is not
threaded through the pipeline (single-activation stages); use the
standard data/tensor-parallel path when the aux term matters.
Correctness vs ``transformer.forward`` is asserted in
``examples/pipeline_demo.py`` / ``tests/test_pipeline.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.pipeline import gpipe_forward
from . import layers as L
from .transformer import _LAYER_APPLY, _logits, _embed, ModelConfig

Array = jax.Array


def split_stage_params(params, cfg: ModelConfig, n_stages: int):
    """(n_periods, ...) stacked layers -> (n_stages, periods/stage, ...)."""
    assert not cfg.prefix, "pipelined path requires no prefix layers"
    assert cfg.n_periods % n_stages == 0, (cfg.n_periods, n_stages)
    pp = cfg.n_periods // n_stages
    return jax.tree_util.tree_map(
        lambda a: a.reshape((n_stages, pp) + a.shape[1:]), params["layers"])


def pipelined_forward(params, cfg: ModelConfig, tokens: Array, *, mesh,
                      n_stages: int, microbatches: int,
                      axis_name: str = "stage") -> Array:
    """Returns logits (B, S, V); B must divide into ``microbatches``."""
    b, s = tokens.shape[0], tokens.shape[1]
    assert b % microbatches == 0
    stage_params = split_stage_params(params, cfg, n_stages)

    x = _embed(params, cfg, tokens, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    xs = x.reshape((microbatches, b // microbatches) + x.shape[1:])
    pos_mb = positions[: b // microbatches]

    def stage_fn(stage_p, act):
        def body(carry, per_params):
            h = carry
            for j, kind in enumerate(cfg.pattern):
                h, _, _ = _LAYER_APPLY[kind](
                    per_params[f"m{j}"], h, cfg, mode="train", cache=None,
                    positions=pos_mb, cache_len=None)
            return h, None

        out, _ = jax.lax.scan(body, act, stage_p)
        return out

    y = gpipe_forward(stage_fn, stage_params, xs, mesh=mesh,
                      axis_name=axis_name)
    y = y.reshape((b,) + y.shape[2:])
    y = L.apply_norm(params["final_norm"], y, cfg.norm)
    return _logits(params, cfg, y)
