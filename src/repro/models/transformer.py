"""Unified decoder model covering all 10 assigned architectures.

One config drives: dense GQA transformers (command-r, tinyllama, glm4,
deepseek, pixtral backbone), MoE transformers (dbrx, grok-1), multi-
codebook audio decoders (musicgen), attention-free RWKV-6, and the
RG-LRU/attention hybrid (recurrentgemma) — via a periodic layer
``pattern`` of mixer kinds ('attn' | 'rwkv6' | 'rglru').

Layers are *scanned*: parameters of one pattern period are stacked along
a leading axis and the stack is consumed by ``lax.scan`` — compile time
and HLO size stay O(period), not O(n_layers), which is what makes 80
dry-run compiles of up-to-314B-parameter models tractable.  Hybrid
patterns scan whole periods (e.g. (rglru, rglru, attn)); a remainder
prefix runs unscanned.

Three modes share the same layer code: 'train' (full seq, no cache),
'prefill' (full seq, emits caches), 'decode' (one token, carries caches).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import logical_constraint
from . import layers as L
from .layers import ParamDef
from .moe import MoEConfig, moe_apply, moe_def
from .rwkv6 import (RWKVConfig, channel_mix_apply, channel_mix_def,
                    channel_mix_step, time_mix_apply, time_mix_def,
                    time_mix_step)
from .rglru import (RGLRUConfig, rglru_block_apply, rglru_block_def,
                    rglru_block_step, CONV_WIDTH)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    norm: str = "rms"                  # rms | layer
    act: str = "swiglu"
    parallel_block: bool = False       # command-r: attn and mlp in parallel
    qkv_bias: bool = False
    out_bias: bool = False
    mlp_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    window: int | None = None          # sliding window for attn layers
    attn_softcap: float | None = None
    logits_softcap: float | None = None
    logit_scale: float = 1.0
    embed_scale: bool = False          # multiply embeddings by sqrt(d)
    tie_embeddings: bool = True
    qk_norm: bool = False
    pattern: tuple[str, ...] = ("attn",)
    moe: MoEConfig | None = None
    rwkv: RWKVConfig | None = None
    rglru: RGLRUConfig | None = None
    codebooks: int = 1                 # musicgen: 4 parallel codebooks
    frontend_embeds: bool = False      # pixtral: extra (B, P, D) embeds input
    dtype: Any = jnp.bfloat16
    remat: str = "none"                # none | full | dots
    moe_aux_coef: float = 0.01
    # False unrolls the layer stack into straight-line HLO.  Used by the
    # dry-run's 1-period/2-period FLOP-extrapolation compiles (XLA cost
    # analysis counts a While body once, so the scanned model's FLOPs
    # must be reconstructed from an unrolled delta).
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def prefix(self) -> tuple[str, ...]:
        return self.pattern[: self.n_layers % len(self.pattern)]

    def attn_cfg(self) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            kv_heads=self.kv_heads, head_dim=self.hd,
            rope_theta=self.rope_theta, rope_fraction=self.rope_fraction,
            use_rope=self.use_rope, qkv_bias=self.qkv_bias,
            out_bias=self.out_bias, window=self.window,
            softcap=self.attn_softcap, qk_norm=self.qk_norm)

    def mlp_cfg(self) -> L.MLPConfig:
        return L.MLPConfig(d_model=self.d_model, d_ff=self.d_ff,
                           kind=self.act, bias=self.mlp_bias)

    def param_count(self) -> int:
        defs = model_def(self)
        leaves = jax.tree_util.tree_leaves(
            defs, is_leaf=lambda x: isinstance(x, ParamDef))
        total = sum(math.prod(d.shape) for d in leaves)
        # stacked period layers count n_periods times; prefix once; the
        # stacking is applied at init, so account for it here.
        per_period = sum(
            math.prod(d.shape) for d in jax.tree_util.tree_leaves(
                _period_def(self), is_leaf=lambda x: isinstance(x, ParamDef)))
        return total + per_period * (self.n_periods - 1)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_leaves = [d for k, d in _flat_defs(moe_def(self.moe)).items()
                      if k != "w_router"]
        per_layer_moe = sum(math.prod(d.shape) for d in moe_leaves)
        n_moe_layers = self.n_layers  # every layer is MoE in dbrx/grok
        inactive = per_layer_moe * n_moe_layers \
            * (1 - self.moe.top_k / self.moe.num_experts)
        return int(full - inactive)


def _flat_defs(d, prefix=""):
    out = {}
    for k, v in d.items():
        if isinstance(v, ParamDef):
            out[prefix + k] = v
        else:
            out.update(_flat_defs(v, prefix + k + "/"))
    return out


# ---------------------------------------------------------------------------
# Layer definitions per mixer kind
# ---------------------------------------------------------------------------

def _layer_def(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    if kind == "attn":
        out = {"norm1": L.norm_def(d, cfg.norm),
               "attn": L.attn_def(cfg.attn_cfg())}
        if not cfg.parallel_block:
            out["norm2"] = L.norm_def(d, cfg.norm)
        out["ffn"] = moe_def(cfg.moe) if cfg.moe else L.mlp_def(cfg.mlp_cfg())
        return out
    if kind == "rwkv6":
        return {"norm1": L.norm_def(d, cfg.norm),
                "tm": time_mix_def(cfg.rwkv),
                "norm2": L.norm_def(d, cfg.norm),
                "cm": channel_mix_def(cfg.rwkv)}
    if kind == "rglru":
        return {"norm1": L.norm_def(d, cfg.norm),
                "rec": rglru_block_def(cfg.rglru),
                "norm2": L.norm_def(d, cfg.norm),
                "ffn": L.mlp_def(cfg.mlp_cfg())}
    raise ValueError(kind)


def _period_def(cfg: ModelConfig) -> dict:
    return {f"m{i}": _layer_def(cfg, kind)
            for i, kind in enumerate(cfg.pattern)}


def model_def(cfg: ModelConfig) -> dict:
    """ParamDef tree (period layers declared ONCE; stacked at init)."""
    d, v = cfg.d_model, cfg.vocab
    defs: dict[str, Any] = {}
    if cfg.codebooks > 1:
        defs["embed"] = {"embedding": ParamDef(
            (cfg.codebooks, v, d), (None, "vocab", None),
            init="embed", scale=0.02)}
        defs["heads"] = {"unembedding": ParamDef(
            (cfg.codebooks, d, v), (None, None, "vocab"))}
    else:
        defs["embed"] = L.embed_def(v, d)
        if not cfg.tie_embeddings:
            defs["unembed"] = L.unembed_def(v, d)
    defs["final_norm"] = L.norm_def(d, cfg.norm)
    for i, kind in enumerate(cfg.prefix):
        defs[f"prefix{i}"] = _layer_def(cfg, kind)
    defs["period"] = _period_def(cfg)
    return defs


def init_params(key: Array, cfg: ModelConfig):
    defs = model_def(cfg)
    period_defs = defs.pop("period")
    params = L.init_tree(key, defs)
    keys = jax.random.split(jax.random.fold_in(key, 7), cfg.n_periods)
    params["layers"] = jax.vmap(
        lambda k: L.init_tree(k, period_defs))(keys)
    return params


def param_specs(cfg: ModelConfig):
    """PartitionSpec tree matching ``init_params`` (uses active rules)."""
    defs = model_def(cfg)
    period_defs = defs.pop("period")
    specs = L.spec_tree(defs)
    period_specs = L.spec_tree(period_defs)
    specs["layers"] = jax.tree_util.tree_map(
        lambda s: P(*((None,) + tuple(s))), period_specs,
        is_leaf=lambda x: isinstance(x, P))
    return specs


def abstract_params(cfg: ModelConfig, dtype=None):
    """ShapeDtypeStruct tree matching ``init_params`` (no allocation)."""
    defs = model_def(cfg)
    period_defs = defs.pop("period")
    absd = L.abstract_tree(defs, dtype)
    period_abs = L.abstract_tree(period_defs, dtype)
    absd["layers"] = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_periods,) + s.shape, s.dtype),
        period_abs)
    return absd


# ---------------------------------------------------------------------------
# Cache definitions (decode / prefill state)
# ---------------------------------------------------------------------------

def _layer_cache_def(cfg: ModelConfig, kind: str, batch: int,
                     cache_len: int) -> dict:
    d = cfg.d_model
    if kind == "attn":
        return L.attn_cache_def(cfg.attn_cfg(), batch, cache_len,
                                dtype=cfg.dtype)
    if kind == "rwkv6":
        h, dh = cfg.rwkv.n_heads, cfg.rwkv.head_dim
        return {
            "shift_tm": ParamDef((batch, d), ("batch", None), init="zeros",
                                 dtype=cfg.dtype),
            "wkv": ParamDef((batch, h, dh, dh), ("batch", "heads", None, None),
                            init="zeros", dtype=jnp.float32),
            "shift_cm": ParamDef((batch, d), ("batch", None), init="zeros",
                                 dtype=cfg.dtype),
        }
    if kind == "rglru":
        dr = cfg.rglru.d_rnn
        return {
            "h": ParamDef((batch, dr), ("batch", "rnn"), init="zeros",
                          dtype=jnp.float32),
            "conv": ParamDef((batch, CONV_WIDTH - 1, dr),
                             ("batch", None, "rnn"), init="zeros",
                             dtype=cfg.dtype),
        }
    raise ValueError(kind)


def cache_def(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    defs: dict[str, Any] = {}
    for i, kind in enumerate(cfg.prefix):
        defs[f"prefix{i}"] = _layer_cache_def(cfg, kind, batch, cache_len)
    defs["period"] = {f"m{i}": _layer_cache_def(cfg, kind, batch, cache_len)
                      for i, kind in enumerate(cfg.pattern)}
    return defs


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    defs = cache_def(cfg, batch, cache_len)
    period_defs = defs.pop("period")
    cache = L.init_tree(jax.random.PRNGKey(0), defs)
    period = L.init_tree(jax.random.PRNGKey(0), period_defs)
    cache["layers"] = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape), period)
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    defs = cache_def(cfg, batch, cache_len)
    period_defs = defs.pop("period")
    absd = L.abstract_tree(defs)
    period_abs = L.abstract_tree(period_defs)
    absd["layers"] = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_periods,) + s.shape, s.dtype),
        period_abs)
    return absd


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    defs = cache_def(cfg, batch, cache_len)
    period_defs = defs.pop("period")
    specs = L.spec_tree(defs)
    period_specs = L.spec_tree(period_defs)
    specs["layers"] = jax.tree_util.tree_map(
        lambda s: P(*((None,) + tuple(s))), period_specs,
        is_leaf=lambda x: isinstance(x, P))
    return specs


# ---------------------------------------------------------------------------
# Layer application (one code path for train / prefill / decode)
# ---------------------------------------------------------------------------

def _attn_prefill_cache(cfg: ModelConfig, k: Array, v: Array,
                        cache_len: int) -> dict:
    """Pack full-sequence K/V into the decode cache layout (ring-aware)."""
    b, s, kv, dh = k.shape
    if s >= cache_len:
        k_last = k[:, s - cache_len:]
        v_last = v[:, s - cache_len:]
        shift = s % cache_len
        k_c = jnp.roll(k_last, shift, axis=1)
        v_c = jnp.roll(v_last, shift, axis=1)
    else:
        pad = ((0, 0), (0, cache_len - s), (0, 0), (0, 0))
        k_c, v_c = jnp.pad(k, pad), jnp.pad(v, pad)
    return {"k": k_c.astype(cfg.dtype), "v": v_c.astype(cfg.dtype)}


def _apply_attn_layer(params, x, cfg: ModelConfig, *, mode, cache,
                      positions, cache_len):
    acfg = cfg.attn_cfg()
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(params["norm1"], x, cfg.norm)
    new_cache = None
    if mode == "decode":
        a, new_cache = L.attn_decode(params["attn"], h, acfg, cache=cache,
                                     pos=positions[:, 0])
    else:
        # train/prefill share the full-seq path
        b, s, _ = h.shape
        q, k, v = L._qkv(params["attn"], h, acfg, positions)
        ekv = k.shape[2]
        qg = q.reshape(b, s, ekv, acfg.n_heads // ekv, acfg.head_dim)
        o = L.attention(qg, k, v, positions, positions,
                        window=acfg.window, softcap=acfg.softcap)
        if L.seq_parallel_attention(acfg):
            # SP: the score/context tensors shard on the q-seq dim
            o = logical_constraint(o, "batch", "seq_sp", None, None, None)
        o = o.reshape(b, s, acfg.n_heads, acfg.head_dim)
        a = jnp.einsum("bshk,hkd->bsd", o, params["attn"]["wo"].astype(x.dtype))
        if acfg.out_bias:
            a = a + params["attn"]["bo"].astype(x.dtype)
        if mode == "prefill":
            new_cache = _attn_prefill_cache(cfg, k, v, cache_len)
    full_cap = (mode == "decode")
    if cfg.parallel_block:
        if cfg.moe:
            f, aux = moe_apply(params["ffn"], h, cfg.moe,
                               full_capacity=full_cap)
        else:
            f = L.mlp_apply(params["ffn"], h, cfg.mlp_cfg())
        x = x + a + f
    else:
        x = x + a
        h2 = L.apply_norm(params["norm2"], x, cfg.norm)
        if cfg.moe:
            f, aux = moe_apply(params["ffn"], h2, cfg.moe,
                               full_capacity=full_cap)
        else:
            f = L.mlp_apply(params["ffn"], h2, cfg.mlp_cfg())
        x = x + f
    return x, new_cache, aux


def _apply_rwkv_layer(params, x, cfg: ModelConfig, *, mode, cache,
                      positions, cache_len):
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(params["norm1"], x, cfg.norm)
    if mode == "decode":
        y, (sh_tm, wkv) = time_mix_step(
            params["tm"], h[:, 0], cfg.rwkv,
            shift_state=cache["shift_tm"], wkv_state=cache["wkv"])
        x = x + y[:, None]
        h2 = L.apply_norm(params["norm2"], x, cfg.norm)
        y2, sh_cm = channel_mix_step(params["cm"], h2[:, 0], cfg.rwkv,
                                     shift_state=cache["shift_cm"])
        x = x + y2[:, None]
        new_cache = {"shift_tm": sh_tm.astype(cfg.dtype), "wkv": wkv,
                     "shift_cm": sh_cm.astype(cfg.dtype)}
        return x, new_cache, aux
    init_tm = cache["shift_tm"] if mode == "prefill" and cache else None
    y, (sh_tm, wkv) = time_mix_apply(params["tm"], h, cfg.rwkv,
                                     shift_state=None, wkv_state=None)
    x = x + y
    h2 = L.apply_norm(params["norm2"], x, cfg.norm)
    y2, sh_cm = channel_mix_apply(params["cm"], h2, cfg.rwkv)
    x = x + y2
    new_cache = None
    if mode == "prefill":
        new_cache = {"shift_tm": sh_tm.astype(cfg.dtype), "wkv": wkv,
                     "shift_cm": sh_cm.astype(cfg.dtype)}
    del init_tm
    return x, new_cache, aux


def _apply_rglru_layer(params, x, cfg: ModelConfig, *, mode, cache,
                       positions, cache_len):
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(params["norm1"], x, cfg.norm)
    if mode == "decode":
        y, new_state = rglru_block_step(params["rec"], h[:, 0], cfg.rglru,
                                        state=cache)
        x = x + y[:, None]
    else:
        y, new_state = rglru_block_apply(params["rec"], h, cfg.rglru,
                                         state=None)
        x = x + y
    h2 = L.apply_norm(params["norm2"], x, cfg.norm)
    x = x + L.mlp_apply(params["ffn"], h2, cfg.mlp_cfg())
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"h": new_state["h"],
                     "conv": new_state["conv"].astype(cfg.dtype)}
    return x, new_cache, aux


_LAYER_APPLY = {
    "attn": _apply_attn_layer,
    "rwkv6": _apply_rwkv_layer,
    "rglru": _apply_rglru_layer,
}


# ---------------------------------------------------------------------------
# Full model forward
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens: Array | None,
           frontend: Array | None):
    if cfg.codebooks > 1:
        emb = params["embed"]["embedding"].astype(cfg.dtype)  # (CB, V, D)
        x = sum(jnp.take(emb[i], tokens[..., i], axis=0)
                for i in range(cfg.codebooks))
    else:
        x = L.embed_apply(params["embed"], tokens, cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    if frontend is not None:
        x = jnp.concatenate([frontend.astype(cfg.dtype), x], axis=1)
    return x


def _logits(params, cfg: ModelConfig, x: Array):
    if cfg.codebooks > 1:
        w = params["heads"]["unembedding"].astype(x.dtype)  # (CB, D, V)
        logits = jnp.einsum("bsd,cdv->bscv", x, w,
                            preferred_element_type=jnp.float32)
    elif cfg.tie_embeddings:
        logits = L.logits_apply(params["embed"], x)
    else:
        logits = L.unembed_apply(params["unembed"], x)
    logits = logits * cfg.logit_scale
    if cfg.logits_softcap is not None:
        logits = jnp.tanh(logits / cfg.logits_softcap) * cfg.logits_softcap
    return logits


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=None)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def forward(params, cfg: ModelConfig, *, tokens: Array | None = None,
            frontend: Array | None = None, mode: str = "train",
            caches=None, positions: Array | None = None,
            cache_len: int | None = None, return_hidden: bool = False):
    """Returns (logits_or_hidden, new_caches, aux_loss).

    ``return_hidden`` skips the unembedding (the training loss uses the
    chunked CE path instead — the full (B, S, V) logits tensor is never
    materialized).  Prefill slices to the LAST position before the
    unembedding for the same reason.
    """
    x = _embed(params, cfg, tokens, frontend)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = logical_constraint(x, "batch", "seq", "embed_no_fsdp")

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict[str, Any] = {}

    for i, kind in enumerate(cfg.prefix):
        c = caches.get(f"prefix{i}") if caches else None
        x, nc, aux = _LAYER_APPLY[kind](
            params[f"prefix{i}"], x, cfg, mode=mode, cache=c,
            positions=positions, cache_len=cache_len)
        aux_total += aux
        if nc is not None:
            new_caches[f"prefix{i}"] = nc

    def period_body(carry, per):
        x, aux_acc = carry
        per_params, per_cache = per
        per_new_cache = {}
        for j, kind in enumerate(cfg.pattern):
            c = per_cache.get(f"m{j}") if per_cache is not None else None
            x, nc, aux = _LAYER_APPLY[kind](
                per_params[f"m{j}"], x, cfg, mode=mode, cache=c,
                positions=positions, cache_len=cache_len)
            aux_acc += aux
            if nc is not None:
                per_new_cache[f"m{j}"] = nc
        x = logical_constraint(x, "batch", "seq", "embed_no_fsdp")
        return (x, aux_acc), (per_new_cache or None)

    body = _maybe_remat(period_body, cfg)
    layer_caches = caches["layers"] if caches else None
    if cfg.scan_layers:
        (x, aux_total), stacked_caches = jax.lax.scan(
            body, (x, aux_total), (params["layers"], layer_caches))
    else:
        outs = []
        for i in range(cfg.n_periods):
            take = lambda t: jax.tree_util.tree_map(lambda a: a[i], t)
            (x, aux_total), c = body(
                (x, aux_total),
                (take(params["layers"]),
                 take(layer_caches) if layer_caches is not None else None))
            outs.append(c)
        stacked_caches = None if outs and outs[0] is None else \
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs) \
            if outs else None
    if stacked_caches is not None:
        new_caches["layers"] = stacked_caches

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x, (new_caches or None), aux_total
    if mode == "prefill":
        x = x[:, -1:]
    logits = _logits(params, cfg, x)
    return logits, (new_caches or None), aux_total


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch: dict):
    """batch: tokens (B,S[,CB]), targets (B,S[,CB]), optional mask (B,S),
    optional frontend (B,P,D).  Returns (loss, metrics).

    Uses the chunked-CE path: the (B, S, V) logits tensor never exists.
    """
    frontend = batch.get("frontend")
    hidden, _, aux = forward(params, cfg, tokens=batch["tokens"],
                             frontend=frontend, mode="train",
                             return_hidden=True)
    targets = batch["targets"]
    mask = batch.get("mask")
    if frontend is not None:
        # loss only on the text positions (after the frontend prefix)
        p = frontend.shape[1]
        hidden = hidden[:, p:]
    if cfg.codebooks > 1:
        w = params["heads"]["unembedding"]         # (CB, D, V)
        ce = sum(
            L.chunked_cross_entropy(hidden, w[i], targets[..., i], mask,
                                    tied=False, logit_scale=cfg.logit_scale,
                                    softcap=cfg.logits_softcap)
            for i in range(cfg.codebooks)) / cfg.codebooks
    else:
        if cfg.tie_embeddings:
            w, tied = params["embed"]["embedding"], True
        else:
            w, tied = params["unembed"]["unembedding"], False
        ce = L.chunked_cross_entropy(hidden, w, targets, mask, tied=tied,
                                     logit_scale=cfg.logit_scale,
                                     softcap=cfg.logits_softcap)
    loss = ce + cfg.moe_aux_coef * aux
    return loss, {"ce": ce, "moe_aux": aux}


def prefill(params, cfg: ModelConfig, tokens: Array, *,
            cache_len: int, frontend: Array | None = None):
    """Returns (last-position logits, caches)."""
    logits, caches, _ = forward(params, cfg, tokens=tokens,
                                frontend=frontend, mode="prefill",
                                cache_len=cache_len)
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, tokens: Array, caches,
                pos: Array):
    """One decode step.  tokens: (B,) (or (B, CB) multi-codebook);
    pos: (B,) absolute positions.  Returns (logits (B, V[, CB]), caches)."""
    t = tokens[:, None] if cfg.codebooks == 1 else tokens[:, None, :]
    logits, new_caches, _ = forward(
        params, cfg, tokens=t, mode="decode", caches=caches,
        positions=pos[:, None], cache_len=None)
    return logits[:, 0], new_caches
