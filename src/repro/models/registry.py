"""Architecture registry: --arch <id> -> config + input specs + step fns.

Every assigned architecture registers an ``ArchSpec`` with its exact
published configuration and its own input-shape set.  ``input_specs``
returns weak-type-correct ``ShapeDtypeStruct`` stand-ins (no device
allocation) — the dry-run lowers against these.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .transformer import (ModelConfig, abstract_cache, abstract_params,
                          cache_specs, param_specs)
from .resnet_dcn import ResNetDCNConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    kind: str               # train | prefill | decode | train_det | infer_det
    seq_len: int = 0
    global_batch: int = 1
    note: str = ""


# The LM-family shape set shared by all 10 assigned architectures.
LM_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode", 32768, 128),
    "long_500k": ShapeSpec("decode", 524288, 1,
                           note="sub-quadratic archs only"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm | cnn
    config: Any                       # ModelConfig or ResNetDCNConfig
    shapes: dict[str, ShapeSpec]
    long_context_ok: bool = False     # may run long_500k
    source: str = ""
    notes: str = ""
    # per-arch logical->mesh rule overrides (e.g. dbrx: expert parallelism
    # because its 16 experts divide the 16-way model axis; §Perf E)
    rules_overrides: dict | None = None


_REGISTRY: dict[str, ArchSpec] = {}

ARCH_MODULES = [
    "command_r_35b", "tinyllama_1_1b", "glm4_9b", "deepseek_7b",
    "recurrentgemma_9b", "musicgen_medium", "pixtral_12b", "rwkv6_3b",
    "dbrx_132b", "grok_1_314b", "resnet50_dcn",
]


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.name] = spec
    return spec


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get(name: str) -> ArchSpec:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, honouring the long-context skip."""
    _ensure_loaded()
    cells = []
    for name in names():
        spec = _REGISTRY[name]
        for shape_name, shape in spec.shapes.items():
            if shape_name == "long_500k" and not spec.long_context_ok:
                continue
            cells.append((name, shape_name))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    _ensure_loaded()
    out = []
    for name in names():
        spec = _REGISTRY[name]
        for shape_name in spec.shapes:
            if shape_name == "long_500k" and not spec.long_context_ok:
                out.append((name, shape_name,
                            "full-attention arch: 0.5M-token dense KV/attn "
                            "per step is out of scope by design (DESIGN.md)"))
    return out


def reduced_config(arch: ArchSpec):
    """Small same-family config for CPU smoke tests: same mixer pattern,
    same GQA ratio, same MoE routing — tiny widths.  The FULL configs are
    exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""
    import dataclasses
    import jax.numpy as jnp
    cfg = arch.config
    if isinstance(cfg, ResNetDCNConfig):
        return dataclasses.replace(
            cfg, stage_sizes=(1, 1, 1, 1), widths=(32, 64, 128, 256),
            stem_width=16, num_dcn=2, num_classes=8, img_size=64)
    plen = len(cfg.pattern)
    n_layers = plen * 2 + (cfg.n_layers % plen)
    kv = max(1, (4 * cfg.kv_heads) // cfg.n_heads)
    kw = dict(
        n_layers=n_layers, d_model=64, n_heads=4, kv_heads=kv,
        head_dim=16, d_ff=128, vocab=128, dtype=jnp.float32, remat="none",
        name=cfg.name + "-reduced")
    if cfg.window is not None:
        kw["window"] = 16
    if cfg.moe is not None:
        from .moe import MoEConfig
        kw["moe"] = MoEConfig(d_model=64, d_ff=128, num_experts=4,
                              top_k=min(2, cfg.moe.top_k),
                              kind=cfg.moe.kind)
    if cfg.rwkv is not None:
        from .rwkv6 import RWKVConfig
        kw["rwkv"] = RWKVConfig(d_model=64, d_ff=128, head_dim=16,
                                decay_lora_rank=8)
    if cfg.rglru is not None:
        from .rglru import RGLRUConfig
        kw["rglru"] = RGLRUConfig(d_model=64, d_rnn=64)
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: ArchSpec, shape_name: str) -> dict[str, Any]:
    """Abstract inputs for the step function of (arch, shape)."""
    shape = arch.shapes[shape_name]
    cfg = arch.config

    if isinstance(cfg, ResNetDCNConfig):
        b, hw = shape.global_batch, cfg.img_size
        hc = hw // 32
        batch = {
            "images": _sds((b, hw, hw, 3), jnp.float32),
            "obj": _sds((b, hc, hc), jnp.float32),
            "cls": _sds((b, hc, hc), jnp.int32),
            "box": _sds((b, hc, hc, 4), jnp.float32),
        }
        return {"batch": batch}

    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s) if cfg.codebooks == 1 else (b, s, cfg.codebooks)
    if shape.kind == "train":
        batch = {"tokens": _sds(tok_shape, jnp.int32),
                 "targets": _sds(tok_shape, jnp.int32)}
        if cfg.frontend_embeds:
            batch["frontend"] = _sds((b, 256, cfg.d_model), cfg.dtype)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": _sds(tok_shape, jnp.int32)}
        if cfg.frontend_embeds:
            out["frontend"] = _sds((b, 256, cfg.d_model), cfg.dtype)
        return out
    if shape.kind == "decode":
        tok = (b,) if cfg.codebooks == 1 else (b, cfg.codebooks)
        return {
            "tokens": _sds(tok, jnp.int32),
            "pos": _sds((b,), jnp.int32),
            "caches": abstract_cache(cfg, b, s),
        }
    raise ValueError(shape.kind)


def input_shardings(arch: ArchSpec, shape_name: str, mesh) -> dict[str, Any]:
    """PartitionSpec tree matching ``input_specs`` (under active rules)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import logical_spec
    shape = arch.shapes[shape_name]
    cfg = arch.config

    def bs(shp, axes):
        return logical_spec(shp, axes, mesh=mesh)

    if isinstance(cfg, ResNetDCNConfig):
        b, hw = shape.global_batch, cfg.img_size
        hc = hw // 32
        return {"batch": {
            # images spatially partitioned on H over the model axis;
            # GSPMD inserts the conv halo exchanges (the TPU analogue of
            # the paper's Eq. 6 row-band + halo dataflow, at mesh scale).
            "images": bs((b, hw, hw, 3), ("batch", "spatial", None, None)),
            "obj": bs((b, hc, hc), ("batch", "spatial", None)),
            "cls": bs((b, hc, hc), ("batch", "spatial", None)),
            "box": bs((b, hc, hc, 4), ("batch", "spatial", None, None)),
        }}

    b, s = shape.global_batch, shape.seq_len
    tok_axes = ("batch", "seq") if cfg.codebooks == 1 \
        else ("batch", "seq", None)
    tok_shape = (b, s) if cfg.codebooks == 1 else (b, s, cfg.codebooks)
    if shape.kind == "train":
        out = {"batch": {"tokens": bs(tok_shape, tok_axes),
                         "targets": bs(tok_shape, tok_axes)}}
        if cfg.frontend_embeds:
            out["batch"]["frontend"] = bs((b, 256, cfg.d_model),
                                          ("batch", None, None))
        return out
    if shape.kind == "prefill":
        out = {"tokens": bs(tok_shape, tok_axes)}
        if cfg.frontend_embeds:
            out["frontend"] = bs((b, 256, cfg.d_model),
                                 ("batch", None, None))
        return out
    if shape.kind == "decode":
        tok = (b,) if cfg.codebooks == 1 else (b, cfg.codebooks)
        tok_ax = ("batch",) if cfg.codebooks == 1 else ("batch", None)
        return {
            "tokens": bs(tok, tok_ax),
            "pos": bs((b,), ("batch",)),
            "caches": cache_specs(cfg, b, s),
        }
    raise ValueError(shape.kind)
