"""Training launcher: --arch <id> on the current host's mesh.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b \
        --steps 50 [--reduced/--full] [--ckpt DIR] [--microbatches N] \
        [--grad-compression int8_ef]

Default is the REDUCED same-family config (this container is CPU-only;
the full configs need the production cluster — their step functions are
exactly what ``repro.launch.dryrun`` lowers for the 256/512-chip
meshes).  The loop is the production Trainer: sharded params, gradient
accumulation, async atomic checkpoints, auto-resume, restore-and-replay
on failure.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.data import (DetectionDataConfig, LMDataConfig, detection_batch,
                        lm_batch)
from repro.distributed.sharding import use_rules
from repro.launch.mesh import make_host_mesh
from repro.models import registry as reg
from repro.models.registry import reduced_config
from repro.models.resnet_dcn import ResNetDCNConfig
from repro.optim import default_optimizer_for, warmup_cosine
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=reg.names())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", choices=["int8_ef"], default=None)
    ap.add_argument("--lam", type=float, default=0.0,
                    help="Eq. 5 lambda (DCN archs)")
    ap.add_argument("--full", action="store_true",
                    help="use the FULL config (needs a real cluster)")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    arch = reg.get(args.arch)
    cfg = arch.config if args.full else reduced_config(arch)
    mesh = make_host_mesh()
    print(f"arch={args.arch} ({'full' if args.full else 'reduced'}), "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    if isinstance(cfg, ResNetDCNConfig):
        from repro.models import resnet_dcn as R
        dcfg = DetectionDataConfig(img_size=cfg.img_size,
                                   global_batch=args.global_batch,
                                   num_classes=cfg.num_classes)
        lam = args.lam or (0.005 if cfg.offset_bound else 0.0)
        loss = lambda p, b: R.train_loss(p, cfg, b, lam=lam)  # noqa: E731
        batch_fn = lambda s: detection_batch(dcfg, s)          # noqa: E731
        with use_rules(mesh=mesh):
            from repro.models.layers import spec_tree
            specs = spec_tree(R.model_def(cfg))
            params = R.init_params(jax.random.PRNGKey(0), cfg)
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    else:
        from repro.models.transformer import (init_params, loss_fn,
                                              param_specs)
        dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                            global_batch=args.global_batch,
                            codebooks=cfg.codebooks)
        loss = lambda p, b: loss_fn(p, cfg, b)                 # noqa: E731
        batch_fn = lambda s: lm_batch(dcfg, s)                 # noqa: E731
        with use_rules(mesh=mesh):
            specs = param_specs(cfg)
            params = init_params(jax.random.PRNGKey(0), cfg)
        n_params = cfg.param_count()

    opt = default_optimizer_for(
        args.arch, n_params, warmup_cosine(3e-3, 10, args.steps))
    trainer = Trainer(
        loss_fn=loss, params=params, optimizer=opt, mesh=mesh,
        param_specs=specs, batch_fn=batch_fn,
        config=TrainerConfig(total_steps=args.steps,
                             ckpt_every=args.ckpt_every,
                             ckpt_dir=args.ckpt, log_every=10,
                             microbatches=args.microbatches,
                             grad_compression=args.grad_compression))
    if trainer.try_resume():
        print(f"resumed from step {trainer.step}")
    history = trainer.run()
    for h in history:
        print(h)


if __name__ == "__main__":
    main()
