"""Serving launcher: continuous batching for --arch <id>.

LM archs run the token slot engine:

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 12 [--slots 4] [--cache-len 128] [--ckpt DIR]

DCL detection archs run the shape-bucketed engine (PR 7) — calibrated
int8_chain by default, with deadlines / admission control / the
per-request degradation ladder live:

    PYTHONPATH=src python -m repro.launch.serve --arch resnet50_dcn \
        --requests 12 [--buckets 64,128] [--quant int8_chain] \
        [--deadline 30] [--shed-policy reject_new] [--telemetry OUT.json]

Reduced config by default (CPU container); optionally restores params
from a checkpoint produced by ``repro.launch.train``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.models import registry as reg
from repro.models.registry import reduced_config
from repro.models.resnet_dcn import ResNetDCNConfig
from repro.serve import (DCLServeConfig, DCLServingEngine, LADDER,
                         Request, ServeConfig, ServingEngine)


def serve_detection(cfg: ResNetDCNConfig, args) -> None:
    from repro.models import resnet_dcn as R
    from repro.quant.calibrate import calibrate_resnet_dcn

    if cfg.offset_bound is None:
        cfg = dataclasses.replace(cfg, offset_bound=2.0)
    cfg = dataclasses.replace(cfg, use_kernel=True)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint import restore_checkpoint
        restored, step = restore_checkpoint(args.ckpt, {"params": params})
        params = restored["params"]
        print(f"restored params from step {step}")

    rng = np.random.RandomState(0)
    table = None
    if args.quant in ("int8_chain", "int8"):
        t0 = time.monotonic()
        table = calibrate_resnet_dcn(
            params, cfg,
            [rng.randn(2, b, b, 3).astype(np.float32) for b in buckets])
        print(f"calibrated scale table in {time.monotonic() - t0:.1f}s "
              f"({sorted(k for k in table if k != '_meta')})")

    engine = DCLServingEngine(
        params, cfg,
        DCLServeConfig(buckets=buckets, slots=args.slots,
                       quant=args.quant,
                       queue_capacity=args.queue_capacity,
                       shed_policy=args.shed_policy,
                       default_deadline=args.deadline),
        scale_table=table)
    for uid in range(args.requests):
        b = buckets[uid % len(buckets)]
        engine.submit(rng.randn(b, b, 3).astype(np.float32))

    t0 = time.monotonic()
    engine.run_until_drained()
    dt = time.monotonic() - t0
    ok = [r for r in engine.completed if r.outcome == "ok"]
    lats = sorted(r.latency_s() for r in ok)
    print(f"served {len(ok)}/{len(engine.completed)} requests in "
          f"{engine.steps} batched steps ({dt:.1f}s, "
          f"{len(ok) / max(dt, 1e-9):.2f} req/s on CPU interpret)")
    if lats:
        print(f"  p50 latency {lats[len(lats) // 2] * 1e3:.0f} ms, "
              f"max {lats[-1] * 1e3:.0f} ms")
    print(f"  counters: {engine.counters}")
    if args.telemetry:
        from repro.resilience import dump_telemetry
        dump_telemetry(args.telemetry, engine.telemetry())
        print(f"  telemetry -> {args.telemetry}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=reg.names())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    # DCL detection engine knobs
    ap.add_argument("--buckets", default="64",
                    help="comma-separated square shape buckets")
    ap.add_argument("--quant", default="int8_chain", choices=LADDER)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds")
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--shed-policy", default="reject_new",
                    choices=("reject_new", "shed_oldest"))
    ap.add_argument("--telemetry", default=None,
                    help="write engine telemetry JSON here")
    args = ap.parse_args()

    arch = reg.get(args.arch)
    cfg = reduced_config(arch)
    if isinstance(cfg, ResNetDCNConfig):
        serve_detection(cfg, args)
        return
    if cfg.codebooks > 1:
        raise SystemExit("the slot engine tracks one token per slot; "
                         "multi-codebook decoding (musicgen) needs a "
                         "(slots, codebooks) token state — not wired yet")

    from repro.models.transformer import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint import restore_checkpoint
        bundle = {"params": params}
        restored, step = restore_checkpoint(args.ckpt, bundle)
        params = restored["params"]
        print(f"restored params from step {step}")

    engine = ServingEngine(params, cfg,
                           ServeConfig(slots=args.slots,
                                       cache_len=args.cache_len))
    rng = np.random.RandomState(0)
    for uid in range(args.requests):
        prompt = rng.randint(0, cfg.vocab,
                             rng.randint(4, 12)).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.max_new_tokens))

    t0 = time.monotonic()
    steps = 0
    while engine.queue or any(r is not None for r in engine.active):
        engine.step()
        steps += 1
    dt = time.monotonic() - t0
    toks = sum(len(r.output) for r in engine.completed)
    print(f"served {len(engine.completed)} requests / {toks} tokens in "
          f"{steps} batched steps ({dt:.1f}s, {toks / dt:.1f} tok/s "
          f"on CPU interpret)")
    for r in engine.completed[:3]:
        print(f"  req {r.uid}: {r.output[:8]}...")


if __name__ == "__main__":
    main()
