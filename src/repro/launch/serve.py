"""Serving launcher: continuous batching for --arch <id>.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 12 [--slots 4] [--cache-len 128] [--ckpt DIR]

Reduced config by default (CPU container); optionally restores params
from a checkpoint produced by ``repro.launch.train``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import registry as reg
from repro.models.registry import reduced_config
from repro.models.resnet_dcn import ResNetDCNConfig
from repro.serve import Request, ServeConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=reg.names())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    arch = reg.get(args.arch)
    cfg = reduced_config(arch)
    if isinstance(cfg, ResNetDCNConfig):
        raise SystemExit("CNN archs are batch-inference only; "
                         "use repro.launch.dryrun --shape infer_det")
    if cfg.codebooks > 1:
        raise SystemExit("the slot engine tracks one token per slot; "
                         "multi-codebook decoding (musicgen) needs a "
                         "(slots, codebooks) token state — not wired yet")

    from repro.models.transformer import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint import restore_checkpoint
        bundle = {"params": params}
        restored, step = restore_checkpoint(args.ckpt, bundle)
        params = restored["params"]
        print(f"restored params from step {step}")

    engine = ServingEngine(params, cfg,
                           ServeConfig(slots=args.slots,
                                       cache_len=args.cache_len))
    rng = np.random.RandomState(0)
    for uid in range(args.requests):
        prompt = rng.randint(0, cfg.vocab,
                             rng.randint(4, 12)).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.max_new_tokens))

    t0 = time.time()
    steps = 0
    while engine.queue or any(r is not None for r in engine.active):
        engine.step()
        steps += 1
    dt = time.time() - t0
    toks = sum(len(r.output) for r in engine.completed)
    print(f"served {len(engine.completed)} requests / {toks} tokens in "
          f"{steps} batched steps ({dt:.1f}s, {toks / dt:.1f} tok/s "
          f"on CPU interpret)")
    for r in engine.completed[:3]:
        print(f"  req {r.uid}: {r.output[:8]}...")


if __name__ == "__main__":
    main()
