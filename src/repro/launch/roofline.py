"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

For every (arch x shape x mesh) JSON produced by ``launch.dryrun``:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(The per-device HLO numbers already divide by the chip count — the
formulas in the assignment divide global totals by chips; both give the
same per-chip seconds.)  Also reports MODEL_FLOPS = 6*N(active)*D (train)
/ 2*N*D (prefill) / 2*N*B (decode) and the MODEL/HLO ratio — the
"useful compute" fraction that catches remat/dispatch waste.

Notes recorded with the table:
* HLO FLOPs/bytes come from the While-corrected totals (see dryrun.py
  extrapolation) of the post-SPMD per-device module;
* 'bytes accessed' is XLA's pre-fusion operand+result traffic — an
  UPPER bound on HBM bytes (TPU fusion removes much of it), so the
  memory term is pessimistic; the compute and collective terms are the
  decision-grade numbers.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any

from repro.core.tiling import (V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS_BF16)
from repro.models import registry as reg
from repro.models.resnet_dcn import ResNetDCNConfig

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] \
    / "benchmarks" / "results" / "dryrun"


def _model_flops_per_device(arch, shape_name: str, chips: int) -> float:
    cfg = arch.config
    shape = arch.shapes[shape_name]
    if isinstance(cfg, ResNetDCNConfig):
        # conv backbone: analytic MACs of the reduced-stride graph
        from repro.launch.steps import arch_param_count
        n = arch_param_count(arch)
        # rough dense-equivalent: 2 * MACs ~ 2 * params * (H/32 * W/32)
        cells = (cfg.img_size // 32) ** 2
        mult = 6 if shape.kind == "train_det" else 2
        return mult * n * cells * shape.global_batch / chips
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6 * n_active * toks / chips
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2 * n_active * toks / chips
    if shape.kind == "decode":
        return 2 * n_active * shape.global_batch / chips
    raise ValueError(shape.kind)


def analyze_cell(rec: dict) -> dict | None:
    if "error" in rec:
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "error": rec["error"]}
    arch = reg.get(rec["arch"])
    chips = 1
    for d in rec["mesh_shape"]:
        chips *= d
    cost = rec.get("cost_total", {})
    flops = cost.get("flops", float("nan"))
    # NOTE: 'bytes accessed' is the TOTAL; 'bytes accessedN{}' keys are
    # its per-operand breakdown — summing them double-counts.
    hbm_bytes = cost.get("bytes accessed", float("nan"))
    coll_bytes = rec.get("collective_bytes_total", 0)

    compute_s = flops / V5E_PEAK_FLOPS_BF16
    memory_s = hbm_bytes / V5E_HBM_BW
    collective_s = coll_bytes / V5E_ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=lambda k: terms[k])
    model_flops = _model_flops_per_device(arch, rec["shape"], chips)
    step_s = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll_bytes,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_per_device": model_flops,
        "model_over_hlo": model_flops / flops if flops else float("nan"),
        # roofline fraction: useful-FLOPs time at peak over the step's
        # bounding term — the score the perf loop drives up.
        "roofline_fraction": (model_flops / V5E_PEAK_FLOPS_BF16) / step_s
        if step_s > 0 else float("nan"),
        "memory_analysis": rec.get("memory_analysis", {}),
    }


def load_all(mesh: str | None = "single",
             results_dir: pathlib.Path | str | None = None) -> list[dict]:
    out = []
    for p in sorted(pathlib.Path(results_dir or RESULTS_DIR).glob("*.json")):
        rec = json.loads(p.read_text())
        if mesh is not None and rec.get("mesh") != mesh:
            continue
        a = analyze_cell(rec)
        if a is not None:
            out.append(a)
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR: {r['error'][:40]} | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['model_over_hlo']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out")
    ap.add_argument("--dir", default=None,
                    help="dry-run results dir (default: latest)")
    args = ap.parse_args()
    rows = load_all(None if args.mesh == "all" else args.mesh, args.dir)
    md = markdown_table(rows)
    print(md)
    if args.out:
        pathlib.Path(args.out).write_text(md + "\n")
    (RESULTS_DIR.parent / "roofline.json").write_text(
        json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
