"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
smoke tests must see 1 CPU device while the dry-run sees 512 host
devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever the current host offers, flattened to (data, model) with
    model=1 — used by CPU examples and tests."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
