"""Render the ISSUE-8 observability artifacts for humans / CI logs.

Summarizes any of the three artifact families:

* ``--trace trace.jsonl`` — a :meth:`repro.obs.Tracer.export_jsonl`
  dump: top span names by total time (count / total / mean / max) and
  the instant-event counts (fault firings show up here);
* ``--metrics file.json`` — a :meth:`MetricsRegistry.snapshot`, either
  bare or embedded under a ``"metrics"`` key of a
  :func:`~repro.obs.dump_telemetry` file: counters, gauges, and the
  per-label histogram latency table (count / p50 / p99 / mean);
* ``--divergence file.json`` — a divergence report
  (:meth:`DivergenceTracker.report`), bare or under a ``"divergence"``
  key (``BENCH_kernels.json``, serve telemetry): per-dispatch-key
  aggregates and the modeled-vs-measured ratio pairs, anomalies
  flagged.

Pure-stdlib and side-effect free until ``main()`` prints — the
``summarize_*`` functions return row lists so tests assert on content.
"""
from __future__ import annotations

import argparse
import json
import pathlib

__all__ = ["load_metrics", "load_divergence", "load_trace", "main",
           "summarize_divergence", "summarize_metrics", "summarize_trace"]


# -- loaders (tolerant of the wrapped artifact shapes) ------------------

def load_trace(path) -> list[dict]:
    records = []
    for line in pathlib.Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            records.append(json.loads(line))
    return records


def load_metrics(path) -> dict:
    """A registry snapshot — bare, or under ``"metrics"`` of a
    telemetry dump."""
    doc = json.loads(pathlib.Path(path).read_text())
    # telemetry dumps can carry legacy top-level "counters" views (the
    # serve engine's {outcome: n} dict), so the embedded snapshot wins
    if isinstance(doc.get("metrics"), dict):
        return doc["metrics"]
    if "histograms" in doc or "counters" in doc:
        return doc
    raise ValueError(f"{path} holds neither a metrics snapshot nor a "
                     f"telemetry dump with a 'metrics' key")


def load_divergence(path) -> dict:
    """A divergence report — bare, or under ``"divergence"`` of
    ``BENCH_kernels.json`` / a serve telemetry dump."""
    doc = json.loads(pathlib.Path(path).read_text())
    if "dispatches" in doc or "pairs" in doc:
        return doc
    if isinstance(doc.get("divergence"), dict):
        return doc["divergence"]
    raise ValueError(f"{path} holds neither a divergence report nor a "
                     f"document with a 'divergence' key")


# -- summaries ----------------------------------------------------------

def summarize_trace(records: list[dict], *, top: int = 10) -> list[str]:
    """Top span names by total duration + event counts."""
    spans: dict[str, dict] = {}
    events: dict[str, int] = {}
    for r in records:
        if r.get("type") == "span" and r.get("dur_s") is not None:
            s = spans.setdefault(r["name"],
                                 {"n": 0, "total": 0.0, "max": 0.0})
            s["n"] += 1
            s["total"] += r["dur_s"]
            s["max"] = max(s["max"], r["dur_s"])
        elif r.get("type") == "event":
            events[r["name"]] = events.get(r["name"], 0) + 1
    rows = [f"{'span':<28}{'n':>6}{'total_s':>10}{'mean_ms':>10}"
            f"{'max_ms':>10}"]
    ranked = sorted(spans.items(), key=lambda kv: -kv[1]["total"])[:top]
    for name, s in ranked:
        rows.append(f"{name:<28}{s['n']:>6}{s['total']:>10.3f}"
                    f"{s['total'] / s['n'] * 1e3:>10.2f}"
                    f"{s['max'] * 1e3:>10.2f}")
    if events:
        rows.append("")
        rows.append(f"{'event':<28}{'n':>6}")
        for name in sorted(events):
            rows.append(f"{name:<28}{events[name]:>6}")
    return rows


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return "-"
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


def summarize_metrics(snapshot: dict) -> list[str]:
    """Counters/gauges + the per-label histogram latency table."""
    rows: list[str] = []
    for section in ("counters", "gauges"):
        for name, m in sorted(snapshot.get(section, {}).items()):
            for v in m.get("values", []):
                rows.append(f"{name}{{{_fmt_labels(v['labels'])}}} = "
                            f"{v['value']:g}")
    hists = snapshot.get("histograms", {})
    if hists:
        rows.append("")
        rows.append(f"{'histogram':<30}{'labels':<34}{'n':>6}"
                    f"{'p50_ms':>9}{'p99_ms':>9}{'mean_ms':>9}")
        for name, m in sorted(hists.items()):
            for v in m.get("values", []):
                n = v["count"]
                mean = v["sum"] / n if n else float("nan")
                rows.append(
                    f"{name:<30}{_fmt_labels(v['labels']):<34}{n:>6}"
                    f"{v['p50'] * 1e3:>9.2f}{v['p99'] * 1e3:>9.2f}"
                    f"{mean * 1e3:>9.2f}")
    return rows


def summarize_divergence(report: dict) -> list[str]:
    """Per-dispatch-key aggregate table + the named ratio pairs."""
    rows: list[str] = []
    disp = report.get("dispatches", [])
    if disp:
        rows.append(f"{'dispatch key':<44}{'n':>5}{'best_ms':>9}"
                    f"{'modeled_MB':>12}{'implied_GBps':>13}")
        for d in disp:
            mb = d.get("modeled_bytes")
            gbps = d.get("implied_gbps")
            rows.append(
                f"{d['key']:<44}{d['n']:>5}{d['best_s'] * 1e3:>9.2f}"
                f"{(mb / 1e6 if mb else float('nan')):>12.2f}"
                f"{(gbps if gbps is not None else float('nan')):>13.2f}")
    pairs = report.get("pairs", [])
    if pairs:
        if disp:
            rows.append("")
        rows.append(f"{'pair':<44}{'modeled':>9}{'measured':>10}"
                    f"{'diverge':>9}  flag")
        for p in pairs:
            rows.append(
                f"{p['name']:<44}{p['modeled_ratio']:>8.2f}x"
                f"{p['measured_ratio']:>9.2f}x{p['divergence']:>8.2f}x"
                f"  {'ANOMALOUS' if p.get('anomalous') else 'ok'}")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize repro.obs trace/metrics/divergence "
                    "artifacts (CI)")
    ap.add_argument("--trace", help="span/event JSONL "
                    "(Tracer.export_jsonl)")
    ap.add_argument("--metrics", help="metrics snapshot JSON, bare or "
                    "a dump_telemetry file with a 'metrics' key")
    ap.add_argument("--divergence", help="divergence report JSON, bare "
                    "or a document with a 'divergence' key")
    ap.add_argument("--top", type=int, default=10,
                    help="span names to show (default 10)")
    args = ap.parse_args(argv)
    if not (args.trace or args.metrics or args.divergence):
        ap.error("pass at least one of --trace/--metrics/--divergence")

    def emit(title: str, rows: list[str]) -> None:
        print(f"== {title} ==")
        for row in rows or ["(empty)"]:
            print(row)
        print()

    if args.trace:
        emit(f"trace {args.trace}",
             summarize_trace(load_trace(args.trace), top=args.top))
    if args.metrics:
        emit(f"metrics {args.metrics}",
             summarize_metrics(load_metrics(args.metrics)))
    if args.divergence:
        emit(f"divergence {args.divergence}",
             summarize_divergence(load_divergence(args.divergence)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
