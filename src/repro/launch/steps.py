"""Step-function builders shared by the dry-run, trainer, and server.

Each builder returns (step_fn, in_sharding_tree, out_sharding_tree,
abstract_inputs) for one (arch, shape) cell — everything ``jax.jit``
needs to lower without allocating a single parameter.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import use_rules
from repro.models import registry as reg
from repro.models import resnet_dcn
from repro.models.registry import ArchSpec
from repro.models.transformer import (ModelConfig, abstract_params,
                                      decode_step, loss_fn, param_specs,
                                      prefill)
from repro.models.resnet_dcn import ResNetDCNConfig
from repro.optim import (abstract_opt_state, default_optimizer_for,
                         opt_state_specs)
from repro.models import layers as NL

Array = jax.Array


def _named(mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _cnn_abstract_params(cfg: ResNetDCNConfig):
    defs = resnet_dcn.model_def(cfg)
    return NL.abstract_tree(defs)


def _cnn_param_specs(cfg: ResNetDCNConfig):
    defs = resnet_dcn.model_def(cfg)
    return NL.spec_tree(defs)


def arch_abstract_params(arch: ArchSpec):
    cfg = arch.config
    if isinstance(cfg, ResNetDCNConfig):
        return _cnn_abstract_params(cfg)
    return abstract_params(cfg)


def arch_param_specs(arch: ArchSpec):
    cfg = arch.config
    if isinstance(cfg, ResNetDCNConfig):
        return _cnn_param_specs(cfg)
    return param_specs(cfg)


def arch_param_count(arch: ArchSpec) -> int:
    import math
    cfg = arch.config
    if isinstance(cfg, ResNetDCNConfig):
        leaves = jax.tree_util.tree_leaves(_cnn_abstract_params(cfg))
        return sum(math.prod(x.shape) for x in leaves)
    return cfg.param_count()


def _merged_rules(arch: ArchSpec, rules=None):
    """Explicit rules (experiments) > per-arch overrides > defaults."""
    from repro.distributed.sharding import DEFAULT_RULES
    if rules is not None:
        return rules
    if arch.rules_overrides:
        return {**DEFAULT_RULES, **arch.rules_overrides}
    return None


def make_train_step(arch: ArchSpec, mesh, *, optimizer=None, rules=None):
    """Full production train step: fwd + bwd + optimizer update."""
    cfg = arch.config
    rules = _merged_rules(arch, rules)
    with use_rules(rules=rules, mesh=mesh):
        p_abs = arch_abstract_params(arch)
        p_specs = arch_param_specs(arch)
        opt = optimizer or default_optimizer_for(
            arch.name, arch_param_count(arch))
        o_abs = abstract_opt_state(opt, p_abs)
        o_specs = opt_state_specs(opt, p_specs)
        in_specs = reg.input_specs(arch, _train_shape_name(arch))
        in_shard = reg.input_shardings(arch, _train_shape_name(arch), mesh)

    if isinstance(cfg, ResNetDCNConfig):
        lam = 0.005 if cfg.offset_bound is not None else 0.0

        def lf(params, batch):
            return resnet_dcn.train_loss(params, cfg, batch, lam=lam)
    else:
        def lf(params, batch):
            return loss_fn(params, cfg, batch)

    # Gradient accumulation: big models cannot hold a full global-batch
    # activation set even remat'ed (grok-1: ~26 GB/device of layer-
    # boundary checkpoints at batch 256 x 4k).  Microbatching bounds the
    # live activations; the per-microbatch batch dim stays mesh-sharded.
    n_params = arch_param_count(arch)
    micro = 8 if n_params >= 90e9 else (4 if n_params >= 20e9 else 1)

    def train_step(params, opt_state, step, batch):
        if micro > 1:
            def one(carry, mb):
                from repro.distributed.sharding import logical_constraint
                mb = {kk: logical_constraint(
                    vv, "batch", *([None] * (vv.ndim - 1)))
                    for kk, vv in mb.items()}
                acc, loss_acc = carry
                (loss, _), g = jax.value_and_grad(
                    lf, has_aux=True)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), acc, g)
                return (acc, loss_acc + loss), None

            mbs = jax.tree_util.tree_map(
                lambda t: t.reshape((micro, t.shape[0] // micro)
                                    + t.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(
                one, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree_util.tree_map(lambda g_: g_ / micro, gsum)
            loss = loss_sum / micro
        else:
            (loss, _), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, step + 1, loss

    rep = P()
    in_shardings = (p_specs, o_specs, rep, in_shard["batch"])
    out_shardings = (p_specs, o_specs, rep, rep)
    abstract_in = (p_abs, o_abs, jax.ShapeDtypeStruct((), jnp.int32),
                   in_specs["batch"])
    return (train_step, _named(mesh, in_shardings),
            _named(mesh, out_shardings), abstract_in)


def _train_shape_name(arch: ArchSpec) -> str:
    for name, s in arch.shapes.items():
        if s.kind in ("train", "train_det"):
            return name
    raise ValueError(f"{arch.name} has no train shape")


def _serve_rules(arch: ArchSpec):
    from repro.distributed.sharding import serve_rules_for
    rules = serve_rules_for(arch_param_count(arch))
    if arch.rules_overrides:
        rules = {**rules, **arch.rules_overrides}
    return rules


def make_prefill_step(arch: ArchSpec, shape_name: str, mesh):
    cfg = arch.config
    shape = arch.shapes[shape_name]
    with use_rules(rules=_serve_rules(arch), mesh=mesh):
        p_abs = arch_abstract_params(arch)
        p_specs = arch_param_specs(arch)
        in_specs = reg.input_specs(arch, shape_name)
        in_shard = reg.input_shardings(arch, shape_name, mesh)
        from repro.models.transformer import cache_specs
        c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len)

    def prefill_step(params, tokens, frontend=None):
        logits, caches = prefill(params, cfg, tokens,
                                 cache_len=shape.seq_len, frontend=frontend)
        if cfg.codebooks > 1:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    tok_spec = in_shard["tokens"]
    rep = P()
    args = [p_specs, tok_spec]
    abstract = [p_abs, in_specs["tokens"]]
    if cfg.frontend_embeds:
        args.append(in_shard["frontend"])
        abstract.append(in_specs["frontend"])
    out_shardings = (rep, c_specs)
    return (prefill_step, _named(mesh, tuple(args)),
            _named(mesh, out_shardings), tuple(abstract))


def make_decode_step(arch: ArchSpec, shape_name: str, mesh):
    cfg = arch.config
    shape = arch.shapes[shape_name]
    with use_rules(rules=_serve_rules(arch), mesh=mesh):
        p_abs = arch_abstract_params(arch)
        p_specs = arch_param_specs(arch)
        in_specs = reg.input_specs(arch, shape_name)
        in_shard = reg.input_shardings(arch, shape_name, mesh)

    def serve_step(params, caches, tokens, pos):
        logits, new_caches = decode_step(params, cfg, tokens, caches, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    rep = P()
    in_shardings = (p_specs, in_shard["caches"], in_shard["tokens"],
                    in_shard["pos"])
    out_shardings = (rep, in_shard["caches"])
    abstract = (p_abs, in_specs["caches"], in_specs["tokens"],
                in_specs["pos"])
    return (serve_step, _named(mesh, in_shardings),
            _named(mesh, out_shardings), abstract)


def make_infer_step(arch: ArchSpec, shape_name: str, mesh):
    """CNN batch inference (resnet50_dcn infer_det)."""
    cfg = arch.config
    assert isinstance(cfg, ResNetDCNConfig)
    shape = arch.shapes[shape_name]
    with use_rules(mesh=mesh):
        p_abs = arch_abstract_params(arch)
        p_specs = arch_param_specs(arch)
        from repro.distributed.sharding import logical_spec
        b, hw = shape.global_batch, cfg.img_size
        img_abs = jax.ShapeDtypeStruct((b, hw, hw, 3), jnp.float32)
        img_spec = logical_spec((b, hw, hw, 3),
                                ("batch", None, None, None), mesh=mesh)

    def infer_step(params, images):
        outputs, o_maxes = resnet_dcn.forward(params, cfg, images)
        return outputs["cls"], outputs["box"]

    rep = P()
    return (infer_step, _named(mesh, (p_specs, img_spec)),
            _named(mesh, (rep, rep)), (p_abs, img_abs))


def make_cell_step(arch: ArchSpec, shape_name: str, mesh, rules=None):
    """Dispatch to the right builder for a (arch, shape) dry-run cell."""
    kind = arch.shapes[shape_name].kind
    if kind in ("train", "train_det"):
        return make_train_step(arch, mesh, rules=rules)
    if kind == "prefill":
        return make_prefill_step(arch, shape_name, mesh)
    if kind == "decode":
        return make_decode_step(arch, shape_name, mesh)
    if kind == "infer_det":
        return make_infer_step(arch, shape_name, mesh)
    raise ValueError(kind)
