import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST stay first (before any jax import) —
# jax locks the device count at first init.  That is also why this file
# has no ``from __future__ import annotations``.

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.  Do
NOT import this module from tests (they must see 1 device).

For every cell this script:
  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod),
  2. builds the cell's step function + shardings from ``launch.steps``,
  3. ``jax.jit(...).lower(*abstract).compile()`` — proving the sharding
     config is coherent end to end,
  4. records memory_analysis / cost_analysis / per-collective bytes
     (parsed from the post-SPMD HLO) to a JSON file for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch grok-1-314b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from repro.distributed.sharding import use_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_cell_step
from repro.models import registry as reg

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] \
    / "benchmarks" / "results" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _bytes_of(fragment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(fragment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the per-device HLO.
    Async pairs are counted once (on -start); '-done' is skipped."""
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            m = re.search(rf"= (.*?)\s*{c}(-start)?\(", line)
            if m is None:
                continue
            if f"{c}-done" in line:
                continue
            out[c]["count"] += 1
            out[c]["bytes"] += _bytes_of(m.group(1))
            break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _compile_cell(arch, shape_name: str, mesh, rules=None) -> tuple:
    with use_rules(rules=rules, mesh=mesh):
        step, in_sh, out_sh, abstract = make_cell_step(arch, shape_name, mesh,
                                                       rules=rules)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*abstract)
        compiled = lowered.compile()
    return lowered, compiled


def _analyses(compiled) -> tuple[dict, dict]:
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals")
                    or k.startswith("bytes accessed"))}
    except Exception as e:  # noqa: BLE001
        cost = {"error": str(e)}
    coll = parse_collectives(compiled.as_text())
    return cost, coll


def _unrolled_variant(arch, n_periods: int):
    """Arch clone with an unrolled `n_periods`-period layer stack (no
    While loop), used to measure exact per-period HLO flops/bytes."""
    import dataclasses
    cfg = arch.config
    plen = len(cfg.pattern)
    cfg2 = dataclasses.replace(cfg, n_layers=n_periods * plen,
                               scan_layers=False, name=f"{cfg.name}@p{n_periods}")
    return dataclasses.replace(arch, config=cfg2)


def _extrapolate(full: dict, p1: dict, p2: dict, n_periods: int) -> dict:
    """total = full_reported + (n_periods - 1) * (p2 - p1).

    The While body is counted once in `full`; (p2 - p1) on the unrolled
    variants isolates exactly one period (including remat recompute).
    """
    out = {}
    keys = set(p1) & set(p2) & set(full)
    for k in keys:
        if not isinstance(full.get(k), (int, float)):
            continue
        delta = p2[k] - p1[k]
        out[k] = full[k] + max(delta, 0.0) * (n_periods - 1)
    return out


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             *, keep_hlo: bool = False, rules: dict | None = None) -> dict:
    arch = reg.get(arch_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
                 "mesh_shape": list(mesh.devices.shape),
                 "axes": list(mesh.axis_names)}
    # Effective rules (explicit experiment > per-arch overrides > default)
    # must ALSO govern the trace-time context: the in-model
    # logical_constraint calls read the rules active during lower().
    from repro.distributed.sharding import DEFAULT_RULES
    from repro.launch.steps import _merged_rules
    rules = _merged_rules(arch, rules)
    if rules is not None:
        rec["rule_overrides"] = {k: v for k, v in rules.items()
                                 if DEFAULT_RULES.get(k) != v}
    t0 = time.time()
    with use_rules(rules=rules, mesh=mesh):
        step, in_sh, out_sh, abstract = make_cell_step(arch, shape_name, mesh,
                                                       rules=rules)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*abstract)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # noqa: BLE001 - backend-dependent
        rec["memory_analysis"] = {"error": str(e)}

    cost, coll = _analyses(compiled)
    rec["cost_analysis"] = cost
    rec["collectives"] = coll
    hlo = compiled.as_text()
    rec["hlo_bytes"] = len(hlo)
    if keep_hlo:
        rec["hlo_path"] = str(RESULTS_DIR / f"{arch_name}__{shape_name}__{mesh_kind}.hlo")
        pathlib.Path(rec["hlo_path"]).write_text(hlo)

    # --- While-loop FLOP accounting -------------------------------------
    # XLA cost analysis counts the scan body ONCE; reconstruct the true
    # totals from two small UNROLLED compiles (1 and 2 periods).
    cfg = arch.config
    if hasattr(cfg, "scan_layers") and cfg.n_periods > 1 \
            and "error" not in cost:
        try:
            t2 = time.time()
            _, c1 = _compile_cell(_unrolled_variant(arch, 1), shape_name,
                                  mesh, rules)
            _, c2 = _compile_cell(_unrolled_variant(arch, 2), shape_name,
                                  mesh, rules)
            cost1, coll1 = _analyses(c1)
            cost2, coll2 = _analyses(c2)
            rec["extrapolation"] = {
                "method": "full + (n_periods-1) * (p2 - p1), unrolled",
                "n_periods": cfg.n_periods,
                "p1_cost": cost1, "p2_cost": cost2,
                "p1_coll": coll1["total_bytes"],
                "p2_coll": coll2["total_bytes"],
                "extra_compile_s": round(time.time() - t2, 2),
            }
            rec["cost_total"] = _extrapolate(cost, cost1, cost2,
                                             cfg.n_periods)
            rec["collective_bytes_total"] = (
                coll["total_bytes"]
                + max(coll2["total_bytes"] - coll1["total_bytes"], 0)
                * (cfg.n_periods - 1))
        except Exception as e:  # noqa: BLE001
            rec["extrapolation"] = {"error": str(e),
                                    "traceback": traceback.format_exc()}
            rec["cost_total"] = dict(cost)
            rec["collective_bytes_total"] = coll["total_bytes"]
    else:
        rec["cost_total"] = dict(cost)
        rec["collective_bytes_total"] = coll["total_bytes"]
    return rec


def save(rec: dict) -> pathlib.Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    p = RESULTS_DIR / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    p.write_text(json.dumps(rec, indent=1))
    return p


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set-rule", action="append", default=[],
                    help="override a logical->mesh rule, e.g. experts=model")
    ap.add_argument("--tag", default="",
                    help="suffix for result filenames (rule experiments)")
    args = ap.parse_args()

    rules = None
    if args.set_rule:
        from repro.distributed.sharding import DEFAULT_RULES
        rules = dict(DEFAULT_RULES)
        for kv in args.set_rule:
            k, v = kv.split("=", 1)
            rules[k] = None if v in ("none", "None") else v

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = reg.runnable_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch_name, shape_name in cells:
        for mesh_kind in meshes:
            out = RESULTS_DIR / f"{arch_name}__{shape_name}__{mesh_kind}.json"
            if args.skip_existing and out.exists():
                prev = json.loads(out.read_text())
                if "error" not in prev:
                    print(f"[skip] {arch_name} {shape_name} {mesh_kind}")
                    continue
            tag = f"{arch_name} x {shape_name} x {mesh_kind}"
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = run_cell(arch_name, shape_name, mesh_kind,
                               keep_hlo=args.keep_hlo, rules=rules)
                if args.tag:
                    rec["shape"] = rec["shape"] + "@" + args.tag
                p = save(rec)
                ca = rec.get("cost_total", {})
                print(f"  OK lower={rec['lower_s']}s compile={rec['compile_s']}s "
                      f"flops={ca.get('flops', float('nan')):.3e} "
                      f"coll={rec['collective_bytes_total']:.3e}B "
                      f"-> {p.name}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append((tag, str(e)))
                save({"arch": arch_name, "shape": shape_name,
                      "mesh": mesh_kind, "error": str(e),
                      "traceback": traceback.format_exc()})
                print(f"  FAIL {e}", flush=True)

    print(f"\n{len(cells) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed, "
          f"{len(reg.skipped_cells())} recorded skips "
          f"(long_500k on full-attention archs)")
    if failures:
        for tag, err in failures:
            print(f"  FAIL {tag}: {err.splitlines()[0] if err else ''}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
