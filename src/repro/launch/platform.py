"""Lowering-platform switch for the bounded-kernel emitter (ISSUE 9).

Every bounded kernel is emitted from the one ``band_pipeline``
abstraction, so the repo has exactly three ways to lower a dispatch:

* ``"tpu"``      — Mosaic lowering of the Pallas kernels (real TPU
  backend required; the container only dry-runs this).
* ``"interpret"`` — Pallas interpret mode (the CPU default of this
  container: the kernel's grid loop runs in Python, wall times are a
  scaling signal only).
* ``"xla_ref"``  — pure-XLA reference lowering: ``ops.deform_conv`` /
  ``ops.deform_conv_chain`` dispatch the ``ref.py`` / fake-quant
  reference forms of the same arithmetic instead of emitting a Pallas
  kernel at all.  This is the degradation ladder's bottom rung promoted
  to a first-class backend — the parity baseline the tuner and the
  test-suite compare the emitted kernels against.

The idiom follows SNIPPETS Snippet 1 (``set_platform``): one
process-global switch, consulted at dispatch time by
``ops.default_interpret`` and keyed into the tuned-tile cache
(``repro.tune``) so measured winners never leak across backends — an
interpret-mode wall-time winner says nothing about Mosaic.

Switching platforms invalidates both tile-resolution memoization
(``kernels.plan.resolve_tiles`` — its results now depend on the
platform-keyed tuned cache) and the jit trace caches (``interpret`` is
a static baked at trace time), so a switch mid-process cannot serve a
stale lowering.
"""
from __future__ import annotations

import contextlib

PLATFORMS = ("tpu", "interpret", "xla_ref")

_platform: str | None = None        # None -> default_platform()


def default_platform() -> str:
    """The platform this process lowers to when none is set: Mosaic on
    a real TPU backend, Pallas interpret mode everywhere else."""
    import jax
    return "tpu" if jax.default_backend() == "tpu" else "interpret"


def current_platform() -> str:
    """The active lowering platform — the tuned-tile cache key
    component (``repro.tune.cache``) and the ``ops.default_interpret``
    source of truth."""
    return _platform if _platform is not None else default_platform()


def _invalidate_lowering_caches() -> None:
    """Drop every cache that baked the previous platform: the memoized
    tile resolution (tuned entries are platform-keyed) and the jit
    traces (``interpret`` is a static argument resolved at trace
    time)."""
    try:
        from repro.kernels.plan import resolve_tiles
        resolve_tiles.cache_clear()
    except Exception:  # noqa: BLE001 — plan not importable yet is fine
        pass
    try:
        import jax
        if hasattr(jax, "clear_caches"):
            jax.clear_caches()
    except Exception:  # noqa: BLE001
        pass


def set_platform(name: str) -> str:
    """Select the lowering platform; returns the previously active one
    (the save/restore value ``platform_scope`` uses).

    ``"tpu"`` is only valid when the jax backend actually is a TPU —
    selecting Mosaic lowering on a CPU container would fail deep inside
    Pallas; the friendly error here is the co-design guard.
    """
    global _platform
    if name not in PLATFORMS:
        raise ValueError(
            f"unknown platform {name!r}; expected one of {PLATFORMS} "
            f"(see docs/autotuning.md)")
    import jax
    if name == "tpu" and jax.default_backend() != "tpu":
        raise ValueError(
            f"platform='tpu' selects Mosaic lowering, but the jax "
            f"backend is {jax.default_backend()!r} — run on a TPU host "
            f"or pick 'interpret' / 'xla_ref'")
    prev = current_platform()
    _platform = name
    if name != prev:
        _invalidate_lowering_caches()
    return prev


@contextlib.contextmanager
def platform_scope(name: str):
    """Scoped :func:`set_platform` with guaranteed restore — what the
    parity suite and the tuner use to compare lowerings without leaking
    the switch into unrelated callers."""
    prev = set_platform(name)
    try:
        yield
    finally:
        set_platform(prev)
