"""Fault-tolerant checkpointing: atomic, keep-k, async, elastic restore.

Layout (one directory per step):

    <dir>/step_000120/
        manifest.json        {step, leaf paths, shapes, dtypes}
        000.npy ... NNN.npy  one file per pytree leaf

Writes go to ``step_X.tmp`` and are atomically ``os.rename``d — a crash
mid-write can never corrupt the latest checkpoint (restart resumes from
the previous complete one).  ``keep`` bounds disk usage.  The async
writer moves host transfer + serialization off the training thread; a
barrier before the next save (or shutdown) guarantees ordering.

Elastic restore: arrays are loaded host-side and ``jax.device_put`` with
whatever sharding the RESTART mesh prescribes — the checkpoint carries
no mesh assumptions, so a 256-chip run restores onto 512 chips (or onto
1 CPU in the tests) unchanged.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

PathLike = str | os.PathLike


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: PathLike, step: int, tree: Any,
                    *, keep: int = 3) -> pathlib.Path:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if arr.dtype.kind == "V" or logical not in np.sctypeDict:
            # ml_dtypes (bfloat16, fp8...): store raw bits + logical name
            arr = arr.view(
                {1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize])
        np.save(tmp / f"{i:03d}.npy", arr)
        manifest["leaves"].append({
            "index": i, "shape": list(arr.shape), "dtype": logical})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish

    _gc(directory, keep)
    return final


def _gc(directory: pathlib.Path, keep: int) -> None:
    steps = sorted(
        (p for p in directory.iterdir()
         if re.fullmatch(r"step_\d{8}", p.name)),
        key=lambda p: p.name)
    for p in steps[:-keep] if keep else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: PathLike) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.iterdir()
             if re.fullmatch(r"step_\d{8}", p.name)
             and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(directory: PathLike, tree_like: Any,
                       *, step: int | None = None,
                       shardings: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; reshard to
    ``shardings`` (a pytree of jax.sharding.Sharding) if given —
    the elastic path."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = directory / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    leaves_like, treedef = _flatten(tree_like)
    n = len(leaves_like)
    assert n == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"restore target has {n}")
    arrs = []
    for i in range(n):
        arr = np.load(path / f"{i:03d}.npy")
        logical = manifest["leaves"][i]["dtype"]
        if str(arr.dtype) != logical:
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, logical, logical)))
        arrs.append(arr)
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        arrs = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves)]
    else:
        arrs = [jax.device_put(a) for a in arrs]
    return jax.tree_util.tree_unflatten(treedef, arrs), step


class CheckpointManager:
    """Async wrapper with a single in-flight write and keep-k GC."""

    def __init__(self, directory: PathLike, *, keep: int = 3,
                 async_write: bool = True):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any) -> None:
        self.wait()                      # one in-flight write at a time
        # Materialize on host BEFORE returning so the training loop can
        # donate/overwrite device buffers safely.
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        if not self.async_write:
            save_checkpoint(self.directory, step, host_tree, keep=self.keep)
            return

        def _work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                keep=self.keep)
            except BaseException as e:   # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def restore(self, tree_like: Any, *, shardings=None):
        return restore_checkpoint(self.directory, tree_like,
                                  shardings=shardings)

    def latest_step(self):
        return latest_step(self.directory)
