"""Fault-tolerant checkpointing: atomic, keep-k, async, elastic, verified.

Layout (one directory per step):

    <dir>/step_000120/
        manifest.json        {step, treedef, leaves: [{shape, dtype, crc32}]}
        000.npy ... NNN.npy  one file per pytree leaf

Writes go to ``step_X.tmp`` and are atomically ``os.rename``d — a crash
mid-write can never corrupt the latest checkpoint (restart resumes from
the previous complete one).  ``keep`` bounds disk usage.  The async
writer moves host transfer + serialization off the training thread; a
barrier before the next save (or shutdown) guarantees ordering.

Hardening (PR 6): every leaf's CRC32 and the saved treedef are recorded
in the manifest and verified on restore.  A checkpoint that fails
verification (truncated leaf, bad manifest, CRC mismatch — bit-rot or
a corrupting crash that slipped past the rename barrier) raises
:class:`CheckpointCorruptError`; the auto-latest restore catches it,
logs a warning, and falls back to the previous complete step instead of
taking the job down.  ``CheckpointManager`` also sweeps stale
``step_*.tmp`` directories on init (debris from a killed writer).

Elastic restore: arrays are loaded host-side and ``jax.device_put`` with
whatever sharding the RESTART mesh prescribes — the checkpoint carries
no mesh assumptions, so a 256-chip run restores onto 512 chips (or onto
1 CPU in the tests) unchanged.
"""
from __future__ import annotations

import json
import logging
import os
import pathlib
import re
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

PathLike = str | os.PathLike

_log = logging.getLogger("repro.resilience")


class CheckpointCorruptError(RuntimeError):
    """A stored checkpoint failed verification (manifest / leaf / CRC)."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: PathLike, step: int, tree: Any,
                    *, keep: int = 3) -> pathlib.Path:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if arr.dtype.kind == "V" or logical not in np.sctypeDict:
            # ml_dtypes (bfloat16, fp8...): store raw bits + logical name
            arr = arr.view(
                {1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize])
        np.save(tmp / f"{i:03d}.npy", arr)
        manifest["leaves"].append({
            "index": i, "shape": list(arr.shape), "dtype": logical,
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes())})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish

    _gc(directory, keep)
    return final


def _gc(directory: pathlib.Path, keep: int) -> None:
    steps = sorted(
        (p for p in directory.iterdir()
         if re.fullmatch(r"step_\d{8}", p.name)),
        key=lambda p: p.name)
    for p in steps[:-keep] if keep else []:
        shutil.rmtree(p, ignore_errors=True)


def complete_steps(directory: PathLike) -> list[int]:
    """Steps with a published directory + manifest, newest first."""
    directory = pathlib.Path(directory)
    if not directory.exists():
        return []
    steps = [int(p.name.split("_")[1]) for p in directory.iterdir()
             if re.fullmatch(r"step_\d{8}", p.name)
             and (p / "manifest.json").exists()]
    return sorted(steps, reverse=True)


def latest_step(directory: PathLike) -> int | None:
    steps = complete_steps(directory)
    return steps[0] if steps else None


def _load_manifest(path: pathlib.Path) -> dict:
    mf = path / "manifest.json"
    if not mf.exists():
        raise CheckpointCorruptError(f"{path}: manifest.json missing")
    try:
        manifest = json.loads(mf.read_text())
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointCorruptError(
            f"{path}: manifest.json unreadable ({e})") from e
    if not isinstance(manifest.get("leaves"), list):
        raise CheckpointCorruptError(
            f"{path}: manifest has no leaf table")
    return manifest


def _restore_step(path: pathlib.Path, leaves_like, treedef,
                  shardings) -> Any:
    """Load + verify one published checkpoint directory.

    Raises :class:`CheckpointCorruptError` for on-disk damage (missing
    or truncated leaves, CRC mismatch, unreadable manifest) and
    ``ValueError`` for a restore-target mismatch (leaf count / treedef)
    — target mismatches are a caller bug that no older checkpoint can
    fix, so they never trigger the fallback path.
    """
    manifest = _load_manifest(path)
    n = len(leaves_like)
    if n != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint {path} has {len(manifest['leaves'])} leaves "
            f"but the restore target has {n} — the stored pytree and "
            f"the template passed to restore_checkpoint disagree")
    stored_treedef = manifest.get("treedef")
    if stored_treedef is not None and stored_treedef != str(treedef):
        raise ValueError(
            f"checkpoint {path} was saved with treedef\n"
            f"  {stored_treedef}\nbut the restore target has\n"
            f"  {treedef}\n— same leaf count, different structure; "
            f"restore into the structure that was saved")
    arrs = []
    for i in range(n):
        entry = manifest["leaves"][i]
        leaf_path = path / f"{i:03d}.npy"
        if not leaf_path.exists():
            raise CheckpointCorruptError(f"{path}: leaf {i:03d}.npy missing")
        try:
            arr = np.load(leaf_path)
        except (ValueError, OSError, EOFError) as e:
            raise CheckpointCorruptError(
                f"{path}: leaf {i:03d}.npy unreadable ({e})") from e
        if list(arr.shape) != list(entry["shape"]):
            raise CheckpointCorruptError(
                f"{path}: leaf {i:03d}.npy has shape {list(arr.shape)}, "
                f"manifest says {entry['shape']}")
        want_crc = entry.get("crc32")
        if want_crc is not None:
            got_crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if got_crc != want_crc:
                raise CheckpointCorruptError(
                    f"{path}: leaf {i:03d}.npy CRC32 {got_crc:#010x} != "
                    f"manifest {want_crc:#010x} (bit-rot or a partial "
                    f"write)")
        logical = entry["dtype"]
        if str(arr.dtype) != logical:
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, logical, logical)))
        arrs.append(arr)
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        if len(sh_leaves) != n:
            raise ValueError(
                f"checkpoint {path} has {n} leaves but shardings has "
                f"{len(sh_leaves)} — pass a sharding per restored leaf")
        arrs = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves)]
    else:
        arrs = [jax.device_put(a) for a in arrs]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def restore_checkpoint(directory: PathLike, tree_like: Any,
                       *, step: int | None = None,
                       shardings: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; reshard to
    ``shardings`` (a pytree of jax.sharding.Sharding) if given —
    the elastic path.

    Every leaf is CRC-verified against the manifest.  With ``step=None``
    (auto-latest) a corrupt checkpoint is logged and skipped: the
    restore falls back to the previous complete step rather than taking
    the run down with it.  An explicit ``step`` raises
    :class:`CheckpointCorruptError` directly — the caller asked for that
    exact state.
    """
    directory = pathlib.Path(directory)
    leaves_like, treedef = _flatten(tree_like)
    if step is not None:
        path = directory / f"step_{step:08d}"
        return _restore_step(path, leaves_like, treedef, shardings), step
    steps = complete_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    last_err: CheckpointCorruptError | None = None
    for s in steps:
        path = directory / f"step_{s:08d}"
        try:
            restored = _restore_step(path, leaves_like, treedef, shardings)
        except CheckpointCorruptError as e:
            _log.warning(
                "checkpoint step %d failed verification (%s); falling "
                "back to the previous complete step", s, e)
            last_err = e
            continue
        if last_err is not None:
            _log.warning("recovered from corrupt checkpoint: restored "
                         "step %d instead", s)
        return restored, s
    raise CheckpointCorruptError(
        f"every checkpoint in {directory} failed verification; "
        f"last error: {last_err}")


class CheckpointManager:
    """Async wrapper with a single in-flight write and keep-k GC.

    On init, stale ``step_*.tmp`` directories (debris of a writer that
    died mid-save) are swept so they can never shadow a real save.
    """

    def __init__(self, directory: PathLike, *, keep: int = 3,
                 async_write: bool = True):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        if self.directory.exists():
            for p in self.directory.glob("step_*.tmp"):
                if p.is_dir():
                    _log.warning("removing stale checkpoint temp dir %s", p)
                    shutil.rmtree(p, ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any) -> None:
        from repro.obs.trace import get_tracer
        # The span covers only the synchronous portion (host
        # materialization + the handoff) — the async writer thread must
        # not touch the tracer, whose clock/stack are not thread-safe.
        with get_tracer().span("ckpt/save", step=step,
                               sync=not self.async_write):
            self.wait()                  # one in-flight write at a time
            # Materialize on host BEFORE returning so the training loop
            # can donate/overwrite device buffers safely.
            host_tree = jax.tree_util.tree_map(
                lambda x: np.asarray(jax.device_get(x)), tree)
            if not self.async_write:
                save_checkpoint(self.directory, step, host_tree,
                                keep=self.keep)
                return

            def _work():
                try:
                    save_checkpoint(self.directory, step, host_tree,
                                    keep=self.keep)
                except BaseException as e:   # noqa: BLE001
                    self._error = e

            self._thread = threading.Thread(target=_work, daemon=True)
            self._thread.start()

    def restore(self, tree_like: Any, *, shardings=None, step=None):
        from repro.obs.trace import get_tracer
        with get_tracer().span("ckpt/restore", step=step):
            return restore_checkpoint(self.directory, tree_like,
                                      step=step, shardings=shardings)

    def latest_step(self):
        return latest_step(self.directory)
