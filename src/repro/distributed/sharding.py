"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Model code never names mesh axes directly; it tags tensor dimensions with
*logical* names ('batch', 'ff', 'vocab', ...).  The active ``AxisRules``
maps logical names to mesh axes, and every lookup is guarded by a
divisibility check against the live mesh — a logical axis whose dimension
does not divide evenly simply stays unsharded (GSPMD is then free to
choose).  This is what lets one model definition serve 10 architectures
whose head counts (24, 32, 48, 64...) do not all divide the 16-way model
axis.

Default mapping (single pod (data=16, model=16); multi-pod adds 'pod'):

    batch   -> ('pod', 'data')     DP across pods and the data axis
    seq     -> None                (SP variants map it to 'data')
    embed   -> 'data'              ZeRO/FSDP: params+optimizer sharded on DP
    heads   -> 'model'             TP attention
    kv      -> 'model'             TP for KV heads when divisible
    ff      -> 'model'             TP MLP
    vocab   -> 'model'             TP embedding + logits
    experts -> None                experts replicated, inner dims sharded
                                   (tensor-parallel experts; EP variant in
                                   EXPERIMENTS.md §Perf)
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRules = Mapping[str, str | tuple[str, ...] | None]

DEFAULT_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "model",     # sequence-parallel attention fallback
    "embed": "data",
    "embed_no_fsdp": None,
    "heads": "model",
    "kv": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": None,
    "model": "model",
    "data": "data",
    "conv_in": None,
    # §Perf hillclimb (resnet50_dcn): channel-TP convs all-reduce every
    # layer while the weights are only ~100 MB — replicate them and give
    # the model axis to SPATIAL partitioning instead (GSPMD halo
    # exchange), which divides the conv compute 16 further ways.
    "conv_out": None,
    "spatial": "model",
    "rnn": "model",
}

# Serving rules (§Perf): decode/prefill re-gather FSDP-sharded params on
# EVERY step — for one token that is pure waste.  When the TP shard of
# the weights fits HBM next to the KV cache, serve with params
# replicated across 'data' (sharded on 'model' only).
SERVE_RULES: AxisRules = {**DEFAULT_RULES, "embed": None}

SERVE_REPLICATION_BUDGET_BYTES = 8 << 30   # bf16 TP-shard budget


def serve_rules_for(param_count: int, *, tp: int = 16,
                    bytes_per_param: int = 2) -> AxisRules:
    if param_count * bytes_per_param / tp <= SERVE_REPLICATION_BUDGET_BYTES:
        return dict(SERVE_RULES)
    return dict(DEFAULT_RULES)


_state = threading.local()


def _mesh_axis_sizes(mesh: Mesh | None) -> dict[str, int]:
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@contextlib.contextmanager
def use_rules(rules: AxisRules | None = None, mesh: Mesh | None = None):
    """Activate logical->mesh rules (and the mesh for divisibility checks)."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = (dict(DEFAULT_RULES if rules is None else rules), mesh)
    try:
        yield
    finally:
        _state.ctx = prev


def current_rules() -> tuple[AxisRules, Mesh | None] | None:
    return getattr(_state, "ctx", None)


def _resolve_axis(logical: str | None, dim_size: int,
                  rules: AxisRules, sizes: dict[str, int],
                  used: set[str]) -> str | tuple[str, ...] | None:
    """Map one logical name to mesh axes, dropping non-dividing or
    already-used mesh axes (a mesh axis may appear once per spec)."""
    if logical is None:
        return None
    target = rules.get(logical)
    if target is None:
        return None
    axes = (target,) if isinstance(target, str) else tuple(target)
    picked: list[str] = []
    remaining = dim_size
    for ax in axes:
        n = sizes.get(ax)
        if n is None or ax in used:
            continue
        if remaining % n != 0:
            continue
        picked.append(ax)
        used.add(ax)
        remaining //= n
    if not picked:
        return None
    return picked[0] if len(picked) == 1 else tuple(picked)


def logical_spec(shape: Sequence[int], axes: Sequence[str | None],
                 *, rules: AxisRules | None = None,
                 mesh: Mesh | None = None) -> P:
    """Build a PartitionSpec for ``shape`` from logical axis names."""
    ctx = current_rules()
    if rules is None:
        rules = ctx[0] if ctx else dict(DEFAULT_RULES)
    if mesh is None:
        mesh = ctx[1] if ctx else None
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    assert len(shape) == len(axes), (shape, axes)
    entries = [_resolve_axis(a, d, rules, sizes, used)
               for d, a in zip(shape, axes)]
    # Trailing Nones can be dropped but keeping them is harmless.
    return P(*entries)


def logical_constraint(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op when no mesh
    is active — CPU smoke tests run the same code path unconstrained)."""
    ctx = current_rules()
    if ctx is None or ctx[1] is None:
        return x
    rules, mesh = ctx
    spec = logical_spec(x.shape, axes, rules=rules, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, shape: Sequence[int],
                   axes: Sequence[str | None],
                   rules: AxisRules | None = None) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(shape, axes, rules=rules,
                                            mesh=mesh))


def batch_mesh_axes(*, logical: str = "batch"
                    ) -> tuple[Mesh, tuple[str, ...], int] | None:
    """Mesh axes the 'batch' logical axis maps to under the *active*
    rules: ``(mesh, axis_names, total_size)``, or None when no mesh is
    active, the rules map ``logical`` to nothing, or every mapped axis
    has size 1 (a 1-device host mesh — nothing to shard over).

    Unlike ``logical_spec`` this does NOT apply the divisibility
    fallback: the caller decides whether a non-dividing batch falls
    back to the unsharded path or raises (``kernels.ops`` raises when
    sharding was explicitly requested — the data-parallel ``shard_map``
    kernel path needs equal shards, there is no GSPMD to pick up the
    slack).
    """
    ctx = current_rules()
    if ctx is None or ctx[1] is None:
        return None
    rules, mesh = ctx
    target = rules.get(logical)
    if target is None:
        return None
    sizes = _mesh_axis_sizes(mesh)
    axes = tuple(ax for ax in ((target,) if isinstance(target, str)
                               else tuple(target))
                 if sizes.get(ax, 1) > 1)
    total = math.prod(sizes[ax] for ax in axes)
    if total <= 1:
        return None
    return mesh, axes, total
