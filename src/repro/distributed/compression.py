"""Error-feedback int8 gradient compression.

At multi-pod scale the cross-DCI gradient all-reduce dominates the
collective term (§Roofline); int8 quantization cuts those bytes 4x
(fp32) / 2x (bf16).  Error feedback keeps the *accumulated* quantization
error in a per-leaf buffer and re-injects it next step, so the scheme is
unbiased in the long run (EF-SGD; Karimireddy et al. 2019) and training
quality is preserved — ``tests/test_compression.py`` checks the
contraction property.

Two entry points:

* ``ef_compress_grads`` / state — numerics-level wrapper used by the
  Trainer (``--grad-compression int8_ef``): quantize -> dequantize with
  error feedback *before* the optimizer.  Under pjit the all-reduce
  itself is emitted by GSPMD; on a real deployment the quantized tensor
  is what crosses the DCI (see ``compressed_psum`` for the explicit
  shard_map form).
* ``compressed_psum`` — explicit shard_map collective: int8-quantized
  psum over a named axis, for pipelines that manage their own
  collectives.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def init_ef_state(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: Array) -> tuple[Array, Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: Any, ef_state: Any) -> tuple[Any, Any]:
    """Returns (compressed-then-decompressed grads, new ef_state)."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = _quantize(g)
        deq = _dequantize(q, scale)
        return deq, g - deq

    flat = jax.tree_util.tree_map(one, grads, ef_state)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], flat, is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), pick(1)


def _psum_int8(q: Array, scale: Array, axis_name: str) -> Array:
    """int8 payload (as int32 — psum needs an accumulator type) crosses
    the interconnect; ``scale`` is the SHARED grid every shard already
    quantized onto, so the dequant is a plain scalar multiply."""
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return s.astype(jnp.float32) * scale


def compressed_psum(x: Array, axis_name: str) -> Array:
    """Quantize-then-psum: only int8 bytes traverse ``axis_name`` links.
    Call inside shard_map.

    Deterministic and shard-symmetric: the quantization grid is agreed
    FIRST (``pmax`` of the per-shard absmax scales), every shard
    quantizes onto that shared grid, and the int8 payloads psum exactly
    in int32 — the result is invariant to shard order and reduction
    grouping.  (An earlier formulation quantized each shard on its own
    local grid and rescaled the sum by ``pmax(scale)`` afterwards,
    which inflated every shard whose local absmax was below the max —
    an asymmetry that made the collective depend on which shard held
    the largest gradient.)  Costs one extra scalar pmax before the
    payload psum; the bytes on the links are unchanged.
    """
    x = x.astype(jnp.float32)
    local = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    scale = jax.lax.pmax(local, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return _psum_int8(q, scale, axis_name)
