"""Spatial (height) sharding of the bounded DCL kernels — bounded halo
exchange over a mesh axis (ISSUE 10).

Batch data-parallelism (``ops.resolve_batch_shard``) cannot reduce the
latency of *one* megapixel image; this module shards the height grid
axis instead.  The paper's Eq. 5 trained offset bound is what makes
that cheap: the same bound that keeps every gather inside the Eq. 6
band statically bounds the inter-device dependency to

    halo = dilation*(K//2) + ceil(B) + 1        (= B + ceil(K/2) rows
                                                 for dilation=1, odd K)

rows of the neighbor shard (``core.tiling.spatial_halo_rows`` is the
single source of that algebra), so spatial parallelism is exactly one
``lax.ppermute`` up/down halo-exchange pair per layer — the
bounded-access locality argument of Huang et al. / CoDeNet transplanted
from on-chip buffers to the mesh.

Geometry.  The unsharded zero-copy path pads the input top/left by
``p0 = dilation*(K//2) + ceil(B)`` zero rows (``plan.pad_zerocopy``);
output row ``t`` then reads padded rows ``[t*s, t*s + band_extent(1))``
= original rows ``[t*s - p0, t*s + p0 + 1]``.  With the height split
``H % (stride*shards) == 0``, shard ``i`` owns output rows
``[i*ho_loc, (i+1)*ho_loc)`` and needs original rows
``[i*h_loc - p0, (i+1)*h_loc - s + p0 + 1]`` — at most ``halo = p0+1``
rows beyond its own block on either side.  After the exchange the
shard trims its halo-extended block to the exact local analogue of the
global padded slab (``_shard_slab``) and runs the *unmodified*
zero-copy kernels on it, so per-shard outputs equal the corresponding
global output rows bit-for-bit (same tiles => same arithmetic).  The
non-cyclic ``ppermute`` delivers zeros at the edge shards — exactly
the zero padding the global path applies, for free.

Backward mirrors it: the fused backward kernel produces ``d_input``
over the halo-extended extent; the rows that belong to the neighbors
are ppermuted back (reverse directions) and added into their local
``d_input``, ``d_weights`` is psummed over the spatial (and any
composed batch) mesh axes, and ``d_offsets`` stays local.  The
``custom_vjp`` wraps the shard_maps — never the other way round — so
gradient correctness does not depend on shard_map transpose rules
(same structure as ``ops._deform_conv_sharded``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.tiling import spatial_halo_rows
from repro.kernels import plan as _plan
from repro.kernels.band_pipeline import band_geometry
from repro.kernels.deform_conv_bwd import deform_conv_bwd_zerocopy
from repro.kernels.deform_conv_fused import deform_conv_fused_zerocopy
from repro.kernels.deform_conv_q import deform_conv_fused_zerocopy_q
from .sharding import current_rules

Array = jax.Array


def halo_rows(*, kernel_size: int, dilation: int = 1,
              offset_bound: float) -> int:
    """Rows exchanged with each height-shard neighbor — delegates to
    ``core.tiling.spatial_halo_rows`` so the runtime exchange and the
    HBM/ICI traffic model can never disagree."""
    return spatial_halo_rows(kernel_size=kernel_size, dilation=dilation,
                             offset_bound=offset_bound)


def check_height_split(h: int, *, shards: int, stride: int = 1,
                       min_rows: int | None = None) -> None:
    """Reject height splits the spatial shard_map cannot serve — a clear
    ``ValueError`` naming the sizes at the public entry (a la
    ``ops.check_batch_split``) instead of a deep shard_map shape error.

    ``min_rows`` (the halo extent, when the caller knows it) addition-
    ally rejects shards thinner than their own halo — the exchange
    slices ``x[:, -halo:]`` need ``H/shards >= halo`` rows per shard.
    """
    if shards < 1:
        raise ValueError(f"spatial shards={shards} must be >= 1")
    if h % (stride * shards) != 0:
        raise ValueError(
            f"spatial shards={shards} does not evenly divide height "
            f"H={h} at stride={stride}; the spatial shard_map needs "
            f"equal per-device row blocks (H % (stride*shards) == 0) — "
            f"pad the input height or pick a shard count dividing "
            f"{h // stride if h % stride == 0 else h}")
    if min_rows is not None and shards > 1 and h // shards < min_rows:
        raise ValueError(
            f"spatial shards={shards} leaves only {h // shards} rows "
            f"per shard, thinner than the {min_rows}-row halo the "
            f"bounded exchange needs — use fewer shards (or a smaller "
            f"offset bound)")


def spatial_mesh_axes() -> tuple[Mesh, str, int] | None:
    """Mesh axis the 'spatial' logical axis maps to under the *active*
    rules: ``(mesh, axis_name, size)``, or None when no mesh is active
    or the rules map 'spatial' to nothing.

    Unlike ``sharding.batch_mesh_axes`` this keeps size-1 axes: a
    1-shard spatial run still routes through the halo-exchange path
    (empty ``ppermute`` perm => zero halos == the global zero padding),
    which is exactly what the bit-identity parity tests exercise.
    Multiple mapped mesh axes raise — the ``ppermute`` ring needs one
    well-ordered axis.
    """
    ctx = current_rules()
    if ctx is None or ctx[1] is None:
        return None
    rules, mesh = ctx
    target = rules.get("spatial")
    if target is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = tuple(ax for ax in ((target,) if isinstance(target, str)
                               else tuple(target))
                 if ax in sizes)
    if not axes:
        return None
    if len(axes) > 1:
        raise ValueError(
            f"the 'spatial' logical axis maps to {axes} under the "
            f"active rules; the halo-exchange ppermute needs exactly "
            f"one mesh axis — map 'spatial' to a single axis")
    return mesh, axes[0], sizes[axes[0]]


@dataclasses.dataclass(frozen=True)
class SpatialSpec:
    """Hashable mesh context of one height-sharded deform_conv call.

    ``batch_axes`` composes batch data-parallelism into the same
    shard_map (spatial x data 2-D mesh): dim 0 of every activation is
    sharded over them while dim 1 (height) rides ``axis``.
    """
    mesh: Mesh
    axis: str
    shards: int
    batch_axes: tuple[str, ...] = ()

    def pspec(self, rank: int) -> P:
        """PartitionSpec sharding dim 0 over the batch axes (if any)
        and dim 1 (height) over the spatial axis."""
        b = self.batch_axes if self.batch_axes else None
        return P(b, self.axis, *([None] * (rank - 2)))

    @property
    def psum_axes(self) -> tuple[str, ...]:
        return (self.axis, *self.batch_axes)


def resolve_spatial_shard(h: int, *, shard_spatial: bool | None = None,
                          stride: int = 1, kernel_size: int = 3,
                          dilation: int = 1, offset_bound: float = 0.0,
                          batch_axes: tuple[str, ...] = ()
                          ) -> SpatialSpec | None:
    """Decide whether to shard the height axis over the active mesh.

    * ``shard_spatial=None``/``False``: never — spatial sharding is
      strictly opt-in (unlike ``shard_batch``'s auto mode) because the
      default rules map 'spatial' to the 'model' axis, and silently
      height-sharding every bounded call under a model-parallel mesh
      would change the layout of existing callers.
    * ``shard_spatial=True``: require it — no active mesh mapping
      'spatial', a ragged height split, or shards thinner than the
      halo raise a ``ValueError`` naming the sizes.
    """
    if not shard_spatial:
        return None
    got = spatial_mesh_axes()
    if got is None:
        raise ValueError(
            "shard_spatial=True but no mesh maps the 'spatial' logical "
            "axis — activate one with distributed.sharding."
            "use_rules(mesh=...) whose rules map 'spatial' to a mesh "
            "axis (DEFAULT_RULES maps it to 'model')")
    mesh, axis, size = got
    if axis in batch_axes:
        raise ValueError(
            f"the 'spatial' mesh axis {axis!r} is already used by the "
            f"batch shard {batch_axes} — a mesh axis may carry one "
            f"logical axis per call; use a 2-D mesh (e.g. ('data', "
            f"'model')) so batch and height shard different axes")
    halo = halo_rows(kernel_size=kernel_size, dilation=dilation,
                     offset_bound=offset_bound)
    check_height_split(h, shards=size, stride=stride, min_rows=halo)
    return SpatialSpec(mesh=mesh, axis=axis, shards=size,
                       batch_axes=tuple(batch_axes))


# ---------------------------------------------------------------------------
# Shard bodies
# ---------------------------------------------------------------------------

def exchange_halo(x: Array, *, axis_name: str, shards: int,
                  halo: int) -> Array:
    """The one up/down halo-exchange pair: concatenate each shard's
    height block with ``halo`` edge rows from both neighbors.  The
    non-cyclic ``ppermute`` (idiom of ``distributed.pipeline``) leaves
    the edge shards' missing neighbor as zeros — exactly the zero
    padding the unsharded path applies there."""
    down = [(i, i + 1) for i in range(shards - 1)]
    up = [(i + 1, i) for i in range(shards - 1)]
    top = jax.lax.ppermute(x[:, -halo:], axis_name, down)
    bot = jax.lax.ppermute(x[:, :halo], axis_name, up)
    return jnp.concatenate([top, x, bot], axis=1)


def _shard_slab(x_ext: Array, *, kernel_size: int, stride: int,
                dilation: int, offset_bound: float, tile_h: int,
                tile_w: int, ho: int, wo: int) -> Array:
    """Trim one halo-extended shard block to the local analogue of the
    global ``plan.pad_zerocopy`` slab.

    Global slab row ``u`` is original row ``u - p0``; local slab row
    ``j`` must be original row ``i*h_loc - p0 + j``, and ``x_ext`` row
    0 is original row ``i*h_loc - halo`` — so the slab starts at
    ``x_ext`` row ``halo - p0`` (= 1, by construction).  Width gets the
    same left-``p0``/right zero padding as ``pad_zerocopy`` (the width
    axis is not sharded), and the bottom is zero-padded out to the
    tile-rounded band extent (those rows feed only the padded output
    rows that are sliced away)."""
    n, h_ext, w_, c = x_ext.shape
    pad = dilation * (kernel_size // 2)
    hb, band_h = band_geometry(kernel_size=kernel_size, stride=stride,
                               dilation=dilation, offset_bound=offset_bound,
                               tile_h=tile_h)
    _, band_w = band_geometry(kernel_size=kernel_size, stride=stride,
                              dilation=dilation, offset_bound=offset_bound,
                              tile_h=tile_w)
    p0 = pad + hb
    halo = p0 + 1
    h_tiles = ho // tile_h
    w_tiles = wo // tile_w
    top = halo - p0                                    # = 1
    need_h = (h_tiles - 1) * tile_h * stride + band_h
    pb = max(0, need_h - (h_ext - top))
    pr = max(0, (w_tiles - 1) * tile_w * stride + band_w - p0 - w_)
    slab = x_ext[:, top:]
    return jnp.pad(slab, ((0, 0), (0, pb), (p0, pr), (0, 0)))


def _spatial_forward(spec: _plan.DCSpec, sspec: SpatialSpec, x: Array,
                     offsets: Array, w: Array) -> Array:
    """Per-shard forward body: halo exchange, slab trim, then the
    unmodified zero-copy kernel on the local rows.  Tiles resolve at
    the LOCAL shard shape (``x`` here is the per-device block), so the
    ``resolve_tiles`` memo and the tuned-tile cache key by shard-local
    height — tuned plans never leak across shard counts."""
    ho, wo = offsets.shape[1], offsets.shape[2]
    th, tw, tc, tm = _plan.spec_tiles(spec, x, offsets, w)
    pad_h, pad_w = (-ho) % th, (-wo) % tw
    if pad_h or pad_w:
        offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    halo = halo_rows(kernel_size=spec.kernel_size, dilation=spec.dilation,
                     offset_bound=spec.offset_bound)
    x_ext = exchange_halo(x, axis_name=sspec.axis, shards=sspec.shards,
                          halo=halo)
    slab = _shard_slab(x_ext, kernel_size=spec.kernel_size,
                       stride=spec.stride, dilation=spec.dilation,
                       offset_bound=spec.offset_bound, tile_h=th,
                       tile_w=tw, ho=ho + pad_h, wo=wo + pad_w)
    w_tiled = _plan.tile_weights(w.astype(x.dtype), tc)
    y = deform_conv_fused_zerocopy(
        slab, offsets, w_tiled, kernel_size=spec.kernel_size,
        stride=spec.stride, dilation=spec.dilation,
        offset_bound=spec.offset_bound, tile_h=th, tile_w=tw,
        tile_c=tc, tile_m=tm, interpret=spec.interpret)
    return y[:, :ho, :wo]


def _spatial_backward(spec: _plan.DCSpec, sspec: SpatialSpec, x: Array,
                      offsets: Array, w: Array, gy: Array
                      ) -> tuple[Array, Array, Array]:
    """Per-shard backward body.  The fused backward kernel writes
    ``d_input`` over the halo-extended slab; the ``p0`` rows above the
    local block belong to the previous shard and the ``p0+1`` rows
    below to the next — those halo-gradient rows are ppermuted back
    (reverse directions of the forward exchange) and ADDED into the
    neighbors' local ``d_input``; edge shards receive zeros (a no-op
    add), matching the global path's discarded zero-pad gradients.
    ``d_weights`` is psummed over the spatial + batch axes."""
    n, h_loc, w_in, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    th, tw, tc, _ = _plan.spec_tiles(spec, x, offsets, w)
    off_dtype = offsets.dtype
    dwf = spec.dw_flush_every_step
    if dwf is None:
        entry = _plan._tuned_lookup(
            h_loc, w_in, c, w.shape[-1], kernel_size=spec.kernel_size,
            stride=spec.stride, dilation=spec.dilation,
            offset_bound=spec.offset_bound, objective="training",
            dtype=None, cores=spec.cores)
        if entry is not None:
            v = entry.get("dw_flush_every_step")
            dwf = v if isinstance(v, bool) else None
    pad_h, pad_w = (-ho) % th, (-wo) % tw
    if pad_h or pad_w:
        offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
        gy = jnp.pad(gy, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    halo = halo_rows(kernel_size=spec.kernel_size, dilation=spec.dilation,
                     offset_bound=spec.offset_bound)
    p0 = halo - 1
    x_ext = exchange_halo(x, axis_name=sspec.axis, shards=sspec.shards,
                          halo=halo)
    slab = _shard_slab(x_ext, kernel_size=spec.kernel_size,
                       stride=spec.stride, dilation=spec.dilation,
                       offset_bound=spec.offset_bound, tile_h=th,
                       tile_w=tw, ho=ho + pad_h, wo=wo + pad_w)
    w_tiled = _plan.tile_weights(w.astype(x.dtype), tc)
    dxp, doff, dwt = deform_conv_bwd_zerocopy(
        slab, offsets, gy, w_tiled, kernel_size=spec.kernel_size,
        stride=spec.stride, dilation=spec.dilation,
        offset_bound=spec.offset_bound, tile_h=th, tile_w=tw, tile_c=tc,
        cores=spec.cores, interpret=spec.interpret,
        dw_flush_every_step=dwf)
    # Un-pad width (the width axis is unsharded, same as the global
    # path), keep the full halo-extended row extent for the exchange.
    dxe = dxp[:, :, p0:p0 + w_in]
    dx = dxe[:, p0:p0 + h_loc]
    if sspec.shards > 1:
        # Rows [0, p0) are grads of the previous shard's last p0 rows;
        # rows [p0+h_loc, p0+h_loc+p0+1) of the next shard's first ones.
        to_prev = [(i, i - 1) for i in range(1, sspec.shards)]
        to_next = [(i, i + 1) for i in range(sspec.shards - 1)]
        if p0 > 0:
            from_next = jax.lax.ppermute(dxe[:, :p0], sspec.axis, to_prev)
            dx = dx.at[:, h_loc - p0:].add(from_next)
        from_prev = jax.lax.ppermute(
            dxe[:, p0 + h_loc:p0 + h_loc + p0 + 1], sspec.axis, to_next)
        dx = dx.at[:, :p0 + 1].add(from_prev)
    doff = doff[:, :ho, :wo]
    dw = _plan.untile_weights(dwt, spec.kernel_size)
    dw = jax.lax.psum(dw, sspec.psum_axes)
    return (dx.astype(x.dtype), doff.astype(off_dtype), dw.astype(w.dtype))


# ---------------------------------------------------------------------------
# custom_vjp over shard_map (fp32) + the plain-shard_map int8 path
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def deform_conv_spatial(spec: _plan.DCSpec, sspec: SpatialSpec, x: Array,
                        offsets: Array, w: Array) -> Array:
    """Height-sharded bounded fp32 deform_conv: shard_map over the
    spatial (x optional batch) mesh axes with one halo exchange per
    call; differentiable via the fused backward kernel + halo-gradient
    return (see module docstring)."""
    ps = sspec.pspec(4)
    fn = shard_map(functools.partial(_spatial_forward, spec, sspec),
                   mesh=sspec.mesh,
                   in_specs=(ps, ps, P(None, None, None)),
                   out_specs=ps, check_rep=False)
    return fn(x, offsets, w)


def _deform_conv_spatial_fwd(spec, sspec, x, offsets, w):
    return deform_conv_spatial(spec, sspec, x, offsets, w), (x, offsets, w)


def _deform_conv_spatial_bwd(spec, sspec, res, gy):
    x, offsets, w = res
    ps = sspec.pspec(4)
    rep_w = P(None, None, None)
    fn = shard_map(functools.partial(_spatial_backward, spec, sspec),
                   mesh=sspec.mesh,
                   in_specs=(ps, ps, rep_w, ps),
                   out_specs=(ps, ps, rep_w), check_rep=False)
    return fn(x, offsets, w, gy)


deform_conv_spatial.defvjp(_deform_conv_spatial_fwd,
                           _deform_conv_spatial_bwd)


def spatial_int8_forward(x: Array, offsets: Array, w: Array, *,
                         kernel_size: int, stride: int, dilation: int,
                         offset_bound: float, tile_h: int | None,
                         tile_w: int | None, tile_c: int | None,
                         tile_m: int | None, x_scale: Array | None,
                         w_scale: Array | None, interpret: bool,
                         sspec: SpatialSpec) -> Array:
    """Height-sharded int8 inference datapath (no VJP — quantized
    inference only, like ``plan.int8_forward``).

    The quantization scales are hoisted OUTSIDE the shard_map: a
    per-shard dynamic absmax would give each shard its own int8 grid
    and break parity with the unsharded kernel, so the global plane is
    quantized once (calibrated scales, or one global absmax) and the
    halo exchange carries int8 rows — 4x cheaper on the wire, and
    exactly the bytes the traffic model charges.  int8 accumulation is
    exact (s8 x s8 -> s32), so per-shard outputs match the unsharded
    kernel bit-for-bit regardless of the locally resolved tiles."""
    from repro.quant.qtypes import compute_scale, quantize_values

    m = w.shape[-1]
    sx = compute_scale(x) if x_scale is None \
        else jnp.asarray(x_scale, jnp.float32)
    sw = compute_scale(w, axis=-1) if w_scale is None \
        else jnp.asarray(w_scale, jnp.float32).reshape(1, 1, m)
    xq = quantize_values(x, sx)
    wq = quantize_values(w, sw)
    scale = (sx * sw).reshape(1, m).astype(jnp.float32)

    def body(xq, offsets, wq, scale):
        ho, wo = offsets.shape[1], offsets.shape[2]
        h_loc, w_in, c = xq.shape[1], xq.shape[2], xq.shape[3]
        th, tw, tc, tm = _plan.resolve_tiles(
            h_loc, w_in, c, m, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
            tile_w=tile_w, tile_c=tile_c, tile_m=tile_m,
            objective="forward", dtype="int8")
        th, tw = min(th, ho), min(tw, wo)
        pad_h, pad_w = (-ho) % th, (-wo) % tw
        offs = offsets
        if pad_h or pad_w:
            offs = jnp.pad(offs, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
        halo = halo_rows(kernel_size=kernel_size, dilation=dilation,
                         offset_bound=offset_bound)
        x_ext = exchange_halo(xq, axis_name=sspec.axis,
                              shards=sspec.shards, halo=halo)
        slab = _shard_slab(x_ext, kernel_size=kernel_size, stride=stride,
                           dilation=dilation, offset_bound=offset_bound,
                           tile_h=th, tile_w=tw, ho=ho + pad_h,
                           wo=wo + pad_w)
        w_tiled = _plan.tile_weights(wq, tc)
        y = deform_conv_fused_zerocopy_q(
            slab, offs.astype(jnp.float32), w_tiled, scale,
            kernel_size=kernel_size, stride=stride, dilation=dilation,
            offset_bound=offset_bound, tile_h=th, tile_w=tw, tile_c=tc,
            tile_m=tm, interpret=interpret)
        return y[:, :ho, :wo]

    ps = sspec.pspec(4)
    fn = shard_map(body, mesh=sspec.mesh,
                   in_specs=(ps, ps, P(None, None, None), P(None, None)),
                   out_specs=ps, check_rep=False)
    return fn(xq, offsets, wq, scale).astype(x.dtype)
