from .sharding import (  # noqa: F401
    AxisRules, use_rules, current_rules, logical_constraint, logical_spec,
    DEFAULT_RULES,
)
