"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

The production meshes in this repo are (data, model) / (pod, data,
model); PP is the optional third parallelism dimension for meshes that
add a 'stage' axis (DESIGN.md §5).  Implementation is jax-native:
``shard_map`` over the stage axis + ``lax.ppermute`` to hand
activations to the next stage, with the classic GPipe schedule —
n_micro + n_stages - 1 ticks, bubble fraction (S-1)/(M+S-1).

``gpipe_forward`` is deliberately minimal (single-activation stage
functions) — it is the substrate demonstrator exercised by
``examples/pipeline_demo.py`` and its test; wiring it under the
transformer's period scan is mechanical (each period = one stage).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def gpipe_forward(stage_fn: Callable[[Any, Array], Array],
                  stage_params: Any, xs: Array, *, mesh: Mesh,
                  axis_name: str = "stage") -> Array:
    """Run microbatches through pipeline stages.

    stage_fn:     (params_of_one_stage, activation) -> activation
    stage_params: pytree with leading axis n_stages (sharded on `axis_name`)
    xs:           (n_micro, ...) microbatch activations fed to stage 0
    returns:      (n_micro, ...) outputs of the LAST stage.
    """
    n_stages = mesh.shape[axis_name]
    n_micro = xs.shape[0]
    assert n_micro >= 1

    def per_stage(params_local, xs_local):
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis_name)
        ticks = n_micro + n_stages - 1

        def tick(t, state):
            carry, ys = state
            # stage 0 consumes a fresh microbatch; others take the carry
            feed = xs_local[jnp.minimum(t, n_micro - 1)]
            inp = jnp.where(stage == 0, feed, carry)
            out = stage_fn(params_local, inp)
            # the last stage finishes microbatch t-(S-1) at tick t
            idx = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (idx >= 0)
            ys = jnp.where(
                write,
                jax.lax.dynamic_update_index_in_dim(
                    ys, out, jnp.maximum(idx, 0), 0),
                ys)
            # hand the activation to the next stage (non-cyclic shift)
            carry = jax.lax.ppermute(
                out, axis_name,
                [(i, i + 1) for i in range(n_stages - 1)])
            return carry, ys

        carry0 = jnp.zeros_like(xs_local[0])
        ys0 = jnp.zeros_like(xs_local)
        _, ys = jax.lax.fori_loop(0, ticks, tick, (carry0, ys0))
        return ys[None]          # (1, n_micro, ...) per stage

    stacked = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(axis_name),
        check_rep=False,
    )(stage_params, xs)
    return stacked[-1]           # last stage's outputs


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: (S-1) / (M+S-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
