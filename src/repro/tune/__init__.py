"""Empirical plan autotuning (ISSUE 9): measured-time tile/cadence
search (``autotune``) + the versioned platform-keyed winner cache
(``cache``) that ``kernels.plan.resolve_tiles`` consults before the
analytic Sec. 3.2 chooser.  See docs/autotuning.md.
"""
from .autotune import measure_best_of, tune_deform_conv
from .cache import (CACHE_VERSION, DEFAULT_CACHE_PATH, TileCache,
                    TileCacheError, active_tile_cache, cache_info,
                    entry_key, install_tile_cache, load_tile_cache,
                    reset_cache_warnings, tile_cache_scope, warn_once)

__all__ = [
    "CACHE_VERSION", "DEFAULT_CACHE_PATH", "TileCache", "TileCacheError",
    "active_tile_cache", "cache_info", "entry_key", "install_tile_cache",
    "load_tile_cache", "measure_best_of", "reset_cache_warnings",
    "tile_cache_scope", "tune_deform_conv", "warn_once",
]
