"""Versioned tuned-tile cache (ISSUE 9).

The measured-time autotuner (``repro.tune.autotune``) persists its
winners here: one JSON file under ``bench-out/`` mapping a canonical
per-(op, shape, dtype/quant, cores, platform) key to the measured-best
tile geometry + dw-flush cadence.  ``kernels.plan.resolve_tiles``
consults the *installed* cache before the analytic Sec. 3.2 chooser —
so the dispatcher, the Trainer, and the serving engine's bucket plan
warming all read tuned tiles with zero call-site changes.

Resilience contract (the warn-once idiom of ``repro.resilience``): a
missing cache file is COLD (silent analytic fallback — the normal
state of a fresh checkout); a corrupt or version-incompatible file
falls back to the analytic chooser with exactly one warning per path on
the ``repro.tune`` logger.  Platform keys (``launch.platform``) keep
interpret-mode wall-time winners from ever being served under Mosaic
or the XLA reference lowering.

Installing a cache invalidates the memoized tile resolution and the
jit trace caches — ``resolve_tiles`` runs at trace time, so traces
built before the install would otherwise keep their analytic tiles.
Install before building engines/Trainers to avoid paying that
recompile.
"""
from __future__ import annotations

import contextlib
import json
import logging
import os

CACHE_VERSION = 1

# Canonical on-disk location (relative to the repo root / bench cwd) —
# what ``benchmarks/run.py --tune`` writes and CI uploads.
DEFAULT_CACHE_PATH = os.path.join("bench-out", "TUNED_tiles.json")

_log = logging.getLogger("repro.tune")

_WARNED: set = set()


class TileCacheError(RuntimeError):
    """A cache file exists but cannot be served (corrupt JSON, wrong
    schema, incompatible version)."""


def reset_cache_warnings() -> None:
    """Forget which cache paths / entries already warned (tests)."""
    _WARNED.clear()


def warn_once(key, msg: str, *args) -> None:
    """Warn exactly once per ``key`` on the ``repro.tune`` logger —
    the same warn-once idiom as ``ops``'s degradation fallback."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    _log.warning(msg, *args)


def entry_key(*, h: int, w: int, c: int, m: int, kernel_size: int = 3,
              stride: int = 1, dilation: int = 1, offset_bound: float,
              objective: str, dtype: str | None, cores: int,
              platform: str) -> str:
    """Canonical string key of one tuned entry.

    The fields are exactly the signature of
    ``kernels.plan.resolve_tiles`` plus the lowering platform — batch
    is deliberately NOT part of the key (``resolve_tiles`` never sees
    it; the tuner records its measurement batch inside the entry
    instead).  ``objective`` doubles as the op discriminator
    ("training" = the fwd+bwd deform_conv dispatch, "forward" = the
    inference/serving resolution), ``dtype`` as the quant discriminator
    (None = fp32 datapath, "int8" = quantized band).
    """
    return (f"dcl/{h}x{w}x{c}->{m}/k{kernel_size}s{stride}d{dilation}"
            f"/B{float(offset_bound):g}/{objective}/{dtype or 'fp32'}"
            f"/cores{cores}/{platform}")


class TileCache:
    """In-memory view of one versioned tuned-tile cache file.

    ``entries`` maps :func:`entry_key` strings to plain dicts — at
    minimum ``{"tiles": [th, tw, tc, tm]}``, typically also
    ``dw_flush_every_step``, ``cores``, ``recommended_cores``,
    ``measured_us``, ``analytic_us``, ``analytic_tiles``, ``batch``,
    ``reps``.  Consumers (``plan.resolve_tiles``) validate entries at
    lookup time and fall back to the analytic chooser on anything
    malformed — a stale cache can cost a warning, never a crash.
    """

    def __init__(self, entries: dict | None = None, *,
                 path: str | None = None):
        self.entries: dict[str, dict] = dict(entries or {})
        self.path = path

    def __len__(self) -> int:
        return len(self.entries)

    def lookup(self, **key_fields) -> dict | None:
        """The tuned entry for one resolution key, or None (cold)."""
        return self.entries.get(entry_key(**key_fields))

    def put(self, entry: dict, **key_fields) -> str:
        """Store ``entry`` under the canonical key; returns the key."""
        key = entry_key(**key_fields)
        self.entries[key] = dict(entry)
        return key

    def save(self, path: str | None = None) -> str:
        """Write the versioned JSON file (creating the directory)."""
        path = path or self.path or DEFAULT_CACHE_PATH
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        payload = {
            "version": CACHE_VERSION,
            "note": "measured-time autotuner winners (repro.tune) — "
                    "keys are per-(shape, objective, dtype, cores, "
                    "platform); see docs/autotuning.md",
            "entries": self.entries,
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        self.path = path
        return path

    @classmethod
    def load(cls, path: str) -> "TileCache":
        """Parse a cache file; raises :class:`TileCacheError` on corrupt
        JSON, a non-dict schema, or a version mismatch (the loader
        never guesses across versions — re-tune instead)."""
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as e:
            raise TileCacheError(
                f"tuned-tile cache {path!r} is unreadable "
                f"({type(e).__name__}: {e})") from e
        if not isinstance(payload, dict) \
                or not isinstance(payload.get("entries"), dict):
            raise TileCacheError(
                f"tuned-tile cache {path!r} has no 'entries' mapping")
        version = payload.get("version")
        if version != CACHE_VERSION:
            raise TileCacheError(
                f"tuned-tile cache {path!r} is version {version!r}; this "
                f"build reads version {CACHE_VERSION} — re-run the tuner "
                f"(benchmarks/run.py --tune)")
        return cls(payload["entries"], path=path)


# ---------------------------------------------------------------------------
# Process-global active cache (what ``plan.resolve_tiles`` consults).
# ---------------------------------------------------------------------------

_active: TileCache | None = None
_load_errors = 0


def load_tile_cache(path: str) -> TileCache | None:
    """Load a cache file with the resilience contract: a missing file
    is cold (None, silent); a corrupt/incompatible file is None with a
    single warning per path on the ``repro.tune`` logger — the caller
    falls back to the analytic chooser either way."""
    global _load_errors
    if not os.path.exists(path):
        return None
    try:
        return TileCache.load(path)
    except TileCacheError as e:
        _load_errors += 1
        warn_once(("load", os.path.abspath(path)),
                  "%s; falling back to the analytic tile chooser "
                  "(warned once per path)", e)
        return None


def install_tile_cache(cache) -> TileCache | None:
    """Install (or clear, with None) the process-global tuned cache;
    returns the previous one.  Accepts a :class:`TileCache` or a path
    (loaded via :func:`load_tile_cache` — corrupt files install None).

    Installing invalidates ``plan.resolve_tiles``'s memoization and the
    jit trace caches: tile resolution happens at trace time, so traces
    built against the previous cache would otherwise survive the
    switch.
    """
    global _active
    if isinstance(cache, (str, os.PathLike)):
        cache = load_tile_cache(os.fspath(cache))
    prev, _active = _active, cache
    try:
        from repro.kernels.plan import resolve_tiles
        resolve_tiles.cache_clear()
    except Exception:  # noqa: BLE001
        pass
    try:
        import jax
        if hasattr(jax, "clear_caches"):
            jax.clear_caches()
    except Exception:  # noqa: BLE001
        pass
    return prev


def active_tile_cache() -> TileCache | None:
    """The installed tuned-tile cache (None when cold/analytic)."""
    return _active


@contextlib.contextmanager
def tile_cache_scope(cache):
    """Scoped :func:`install_tile_cache` with guaranteed restore."""
    prev = install_tile_cache(cache)
    try:
        yield
    finally:
        install_tile_cache(prev)


def cache_info() -> dict:
    """Status of the installed cache — merged into
    ``plan.tile_cache_info`` (and from there the serving engine's
    telemetry) so a cold or corrupt cache is visible, not silent."""
    return {
        "installed": _active is not None,
        "entries": len(_active) if _active is not None else 0,
        "path": getattr(_active, "path", None),
        "load_errors": _load_errors,
    }
