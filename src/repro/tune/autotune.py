"""Measured-time autotuner for the DCL kernel plans (ISSUE 9).

The analytic Sec. 3.2 chooser minimizes *modeled* HBM traffic — and
PR 8's divergence tracker proves the model mispicks (the 128c Megacore
backward: modeled ~1.9x per-core traffic win, measured wall-time
slower).  This module closes the co-design loop the way Huang et al.
and CoDeNet do on FPGAs: enumerate the VMEM-feasible plan candidates
around the analytic pick (``core.tiling.neighbor_kernel_tiles``),
*measure* each by best-of-N wall time through the obs
``DispatchRecorder`` seam, and persist the winners in the versioned
platform-keyed cache of ``repro.tune.cache`` that
``kernels.plan.resolve_tiles`` consults.

The search space per (shape, objective, dtype) config:

* tile geometry — the seed (analytic pick) plus its ladder neighbors,
  capped to ``max_candidates`` by even-stride sampling of the
  modeled-traffic ordering (the seed is never dropped);
* ``cores`` — each value in ``sweep_cores`` is tuned *independently*
  and gets its own cache entry (a ``resolve_tiles(cores=2)`` lookup
  must never be served tiles measured at cores=1), with the
  cross-cores winner recorded as ``recommended_cores``;
* ``dw_flush_every_step`` — the backward d_weights flush cadence, swept
  on the winning tiles only (both cadences are bit-exact —
  ``tests/test_deform_conv_grad.py`` parity — so this is a pure perf
  knob).

Measurement notes: candidates are timed with EXPLICIT tiles under
``tile_cache_scope(None)``, so an installed tuned cache can never
contaminate its own baseline; the jitted workload is invoked through a
private ``DispatchRecorder`` instance directly (NOT via
``ops.set_dispatch_hook`` — inside ``jax.jit`` the dispatch hook fires
at trace time only), with a compile/warm-up call excluded from timing.
On this container (CPU, Pallas interpret mode) wall time scales with
grid-step count rather than modeled bytes — which is exactly why the
measured tuner beats the traffic model here.
"""
from __future__ import annotations

import time

from .cache import DEFAULT_CACHE_PATH, TileCache, _log, tile_cache_scope


def measure_best_of(fn, args, *, context: dict, reps: int = 3) -> float:
    """Best-of-``reps`` wall seconds of ``fn(*args)``, timed through the
    obs ``DispatchRecorder`` seam (private registry/tracker — nothing
    leaks into the process-global metrics), with one untimed warm-up
    call absorbing compilation.

    ``context`` is an ``ops``-style dispatch-hook context dict (op,
    shape, cores, ...) — it keys the recorder's divergence row and
    makes tuner timings aggregate exactly like production dispatches.
    """
    import jax
    from repro.obs import DispatchRecorder, DivergenceTracker, MetricsRegistry

    tracker = DivergenceTracker()
    rec = DispatchRecorder(registry=MetricsRegistry(), tracker=tracker,
                           clock=time.perf_counter, block=True)
    jax.block_until_ready(fn(*args))        # warm-up: compile untimed
    for _ in range(max(1, int(reps))):
        finish = rec(context)
        finish(out=fn(*args))
    rows = tracker.report()["dispatches"]
    return min(r["best_s"] for r in rows)


def _traffic_key(shape, kt, *, batch, dilation, objective, cores):
    """Modeled whole-layer HBM bytes of one candidate — the ordering
    used when sampling an oversized candidate list down to
    ``max_candidates`` (measurement stays the decider)."""
    from repro.core.tiling import (TileConfig, dcl_total_hbm_bytes,
                                   dcl_train_hbm_bytes)
    t = TileConfig(t_h=kt.tile_h, t_w=kt.tile_w, t_n=kt.tile_c,
                   t_m=kt.tile_m)
    if objective == "training":
        return dcl_train_hbm_bytes(shape, t, batch=batch,
                                   dilation=dilation, cores=cores)
    return dcl_total_hbm_bytes(shape, t, batch=batch, dilation=dilation)


def _cap_candidates(cands, max_candidates, traffic):
    """Seed-preserving even-stride sample of the traffic-sorted list."""
    if max_candidates is None or len(cands) <= max_candidates:
        return cands
    k = max(0, int(max_candidates) - 1)
    rest = sorted(cands[1:], key=traffic)
    if k == 0:
        return cands[:1]
    idxs = sorted({round(i * (len(rest) - 1) / max(k - 1, 1))
                   for i in range(k)})
    return [cands[0]] + [rest[i] for i in idxs]


def _tune_single(*, h: int, w: int, c: int, m: int, batch: int = 1,
                 kernel_size: int = 3, stride: int = 1,
                 dilation: int = 1, offset_bound: float = 2.0,
                 objective: str = "training",
                 dtype: str | None = None,
                 cores: int = 1,
                 sweep_cores: tuple | None = None,
                 reps: int = 3,
                 max_candidates: int | None = 12,
                 cache: TileCache | None = None,
                 rng_seed: int = 0) -> dict:
    """Tune ONE (shape, objective, dtype) config — the measurement body
    of :func:`tune_deform_conv`, which sweeps quant modes over it."""
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro.core.tiling import LayerShape, choose_kernel_tiles, \
        neighbor_kernel_tiles
    from repro.kernels import ops
    from repro.launch.platform import current_platform

    if objective not in ("forward", "training"):
        raise ValueError(f"unknown objective {objective!r}")
    if dtype in ("int8", "int8_chain") and objective == "training":
        raise ValueError(f"dtype={dtype!r} tunes the inference datapath "
                         f"— use objective='forward'")
    sweep = tuple(sweep_cores) if sweep_cores else (cores,)
    if cores not in sweep:
        sweep = (cores,) + sweep
    plat = current_platform()
    precision = "int8" if dtype == "int8" else "fp32"
    # The analytic chooser only knows element widths (chain bands are
    # int8); the tuned-cache entries keep the full "int8_chain" key.
    chooser_dtype = "int8" if dtype == "int8_chain" else dtype
    shape = LayerShape(h=h, w=w, c_in=c, c_out=m, kernel_size=kernel_size,
                       stride=stride, offset_bound=offset_bound)

    key = jax.random.PRNGKey(rng_seed)
    kx, ko, kw = jax.random.split(key, 3)
    k2 = kernel_size * kernel_size
    from repro.core.tiling import out_hw
    ho, wo = out_hw(h, w, kernel_size=kernel_size, stride=stride,
                    dilation=dilation)
    x = jax.random.normal(kx, (batch, h, w, c), jnp.float32)
    offs = offset_bound * jax.random.uniform(
        ko, (batch, ho, wo, 2 * k2), jnp.float32, -1.0, 1.0)
    wgt = jax.random.normal(kw, (k2, c, m), jnp.float32) * 0.1

    if dtype == "int8_chain":
        # Chained workload: the offset conv is fused in-kernel, so the
        # measured dispatch takes the offset-conv weights, not offsets.
        from repro.quant.qtypes import compute_scale
        w_off = jax.random.normal(ko, (k2, c, 2 * k2), jnp.float32) * 0.05
        b_off = jnp.zeros((2 * k2,), jnp.float32)
        x_scale = compute_scale(x)
        meas_args = (x, wgt, w_off)

        def workload(kt, co, dwf):
            def fwd(xx, ww, wo_):
                return ops.deform_conv_chain(
                    xx, ww, wo_, b_off, kernel_size=kernel_size,
                    stride=stride, dilation=dilation,
                    offset_bound=offset_bound, x_scale=x_scale,
                    tile_h=kt.tile_h, tile_w=kt.tile_w, tile_c=kt.tile_c,
                    tile_m=kt.tile_m, emit="fp32")
            return jax.jit(fwd)
    else:
        meas_args = (x, offs, wgt)

        def workload(kt, co, dwf):
            """Jitted measurement target at EXPLICIT tiles (bypasses both
            the memoized resolver and any installed tuned cache)."""
            def fwd(xx, oo, ww):
                return ops.deform_conv(
                    xx, oo, ww, kernel_size=kernel_size, stride=stride,
                    dilation=dilation, offset_bound=offset_bound,
                    tile_h=kt.tile_h, tile_w=kt.tile_w, tile_c=kt.tile_c,
                    tile_m=kt.tile_m, precision=precision,
                    cores=co if objective == "training" else 1,
                    dw_flush_every_step=dwf if objective == "training"
                    else None)
            if objective == "training":
                return jax.jit(jax.grad(
                    lambda xx, oo, ww: jnp.sum(fwd(xx, oo, ww)),
                    argnums=(0, 1, 2)))
            return jax.jit(fwd)

    def ctx(kt, co):
        return dict(op="deform_conv", precision=dtype or "fp32",
                    dataflow="zero_copy", shape=tuple(x.shape),
                    offset_bound=offset_bound, kernel_size=kernel_size,
                    stride=stride, dilation=dilation, m=m, cores=co,
                    platform=plat, tiles=(kt.tile_h, kt.tile_w,
                                          kt.tile_c, kt.tile_m))

    def _pin_chain(cands):
        """Chaining pins tile_c = C (the fused offset stage needs the
        full channel extent staged per band) — collapse the candidate
        list onto that plane, seed first, order preserved."""
        out = []
        for kt in cands:
            kt = _dc.replace(kt, tile_c=c)
            if kt not in out:
                out.append(kt)
        return out

    analytic_kt = choose_kernel_tiles(
        shape, batch=batch, dilation=dilation, objective=objective,
        dtype=chooser_dtype, cores=cores)
    if dtype == "int8_chain":
        analytic_kt = _dc.replace(analytic_kt, tile_c=c)
    per_cores: dict[str, dict] = {}
    analytic_us = None
    n_measured = 0

    with tile_cache_scope(None):        # baseline must stay analytic
        for co in sweep:
            if objective == "training" and batch % co != 0:
                _log.info("tune: skipping cores=%d (does not divide "
                          "batch N=%d)", co, batch)
                continue
            seed_kt = choose_kernel_tiles(
                shape, batch=batch, dilation=dilation, objective=objective,
                dtype=chooser_dtype, cores=co)
            cands = neighbor_kernel_tiles(
                shape, seed_kt, dilation=dilation, objective=objective,
                dtype=chooser_dtype)
            if dtype == "int8_chain":
                seed_kt = _dc.replace(seed_kt, tile_c=c)
                cands = _pin_chain(cands)
            cands = _cap_candidates(
                cands, max_candidates,
                lambda kt: _traffic_key(shape, kt, batch=batch,
                                        dilation=dilation,
                                        objective=objective, cores=co))
            best = None
            for kt in cands:
                try:
                    s = measure_best_of(workload(kt, co, None),
                                        meas_args,
                                        context=ctx(kt, co), reps=reps)
                except Exception as e:  # noqa: BLE001 — skip, keep tuning
                    _log.debug("tune: candidate %s at cores=%d failed "
                               "(%s: %s)", kt, co, type(e).__name__, e)
                    continue
                n_measured += 1
                if co == cores and kt == cands[0]:
                    analytic_us = s * 1e6
                if best is None or s < best[1]:
                    best = (kt, s)
            if best is None:
                continue
            kt, s = best
            # Phase 2: cadence sweep on the winning tiles (training
            # objective only — the cadence is a backward-kernel knob).
            # The kernel default (dwf=None) resolves to every-step under
            # interpret, so only the non-default needs a measurement.
            dwf = None
            if objective == "training":
                try:
                    s_alt = measure_best_of(workload(kt, co, False),
                                            meas_args,
                                            context=ctx(kt, co), reps=reps)
                    n_measured += 1
                    dwf = True if s <= s_alt else False
                    s = min(s, s_alt)
                except Exception as e:  # noqa: BLE001
                    _log.debug("tune: cadence sweep failed at cores=%d "
                               "(%s: %s)", co, type(e).__name__, e)
            per_cores[str(co)] = {
                "tiles": [kt.tile_h, kt.tile_w, kt.tile_c, kt.tile_m],
                "us": s * 1e6,
                "dw_flush_every_step": dwf,
                "analytic_tiles": [seed_kt.tile_h, seed_kt.tile_w,
                                   seed_kt.tile_c, seed_kt.tile_m],
            }

    if not per_cores or analytic_us is None:
        raise RuntimeError(
            f"autotuner measured no viable candidate for "
            f"{h}x{w}x{c}->{m} (objective={objective!r}, sweep={sweep})")

    best_co = min(per_cores, key=lambda k: per_cores[k]["us"])
    best = per_cores[best_co]
    result = {
        "op": "deform_conv", "h": h, "w": w, "c": c, "m": m,
        "batch": batch, "kernel_size": kernel_size, "stride": stride,
        "dilation": dilation, "offset_bound": offset_bound,
        "objective": objective, "dtype": dtype, "platform": plat,
        "reps": reps, "n_candidates": n_measured,
        "analytic": {
            "tiles": [analytic_kt.tile_h, analytic_kt.tile_w,
                      analytic_kt.tile_c, analytic_kt.tile_m],
            "cores": cores, "us": analytic_us,
        },
        "best": {
            "tiles": best["tiles"], "cores": int(best_co),
            "dw_flush_every_step": best["dw_flush_every_step"],
            "us": best["us"],
        },
        "tuned_vs_analytic_ratio": (analytic_us / best["us"]
                                    if best["us"] else float("inf")),
        "per_cores": per_cores,
    }

    if cache is not None:
        for co_str, rec in per_cores.items():
            cache.put(
                {"tiles": rec["tiles"],
                 "dw_flush_every_step": rec["dw_flush_every_step"],
                 "cores": int(co_str),
                 "recommended_cores": int(best_co),
                 "measured_us": rec["us"],
                 "analytic_us": analytic_us,
                 "analytic_tiles": rec["analytic_tiles"],
                 "batch": batch, "reps": reps, "op": "deform_conv"},
                h=h, w=w, c=c, m=m, kernel_size=kernel_size,
                stride=stride, dilation=dilation,
                offset_bound=offset_bound, objective=objective,
                dtype=dtype, cores=int(co_str), platform=plat)
    return result


_QUANT_MODES = (None, "int8", "int8_chain")


def tune_deform_conv(*, h: int, w: int, c: int, m: int, batch: int = 1,
                     kernel_size: int = 3, stride: int = 1,
                     dilation: int = 1, offset_bound: float = 2.0,
                     objective: str = "training",
                     dtype: str | None = None,
                     sweep_quant: tuple | None = None,
                     cores: int = 1,
                     sweep_cores: tuple | None = None,
                     reps: int = 3,
                     max_candidates: int | None = 12,
                     cache: TileCache | None = None,
                     rng_seed: int = 0) -> dict:
    """Tune one deform_conv shape; returns the result record for
    ``dtype`` and (when ``cache`` is given) writes one entry per swept
    (quant mode, cores) pair.

    ``objective="training"`` measures the jitted fwd+bwd pullback
    (``jax.grad`` through the custom-VJP zero-copy backward — the
    Trainer's workload); ``"forward"`` the jitted inference dispatch
    (the serving engine's).  ``cores`` is the value the *analytic*
    dispatch would use (the baseline); ``sweep_cores`` (default
    ``(cores,)``) expands the search.

    Quant sweep (ISSUE 10 satellite): for ``objective="forward"`` the
    tuner sweeps the serving quant modes **by default** — ``dtype``
    plus ``"int8"`` and ``"int8_chain"`` (the chained mode measures the
    fused-offset ``ops.deform_conv_chain`` dispatch with ``tile_c``
    pinned to C) — and persists each winner under its OWN quant-keyed
    cache entry, so the serving ladder's int8 rungs resolve tuned
    plans, not plans measured on the fp32 kernel.  Pass
    ``sweep_quant=(dtype,)`` to tune a single mode, or any subset of
    ``(None, "int8", "int8_chain")``.  Training always tunes fp32 only
    (the quantized datapaths are inference).  The sweep's extra records
    ride along under ``result["quant_sweep"]``.
    """
    if objective not in ("forward", "training"):
        raise ValueError(f"unknown objective {objective!r}")
    if sweep_quant is None:
        sweep_quant = (dtype, "int8", "int8_chain") \
            if objective == "forward" else (dtype,)
    modes: list = []
    for dt in (dtype, *sweep_quant):
        if dt not in _QUANT_MODES:
            raise ValueError(
                f"unknown quant mode {dt!r} in sweep_quant; expected a "
                f"subset of {_QUANT_MODES}")
        if dt not in modes:
            modes.append(dt)

    kw = dict(h=h, w=w, c=c, m=m, batch=batch, kernel_size=kernel_size,
              stride=stride, dilation=dilation, offset_bound=offset_bound,
              objective=objective, cores=cores, sweep_cores=sweep_cores,
              reps=reps, max_candidates=max_candidates, cache=cache,
              rng_seed=rng_seed)
    results: dict[str, dict] = {}
    for dt in modes:
        results[dt or "fp32"] = _tune_single(dtype=dt, **kw)
    primary = results[dtype or "fp32"]
    extras = {k: v for k, v in results.items() if v is not primary}
    if extras:
        primary["quant_sweep"] = extras
    return primary
