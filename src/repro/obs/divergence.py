"""Modeled-vs-measured divergence reporting (ISSUE 8).

The repo's primary perf signal is the analytic HBM-traffic model of
``core/tiling`` — but the paper's own argument is that deformable
convolution's access pattern resists static analysis, and
``BENCH_kernels.json`` already shows the model diverging from wall
time (the 128-channel Megacore backward: modeled 1.92x per-core
traffic drop, measured *slower*).  This module pairs every
instrumented bounded-kernel dispatch with its modeled bytes and
aggregates the comparison per ``(op, shape, dtype, cores, quant)``
key — the measurement substrate the ROADMAP's measured-time autotuner
will consume.

* :func:`modeled_dispatch_bytes` prices one dispatch from its hook
  context via the memoized tile chooser + the Eq. 6/7 traffic model.
* :class:`DivergenceTracker` aggregates measured seconds against the
  model and emits the report (per-key rows + explicit named
  modeled-vs-measured ratio pairs, e.g. the 128c Megacore case).
* :class:`DispatchRecorder` is an ``ops.set_dispatch_hook`` hook that
  times each dispatch (span + histogram + counter) and feeds the
  tracker; it chains to a previously installed hook (the chaos
  harness) so instrumentation composes with fault injection.
"""
from __future__ import annotations

import dataclasses
import time

from . import metrics as _metrics
from . import trace as _trace

__all__ = ["DispatchKey", "DispatchRecorder", "DivergenceTracker",
           "modeled_dispatch_bytes"]


@dataclasses.dataclass(frozen=True)
class DispatchKey:
    """Aggregation key of one bounded-kernel dispatch population."""
    op: str
    shape: tuple            # (N, H, W, C) of the dispatched input
    dtype: str              # band element dtype: fp32 | int8
    cores: int
    quant: str              # none | int8 | int8_chain

    def label(self) -> str:
        n, h, w, c = self.shape
        return (f"{self.op}[{n}x{h}x{w}x{c}]"
                f"/{self.quant}/cores={self.cores}")


def key_from_context(context: dict) -> DispatchKey | None:
    """Build the aggregation key from an ``ops`` dispatch-hook context
    dict; None when the context predates the ISSUE-8 fields."""
    op = context.get("op")
    shape = context.get("shape")
    if op is None or shape is None or len(shape) != 4:
        return None
    if op == "deform_conv_chain":
        dtype, quant = "int8", "int8_chain"
    else:
        precision = context.get("precision", "fp32")
        dtype = "int8" if precision == "int8" else "fp32"
        quant = "int8" if precision == "int8" else "none"
    return DispatchKey(op=op, shape=tuple(int(s) for s in shape),
                       dtype=dtype, cores=int(context.get("cores", 1)),
                       quant=quant)


def modeled_dispatch_bytes(context: dict) -> int | None:
    """Modeled whole-layer HBM bytes of the dispatch described by an
    ``ops`` hook context, at the tiles the dispatcher itself would
    resolve (the memoized Sec. 3.2 chooser).  None when the context
    lacks the geometry fields or the model cannot price the call —
    observability never raises into the dispatch path.
    """
    try:
        from repro.core.tiling import (LayerShape, TileConfig,
                                       dcl_chain_hbm_bytes,
                                       dcl_total_hbm_bytes)
        from repro.kernels.plan import resolve_tiles

        op = context["op"]
        n, h, w, c = context["shape"]
        m = context["m"]
        ks = context.get("kernel_size", 3)
        stride = context.get("stride", 1)
        dilation = context.get("dilation", 1)
        bound = context["offset_bound"]
        shape = LayerShape(h=h, w=w, c_in=c, c_out=m, kernel_size=ks,
                           stride=stride, offset_bound=bound)
        if op == "deform_conv_chain":
            th, tw, tc, tm = resolve_tiles(
                h, w, c, m, kernel_size=ks, stride=stride,
                dilation=dilation, offset_bound=bound, tile_h=None,
                tile_w=None, tile_c=c, tile_m=None,
                objective="forward", dtype="int8")
            return dcl_chain_hbm_bytes(
                shape, TileConfig(t_h=th, t_w=tw, t_n=tc, t_m=tm),
                layers=1, batch=n, dilation=dilation, chained=True)
        precision = context.get("precision", "fp32")
        if precision == "int8":
            th, tw, tc, tm = resolve_tiles(
                h, w, c, m, kernel_size=ks, stride=stride,
                dilation=dilation, offset_bound=bound, tile_h=None,
                tile_w=None, tile_c=None, tile_m=None,
                objective="forward", dtype="int8")
            return dcl_total_hbm_bytes(
                shape, TileConfig(t_h=th, t_w=tw, t_n=tc, t_m=tm),
                batch=n, dilation=dilation, bytes_per_elem=1,
                offset_bytes_per_elem=4, out_bytes_per_elem=4)
        th, tw, tc, tm = resolve_tiles(
            h, w, c, m, kernel_size=ks, stride=stride,
            dilation=dilation, offset_bound=bound, tile_h=None,
            tile_w=None, tile_c=None, tile_m=None,
            objective="training", cores=context.get("cores", 1))
        return dcl_total_hbm_bytes(
            shape, TileConfig(t_h=th, t_w=tw, t_n=tc, t_m=tm),
            batch=n, dilation=dilation, bytes_per_elem=4)
    except Exception:  # noqa: BLE001 — pricing failure is not a fault
        return None


class DivergenceTracker:
    """Aggregate (modeled bytes, measured seconds) per dispatch key and
    emit the divergence report.

    Two record families:

    * per-key aggregates from :meth:`observe` — n, best/mean measured
      seconds, modeled bytes, the implied bandwidth each dispatch
      would need for the model to explain the wall time;
    * named ratio pairs from :meth:`record_pair` — a modeled
      improvement ratio vs the measured one for a specific comparison
      (the bench uses this for zero-copy-vs-banded and the known-bad
      128c Megacore backward case), flagged ``anomalous`` when the
      model predicts a win the measurement inverts.
    """

    def __init__(self):
        self._agg: dict[DispatchKey, dict] = {}
        self.pairs: list[dict] = []

    def observe(self, key: DispatchKey, modeled_bytes: int | None,
                measured_s: float) -> None:
        a = self._agg.get(key)
        if a is None:
            a = self._agg[key] = {
                "n": 0, "sum_s": 0.0, "min_s": float("inf"),
                "modeled_bytes": modeled_bytes}
        a["n"] += 1
        a["sum_s"] += measured_s
        a["min_s"] = min(a["min_s"], measured_s)
        if a["modeled_bytes"] is None:
            a["modeled_bytes"] = modeled_bytes

    def record_pair(self, name: str, *, modeled_ratio: float,
                    measured_ratio: float, note: str = "") -> dict:
        rec = {
            "name": name,
            "modeled_ratio": modeled_ratio,
            "measured_ratio": measured_ratio,
            "divergence": (modeled_ratio / measured_ratio
                           if measured_ratio else float("inf")),
            # the model claims an improvement the measurement inverts —
            # the 128c Megacore backward signature
            "anomalous": bool(modeled_ratio > 1.0 > measured_ratio),
        }
        if note:
            rec["note"] = note
        self.pairs.append(rec)
        return rec

    def annotate_pair(self, name: str, **fields) -> dict | None:
        """Attach post-hoc fields to the recorded pair ``name`` (the
        last one, if re-recorded) and return it; None when no such
        pair exists.

        ISSUE 9: the autotuner re-measures anomalous pairs after tuning
        and writes ``measured_ratio_post_tuning`` /
        ``anomalous_post_tuning`` / ``tuned_note`` here, so the
        divergence report shows before/after measured ratios instead of
        a stale anomaly flag.
        """
        for rec in reversed(self.pairs):
            if rec.get("name") == name:
                rec.update(fields)
                return rec
        return None

    def report(self) -> dict:
        rows = []
        for key, a in self._agg.items():
            mb = a["modeled_bytes"]
            best = a["min_s"]
            rows.append({
                "key": key.label(), "op": key.op,
                "shape": list(key.shape), "dtype": key.dtype,
                "cores": key.cores, "quant": key.quant,
                "n": a["n"], "modeled_bytes": mb,
                "best_s": best, "mean_s": a["sum_s"] / max(a["n"], 1),
                "implied_gbps": (mb / best / 1e9
                                 if mb and best > 0 else None),
            })
        rows.sort(key=lambda r: r["key"])
        return {"dispatches": rows, "pairs": list(self.pairs)}


class DispatchRecorder:
    """``ops`` dispatch hook: time every bounded dispatch into the
    metrics registry (``kernel_dispatch_seconds`` histogram +
    ``kernel_dispatch_total`` counter), open a ``kernel/dispatch``
    span, and feed the divergence tracker.

    The hook protocol (ISSUE 8): a dispatch hook may return a
    ``finish(out=None, error=None)`` callable, which ``ops`` invokes
    after the kernel call (success or failure) — that is where the
    measurement closes.  ``next_hook`` chains a previously installed
    hook (the chaos harness's ``dispatch_hook``) and runs FIRST, so an
    injected fault still aborts the kernel path before any timing
    starts.

    ``block=True`` calls ``jax.block_until_ready`` on the output so the
    measured time covers the dispatched computation, not just trace
    time.  ``tracer=None`` resolves the process-global tracer per call
    (so ``tracer_scope`` in tests is honored); the default tracer is
    disabled, making the span a shared no-op.
    """

    def __init__(self, *, registry: _metrics.MetricsRegistry | None = None,
                 tracer: _trace.Tracer | None = None,
                 tracker: DivergenceTracker | None = None,
                 next_hook=None, clock=time.monotonic, block: bool = True):
        self.registry = registry if registry is not None \
            else _metrics.get_registry()
        self._tracer = tracer
        self.tracker = tracker
        self.next_hook = next_hook
        self.clock = clock
        self.block = block
        self._hist = self.registry.histogram(
            "kernel_dispatch_seconds",
            "wall time of one bounded-kernel dispatch (blocked on the "
            "output)")
        self._total = self.registry.counter(
            "kernel_dispatch_total", "bounded-kernel dispatches by outcome")
        self._modeled_cache: dict[DispatchKey, int | None] = {}

    def __call__(self, context: dict):
        if self.next_hook is not None:
            self.next_hook(context)     # chaos first: a raise aborts here
        key = key_from_context(context)
        quant = key.quant if key is not None else str(
            context.get("precision", context.get("emit", "?")))
        op = str(context.get("op", "?"))
        tracer = self._tracer if self._tracer is not None \
            else _trace.get_tracer()
        span = tracer.span("kernel/dispatch", op=op, quant=quant,
                           shape=context.get("shape"),
                           cores=context.get("cores", 1)).start()
        t0 = self.clock()

        def finish(out=None, error=None) -> None:
            if out is not None and self.block:
                try:
                    import jax
                    jax.block_until_ready(out)
                except Exception:  # noqa: BLE001
                    pass
            dt = self.clock() - t0
            outcome = "ok" if error is None else "error"
            span.set_attr(outcome=outcome)
            if error is not None:
                span.set_attr(error=f"{type(error).__name__}: {error}")
            span.end()
            self._hist.observe(dt, op=op, quant=quant)
            self._total.inc(op=op, quant=quant, outcome=outcome)
            if self.tracker is not None and key is not None \
                    and error is None:
                if key not in self._modeled_cache:
                    self._modeled_cache[key] = modeled_dispatch_bytes(
                        context)
                self.tracker.observe(key, self._modeled_cache[key], dt)

        return finish
