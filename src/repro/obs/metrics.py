"""Labeled metrics registry (ISSUE 8): counters, gauges, fixed-bucket
latency histograms — one schema for the Trainer, the DCL serving
engine, and the chaos harness.

Histograms never retain samples: observations land in fixed
geometrically-spaced buckets (``DEFAULT_LATENCY_BUCKETS``, ~10 per
decade from 10 µs to ~2 min), and ``quantile()`` interpolates inside
the bucket the requested rank falls in — p50/p99 at bucket resolution
with O(buckets) memory per label set, the property the serving bench
relies on (values within one bucket width of the exact sample
quantile; asserted in ``tests/test_obs.py``).

Exports: :meth:`MetricsRegistry.snapshot` (plain-JSON dict, the CI
artifact format written by :func:`dump_telemetry`) and
:meth:`MetricsRegistry.prometheus_text` (text exposition — cumulative
``_bucket{le=...}`` samples plus ``_sum``/``_count``), with
:func:`parse_prometheus_text` closing the round-trip for tests.
"""
from __future__ import annotations

import contextlib
import json
import math
import pathlib

__all__ = ["DEFAULT_LATENCY_BUCKETS", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "dump_telemetry", "get_registry",
           "parse_prometheus_text", "registry_scope", "set_registry"]

# ~10 buckets per decade, 10 us .. ~126 s; dispatch latencies and
# request latencies both live comfortably inside this range.
DEFAULT_LATENCY_BUCKETS = tuple(
    round(10.0 ** (k / 10.0), 12) for k in range(-50, 22))


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._values: dict[tuple, float] = {}

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc by {amount})")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0)

    def items(self):
        """(label_key, value) pairs; label_key is a sorted tuple of
        (name, value) string pairs."""
        return self._values.items()


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._values: dict[tuple, float] = {}

    def set(self, value: float, **labels) -> None:
        self._values[_label_key(labels)] = value

    def inc(self, amount: float = 1, **labels) -> None:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0)

    def items(self):
        return self._values.items()


class Histogram(_Metric):
    """Fixed-bucket histogram; the implicit overflow bucket (+Inf)
    rides at the end of each counts list."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help)
        self.bounds = tuple(sorted(float(b) for b in buckets))
        if not self.bounds:
            raise ValueError(f"histogram {name} needs at least one "
                             f"finite bucket bound")
        self._counts: dict[tuple, list[int]] = {}
        self._sums: dict[tuple, float] = {}

    def _bucket_index(self, value: float) -> int:
        import bisect
        return bisect.bisect_left(self.bounds, value)

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        counts = self._counts.get(key)
        if counts is None:
            counts = self._counts[key] = [0] * (len(self.bounds) + 1)
            self._sums[key] = 0.0
        counts[self._bucket_index(value)] += 1
        self._sums[key] += value

    def count(self, **labels) -> int:
        return sum(self._counts.get(_label_key(labels), ()))

    def sum(self, **labels) -> float:
        return self._sums.get(_label_key(labels), 0.0)

    def bucket_width(self, value: float) -> float:
        """Width of the bucket ``value`` falls in — the resolution bound
        of :meth:`quantile` near that value."""
        i = self._bucket_index(value)
        if i >= len(self.bounds):
            return float("inf")
        lo = self.bounds[i - 1] if i > 0 else 0.0
        return self.bounds[i] - lo

    def quantile(self, q: float, **labels) -> float:
        """q-th quantile by linear interpolation inside the covering
        bucket (no samples retained).  Accurate to one bucket width;
        nan with no observations; the overflow bucket clamps to the
        largest finite bound."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q={q} must be in [0, 1]")
        counts = self._counts.get(_label_key(labels))
        if counts is None:
            return float("nan")
        n = sum(counts)
        if n == 0:
            return float("nan")
        rank = max(1, math.ceil(q * n))
        cum = 0
        for i, cnt in enumerate(counts):
            if cnt == 0:
                continue
            if cum + cnt >= rank:
                if i >= len(self.bounds):          # overflow bucket
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                return lo + (hi - lo) * (rank - cum) / cnt
            cum += cnt
        return self.bounds[-1]

    def items(self):
        return self._counts.items()

    def label_stats(self, key: tuple) -> dict:
        counts = self._counts[key]
        n = sum(counts)
        return {"labels": dict(key), "counts": list(counts),
                "sum": self._sums[key], "count": n,
                "p50": self.quantile(0.50, **dict(key)),
                "p99": self.quantile(0.99, **dict(key))}


class MetricsRegistry:
    """Get-or-create registry; re-requesting a name with a different
    metric kind is an error, not a silent shadow."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kwargs)
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested as {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def metrics(self):
        return self._metrics.values()

    # -- export --------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-JSON dict of every metric — the artifact format
        ``launch.obs_report`` renders and CI uploads."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in self._metrics.values():
            if isinstance(m, Histogram):
                out["histograms"][m.name] = {
                    "help": m.help, "buckets": list(m.bounds),
                    "values": [m.label_stats(k) for k in sorted(
                        m._counts)]}
            else:
                section = "counters" if isinstance(m, Counter) else "gauges"
                out[section][m.name] = {
                    "help": m.help,
                    "values": [{"labels": dict(k), "value": v}
                               for k, v in sorted(m.items())]}
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition (histograms as cumulative
        ``_bucket{le=...}`` + ``_sum`` + ``_count``)."""
        def fmt_labels(pairs) -> str:
            if not pairs:
                return ""
            body = ",".join(f'{k}="{v}"' for k, v in pairs)
            return "{" + body + "}"

        def fmt_num(v: float) -> str:
            if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
                return str(int(v))
            return repr(v)

        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key in sorted(m._counts):
                    counts = m._counts[key]
                    cum = 0
                    for bound, cnt in zip(m.bounds, counts):
                        cum += cnt
                        lines.append(
                            f"{name}_bucket"
                            f"{fmt_labels(key + (('le', repr(bound)),))}"
                            f" {cum}")
                    total = cum + counts[-1]
                    lines.append(
                        f"{name}_bucket"
                        f"{fmt_labels(key + (('le', '+Inf'),))} {total}")
                    lines.append(f"{name}_sum{fmt_labels(key)} "
                                 f"{repr(m._sums[key])}")
                    lines.append(f"{name}_count{fmt_labels(key)} {total}")
            else:
                for key, v in sorted(m.items()):
                    lines.append(f"{name}{fmt_labels(key)} {fmt_num(v)}")
        return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> dict:
    """Parse the exposition back into ``{(name, label_key): value}`` —
    the test-side half of the round-trip.  Only the subset
    :meth:`MetricsRegistry.prometheus_text` emits is supported."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        sample, value = line.rsplit(" ", 1)
        labels: tuple = ()
        if "{" in sample:
            name, rest = sample.split("{", 1)
            body = rest.rstrip("}")
            if body:
                pairs = []
                for part in body.split(","):
                    k, v = part.split("=", 1)
                    pairs.append((k, v.strip('"')))
                labels = tuple(sorted(pairs))
        else:
            name = sample
        out[(name, labels)] = float(value)
    return out


# ---------------------------------------------------------------------------
# JSON exporter — the shared telemetry sink (moved here from
# repro.resilience in ISSUE 8; resilience re-exports it for back-compat).
# ---------------------------------------------------------------------------

def _json_default(o):
    """Coerce the numpy scalars/arrays telemetry records accumulate."""
    import numpy as np
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


def dump_telemetry(path, record: dict, extra: dict | None = None,
                   *, registry: MetricsRegistry | None = None
                   ) -> pathlib.Path:
    """Write a telemetry record (plus optional ``extra`` keys) as JSON.

    The shared sink for every observability artifact — chaos-run
    injections, serving-engine per-request records, trainer health
    counters.  ``registry=`` attaches its :meth:`~MetricsRegistry.
    snapshot` under a ``"metrics"`` key, so one file carries both the
    ad-hoc record and the unified metric view.  Numpy scalars and
    arrays are coerced to plain JSON so a round-trip through
    :func:`json.loads` reproduces the record exactly.  Returns the
    written path.
    """
    rec = dict(record)
    if extra:
        rec.update(extra)
    if registry is not None:
        rec["metrics"] = registry.snapshot()
    p = pathlib.Path(path)
    p.write_text(json.dumps(rec, indent=2, default=_json_default))
    return p


# ---------------------------------------------------------------------------
# Process-global default registry.  Subsystems that need isolation (two
# serving engines in one process, each Trainer) construct their own.
# ---------------------------------------------------------------------------

_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _registry


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    global _registry
    prev, _registry = _registry, registry
    return prev


@contextlib.contextmanager
def registry_scope(registry: MetricsRegistry):
    prev = set_registry(registry)
    try:
        yield registry
    finally:
        set_registry(prev)
