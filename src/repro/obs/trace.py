"""Hierarchical span tracer (ISSUE 8) — the timing substrate every
subsystem reports through.

A :class:`Tracer` hands out :class:`Span` context managers; spans nest
via an explicit stack (the enclosing open span becomes the parent), so
a trace of ``train/step`` > ``train/compute`` > ``kernel/dispatch``
reconstructs the call tree without any thread-local magic.  The clock
is injectable (``clock=``, monotonic by default) so tests drive spans
on a fake clock and assert exact durations.

Disabled tracers are zero-cost: ``span()`` returns one shared no-op
singleton (no allocation, no clock read, nothing retained), which is
what lets the dispatcher and serving engine stay instrumented
unconditionally — the default process-global tracer is disabled.

Exports: JSONL (one record per span/event, the CI artifact format) and
Chrome trace-event JSON (``ph: "X"`` duration + ``ph: "i"`` instant
events, microsecond timestamps — loadable in Perfetto / chrome://tracing).
"""
from __future__ import annotations

import contextlib
import itertools
import json
import pathlib
import time

__all__ = ["NOOP_SPAN", "Span", "Tracer", "get_tracer", "set_tracer",
           "tracer_scope"]


class Span:
    """One timed region.  Use as a context manager (``with tracer.span``)
    or drive manually: ``sp = tracer.span(...).start(); ...; sp.end()``.
    """

    __slots__ = ("_tracer", "name", "span_id", "parent_id", "t0", "t1",
                 "attrs")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = next(tracer._ids)
        self.parent_id: int | None = None
        self.t0: float | None = None
        self.t1: float | None = None

    def set_attr(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def start(self) -> "Span":
        tr = self._tracer
        self.parent_id = tr._stack[-1].span_id if tr._stack else None
        tr._stack.append(self)
        self.t0 = tr.clock()
        return self

    def end(self) -> None:
        if self.t1 is not None or self.t0 is None:
            return                       # never started / already ended
        tr = self._tracer
        self.t1 = tr.clock()
        if tr._stack and tr._stack[-1] is self:
            tr._stack.pop()
        elif self in tr._stack:          # out-of-order end: drop anyway
            tr._stack.remove(self)
        tr.spans.append(self)

    @property
    def duration(self) -> float:
        if self.t0 is None or self.t1 is None:
            return float("nan")
        return self.t1 - self.t0

    def __enter__(self) -> "Span":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.end()
        return False

    def record(self) -> dict:
        return {"type": "span", "name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "t0": self.t0, "t1": self.t1,
                "dur_s": self.duration, "attrs": self.attrs}


class _NoopSpan:
    """Shared do-nothing span — the disabled-tracer fast path.  One
    instance serves every call site; nothing is allocated or timed."""

    __slots__ = ()
    name = "noop"
    duration = float("nan")

    def set_attr(self, **attrs) -> "_NoopSpan":
        return self

    def start(self) -> "_NoopSpan":
        return self

    def end(self) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NOOP_SPAN = _NoopSpan()


class Tracer:
    """See module docstring.  ``spans`` holds finished spans in end
    order; ``events`` holds instant events in emission order."""

    def __init__(self, *, clock=time.monotonic, enabled: bool = True):
        self.clock = clock
        self.enabled = enabled
        self.spans: list[Span] = []
        self.events: list[dict] = []
        self._stack: list[Span] = []
        self._ids = itertools.count(1)

    # -- recording -----------------------------------------------------
    def span(self, name: str, **attrs):
        """A new child span of the innermost open span (entered lazily:
        the parent is resolved at ``start()``/``__enter__`` time)."""
        if not self.enabled:
            return NOOP_SPAN
        return Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        """An instant event at the current clock, parented like a span."""
        if not self.enabled:
            return
        self.events.append({
            "type": "event", "name": name, "ts": self.clock(),
            "parent_id": self._stack[-1].span_id if self._stack else None,
            "attrs": attrs})

    def clear(self) -> None:
        self.spans.clear()
        self.events.clear()
        self._stack.clear()

    # -- export --------------------------------------------------------
    def records(self) -> list[dict]:
        """All finished spans + events as plain dicts (JSONL payload)."""
        return [s.record() for s in self.spans] + list(self.events)

    def export_jsonl(self, path) -> pathlib.Path:
        p = pathlib.Path(path)
        p.write_text("".join(json.dumps(r, default=str) + "\n"
                             for r in self.records()))
        return p

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable): ``ph: "X"``
        complete events for spans, ``ph: "i"`` instants for events,
        timestamps/durations in microseconds."""
        out = []
        for s in self.spans:
            out.append({"name": s.name, "ph": "X", "pid": 0, "tid": 0,
                        "ts": (s.t0 or 0.0) * 1e6,
                        "dur": max(s.duration, 0.0) * 1e6,
                        "args": {str(k): str(v)
                                 for k, v in s.attrs.items()}})
        for e in self.events:
            out.append({"name": e["name"], "ph": "i", "s": "t",
                        "pid": 0, "tid": 0, "ts": e["ts"] * 1e6,
                        "args": {str(k): str(v)
                                 for k, v in e["attrs"].items()}})
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export_chrome(self, path) -> pathlib.Path:
        p = pathlib.Path(path)
        p.write_text(json.dumps(self.to_chrome()))
        return p


# ---------------------------------------------------------------------------
# Process-global default tracer — DISABLED until something opts in
# (tests via tracer_scope, launchers via --trace).  Instrumented code
# paths call get_tracer() at use time so a scoped tracer is honored
# even by objects constructed earlier.
# ---------------------------------------------------------------------------

_tracer = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _tracer


def set_tracer(tracer: Tracer) -> Tracer:
    """Install the process-global tracer; returns the previous one."""
    global _tracer
    prev, _tracer = _tracer, tracer
    return prev


@contextlib.contextmanager
def tracer_scope(tracer: Tracer):
    """Scoped :func:`set_tracer` with guaranteed restore."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)
