"""Unified observability subsystem (ISSUE 8).

Three pieces, one schema:

* :mod:`repro.obs.trace` — hierarchical span tracer (injectable
  monotonic clock, nested spans, per-span attributes; zero-cost no-op
  when disabled; JSONL + Chrome trace-event exports);
* :mod:`repro.obs.metrics` — labeled counters / gauges / fixed-bucket
  latency histograms (p50/p99 without sample retention; JSON snapshot
  + Prometheus text exposition; the shared :func:`dump_telemetry`
  JSON sink);
* :mod:`repro.obs.divergence` — modeled-vs-measured reporting: every
  instrumented bounded-kernel dispatch is paired with its modeled HBM
  bytes from the Eq. 6/7 traffic model, aggregated per
  (shape, dtype, cores, quant) key.

The Trainer, the DCL serving engine, the checkpoint manager, and the
chaos harness all report through here (docs/observability.md);
``launch.obs_report`` renders the CI artifacts.
"""
from .divergence import (DispatchKey, DispatchRecorder, DivergenceTracker,
                         modeled_dispatch_bytes)
from .metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, dump_telemetry, get_registry,
                      parse_prometheus_text, registry_scope, set_registry)
from .trace import (NOOP_SPAN, Span, Tracer, get_tracer, set_tracer,
                    tracer_scope)

__all__ = [
    "DEFAULT_LATENCY_BUCKETS", "Counter", "DispatchKey",
    "DispatchRecorder", "DivergenceTracker", "Gauge", "Histogram",
    "MetricsRegistry", "NOOP_SPAN", "Span", "Tracer", "dump_telemetry",
    "get_registry", "get_tracer", "modeled_dispatch_bytes",
    "parse_prometheus_text", "registry_scope", "set_registry",
    "set_tracer", "tracer_scope",
]
