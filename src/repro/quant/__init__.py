"""int8 quantized DCL datapath: QTensor primitives, PTQ calibration,
QAT wrappers.  See ``kernels/deform_conv_q.py`` for the int8 zero-copy
kernel these feed and EXPERIMENTS.md §Quantization for measured
results."""
from .qtypes import (  # noqa: F401
    QMAX, QTensor, compute_scale, fake_quant, fake_quant_absmax, quantize,
    quantize_values)
from .calibrate import (  # noqa: F401
    AbsMaxObserver, PercentileObserver, calibrate_resnet_dcn,
    load_scale_table, make_observer, save_scale_table,
    weight_channel_scales)
from .qat import (  # noqa: F401
    fake_quant_dcl_chain_reference, fake_quant_dcl_reference, qat_dcl_apply,
    qat_quantize_inputs)
