"""Post-training calibration: observers + a ResNet-DCN sweep.

A calibration run sweeps a handful of batches through the fp32 model
(``models.resnet_dcn.forward`` with its ``tap`` hook), feeds every DCL
input AND output activation into observers, and emits a *scale table*

    {block_name: {"x_scale": float,            # per-tensor activation
                  "w_scale": [float, ...],     # per-out-channel weights
                  "w_offset_scale": [...],     # per-channel offset-conv w
                  "y_scale": float}}           # per-tensor DCL output

that the int8 datapath (``ops.deform_conv(precision="int8")``), the
model-level PTQ mode (``ResNetDCNConfig.quant="int8"``), and the
chained datapath (``quant="int8_chain"`` — ``w_offset_scale`` feeds the
fused in-kernel offset conv, ``y_scale`` pins the int8 emission grid
that the next consumer reads) consume.  Two
observers are provided:

* ``absmax`` — running max of |x| (exact, outlier-sensitive);
* ``percentile`` — clips the top (100-p)% of |x| mass (a deterministic
  strided subsample keeps memory bounded), trading saturation of rare
  outliers for a finer grid over the bulk — the standard PTQ knob.

Calibration runs eagerly (the observers need concrete values); keep the
sweep to a few batches.  Weight scales are always exact per-channel
absmax — weights are static, there is nothing to observe over batches.
"""
from __future__ import annotations

import json
from typing import Any, Callable, Iterable, Mapping

import jax.numpy as jnp
import numpy as np

from .qtypes import EPS, QMAX, compute_scale

_RESERVOIR = 1 << 15     # per-update subsample cap of the percentile observer


class AbsMaxObserver:
    """Running absolute maximum -> symmetric scale."""

    def __init__(self) -> None:
        self.amax = 0.0
        self.updates = 0

    def update(self, x) -> None:
        self.amax = max(self.amax, float(jnp.max(jnp.abs(x))))
        self.updates += 1

    def scale(self) -> float:
        return max(self.amax, EPS) / QMAX


class PercentileObserver:
    """p-th percentile of |x| over the sweep -> symmetric scale.

    Keeps a deterministic strided subsample of each update (at most
    ``_RESERVOIR`` values per batch) so memory stays bounded no matter
    how many calibration batches are swept.
    """

    def __init__(self, percentile: float = 99.9) -> None:
        assert 0.0 < percentile <= 100.0, percentile
        self.percentile = percentile
        self.samples: list[np.ndarray] = []
        self.updates = 0

    def update(self, x) -> None:
        a = np.abs(np.asarray(x, np.float32)).reshape(-1)
        stride = max(1, a.size // _RESERVOIR)
        self.samples.append(a[::stride])
        self.updates += 1

    def scale(self) -> float:
        if not self.samples:
            return EPS / QMAX
        v = float(np.percentile(np.concatenate(self.samples),
                                self.percentile))
        return max(v, EPS) / QMAX


def make_observer(kind: str, *, percentile: float = 99.9):
    if kind == "absmax":
        return AbsMaxObserver()
    if kind == "percentile":
        return PercentileObserver(percentile)
    raise ValueError(f"unknown observer {kind!r}; expected 'absmax' or "
                     f"'percentile'")


def weight_channel_scales(w) -> np.ndarray:
    """Exact per-output-channel absmax scales for (..., M) weights."""
    return np.asarray(compute_scale(jnp.asarray(w), axis=-1)).reshape(-1)


def calibrate_resnet_dcn(params: Mapping[str, Any], cfg, batches: Iterable,
                         *, observer: str = "absmax",
                         percentile: float = 99.9,
                         forward: Callable | None = None) -> dict:
    """Sweep calibration batches through the fp32 model and emit the
    scale table for every DCL block.

    ``params``/``cfg`` are the ``models.resnet_dcn`` pair; ``batches``
    yields image arrays (N, H, W, 3) or dicts with an ``"images"`` key.
    The sweep always runs the fp32 reference semantics (whatever
    ``cfg.quant`` says) — calibration observes the un-quantized network.

    The model taps each DCL's input under its block name and its output
    under ``<name>/out``; the output observer becomes the block's
    ``y_scale`` (the int8 emission grid of the chained datapath), and
    the offset-conv weights get exact per-channel ``w_offset_scale``
    entries alongside the deform ``w_scale``.
    """
    import dataclasses

    from repro.models import resnet_dcn as R

    fwd = forward or R.forward
    cfg_fp = dataclasses.replace(cfg, quant="none") \
        if getattr(cfg, "quant", "none") != "none" else cfg
    obs: dict[str, Any] = {}

    def tap(name: str, x) -> None:
        if name not in obs:
            obs[name] = make_observer(observer, percentile=percentile)
        obs[name].update(x)

    n_batches = 0
    for batch in batches:
        images = batch["images"] if isinstance(batch, Mapping) else batch
        fwd(params, cfg_fp, jnp.asarray(images), tap=tap)
        n_batches += 1
    if not obs:
        raise ValueError(
            "calibration sweep saw no DCL activations — does the config "
            f"have num_dcn > 0 (got cfg={cfg})?")

    table: dict[str, dict] = {}
    for name, o in sorted(obs.items()):
        if name.endswith("/out"):
            continue                    # folded into y_scale below
        w = params[name]["dcl"]["w_deform"]
        w_off = params[name]["dcl"]["w_offset"]
        table[name] = {
            "x_scale": float(o.scale()),
            "w_scale": [float(s) for s in weight_channel_scales(w)],
            "w_offset_scale": [float(s)
                               for s in weight_channel_scales(w_off)],
        }
        if f"{name}/out" in obs:
            table[name]["y_scale"] = float(obs[f"{name}/out"].scale())
    table["_meta"] = {"observer": observer, "percentile": percentile,
                      "batches": n_batches}
    return table


def save_scale_table(table: Mapping[str, Any], path: str) -> None:
    with open(path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)


def load_scale_table(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
