"""Symmetric int8 quantization primitives for the DCL datapath.

The paper's accelerator is a fixed-point design; on TPU the equivalent
win is the int8 zero-copy dataflow of ``kernels/deform_conv_q.py``:
every VMEM byte holds 4x more of the Eq. 6 offset band than fp32, so
the same budget admits wider tiles (``tiling.choose_kernel_tiles``
``dtype="int8"``).  Following CoDeNet (Dong et al., 2020) and Xu et
al. (2021), weights and activations quantize to 8 bits while the
bilinear-interpolation *coefficients* stay fp32 — the address/fraction
path is full precision, only the sampled values and the MXU contraction
run integer.

Conventions (shared by the kernel, the fake-quant reference, and QAT):

* symmetric, zero-point-free: ``q = round(clip(x / s, -127, 127))``,
  ``x ~= q * s``.  Zero maps to 0, so zero-padding commutes with
  quantization — the bounded kernels' pre-padded halo needs no special
  casing.
* per-tensor scale for activations (one scalar per DCL input plane),
  per-output-channel scales for the deform weights (axis=-1), matching
  the fused dequant epilogue's per-M rescale.
* rounding is ``jnp.round`` (ties-to-even) everywhere, so the int8
  kernel and the fake-quant reference agree bit-for-bit wherever the
  fp32 pre-round values agree.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

QMAX = 127.0            # symmetric int8 range [-127, 127]
EPS = 1e-12


def _scale_shape(shape: tuple[int, ...], axis: int | None) -> tuple[int, ...]:
    if axis is None:
        return ()
    axis = axis % len(shape)
    return tuple(shape[i] if i == axis else 1 for i in range(len(shape)))


def compute_scale(x: Array, *, axis: int | None = None) -> Array:
    """Symmetric absmax scale: per-tensor (axis=None) or per-channel.

    Returns fp32, shaped () or broadcast-ready (1, ..., C, ..., 1).
    """
    ax = None if axis is None else axis % x.ndim
    red = tuple(i for i in range(x.ndim) if i != ax) if ax is not None \
        else None
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=red,
                   keepdims=ax is not None)
    return jnp.maximum(amax, EPS) / QMAX


def quantize_values(x: Array, scale: Array) -> Array:
    """x -> int8 values on the symmetric grid (scale broadcasts)."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    """int8 values + fp32 scale(s); ``axis`` is the per-channel axis
    (None = per-tensor).  A pytree, so it passes through jit/vmap."""
    values: Array          # int8
    scale: Array           # fp32, () or keepdims per-channel shape
    axis: int | None = None

    @property
    def shape(self) -> tuple[int, ...]:
        return self.values.shape

    def dequantize(self, dtype: Any = jnp.float32) -> Array:
        return (self.values.astype(jnp.float32) * self.scale).astype(dtype)

    def tree_flatten(self):
        return (self.values, self.scale), self.axis

    @classmethod
    def tree_unflatten(cls, axis, children):
        values, scale = children
        return cls(values=values, scale=scale, axis=axis)


def quantize(x: Array, *, axis: int | None = None,
             scale: Array | None = None) -> QTensor:
    """Quantize to a symmetric int8 ``QTensor``; ``scale`` overrides the
    absmax observer (e.g. a calibrated table entry)."""
    s = compute_scale(x, axis=axis) if scale is None \
        else jnp.asarray(scale, jnp.float32)
    if axis is not None and s.ndim == 1:
        s = s.reshape(_scale_shape(x.shape, axis))
    return QTensor(values=quantize_values(x, s), scale=s, axis=axis)


# ---------------------------------------------------------------------------
# Fake quantization (quantize-dequantize) with a straight-through estimator
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fake_quant(x: Array, scale: Array) -> Array:
    """STE fake-quant: forward quantize-dequantize onto the int8 grid,
    backward identity inside the representable range and zero outside
    (the standard QAT estimator).  ``scale`` gets a zero cotangent —
    scales are observer-driven, not learned."""
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -QMAX, QMAX)
    return (q * scale).astype(x.dtype)


def _fake_quant_fwd(x, scale):
    mask = (jnp.abs(x.astype(jnp.float32)) <= scale * QMAX)
    return fake_quant(x, scale), (mask, scale)


def _fake_quant_bwd(res, g):
    mask, scale = res
    return (g * mask.astype(g.dtype), jnp.zeros_like(scale))


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def fake_quant_absmax(x: Array, *, axis: int | None = None) -> Array:
    """Dynamic fake-quant: observe the absmax scale on the fly (stopped
    gradient) and fake-quantize.  The QAT default — no calibration table
    needed during training."""
    s = jax.lax.stop_gradient(compute_scale(x, axis=axis))
    return fake_quant(x, s)
