"""Quantization-aware training wrappers for the DCL.

QAT simulates the int8 datapath of ``kernels/deform_conv_q.py`` inside
the fp32 training graph: the deform-conv input plane and the deform
weights are fake-quantized (``qtypes.fake_quant``, STE backward) before
the convolution, while the offset-generating conv, the bilinear
coefficients, and every gradient stay fp32 — exactly the precision
split of the int8 kernel.  Because fake-quant is applied *outside*
``ops.deform_conv``, the Trainer's backward still routes through the
existing custom-VJP zero-copy kernel path untouched: STE passes the
cotangent through the quantization grid, then the fused backward kernel
does the rest.

``fake_quant_dcl_reference`` is the bit-level oracle of the int8
kernel: quantize input per-tensor and weights per-channel, sample with
fp32 bilinear coefficients, re-round the patches onto the activation
grid (the convex bilinear combination of int8 values stays in range, so
re-rounding loses no range), contract, and rescale.  Tests gate the
kernel against it to <= 1 LSB of the per-channel output scale
(``s_x * s_w[m]``).
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

from .qtypes import QMAX, compute_scale, fake_quant, fake_quant_absmax

Array = jax.Array


def qat_quantize_inputs(x: Array, w: Array, *,
                        x_scale: Array | None = None,
                        w_scale: Array | None = None
                        ) -> tuple[Array, Array]:
    """Fake-quantize one DCL's (input plane, deform weights) pair.

    x: (N, H, W, C) activation, per-tensor scale; w: (..., M) weights,
    per-output-channel scales.  Scales default to dynamic absmax
    (stop-gradient observers); calibrated values override.
    """
    if x_scale is None:
        xq = fake_quant_absmax(x)
    else:
        xq = fake_quant(x, jnp.asarray(x_scale, jnp.float32))
    if w_scale is None:
        wq = fake_quant_absmax(w, axis=-1)
    else:
        s = jnp.asarray(w_scale, jnp.float32)
        if s.ndim == 1:
            s = s.reshape((1,) * (w.ndim - 1) + (-1,))
        wq = fake_quant(w, s)
    return xq, wq


def qat_dcl_apply(params: Mapping[str, Array], x: Array, *,
                  scales: Mapping[str, Any] | None = None,
                  **dcl_kwargs):
    """Fake-quant wrapper around ``models.layers.dcl_apply``.

    Quantizes the deform-conv operands (activation per-tensor, weights
    per-channel) and delegates to the unmodified layer — both the
    pure-JAX reference and the Pallas kernel path (``use_kernel=True``)
    see the fake-quantized values, so QAT trains end-to-end through the
    custom-VJP zero-copy kernels.  ``scales`` is one calibration-table
    entry ({"x_scale": float, "w_scale": [...]}); None = dynamic absmax.
    """
    from repro.models.layers import dcl_apply

    x_scale = scales.get("x_scale") if scales else None
    w_scale = scales.get("w_scale") if scales else None
    xq, wq = qat_quantize_inputs(x, params["w_deform"],
                                 x_scale=x_scale, w_scale=w_scale)
    qparams = dict(params)
    qparams["w_deform"] = wq
    return dcl_apply(qparams, xq, **dcl_kwargs)


def fake_quant_dcl_chain_reference(x: Array, w: Array, w_offset: Array,
                                   b_offset: Array,
                                   b_deform: Array | None = None, *,
                                   kernel_size: int = 3, stride: int = 1,
                                   dilation: int = 1,
                                   offset_bound: float | None = None,
                                   x_scale=None, w_scale=None,
                                   w_offset_scale=None, y_scale=None
                                   ) -> tuple[Array, Array]:
    """STE fake-quant oracle of the chained int8 kernel — the *training*
    path of ``quant="int8_chain"`` (differentiable end-to-end).

    Simulates every quantization boundary of
    ``kernels.deform_conv_q.deform_conv_fused_zerocopy_chain`` on the
    fp32 graph with straight-through estimators:

    * input and weights fake-quantize onto their calibrated grids
      (activation per-tensor, deform/offset weights per-out-channel);
    * the offsets come from the *quantized* offset conv (the fused
      in-kernel stage) plus the fp32 bias — address generation itself
      stays fp32, matching the kernel's dequant;
    * the sampled patches re-round onto the activation grid (the
      kernel's patch requantization before its MXU step);
    * the output (bias folded first — int8 emission quantizes ``y + b``)
      fake-quantizes onto the next layer's ``y_scale`` grid when given
      (the per-channel requant epilogue; ``y_scale=None`` is the fp32
      chain tail).

    Returns ``(y, offsets)`` — offsets feed the Eq. 5 ``o_max``
    statistic, which the fused kernel never materializes.  Values agree
    with the kernel to <= 1 LSB of the emission grid (fp32 product
    rounding in the offset conv vs the kernel's exact int32
    accumulation; see ``tests/test_chain.py``).
    """
    from repro.core.deform_conv import conv2d
    from repro.kernels.ref import deform_sample_ref

    k = kernel_size
    k2 = k * k
    cin = x.shape[-1]
    cout = w.shape[-1]
    if x_scale is None:
        raise ValueError(
            "the chain reference needs x_scale: chained layers exchange "
            "values on a pinned activation grid (calibrate first)")
    sx = jnp.asarray(x_scale, jnp.float32)
    xq = fake_quant(x, sx)
    if w_scale is None:
        wq = fake_quant_absmax(w, axis=-1)
    else:
        wq = fake_quant(w, jnp.asarray(w_scale, jnp.float32)
                        .reshape(1, 1, cout))
    if w_offset_scale is None:
        woq = fake_quant_absmax(w_offset, axis=-1)
    else:
        woq = fake_quant(w_offset, jnp.asarray(w_offset_scale, jnp.float32)
                         .reshape(1, 1, 2 * k2))
    offsets = conv2d(xq, woq.reshape(k, k, cin, 2 * k2), stride=stride,
                     dilation=dilation, padding=dilation * (k // 2))
    offsets = offsets + jnp.asarray(b_offset, jnp.float32)
    patches = deform_sample_ref(
        xq, offsets, kernel_size=k, stride=stride, dilation=dilation,
        offset_bound=offset_bound)
    patches_q = fake_quant(patches, sx)
    y = jnp.einsum("nhwkc,kcm->nhwm", patches_q, wq,
                   preferred_element_type=jnp.float32)
    if b_deform is not None:
        y = y + jnp.asarray(b_deform, jnp.float32)
    if y_scale is not None:
        y = fake_quant(y, jnp.asarray(y_scale, jnp.float32))
    return y.astype(x.dtype), offsets


def fake_quant_dcl_reference(x: Array, offsets: Array, w: Array, *,
                             kernel_size: int = 3, stride: int = 1,
                             dilation: int = 1,
                             offset_bound: float | None = None,
                             x_scale: Array | None = None,
                             w_scale: Array | None = None) -> Array:
    """Pure-XLA fake-quant oracle of the int8 zero-copy kernel.

    Mirrors the kernel's arithmetic step for step in fp32 holding
    integer values: the int32 MXU accumulation is exact, and for the
    magnitudes involved (|q| <= 127, K^2*C terms) so is this fp32
    einsum — the only divergence is fp32 coefficient rounding in the
    bilinear stage, bounded well under 1 output LSB.
    """
    from repro.kernels.ref import deform_sample_ref

    sx = compute_scale(x) if x_scale is None \
        else jnp.asarray(x_scale, jnp.float32)
    sw = compute_scale(w, axis=-1) if w_scale is None \
        else jnp.asarray(w_scale, jnp.float32).reshape(1, 1, -1)
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) / sx), -QMAX, QMAX)
    wq = jnp.clip(jnp.round(w.astype(jnp.float32) / sw), -QMAX, QMAX)
    # Sample the integer-valued plane with fp32 coefficients, then
    # re-round the patches onto the activation grid (what the kernel's
    # int8 requantization before the MXU does).
    patches = deform_sample_ref(
        xq, offsets, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound)
    patches_q = jnp.round(patches)
    y = jnp.einsum("nhwkc,kcm->nhwm", patches_q, wq,
                   preferred_element_type=jnp.float32)
    return (y * sx * sw.reshape(1, 1, 1, -1)).astype(x.dtype)
