"""Optimizers: SGD+momentum (the paper's), AdamW, Adafactor (>=100B).

Minimal optax-style API: ``Optimizer(init, update)`` where
``update(grads, state, params, step) -> (new_params, new_state)``.
Optimizer state mirrors the param pytree, so the same PartitionSpecs
shard it (ZeRO-style: moments inherit the FSDP 'embed'->data sharding).

``adafactor`` keeps factored second moments for rank>=2 leaves — the
memory that lets grok-1 (316B params) train on 16 GB/chip meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Array], tuple[Any, Any]]
    # mirror of init for ShapeDtypeStruct trees (dry-run, no allocation)
    abstract_init: Callable[[Any], Any]


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def _clipped(grads, max_norm: float | None):
    if max_norm is None:
        return grads
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


# ---------------------------------------------------------------------------
# SGD + momentum (the paper trains with SGD, lr 0.005, momentum 0.9)
# ---------------------------------------------------------------------------

def sgd(lr_fn, *, momentum: float = 0.9, weight_decay: float = 0.0,
        max_norm: float | None = None) -> Optimizer:
    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def abstract_init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        grads = _clipped(grads, max_norm)
        lr = lr_fn(step)

        def upd(g, mu, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            mu = momentum * mu + g
            return (p.astype(jnp.float32) - lr * mu).astype(p.dtype), mu

        flat = jax.tree_util.tree_map(upd, grads, state["mu"], params)
        new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"mu": new_mu}

    return Optimizer("sgd", init, update, abstract_init)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr_fn, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1,
          max_norm: float | None = 1.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def abstract_init(params):
        z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        grads = _clipped(grads, max_norm)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            step_ = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                      params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda t_: t_[i], flat, is_leaf=lambda t_: isinstance(t_, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}

    return Optimizer("adamw", init, update, abstract_init)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; memory for 100B+ models)
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(lr_fn, *, decay_pow: float = 0.8, eps: float = 1e-30,
              clip_rms: float = 1.0, weight_decay: float = 0.0,
              max_norm: float | None = 1.0) -> Optimizer:
    def _state_for(p, abstract: bool):
        mk = (lambda s: jax.ShapeDtypeStruct(s, jnp.float32)) if abstract \
            else (lambda s: jnp.zeros(s, jnp.float32))
        if _factored(p.shape):
            return {"vr": mk(p.shape[:-1]), "vc": mk(p.shape[:-2] +
                                                     (p.shape[-1],))}
        return {"v": mk(p.shape)}

    def init(params):
        return {"f": jax.tree_util.tree_map(
            lambda p: _state_for(p, False), params)}

    def abstract_init(params):
        return {"f": jax.tree_util.tree_map(
            lambda p: _state_for(p, True), params)}

    def update(grads, state, params, step):
        grads = _clipped(grads, max_norm)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-decay_pow)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                         + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g / (jnp.sqrt(v) + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_rms)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        is_state = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        flat = jax.tree_util.tree_map(
            upd, grads, state["f"], params,
            is_leaf=lambda x: isinstance(x, jax.Array))
        # tree_map above maps over grads' structure; state leaves are dicts
        # aligned one-to-one, so unpack the resulting tuples.
        new_p = jax.tree_util.tree_map(
            lambda t_: t_[0], flat, is_leaf=lambda t_: isinstance(t_, tuple))
        new_s = jax.tree_util.tree_map(
            lambda t_: t_[1], flat, is_leaf=lambda t_: isinstance(t_, tuple))
        del is_state
        return new_p, {"f": new_s}

    return Optimizer("adafactor", init, update, abstract_init)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def chain_clip(opt: Optimizer) -> Optimizer:     # kept for API symmetry
    return opt


def abstract_opt_state(opt: Optimizer, abstract_params):
    return opt.abstract_init(abstract_params)


def opt_state_specs(opt: Optimizer, params_specs):
    """Optimizer-state PartitionSpecs derived from the param specs."""
    from jax.sharding import PartitionSpec as P
    if opt.name == "sgd":
        return {"mu": params_specs}
    if opt.name == "adamw":
        return {"m": params_specs, "v": params_specs}
    # adafactor: factored moments drop the last / second-to-last dim.
    def spec_for(s):
        ent = tuple(s)
        if len(ent) >= 2:
            return {"vr": P(*ent[:-1]), "vc": P(*(ent[:-2] + (ent[-1],)))}
        return {"v": P(*ent)}
    return {"f": jax.tree_util.tree_map(
        spec_for, params_specs, is_leaf=lambda x: isinstance(x, P))}


def default_optimizer_for(arch_name: str, param_count: int, lr_fn=None):
    """>=100B -> Adafactor; CNN (paper model) -> SGD(0.005, 0.9); else AdamW."""
    from .schedules import constant
    lr_fn = lr_fn or constant(1e-4)
    if arch_name.startswith("resnet50_dcn"):
        return sgd(constant(0.005), momentum=0.9, weight_decay=1e-4)
    if param_count >= 90e9:
        return adafactor(lr_fn)
    return adamw(lr_fn)
