from .optimizers import (  # noqa: F401
    Optimizer, sgd, adamw, adafactor, chain_clip, global_norm,
    abstract_opt_state, opt_state_specs, default_optimizer_for)
from .schedules import warmup_cosine, constant  # noqa: F401
