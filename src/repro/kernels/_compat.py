"""Small jax-version compatibility layer for the Pallas TPU kernels.

``pltpu.CompilerParams`` was renamed from ``pltpu.TPUCompilerParams``
across jax releases; the container pins one side of the rename.  Every
kernel routes through :func:`tpu_compiler_params` so the package works
on either spelling.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(*, dimension_semantics: tuple[str, ...], **kw):
    """Build the TPU compiler-params object for ``pl.pallas_call``."""
    return _CompilerParams(dimension_semantics=dimension_semantics, **kw)
