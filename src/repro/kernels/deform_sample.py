"""Stage-1 Pallas kernel: bounded-halo bilinear sampling (paper Fig. 4).

This is the paper's *input sampling stage* adapted to the TPU memory
hierarchy.  The whole point of the Eq. 5 regularizer is that the trained
offset bound ``B`` makes the receptive field static:

    RF = K + 2*ceil(B)                       (Eq. 4)

so every bilinear sample of an output (row, col) tile provably lies
inside a fixed input band of extent

    BAND = (T - 1)*S + (K - 1)*D + 2*(ceil(B) + 1)    (Eq. 6, per axis)

All gather irregularity is confined to VMEM, where random access costs
nothing compared to HBM.  There is no miss path — offsets are clamped to
``B`` in-kernel (the TPU-idiomatic equivalent of the paper's "provably
no cache miss"), and the input is zero-pre-padded so no validity masks
are needed inside the kernel either: bounded offsets mean every corner
index is in-band by construction.

Two dataflows are provided (dispatched by ``ops.py``):

* **zero-copy** (default) — ``deform_sample_zerocopy``: the padded
  input stays whole in ``ANY``/HBM memory space; the kernel issues
  manual ``pltpu.make_async_copy`` DMAs for each (row-tile, width-tile)
  band into a double-buffered VMEM scratch, overlapping the next band's
  fetch with the current tile's gather work.  Halo rows are re-read from
  HBM only at tile boundaries; nothing is ever duplicated in HBM, and
  the VMEM footprint is bounded by ``(band_h, band_w)`` independent of
  image size (the Eq. 6 width-band geometry).
* **banded** (legacy) — ``deform_sample_banded``: ``ops._pad_and_band``
  materializes every overlapping row band in HBM via a gather (a
  ``band_h / (tile_h*stride)`` ~ 2-3x duplication of the input) and the
  BlockSpec pipeline stages full-width bands into VMEM.  Kept as the
  parity/regression baseline; see EXPERIMENTS.md §Perf for the measured
  traffic difference.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

Array = jax.Array

N_BUFFERS = 2     # double buffering: fetch band i+1 while computing band i


def band_geometry(*, kernel_size: int, stride: int, dilation: int,
                  offset_bound: float, tile_h: int) -> tuple[int, int]:
    """(halo, band_h): halo = ceil(B)+1 rows each side (bilinear +1);
    band_h per Eq. 6 with the bilinear corner accounted.  The same
    algebra applies along width with ``tile_h`` replaced by ``tile_w``.
    Delegates to ``core.tiling.band_extent`` so the kernels and the
    traffic/VMEM models can never disagree on the geometry.
    """
    from repro.core.tiling import band_extent
    hb = int(math.ceil(offset_bound))
    band_h = band_extent(tile_h, kernel_size=kernel_size, stride=stride,
                         dilation=dilation, offset_bound=offset_bound)
    return hb, band_h


def corner_geometry(off, *, kernel_size: int, stride: int, dilation: int,
                    offset_bound: float, tile_h: int, wo: int):
    """Bilinear corner geometry for one output tile, in band-local coords.

    off: (tile_h, wo, K*K, 2) raw offsets (clamped here to the Eq. 5 bound).
    Returns (y0, x0, ty, tx): int32 top-left corner indices and fp32
    fractional coefficients, each (tile_h, wo, K*K).  Shared between the
    forward gather (``_bilinear_from_band``) and the backward kernels of
    ``deform_conv_bwd.py`` — the same bound ``B`` that keeps forward
    gathers in-band keeps backward scatters in-band, so both sides use
    one geometry.
    """
    k, s, d = kernel_size, stride, dilation
    k2 = k * k
    hb = int(math.ceil(offset_bound))       # static: offset_bound is Python

    # Positions/coefficients in fp32 (address generation is full precision
    # even on a bf16 datapath).
    off = jnp.clip(off.astype(jnp.float32), -offset_bound, offset_bound)

    # Base tap positions in band-local (pre-padded) coordinates: the band
    # starts ``hb`` rows above the first tap row, and the width axis is
    # pre-padded by (pad + hb) so the same formula applies.
    ky = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0).reshape(k2) * d
    kx = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1).reshape(k2) * d
    oy = jax.lax.iota(jnp.int32, tile_h) * s + hb
    ox = jax.lax.iota(jnp.int32, wo) * s + hb

    base_y = (oy[:, None, None] + ky[None, None, :]).astype(jnp.float32)
    base_x = (ox[None, :, None] + kx[None, None, :]).astype(jnp.float32)
    pos_y = base_y + off[..., 0]                  # (tile_h, wo, k2)
    pos_x = base_x + off[..., 1]

    y0f = jnp.floor(pos_y)
    x0f = jnp.floor(pos_x)
    ty = pos_y - y0f
    tx = pos_x - x0f
    return y0f.astype(jnp.int32), x0f.astype(jnp.int32), ty, tx


def _bilinear_from_band(band, off, *, kernel_size: int, stride: int,
                        dilation: int, offset_bound: float, tile_h: int,
                        wo: int):
    """Sample (tile_h, wo, K*K) positions from a VMEM band.

    band: (band_h, w_pad, tc) zero-padded input rows
    off:  (tile_h, wo, K*K, 2) raw offsets (clamped here)
    returns (tile_h, wo, K*K, tc) interpolated values
    """
    k2 = kernel_size * kernel_size
    band_h, w_pad, tc = band.shape
    y0, x0, ty, tx = corner_geometry(
        off, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=tile_h, wo=wo)

    flat = band.reshape(band_h * w_pad, tc)
    p = tile_h * wo * k2

    def corner(yc, xc, wgt):
        idx = (yc * w_pad + xc).reshape(p)
        v = jnp.take(flat, idx, axis=0)           # VMEM gather — in-band
        return v.astype(jnp.float32) * wgt.reshape(p, 1)

    # Values accumulate in fp32, round once.
    out = corner(y0, x0, (1 - ty) * (1 - tx))
    out += corner(y0, x0 + 1, (1 - ty) * tx)
    out += corner(y0 + 1, x0, ty * (1 - tx))
    out += corner(y0 + 1, x0 + 1, ty * tx)
    return out.reshape(tile_h, wo, k2, tc).astype(band.dtype)


def make_band_dma(x_hbm, band_ref, sem_ref, *, batch, row0, col0, c0,
                  band_h: int, band_w: int, tile_c: int, slot):
    """DMA descriptor for one (row-tile, width-tile, C-chunk) band:
    HBM -> VMEM scratch slot.  Reconstructed identically to start and to
    wait (the standard Pallas async-copy pattern)."""
    return pltpu.make_async_copy(
        x_hbm.at[batch,
                 pl.ds(row0, band_h),
                 pl.ds(col0, band_w),
                 pl.ds(c0, tile_c)],
        band_ref.at[slot],
        sem_ref.at[slot])


def _sample_zerocopy_kernel(x_hbm, off_ref, out_ref, band_ref, sem_ref, *,
                            kernel_size: int, stride: int, dilation: int,
                            offset_bound: float, tile_h: int, tile_w: int,
                            band_h: int, band_w: int, tile_c: int):
    k2 = kernel_size * kernel_size
    i = pl.program_id(0)
    j = pl.program_id(1)
    ww = pl.program_id(2)
    cc = pl.program_id(3)
    c_steps = pl.num_programs(3)

    def dma(step, slot):
        return make_band_dma(
            x_hbm, band_ref, sem_ref, batch=i,
            row0=j * (tile_h * stride), col0=ww * (tile_w * stride),
            c0=step * tile_c, band_h=band_h, band_w=band_w,
            tile_c=tile_c, slot=slot)

    # Warm-up fetch for the first C-chunk of this (row, width) tile.
    @pl.when(cc == 0)
    def _warmup():
        dma(0, 0).start()

    # Overlap: kick off the next chunk's band fetch before computing.
    @pl.when(cc + 1 < c_steps)
    def _prefetch():
        dma(cc + 1, (cc + 1) % N_BUFFERS).start()

    dma(cc, cc % N_BUFFERS).wait()

    off = off_ref[0].reshape(tile_h, tile_w, k2, 2)
    out_ref[0] = _bilinear_from_band(
        band_ref[cc % N_BUFFERS], off, kernel_size=kernel_size,
        stride=stride, dilation=dilation, offset_bound=offset_bound,
        tile_h=tile_h, wo=tile_w)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "interpret"))
def deform_sample_zerocopy(x_pad: Array, offsets: Array, *, kernel_size: int,
                           stride: int, dilation: int, offset_bound: float,
                           tile_h: int, tile_w: int,
                           tile_c: int | None = None,
                           interpret: bool = True) -> Array:
    """Run the zero-copy sampling kernel over the whole padded input.

    x_pad:   (N, Hp, Wp, C) zero-padded input, left whole in ANY/HBM
    offsets: (N, Ho, Wo, 2*K*K), Ho = h_tiles*tile_h, Wo = w_tiles*tile_w
    returns: (N, Ho, Wo, K*K, C) patches
    """
    n, hp, wp, c = x_pad.shape
    _, ho, wo, _ = offsets.shape
    assert ho % tile_h == 0 and wo % tile_w == 0, (ho, wo, tile_h, tile_w)
    h_tiles, w_tiles = ho // tile_h, wo // tile_w
    k2 = kernel_size * kernel_size
    tc = tile_c or c
    assert c % tc == 0, (c, tc)
    _, band_h = band_geometry(kernel_size=kernel_size, stride=stride,
                              dilation=dilation, offset_bound=offset_bound,
                              tile_h=tile_h)
    _, band_w = band_geometry(kernel_size=kernel_size, stride=stride,
                              dilation=dilation, offset_bound=offset_bound,
                              tile_h=tile_w)
    assert (h_tiles - 1) * tile_h * stride + band_h <= hp, "underpadded H"
    assert (w_tiles - 1) * tile_w * stride + band_w <= wp, "underpadded W"

    return pl.pallas_call(
        functools.partial(
            _sample_zerocopy_kernel, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
            tile_w=tile_w, band_h=band_h, band_w=band_w, tile_c=tc),
        grid=(n, h_tiles, w_tiles, c // tc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),      # whole padded input
            pl.BlockSpec((1, tile_h, tile_w, 2 * k2),
                         lambda i, j, ww, cc: (i, j, ww, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_h, tile_w, k2, tc),
                               lambda i, j, ww, cc: (i, j, ww, 0, cc)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, k2, c), x_pad.dtype),
        scratch_shapes=[
            pltpu.VMEM((N_BUFFERS, band_h, band_w, tc), x_pad.dtype),
            pltpu.SemaphoreType.DMA((N_BUFFERS,)),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x_pad, offsets)


# ---------------------------------------------------------------------------
# Legacy banded dataflow (HBM-materialized bands) — parity baseline
# ---------------------------------------------------------------------------

def _sample_kernel(bands_ref, off_ref, out_ref, *, kernel_size: int,
                   stride: int, dilation: int, offset_bound: float,
                   tile_h: int, wo: int):
    k2 = kernel_size * kernel_size
    off = off_ref[0].reshape(tile_h, wo, k2, 2)
    out_ref[0] = _bilinear_from_band(
        bands_ref[0, 0], off, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h, wo=wo)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_c", "interpret"))
def deform_sample_banded(bands: Array, offsets: Array, *, kernel_size: int,
                         stride: int, dilation: int, offset_bound: float,
                         tile_h: int, tile_c: int | None = None,
                         interpret: bool = True) -> Array:
    """Run the sampling kernel over pre-banded input.

    bands:   (N, n_tiles, band_h, w_pad, C) zero-padded input bands
    offsets: (N, Ho, Wo, 2*K*K) raw offset-conv output (Ho = n_tiles*tile_h)
    returns: (N, Ho, Wo, K*K, C) patches
    """
    n, n_tiles, band_h, w_pad, c = bands.shape
    _, ho, wo, _ = offsets.shape
    assert ho == n_tiles * tile_h, (ho, n_tiles, tile_h)
    k2 = kernel_size * kernel_size
    tc = tile_c or c
    assert c % tc == 0

    return pl.pallas_call(
        functools.partial(
            _sample_kernel, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
            wo=wo),
        grid=(n, n_tiles, c // tc),
        in_specs=[
            pl.BlockSpec((1, 1, band_h, w_pad, tc),
                         lambda i, j, cc: (i, j, 0, 0, cc)),
            pl.BlockSpec((1, tile_h, wo, 2 * k2),
                         lambda i, j, cc: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_h, wo, k2, tc),
                               lambda i, j, cc: (i, j, 0, 0, cc)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, k2, c), bands.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(bands, offsets)
