"""Stage-1 Pallas kernel: bounded-halo bilinear sampling (paper Fig. 4).

This is the paper's *input sampling stage* adapted to the TPU memory
hierarchy.  The whole point of the Eq. 5 regularizer is that the trained
offset bound ``B`` makes the receptive field static:

    RF = K + 2*ceil(B)                       (Eq. 4)

so every bilinear sample of an output (row, col) tile provably lies
inside a fixed input band of extent

    BAND = (T - 1)*S + (K - 1)*D + 2*(ceil(B) + 1)    (Eq. 6, per axis)

All gather irregularity is confined to VMEM, where random access costs
nothing compared to HBM.  There is no miss path — offsets are clamped to
``B`` in-kernel (the TPU-idiomatic equivalent of the paper's "provably
no cache miss"), and the input is zero-pre-padded so no validity masks
are needed inside the kernel either: bounded offsets mean every corner
index is in-band by construction.

The zero-copy kernel itself is emitted by ``band_pipeline.forward_call``
from a contraction-free ``DCLPlan`` (``tile_m=None``): the shared
double-buffered band stager + the fp32 bilinear gather, with the
patches as the output.  The geometry helpers (``band_geometry``,
``corner_geometry``, ``_bilinear_from_band``) live in ``band_pipeline``
and are re-exported here for compatibility.

``deform_sample_banded`` (legacy) consumes the HBM-materialized
overlapping bands of ``kernels.plan.pad_and_band`` through a BlockSpec
pipeline — kept as the parity/regression baseline (no in-kernel DMA, so
it does not go through the band stager).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from ._compat import tpu_compiler_params
from .band_pipeline import (  # noqa: F401  (re-exports)
    N_BUFFERS, BandSpec, DCLPlan, _bilinear_from_band, band_geometry,
    corner_geometry, forward_call, make_band_dma)

Array = jax.Array


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "interpret"))
def deform_sample_zerocopy(x_pad: Array, offsets: Array, *, kernel_size: int,
                           stride: int, dilation: int, offset_bound: float,
                           tile_h: int, tile_w: int,
                           tile_c: int | None = None,
                           interpret: bool = True) -> Array:
    """Run the zero-copy sampling kernel over the whole padded input.

    x_pad:   (N, Hp, Wp, C) zero-padded input, left whole in ANY/HBM
    offsets: (N, Ho, Wo, 2*K*K), Ho = h_tiles*tile_h, Wo = w_tiles*tile_w
    returns: (N, Ho, Wo, K*K, C) patches
    """
    c = x_pad.shape[-1]
    plan = DCLPlan(
        band=BandSpec(kernel_size=kernel_size, stride=stride,
                      dilation=dilation, offset_bound=offset_bound,
                      tile_h=tile_h, tile_w=tile_w),
        tile_c=tile_c or c, tile_m=None, band_dtype=x_pad.dtype.name)
    return forward_call(plan, x_pad, offsets, interpret=interpret)


# ---------------------------------------------------------------------------
# Legacy banded dataflow (HBM-materialized bands) — parity baseline
# ---------------------------------------------------------------------------

def _sample_kernel(bands_ref, off_ref, out_ref, *, kernel_size: int,
                   stride: int, dilation: int, offset_bound: float,
                   tile_h: int, wo: int):
    k2 = kernel_size * kernel_size
    off = off_ref[0].reshape(tile_h, wo, k2, 2)
    out_ref[0] = _bilinear_from_band(
        bands_ref[0, 0], off, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h, wo=wo)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_c", "interpret"))
def deform_sample_banded(bands: Array, offsets: Array, *, kernel_size: int,
                         stride: int, dilation: int, offset_bound: float,
                         tile_h: int, tile_c: int | None = None,
                         interpret: bool = True) -> Array:
    """Run the sampling kernel over pre-banded input.

    bands:   (N, n_tiles, band_h, w_pad, C) zero-padded input bands
    offsets: (N, Ho, Wo, 2*K*K) raw offset-conv output (Ho = n_tiles*tile_h)
    returns: (N, Ho, Wo, K*K, C) patches
    """
    n, n_tiles, band_h, w_pad, c = bands.shape
    _, ho, wo, _ = offsets.shape
    assert ho == n_tiles * tile_h, (ho, n_tiles, tile_h)
    k2 = kernel_size * kernel_size
    tc = tile_c or c
    assert c % tc == 0

    return pl.pallas_call(
        functools.partial(
            _sample_kernel, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
            wo=wo),
        grid=(n, n_tiles, c // tc),
        in_specs=[
            pl.BlockSpec((1, 1, band_h, w_pad, tc),
                         lambda i, j, cc: (i, j, 0, 0, cc)),
            pl.BlockSpec((1, tile_h, wo, 2 * k2),
                         lambda i, j, cc: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_h, wo, k2, tc),
                               lambda i, j, cc: (i, j, 0, 0, cc)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, k2, c), bands.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(bands, offsets)
