"""Stage-1 Pallas kernel: bounded-halo bilinear sampling (paper Fig. 4).

This is the paper's *input sampling stage* adapted to the TPU memory
hierarchy.  The whole point of the Eq. 5 regularizer is that the trained
offset bound ``B`` makes the receptive field static:

    RF = K + 2*ceil(B)                       (Eq. 4)

so every bilinear sample of an output row-tile provably lies inside a
fixed input band of height

    BAND_H = (T_H - 1)*S + (K - 1)*D + 2*(ceil(B) + 1)    (Eq. 6 row extent)

The band (+ halo) is staged HBM -> VMEM by the BlockSpec; *all* gather
irregularity is confined to VMEM, where random access costs nothing
compared to HBM.  There is no miss path — offsets are clamped to ``B``
in-kernel (the TPU-idiomatic equivalent of the paper's "provably no
cache miss"), and the input is zero-pre-padded so no validity masks are
needed inside the kernel either: bounded offsets mean every corner index
is in-band by construction.

Inputs are pre-padded and pre-banded by ``ops.py`` (the XLA-side
dataflow); see ``ops.deform_sample`` for the public entry point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def band_geometry(*, kernel_size: int, stride: int, dilation: int,
                  offset_bound: float, tile_h: int) -> tuple[int, int]:
    """(halo, band_h): halo = ceil(B)+1 rows each side (bilinear +1);
    band_h per Eq. 6 with the bilinear corner accounted."""
    import math
    hb = int(math.ceil(offset_bound))
    band_h = (tile_h - 1) * stride + (kernel_size - 1) * dilation \
        + 2 * hb + 2
    return hb, band_h


def _bilinear_from_band(band, off, *, kernel_size: int, stride: int,
                        dilation: int, offset_bound: float, tile_h: int,
                        wo: int):
    """Sample (tile_h, wo, K*K) positions from a VMEM band.

    band: (band_h, w_pad, tc) zero-padded input rows
    off:  (tile_h, wo, K*K, 2) raw offsets (clamped here)
    returns (tile_h, wo, K*K, tc) interpolated values
    """
    import math
    k, s, d = kernel_size, stride, dilation
    k2 = k * k
    hb = int(math.ceil(offset_bound))       # static: offset_bound is Python
    band_h, w_pad, tc = band.shape

    # Positions/coefficients in fp32 (address generation is full precision
    # even on a bf16 datapath); values accumulate in fp32, round once.
    off = jnp.clip(off.astype(jnp.float32), -offset_bound, offset_bound)

    # Base tap positions in band-local (pre-padded) coordinates: the band
    # starts ``hb`` rows above the first tap row, and the width axis is
    # pre-padded by (pad + hb) so the same formula applies.
    ky = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0).reshape(k2) * d
    kx = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1).reshape(k2) * d
    oy = jax.lax.iota(jnp.int32, tile_h) * s + hb
    ox = jax.lax.iota(jnp.int32, wo) * s + hb

    base_y = (oy[:, None, None] + ky[None, None, :]).astype(jnp.float32)
    base_x = (ox[None, :, None] + kx[None, None, :]).astype(jnp.float32)
    pos_y = base_y + off[..., 0]                  # (tile_h, wo, k2)
    pos_x = base_x + off[..., 1]

    y0f = jnp.floor(pos_y)
    x0f = jnp.floor(pos_x)
    ty = pos_y - y0f
    tx = pos_x - x0f
    y0 = y0f.astype(jnp.int32)
    x0 = x0f.astype(jnp.int32)

    flat = band.reshape(band_h * w_pad, tc)
    p = tile_h * wo * k2

    def corner(yc, xc, wgt):
        idx = (yc * w_pad + xc).reshape(p)
        v = jnp.take(flat, idx, axis=0)           # VMEM gather — in-band
        return v.astype(jnp.float32) * wgt.reshape(p, 1)

    out = corner(y0, x0, (1 - ty) * (1 - tx))
    out += corner(y0, x0 + 1, (1 - ty) * tx)
    out += corner(y0 + 1, x0, ty * (1 - tx))
    out += corner(y0 + 1, x0 + 1, ty * tx)
    return out.reshape(tile_h, wo, k2, tc).astype(band.dtype)


def _sample_kernel(bands_ref, off_ref, out_ref, *, kernel_size: int,
                   stride: int, dilation: int, offset_bound: float,
                   tile_h: int, wo: int):
    k2 = kernel_size * kernel_size
    off = off_ref[0].reshape(tile_h, wo, k2, 2)
    out_ref[0] = _bilinear_from_band(
        bands_ref[0, 0], off, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h, wo=wo)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_c", "interpret"))
def deform_sample_banded(bands: Array, offsets: Array, *, kernel_size: int,
                         stride: int, dilation: int, offset_bound: float,
                         tile_h: int, tile_c: int | None = None,
                         interpret: bool = True) -> Array:
    """Run the sampling kernel over pre-banded input.

    bands:   (N, n_tiles, band_h, w_pad, C) zero-padded input bands
    offsets: (N, Ho, Wo, 2*K*K) raw offset-conv output (Ho = n_tiles*tile_h)
    returns: (N, Ho, Wo, K*K, C) patches
    """
    n, n_tiles, band_h, w_pad, c = bands.shape
    _, ho, wo, _ = offsets.shape
    assert ho == n_tiles * tile_h, (ho, n_tiles, tile_h)
    k2 = kernel_size * kernel_size
    tc = tile_c or c
    assert c % tc == 0

    return pl.pallas_call(
        functools.partial(
            _sample_kernel, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
            wo=wo),
        grid=(n, n_tiles, c // tc),
        in_specs=[
            pl.BlockSpec((1, 1, band_h, w_pad, tc),
                         lambda i, j, cc: (i, j, 0, 0, cc)),
            pl.BlockSpec((1, tile_h, wo, 2 * k2),
                         lambda i, j, cc: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_h, wo, k2, tc),
                               lambda i, j, cc: (i, j, 0, 0, cc)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, k2, c), bands.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(bands, offsets)
