"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(``interpret=True`` on CPU, sweeping shapes/dtypes — see
``tests/test_kernels.py``).  They intentionally share code with
``repro.core.deform_conv`` — the reference DCL semantics live there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.deform_conv import DCLConfig, sample_patches

Array = jax.Array


def matmul_ref(x: Array, w: Array) -> Array:
    """fp32-accumulated matmul oracle."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def _cfg(c: int, k: int, stride: int, dilation: int) -> DCLConfig:
    return DCLConfig(in_channels=c, out_channels=1, kernel_size=k,
                     stride=stride, dilation=dilation)


def deform_sample_ref(x: Array, offsets: Array, *, kernel_size: int = 3,
                      stride: int = 1, dilation: int = 1,
                      offset_bound: float | None = None) -> Array:
    """Oracle for the stage-1 sampling kernel.

    x:       (N, H, W, C)
    offsets: (N, Ho, Wo, 2*K*K) raw offset-conv output, pairs (dy, dx)
    returns: (N, Ho, Wo, K*K, C) bilinearly interpolated patches.

    Semantics match the kernel: offsets are clamped to ``offset_bound``
    (the trained Eq. 5 bound) before sampling; samples outside the image
    contribute zero.
    """
    n, _, _, c = x.shape
    k2 = kernel_size * kernel_size
    ho, wo = offsets.shape[1], offsets.shape[2]
    off = offsets.reshape(n, ho, wo, k2, 2)
    if offset_bound is not None:
        off = jnp.clip(off, -offset_bound, offset_bound)
    return sample_patches(x, off, _cfg(c, kernel_size, stride, dilation))


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                        softcap: float | None = None) -> Array:
    """Dense oracle for the flash-attention kernel (GQA layout).

    q: (B, Sq, KV, G, Dh); k, v: (B, Sk, KV, Dh) -> (B, Sq, KV, G, Dh).
    """
    import math
    b, sq, kv, g, dh = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(dh)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def deform_conv_fused_ref(x: Array, offsets: Array, w: Array, *,
                          kernel_size: int = 3, stride: int = 1,
                          dilation: int = 1,
                          offset_bound: float | None = None) -> Array:
    """Oracle for the fused sampling + dynamic-convolution kernel.

    w: (K*K, C, M) deform weights.  Returns (N, Ho, Wo, M).
    """
    patches = deform_sample_ref(
        x, offsets, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound)
    y = jnp.einsum("nhwkc,kcm->nhwm", patches, w,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)
