"""Plan builder + dispatch bodies for the bounded DCL kernel family.

``ops.py`` is the public API surface (argument checking, mesh
resolution, the ``jax.custom_vjp`` wiring); this module is everything
between that surface and the ``band_pipeline`` emitter:

* ``DCSpec`` — the hashable static configuration of one bounded call
  (the custom-VJP ``nondiff`` argument, shared by the single-device and
  the shard_map VJPs);
* tile resolution (``resolve_tiles`` — the memoized Sec. 3.2 chooser
  bridge) and the weight blocking (``tile_weights``/``untile_weights``);
* input preparation (``pad_zerocopy`` / ``zerocopy_inputs`` — one code
  path for forward and backward so the backward's un-pad slice can
  never disagree with the forward's padded geometry; ``pad_and_band``
  for the legacy banded dataflow);
* the runners: ``bounded_forward`` / ``bounded_backward`` (fp32,
  both dataflows), ``int8_forward`` (the quantized inference datapath),
  and ``chain_forward`` (the int8 -> int8 layer-chaining datapath:
  fused offset-conv stage + per-channel requant emission).

Everything here is dataflow plumbing — the kernels themselves are
emitted by ``band_pipeline`` from ``DCLPlan``s.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.core.tiling import LayerShape, choose_kernel_tiles, out_hw
from .band_pipeline import band_geometry
from .deform_conv_bwd import deform_conv_bwd_zerocopy
from .deform_conv_fused import (deform_conv_fused_banded,
                                deform_conv_fused_zerocopy)
from .deform_conv_q import (deform_conv_fused_zerocopy_chain,
                            deform_conv_fused_zerocopy_q)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DCSpec:
    """Hashable static configuration of one bounded deform_conv call."""
    kernel_size: int
    stride: int
    dilation: int
    offset_bound: float
    tile_h: int | None
    tile_w: int | None
    tile_c: int | None
    tile_m: int | None
    dataflow: str
    interpret: bool
    cores: int = 1          # Megacore batch split of the backward grid
    # d_weights flush cadence of the backward kernel: None defers to
    # the kernel default (every-step under interpret, last-spatial-step
    # compiled) or, when a tuned cache is installed, to the measured
    # winner of the autotuner (ISSUE 9).
    dw_flush_every_step: bool | None = None


# ---------------------------------------------------------------------------
# Weight blocking
# ---------------------------------------------------------------------------

def tile_weights(w: Array, tile_c: int) -> Array:
    """(K*K, C, M) deform weights -> (C//tile_c, K*K*tile_c, M) blocks
    so the fused kernel's C-step reads one contiguous VMEM block."""
    k2, c, m = w.shape
    assert c % tile_c == 0, (c, tile_c)
    n_c = c // tile_c
    wt = w.reshape(k2, n_c, tile_c, m).transpose(1, 0, 2, 3)
    return wt.reshape(n_c, k2 * tile_c, m)


def untile_weights(wt: Array, kernel_size: int) -> Array:
    """Inverse of ``tile_weights``: (C//tc, K*K*tc, M) -> (K*K, C, M)."""
    k2 = kernel_size * kernel_size
    n_c, k2tc, m = wt.shape
    tc = k2tc // k2
    w = wt.reshape(n_c, k2, tc, m).transpose(1, 0, 2, 3)
    return w.reshape(k2, n_c * tc, m)


# ---------------------------------------------------------------------------
# Tile resolution (memoized — the chooser sweep runs once per layer shape)
#
# ISSUE 9: resolution consults the installed tuned-tile cache
# (``repro.tune``) FIRST — measured autotuner winners, keyed per
# (shape, objective, dtype, cores, platform) — and falls back to the
# analytic Sec. 3.2 chooser when the cache is cold, corrupt, or carries
# an entry incompatible with the layer.  Callers are unchanged: the
# dispatcher, Trainer, and serving engine read tuned tiles through the
# same ``resolve_tiles``/``warm_tile_cache`` they always called.
# ---------------------------------------------------------------------------

_TUNED_STATS = {"tuned_hits": 0, "analytic_resolves": 0,
                "tuned_incompatible": 0}


def reset_tuned_stats() -> None:
    """Zero the tuned-vs-analytic resolution counters (tests)."""
    for k in _TUNED_STATS:
        _TUNED_STATS[k] = 0


def _tuned_lookup(h: int, w: int, c: int, m: int, *, kernel_size: int,
                  stride: int, dilation: int, offset_bound: float,
                  objective: str, dtype: str | None,
                  cores: int) -> dict | None:
    """The installed tuned cache's entry for one resolution key, or
    None (no cache installed / key cold).  The lookup key includes the
    active lowering platform (``launch.platform``) so an interpret-mode
    wall-time winner is never served under Mosaic or the XLA reference
    lowering."""
    try:
        from repro.tune.cache import active_tile_cache
        cache = active_tile_cache()
        if cache is None:
            return None
        from repro.launch.platform import current_platform
        return cache.lookup(h=h, w=w, c=c, m=m, kernel_size=kernel_size,
                            stride=stride, dilation=dilation,
                            offset_bound=offset_bound,
                            objective=objective, dtype=dtype, cores=cores,
                            platform=current_platform())
    except Exception:  # noqa: BLE001 — a broken cache must not break dispatch
        return None


def _entry_tiles(entry: dict, c: int, m: int) -> tuple | None:
    """Validate one tuned entry against the layer it would configure:
    four positive ints whose channel tiles divide (C, M).  None on
    anything malformed or incompatible — the analytic fallback, counted
    in ``tile_cache_info``."""
    try:
        th, tw, tc, tm = (int(v) for v in entry["tiles"])
    except Exception:  # noqa: BLE001
        return None
    if min(th, tw, tc, tm) < 1 or c % tc != 0 or m % tm != 0:
        return None
    return th, tw, tc, tm


@functools.lru_cache(maxsize=256)
def resolve_tiles(h: int, w: int, c: int, m: int, *, kernel_size: int,
                  stride: int, dilation: int, offset_bound: float,
                  tile_h: int | None, tile_w: int | None,
                  tile_c: int | None, tile_m: int | None,
                  objective: str = "training",
                  dtype: str | None = None,
                  cores: int = 1
                  ) -> tuple[int, int, int, int]:
    """Fill unspecified tile sizes from the Sec. 3.2 chooser; explicit
    arguments win.  ``objective="training"`` (the ``deform_conv``
    default — the same resolved tiles serve the forward kernel and its
    custom-VJP backward) minimizes combined fwd+bwd zero-copy traffic
    under both VMEM working sets; the forward-only ``deform_sample``
    resolves with ``objective="forward"``.  ``dtype`` selects the
    element-width-aware budgets (``"int8"`` exploits the 4x band
    density of the quantized datapath); ``cores`` evaluates the
    training objective at the per-core backward traffic of the
    Megacore split.

    The installed tuned-tile cache (``repro.tune`` — measured
    autotuner winners, platform-keyed) is consulted before the analytic
    chooser; explicit tile arguments win over both, and a cold,
    corrupt, or layer-incompatible cache falls back to the chooser
    (recorded in :func:`tile_cache_info`).

    Memoized at both levels: this ``lru_cache`` keys the resolved call
    (so repeated un-jitted ``deform_conv`` calls skip even the chooser
    dispatch), and ``choose_kernel_tiles`` itself memoizes the full
    candidate sweep per layer shape (see ``tests/test_tiling.py``
    cache-hit coverage).  Installing a tuned cache or switching the
    platform clears this memo (``repro.tune.cache.install_tile_cache``
    / ``launch.platform.set_platform``).
    """
    from .ops import check_channel_tiles
    if None in (tile_h, tile_w, tile_c, tile_m):
        entry = _tuned_lookup(h, w, c, m, kernel_size=kernel_size,
                              stride=stride, dilation=dilation,
                              offset_bound=offset_bound,
                              objective=objective, dtype=dtype,
                              cores=cores)
        if entry is not None:
            tuned = _entry_tiles(entry, c, m)
            if tuned is not None:
                _TUNED_STATS["tuned_hits"] += 1
                tile_h = tile_h or tuned[0]
                tile_w = tile_w or tuned[1]
                tile_c = tile_c or tuned[2]
                tile_m = tile_m or tuned[3]
            else:
                _TUNED_STATS["tuned_incompatible"] += 1
                from repro.tune.cache import warn_once
                warn_once(
                    ("entry", h, w, c, m, objective, dtype, cores),
                    "tuned-tile cache entry for %dx%dx%d->%d is "
                    "malformed or incompatible with the layer "
                    "(tiles=%r); falling back to the analytic chooser "
                    "(warned once per key)", h, w, c, m,
                    entry.get("tiles"))
    if None in (tile_h, tile_w, tile_c, tile_m):
        _TUNED_STATS["analytic_resolves"] += 1
        shape = LayerShape(h=h, w=w, c_in=c, c_out=m,
                           kernel_size=kernel_size, stride=stride,
                           offset_bound=offset_bound)
        # The tuned cache keys "int8_chain" as its own quant entry
        # (ISSUE 10 satellite — chained winners never leak onto the
        # per-layer int8 path), but the analytic chooser's dtype-aware
        # budgets only know element widths: chain bands are int8.
        chooser_dtype = "int8" if dtype == "int8_chain" else dtype
        kt = choose_kernel_tiles(shape, dilation=dilation,
                                 objective=objective, dtype=chooser_dtype,
                                 cores=cores)
        tile_h = tile_h or kt.tile_h
        tile_w = tile_w or kt.tile_w
        tile_c = tile_c or kt.tile_c
        tile_m = tile_m or kt.tile_m
    check_channel_tiles(c, m, tile_c, tile_m)
    return tile_h, tile_w, tile_c, tile_m


def spec_tiles(spec: DCSpec, x: Array, offsets: Array,
               w: Array) -> tuple[int, int, int, int]:
    """Resolve (tile_h, tile_w, tile_c, tile_m) for one call — chooser
    defaults (combined fwd+bwd traffic), explicit spec values win, and
    spatial tiles are clamped to the output extent."""
    ho, wo = offsets.shape[1], offsets.shape[2]
    th, tw, tc, tm = resolve_tiles(
        x.shape[1], x.shape[2], x.shape[-1], w.shape[-1],
        kernel_size=spec.kernel_size, stride=spec.stride,
        dilation=spec.dilation, offset_bound=spec.offset_bound,
        tile_h=spec.tile_h, tile_w=spec.tile_w, tile_c=spec.tile_c,
        tile_m=spec.tile_m, cores=spec.cores)
    return min(th, ho), min(tw, wo), tc, tm


def _spatial_local_h(h: int, stride: int, spatial_shards: int, name: str,
                     *, kernel_size: int, dilation: int,
                     offset_bound: float) -> int:
    """Per-shard height a spatially sharded layer resolves tiles at.
    Applies the full split validation (ragged AND halo-thin) so an
    incompatible shard count fails at plan-warming time — engine init —
    not on the first sharded request."""
    if spatial_shards <= 1:
        return h
    from repro.core.tiling import spatial_halo_rows
    from repro.distributed.spatial import check_height_split
    try:
        check_height_split(
            h, shards=spatial_shards, stride=stride,
            min_rows=spatial_halo_rows(kernel_size=kernel_size,
                                       dilation=dilation,
                                       offset_bound=offset_bound))
    except ValueError as e:
        raise ValueError(f"layer {name!r}: {e}") from None
    return h // spatial_shards


def warm_tile_cache(layers, *, offset_bound: float, kernel_size: int = 3,
                    dilation: int = 1, objective: str = "forward",
                    dtype: str | None = None, cores: int = 1,
                    spatial_shards: int = 1,
                    ) -> dict[str, tuple[int, int, int, int]]:
    """Resolve (and memoize) the tile config for every named layer.

    ``layers`` maps a layer name to its dims
    ``{"h", "w", "c", "m", "stride"?}``.  This is the serving engine's
    per-bucket plan cache: each shape bucket calls it once at engine
    start, the Sec. 3.2 chooser sweep runs then (not on the first
    request), and every later ``deform_conv`` dispatch for the bucket
    hits the :func:`resolve_tiles` ``lru_cache``.  Returns
    ``{name: (tile_h, tile_w, tile_c, tile_m)}``.

    ``spatial_shards > 1`` warms the plans the ISSUE 10 spatial path
    will actually resolve: each shard sees the *local* height
    ``h // spatial_shards``, so warming at the global height would
    leave the per-shard plans cold (and the chooser sweep on the first
    request).  Raises the friendly split error per layer when a height
    does not divide.
    """
    resolved = {}
    for name, d in layers.items():
        stride = d.get("stride", 1)
        resolved[name] = resolve_tiles(
            _spatial_local_h(d["h"], stride, spatial_shards, name,
                             kernel_size=kernel_size, dilation=dilation,
                             offset_bound=offset_bound),
            d["w"], d["c"], d["m"], kernel_size=kernel_size,
            stride=stride, dilation=dilation,
            offset_bound=offset_bound, tile_h=None, tile_w=None,
            tile_c=None, tile_m=None, objective=objective, dtype=dtype,
            cores=cores)
    return resolved


def tile_source(h: int, w: int, c: int, m: int, *, kernel_size: int = 3,
                stride: int = 1, dilation: int = 1, offset_bound: float,
                objective: str = "forward", dtype: str | None = None,
                cores: int = 1, spatial_shards: int = 1) -> str:
    """Provenance of one layer's resolved tiles: ``"tuned"`` when the
    installed tuned cache would supply them (a valid platform-keyed
    entry exists), ``"analytic"`` otherwise — the serving engine
    records this per bucket plan so telemetry shows which plans came
    from the autotuner vs the Sec. 3.2 chooser.  ``spatial_shards``
    queries the per-shard (local-height) plan the spatial path uses."""
    h = _spatial_local_h(h, stride, spatial_shards, "tile_source",
                         kernel_size=kernel_size, dilation=dilation,
                         offset_bound=offset_bound)
    entry = _tuned_lookup(h, w, c, m, kernel_size=kernel_size,
                          stride=stride, dilation=dilation,
                          offset_bound=offset_bound, objective=objective,
                          dtype=dtype, cores=cores)
    if entry is not None and _entry_tiles(entry, c, m) is not None:
        return "tuned"
    return "analytic"


def tile_cache_info() -> dict:
    """Hit/miss counters of the memoized tile chooser — surfaced in the
    serving engine's telemetry so a bucket-miss storm (every request a
    fresh compile) is visible as a miss-rate spike.

    ISSUE 9 adds the tuned-cache resolution counters (``tuned_hits`` /
    ``analytic_resolves`` / ``tuned_incompatible`` — counted per
    memoization MISS, i.e. per fresh resolution) and the installed
    cache's status (``tuned_cache``: installed/entries/path/
    load_errors), so an analytic fallback — cold, corrupt, or
    incompatible — is visible, never silent."""
    ci = resolve_tiles.cache_info()
    info = {"hits": ci.hits, "misses": ci.misses, "size": ci.currsize}
    info.update(_TUNED_STATS)
    try:
        from repro.tune.cache import cache_info as _tuned_cache_info
        info["tuned_cache"] = _tuned_cache_info()
    except Exception:  # noqa: BLE001
        pass
    return info


# ---------------------------------------------------------------------------
# Input preparation
# ---------------------------------------------------------------------------

def pad_and_band(x: Array, *, kernel_size: int, stride: int, dilation: int,
                 offset_bound: float, tile_h: int,
                 ho: int) -> tuple[Array, int]:
    """Zero-pad x and slice it into overlapping row bands (legacy banded
    dataflow).

    Returns (bands, n_tiles): bands (N, n_tiles, band_h, w_pad, C).  The
    top/left zero padding of ``pad + halo`` (+1 bottom/right for the
    bilinear corner) makes every in-band corner index valid, so the
    kernel needs no masks — the bounded receptive field is the guarantee.
    """
    n, h, w, c = x.shape
    pad = dilation * (kernel_size // 2)
    hb, band_h = band_geometry(kernel_size=kernel_size, stride=stride,
                               dilation=dilation, offset_bound=offset_bound,
                               tile_h=tile_h)
    n_tiles = -(-ho // tile_h)

    p0 = pad + hb
    hp_needed = (n_tiles - 1) * tile_h * stride + band_h
    p1 = max(0, hp_needed - p0 - h)
    # Left pad aligns the kernel's band-local base (ox*S + hb); the +1 is
    # only needed on the right for the bilinear corner x0+1.
    xp = jnp.pad(x, ((0, 0), (p0, p1), (pad + hb, pad + hb + 1), (0, 0)))

    # Overlapping bands via a row gather (the halo duplication the paper
    # pays in BRAM; here it is an HBM-materialized copy produced by XLA —
    # exactly the redundant traffic the zero-copy dataflow removes).
    starts = jnp.arange(n_tiles) * (tile_h * stride)
    rows = starts[:, None] + jnp.arange(band_h)[None, :]     # (n_tiles, band_h)
    bands = jnp.take(xp, rows.reshape(-1), axis=1)
    bands = bands.reshape(n, n_tiles, band_h, xp.shape[2], c)
    return bands, n_tiles


def pad_zerocopy(x: Array, *, kernel_size: int, stride: int, dilation: int,
                 offset_bound: float, tile_h: int, tile_w: int,
                 ho: int, wo: int) -> Array:
    """Zero-pad x once for the zero-copy kernels — no band
    materialization; every (row-tile, width-tile) Eq. 6 band is a plain
    rectangular window of the result, DMA'd by the kernel itself."""
    n, h, w, c = x.shape
    pad = dilation * (kernel_size // 2)
    hb, band_h = band_geometry(kernel_size=kernel_size, stride=stride,
                               dilation=dilation, offset_bound=offset_bound,
                               tile_h=tile_h)
    _, band_w = band_geometry(kernel_size=kernel_size, stride=stride,
                              dilation=dilation, offset_bound=offset_bound,
                              tile_h=tile_w)
    h_tiles = ho // tile_h
    w_tiles = wo // tile_w
    p0 = pad + hb
    pb = max(0, (h_tiles - 1) * tile_h * stride + band_h - p0 - h)
    pr = max(0, (w_tiles - 1) * tile_w * stride + band_w - p0 - w)
    return jnp.pad(x, ((0, 0), (p0, pb), (p0, pr), (0, 0)))


def zerocopy_inputs(spec: DCSpec, x: Array, offsets: Array, w: Array,
                    th: int, tw: int, tc: int,
                    extra: Array | None = None):
    """Shared input prep of the zero-copy forward and backward kernels:
    pad offsets (and ``extra``, the backward cotangent) to tile
    multiples, zero-pad the input per ``pad_zerocopy``, and block the
    weights.  One code path so the backward's un-pad slice can never
    disagree with the forward's padded geometry."""
    ho, wo = offsets.shape[1], offsets.shape[2]
    pad_h, pad_w = (-ho) % th, (-wo) % tw
    if pad_h or pad_w:
        offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
        if extra is not None:
            extra = jnp.pad(extra, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    xp = pad_zerocopy(
        x, kernel_size=spec.kernel_size, stride=spec.stride,
        dilation=spec.dilation, offset_bound=spec.offset_bound,
        tile_h=th, tile_w=tw, ho=ho + pad_h, wo=wo + pad_w)
    w_tiled = tile_weights(w.astype(x.dtype), tc)
    if extra is not None:
        return xp, offsets, w_tiled, extra
    return xp, offsets, w_tiled


# ---------------------------------------------------------------------------
# Runners (shared by the single-device and the shard_map custom VJPs)
# ---------------------------------------------------------------------------

def reference_forward(x: Array, offsets: Array, w: Array, *,
                      kernel_size: int, stride: int, dilation: int,
                      offset_bound: float | None) -> Array:
    """Pure-XLA runner for the bounded forward — the degradation target
    of ``ops.deform_conv`` (PR 6): same Eq. 2 math and the same Eq. 5
    clamp as the zero-copy kernel, but gathers from HBM instead of
    staging bands.  Slower, never wrong — the bottom rung of the
    degradation ladder (docs/robustness.md)."""
    from .ref import deform_conv_fused_ref

    return deform_conv_fused_ref(
        x, offsets, w, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound).astype(x.dtype)


def bounded_forward(spec: DCSpec, x: Array, offsets: Array,
                    w: Array) -> Array:
    ho, wo = offsets.shape[1], offsets.shape[2]
    c, m = x.shape[-1], w.shape[-1]

    if spec.dataflow == "banded":
        th = spec.tile_h or 8
        tc = spec.tile_c or c
        pad_h = (-ho) % th
        if pad_h:
            offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
        bands, n_tiles = pad_and_band(
            x, kernel_size=spec.kernel_size, stride=spec.stride,
            dilation=spec.dilation, offset_bound=spec.offset_bound,
            tile_h=th, ho=ho + pad_h)
        w_tiles = tile_weights(w.astype(x.dtype), tc)
        y = deform_conv_fused_banded(
            bands, offsets, w_tiles, kernel_size=spec.kernel_size,
            stride=spec.stride, dilation=spec.dilation,
            offset_bound=spec.offset_bound, tile_h=th, tile_c=tc,
            tile_m=spec.tile_m, interpret=spec.interpret)
        return y[:, :ho]

    if spec.dataflow != "zero_copy":
        raise ValueError(
            f"unknown dataflow {spec.dataflow!r}; expected 'zero_copy' or "
            f"'banded'")
    th, tw, tc, tm = spec_tiles(spec, x, offsets, w)
    xp, offsets, w_tiled = zerocopy_inputs(spec, x, offsets, w, th, tw, tc)
    y = deform_conv_fused_zerocopy(
        xp, offsets, w_tiled, kernel_size=spec.kernel_size,
        stride=spec.stride, dilation=spec.dilation,
        offset_bound=spec.offset_bound, tile_h=th, tile_w=tw,
        tile_c=tc, tile_m=tm, interpret=spec.interpret)
    return y[:, :ho, :wo]


def bounded_backward(spec: DCSpec, x: Array, offsets: Array, w: Array,
                     gy: Array) -> tuple[Array, Array, Array]:
    """(d_input, d_offsets, d_weights) of one bounded call via the fused
    zero-copy backward kernel — shared by the single-device VJP and the
    per-shard body of the ``shard_map`` VJP."""
    n, h, w_, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    th, tw, tc, _ = spec_tiles(spec, x, offsets, w)
    off_dtype = offsets.dtype
    dwf = spec.dw_flush_every_step
    if dwf is None:
        # Cadence resolution mirrors the tile resolution: the tuned
        # cache's measured winner (both flush cadences are bit-exact —
        # tests/test_deform_conv_grad.py parity), else the kernel
        # default.  Explicit spec values win, as for tiles.
        entry = _tuned_lookup(
            h, w_, c, w.shape[-1], kernel_size=spec.kernel_size,
            stride=spec.stride, dilation=spec.dilation,
            offset_bound=spec.offset_bound, objective="training",
            dtype=None, cores=spec.cores)
        if entry is not None:
            v = entry.get("dw_flush_every_step")
            dwf = v if isinstance(v, bool) else None
    xp, offsets, w_tiled, gy = zerocopy_inputs(spec, x, offsets, w,
                                               th, tw, tc, extra=gy)
    dxp, doff, dwt = deform_conv_bwd_zerocopy(
        xp, offsets, gy, w_tiled, kernel_size=spec.kernel_size,
        stride=spec.stride, dilation=spec.dilation,
        offset_bound=spec.offset_bound, tile_h=th, tile_w=tw, tile_c=tc,
        cores=spec.cores, interpret=spec.interpret,
        dw_flush_every_step=dwf)
    # Un-pad: pad_zerocopy put pad+hb zero rows/cols top-left.
    p0 = spec.dilation * (spec.kernel_size // 2) \
        + int(math.ceil(spec.offset_bound))
    dx = dxp[:, p0:p0 + h, p0:p0 + w_]
    doff = doff[:, :ho, :wo]
    dw = untile_weights(dwt, spec.kernel_size)
    return (dx.astype(x.dtype), doff.astype(off_dtype),
            dw.astype(w.dtype))


def int8_forward(x: Array, offsets: Array, w: Array, *,
                 kernel_size: int, stride: int, dilation: int,
                 offset_bound: float, tile_h: int | None,
                 tile_w: int | None, tile_c: int | None,
                 tile_m: int | None, x_scale: Array | None,
                 w_scale: Array | None, interpret: bool) -> Array:
    """int8 inference datapath: quantize (symmetric, per-tensor x /
    per-out-channel w), pad the int8 plane (0 -> 0, so padding and
    quantization commute), and run the fused int8->int32 zero-copy
    kernel with its per-M dequant epilogue.  Tiles resolve against the
    dtype-aware budgets (4x band density).  Training quantized models
    goes through ``repro.quant.qat`` (fake-quant over the fp32
    custom-VJP path), not here — ``jnp.round`` has no useful gradient.
    """
    from repro.quant.qtypes import compute_scale, quantize_values

    n, h, w_, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    m = w.shape[-1]
    th, tw, tc, tm = resolve_tiles(
        h, w_, c, m, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
        tile_w=tile_w, tile_c=tile_c, tile_m=tile_m,
        objective="forward", dtype="int8")
    th, tw = min(th, ho), min(tw, wo)

    sx = compute_scale(x) if x_scale is None \
        else jnp.asarray(x_scale, jnp.float32)
    sw = compute_scale(w, axis=-1) if w_scale is None \
        else jnp.asarray(w_scale, jnp.float32).reshape(1, 1, m)
    xq = quantize_values(x, sx)
    wq = quantize_values(w, sw)

    pad_h, pad_w = (-ho) % th, (-wo) % tw
    if pad_h or pad_w:
        offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    xp = pad_zerocopy(
        xq, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=th, tile_w=tw,
        ho=ho + pad_h, wo=wo + pad_w)
    w_tiled = tile_weights(wq, tc)
    scale = (sx * sw).reshape(1, m).astype(jnp.float32)
    y = deform_conv_fused_zerocopy_q(
        xp, offsets.astype(jnp.float32), w_tiled, scale,
        kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=th, tile_w=tw, tile_c=tc,
        tile_m=tm, interpret=interpret)
    return y[:, :ho, :wo].astype(x.dtype)


def chain_forward(x: Array, w: Array, w_offset: Array, b_offset: Array,
                  b_deform: Array | None, *, kernel_size: int, stride: int,
                  dilation: int, offset_bound: float,
                  x_scale, w_scale, w_offset_scale, y_scale,
                  tile_h: int | None, tile_w: int | None,
                  tile_c: int | None, tile_m: int | None,
                  emit: str, interpret: bool) -> Array:
    """int8 -> int8 chained DCL layer (inference datapath).

    x is either an int8 plane already on the ``x_scale`` grid (the
    previous chained layer's emission) or a fp32 plane quantized here
    (the chain head).  The offset conv is fused into the kernel
    (quantized weights ``w_offset``/``w_offset_scale``, fp32 dequant +
    ``b_offset``), and the output is emitted int8 on the ``y_scale``
    grid with the per-channel requant ``s_x * s_w[m] / s_y`` and the
    deform bias folded as ``b[m] / s_y`` (``emit="fp32"`` is the chain
    tail: plain dequant + bias).  The chained layer's activations touch
    HBM only as int8 — no fp32 round-trip and no offsets in HBM at all.
    """
    from repro.quant.qtypes import compute_scale, quantize_values

    n, h, w_in, c = x.shape
    m = w.shape[-1]
    k2 = kernel_size * kernel_size
    ho, wo = out_hw(h, w_in, kernel_size=kernel_size, stride=stride,
                    dilation=dilation)
    if tile_c is not None and tile_c != c:
        raise ValueError(
            f"tile_c={tile_c} is incompatible with chaining: the fused "
            f"offset-conv stage needs the whole channel extent staged "
            f"per band (tile_c == C = {c}), since the offsets must be "
            f"complete before the first bilinear sample consumes them — "
            f"pass tile_c=None (or C) for chained layers")
    th, tw, _, tm = resolve_tiles(
        h, w_in, c, m, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
        tile_w=tile_w, tile_c=c, tile_m=tile_m,
        objective="forward", dtype="int8_chain")
    th, tw = min(th, ho), min(tw, wo)
    # The chooser's VMEM feasibility was evaluated at its own free
    # tile_c; chaining pins tile_c = C, so re-check the working set the
    # kernel will actually allocate (single-buffer full-C int8 band +
    # offset-weight block) and shrink the spatial tiles until it fits.
    from repro.core.tiling import (TileConfig, V5E_VMEM_BYTES,
                                   zerocopy_vmem_bytes)

    def _chain_vmem(th_, tw_):
        base = zerocopy_vmem_bytes(
            LayerShape(h=h, w=w_in, c_in=c, c_out=m,
                       kernel_size=kernel_size, stride=stride,
                       offset_bound=offset_bound),
            TileConfig(th_, tw_, c, tm), dilation=dilation,
            bytes_per_elem=1, aux_bytes_per_elem=4)
        return base + k2 * c * 2 * k2          # + int8 offset-conv block
    while _chain_vmem(th, tw) > V5E_VMEM_BYTES and (th > 1 or tw > 1):
        if tw > 1:
            tw = max(1, tw // 2)
        else:
            th = max(1, th // 2)

    sx = jnp.asarray(x_scale, jnp.float32)
    sw = compute_scale(w, axis=-1) if w_scale is None \
        else jnp.asarray(w_scale, jnp.float32).reshape(1, 1, m)
    swo = compute_scale(w_offset, axis=-1) if w_offset_scale is None \
        else jnp.asarray(w_offset_scale, jnp.float32).reshape(1, 1, 2 * k2)
    xq = x if x.dtype == jnp.int8 else quantize_values(x, sx)
    wq = quantize_values(w, sw)
    woq = quantize_values(w_offset, swo)

    pad_h, pad_w = (-ho) % th, (-wo) % tw
    xp = pad_zerocopy(
        xq, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=th, tile_w=tw,
        ho=ho + pad_h, wo=wo + pad_w)
    w_tiled = tile_weights(wq, c)
    wo_tiled = tile_weights(woq, c)
    off_scale = (sx * swo).reshape(1, 2 * k2).astype(jnp.float32)
    off_bias = jnp.asarray(b_offset, jnp.float32).reshape(1, 2 * k2)
    bias = jnp.zeros((m,), jnp.float32) if b_deform is None \
        else jnp.asarray(b_deform, jnp.float32)
    if emit == "int8":
        sy = jnp.asarray(y_scale, jnp.float32)
        out_scale = (sx * sw / sy).reshape(1, m).astype(jnp.float32)
        out_bias = (bias / sy).reshape(1, m)
    else:
        out_scale = (sx * sw).reshape(1, m).astype(jnp.float32)
        out_bias = bias.reshape(1, m)
    y = deform_conv_fused_zerocopy_chain(
        xp, w_tiled, wo_tiled, off_scale, off_bias, out_scale, out_bias,
        kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=th, tile_w=tw, tile_m=tm,
        emit=emit, ho=ho + pad_h, wo=wo + pad_w, interpret=interpret)
    return y[:, :ho, :wo]
