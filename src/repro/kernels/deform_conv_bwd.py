"""Fused backward Pallas kernel for the bounded deformable conv.

Training previously differentiated through the pure-XLA gather reference
(``core.deform_conv.dcl_forward``) — exactly the "irregular DRAM access"
regime the paper (arXiv:2006.05238) is designed to avoid, and backward
is *worse* than forward: the gather transposes into an irregular HBM
scatter.  The Eq. 6 band geometry fixes both directions at once: the
same offset bound ``B`` that makes forward gathers provably in-band
makes backward scatters provably in-band, so all irregularity stays in
VMEM.

One fused kernel produces all three cotangents per (batch, row-tile,
width-tile, C-chunk) grid step, re-using a single Eq. 6 band DMA — the
same ``band_pipeline.BandStager`` double-buffer pipeline the forward
kernels are emitted with (the stager's warmup/prefetch/wait are called
individually here so the d_input read-modify-write DMA can be
interleaved into the overlap window):

* the input band chunk streams HBM -> VMEM through the shared stager,
  and the sampled patches are **recomputed** from it (cheap-recompute
  wins the traffic model: saving the (N, Ho, Wo, K^2, C) patch tensor
  as a residual would re-read ``K^2`` times the input volume from HBM,
  vs one extra band read here — see ``tiling.dcl_backward_hbm_bytes``);
* ``d_patches = g @ W^T`` and ``d_weights += patches^T @ g`` run on the
  MXU with fp32 accumulation (``d_weights`` accumulates in a VMEM
  scratch across the whole batch/spatial grid and is emitted fp32);
* ``d_offsets`` reuses the forward's bilinear corner values: for corner
  values v00/v01/v10/v11 at fractions (ty, tx),

      d val / d pos_y = (1-tx)(v10-v00) + tx(v11-v01)
      d val / d pos_x = (1-ty)(v01-v00) + ty(v11-v10)

  contracted against ``d_patches`` over channels, then masked by the
  Eq. 5 clamp (gradient is zero where |raw offset| > B, matching the
  ``jnp.clip`` VJP of the XLA reference almost everywhere);
* ``d_input`` is scattered into a zero VMEM band, then flushed to the
  padded-gradient HBM buffer with an async-copy read-modify-write of
  that band region.  Adjacent tiles overlap only in their Eq. 6 halos,
  and the spatial grid axes are sequential (``arbitrary`` dimension
  semantics), so each flush accumulates into a region no concurrent
  step touches.  The HBM buffer is zero-initialized via
  ``input_output_aliases`` and un-padded by the caller.

The in-kernel scatter uses value-level ``.at[].add`` (duplicate corner
indices accumulate); on a real TPU backend Mosaic lowers small-range
scatters like these via one-hot matmul / sorted segments — the band
extent is the Eq. 6 bound, so the one-hot operand is VMEM-bounded
independent of image size.

**Megacore core split (PR 4).**  The grid is
``(cores, n_per_core, h_tiles, w_tiles, c_steps)`` with the leading
core axis carrying ``parallel`` dimension semantics: each core owns a
disjoint contiguous shard of the batch, so on a Megacore TPU the two
TensorCores split the batch halves instead of serializing the whole
grid.  What makes that safe:

* ``d_input``/``d_offsets`` are indexed by the batch sample — shards
  never read-modify-write the same HBM region, halo rows included
  (halos overlap only *within* a sample's spatial tiles, which stay
  sequential per core);
* ``d_weights`` accumulates in per-core VMEM scratch (hardware gives
  each core a private scratch instance; interpret mode runs core
  subgrids back-to-back, so the per-core init at the first shard step
  gives the same isolation) and flushes one *partial* block per core
  on the core's last spatial step — the caller reduces the
  ``(cores, ...)`` partials with a cheap ``sum`` epilogue.

``cores=1`` reproduces the PR-2/3 sequential kernel bit-for-bit (the
singleton-axis reduce is exact); ``cores>1`` changes only the fp32
summation order of ``d_weights`` (per-core partial sums instead of one
interleaved fold).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params
from .band_pipeline import (N_BUFFERS, BandSpec, DCLPlan, corner_geometry)

Array = jax.Array


def _bwd_zerocopy_kernel(plan: DCLPlan, dx0_hbm, x_hbm, off_ref, g_ref,
                         w_ref, dx_hbm, doff_ref, dw_ref,
                         band_ref, rmw_ref, dw_acc, doff_acc,
                         sem_ref, rmw_sem, *, n_per_core: int,
                         dw_flush_every_step: bool):
    del dx0_hbm  # aliased with dx_hbm (zero-initialized output)
    b_ = plan.band
    k2 = b_.k2
    tile_h, tile_w = b_.tile_h, b_.tile_w
    band_h, band_w = b_.band_h, b_.band_w
    tile_c = plan.tile_c
    core = pl.program_id(0)
    b = pl.program_id(1)
    j = pl.program_id(2)
    ww = pl.program_id(3)
    cc = pl.program_id(4)
    c_steps = pl.num_programs(4)
    i = core * n_per_core + b        # batch sample this grid step owns
    row0 = j * (tile_h * b_.stride)
    col0 = ww * (tile_w * b_.stride)

    stager = plan.stager(x_hbm, band_ref, sem_ref, batch=i, row0=row0,
                         col0=col0)

    def rmw_dma(write: bool):
        region = dx_hbm.at[i, pl.ds(row0, band_h), pl.ds(col0, band_w),
                           pl.ds(cc * tile_c, tile_c)]
        if write:
            return pltpu.make_async_copy(rmw_ref, region, rmw_sem.at[1])
        return pltpu.make_async_copy(region, rmw_ref, rmw_sem.at[0])

    @pl.when(cc == 0)
    def _init_tile():
        doff_acc[...] = jnp.zeros_like(doff_acc)
        stager.warmup()

    # First step of THIS core's batch shard: zero the per-core d_weights
    # accumulator.  The condition is core-local (b, not i) so every
    # core starts its partial sum from zero.
    @pl.when((b == 0) & (j == 0) & (ww == 0))
    def _init_dw():
        dw_acc[cc] = jnp.zeros_like(dw_acc[cc])

    # Start the dx read-modify-write *read* early: it rides under the
    # patch recompute + MXU work below.  The previous grid step's write
    # of any overlapping halo has already completed (sequential spatial
    # grid + the write wait at the end of each step).
    rmw_dma(write=False).start()

    stager.prefetch(cc, c_steps)
    band = stager.wait(cc)

    off_raw = off_ref[0].reshape(tile_h, tile_w, k2, 2)
    y0, x0, ty, tx = corner_geometry(
        off_raw, kernel_size=b_.kernel_size, stride=b_.stride,
        dilation=b_.dilation, offset_bound=b_.offset_bound, tile_h=tile_h,
        wo=tile_w)

    flat = band.reshape(band_h * band_w, tile_c)
    p = tile_h * tile_w * k2
    idx00 = (y0 * band_w + x0).reshape(p)
    ty = ty.reshape(p, 1)
    tx = tx.reshape(p, 1)

    def gat(idx):
        return jnp.take(flat, idx, axis=0).astype(jnp.float32)

    v00 = gat(idx00)
    v01 = gat(idx00 + 1)
    v10 = gat(idx00 + band_w)
    v11 = gat(idx00 + band_w + 1)

    w00 = (1 - ty) * (1 - tx)
    w01 = (1 - ty) * tx
    w10 = ty * (1 - tx)
    w11 = ty * tx

    # Recomputed forward patches (fp32), shaped for the MXU contraction.
    patches = w00 * v00 + w01 * v01 + w10 * v10 + w11 * v11   # (p, tc)
    lhs = patches.reshape(tile_h * tile_w, k2 * tile_c)

    g = g_ref[0].astype(jnp.float32).reshape(tile_h * tile_w, -1)
    wblk = w_ref[0].astype(jnp.float32)                # (k2*tc, M)

    # d_weights: patches^T @ g, accumulated fp32 across the whole grid.
    dw_acc[cc] += jnp.dot(lhs.T, g, preferred_element_type=jnp.float32)
    if dw_flush_every_step:
        # Interpret-mode cadence: the interpreter re-materializes the
        # output block buffer on every revisit, so the accumulator must
        # be mirrored into dw_ref each step to survive the copy-out.
        dw_ref[0, 0] = dw_acc[cc]
    else:
        # Compiled cadence (ROADMAP "d_weights flush"): mirror the
        # accumulator only on the LAST spatial grid step of THIS
        # CORE'S batch shard — the final revisit of each (core,
        # C-chunk) block is the only copy-out that has to carry the
        # complete partial sum, cutting the modeled dw write traffic
        # by n_per_core*h_tiles*w_tiles per core (see
        # ``tiling.dcl_backward_hbm_bytes``).  The batch/spatial grid
        # axes are sequential ("arbitrary") within a core, so the
        # core-local last step is well defined; the core axis itself
        # is parallel, which is exactly why the flush condition must
        # not reference it.
        last_spatial = ((b == pl.num_programs(1) - 1)
                        & (j == pl.num_programs(2) - 1)
                        & (ww == pl.num_programs(3) - 1))

        @pl.when(last_spatial)
        def _flush_dw():
            dw_ref[0, 0] = dw_acc[cc]

    # d_patches: g @ W^T  -> (p, tc).
    dp = jnp.dot(g, wblk.T, preferred_element_type=jnp.float32)
    dp = dp.reshape(tile_h * tile_w, k2, tile_c).reshape(p, tile_c)

    # d_offsets: contract d_patches against the corner-value derivatives.
    dval_dy = (1 - tx) * (v10 - v00) + tx * (v11 - v01)
    dval_dx = (1 - ty) * (v01 - v00) + ty * (v11 - v10)
    doff_y = jnp.sum(dp * dval_dy, axis=-1).reshape(tile_h, tile_w, k2)
    doff_x = jnp.sum(dp * dval_dx, axis=-1).reshape(tile_h, tile_w, k2)
    doff_acc[...] += jnp.stack([doff_y, doff_x], axis=-1)

    @pl.when(cc == c_steps - 1)
    def _flush_doff():
        # Eq. 5 clamp VJP: gradient flows only where the raw offset is
        # inside [-B, B] (ties are measure-zero; see module docstring).
        mask = ((off_raw >= -b_.offset_bound)
                & (off_raw <= b_.offset_bound)).astype(jnp.float32)
        doff_ref[0] = (doff_acc[...] * mask).reshape(
            tile_h, tile_w, 2 * k2).astype(doff_ref.dtype)

    # d_input: in-band scatter of the four bilinear corners, then an
    # async-copy read-modify-write flush of this tile's Eq. 6 band.
    dxb = jnp.zeros((band_h * band_w, tile_c), jnp.float32)
    dxb = dxb.at[idx00].add(w00 * dp)
    dxb = dxb.at[idx00 + 1].add(w01 * dp)
    dxb = dxb.at[idx00 + band_w].add(w10 * dp)
    dxb = dxb.at[idx00 + band_w + 1].add(w11 * dp)

    rmw_dma(write=False).wait()
    rmw_ref[...] = (rmw_ref[...].astype(jnp.float32)
                    + dxb.reshape(band_h, band_w, tile_c)
                    ).astype(rmw_ref.dtype)
    wr = rmw_dma(write=True)
    wr.start()
    wr.wait()


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "cores", "interpret",
                     "dw_flush_every_step"))
def deform_conv_bwd_zerocopy(x_pad: Array, offsets: Array, g: Array,
                             w_tiles: Array, *, kernel_size: int,
                             stride: int, dilation: int, offset_bound: float,
                             tile_h: int, tile_w: int,
                             tile_c: int | None = None,
                             cores: int = 1,
                             interpret: bool = True,
                             dw_flush_every_step: bool | None = None
                             ) -> tuple[Array, Array, Array]:
    """Fused backward over the whole padded input (zero-copy dataflow).

    x_pad:   (N, Hp, Wp, C) zero-padded input, left whole in ANY/HBM
    offsets: (N, Ho, Wo, 2*K*K) *raw* offsets, Ho/Wo multiples of tiles
    g:       (N, Ho, Wo, M) output cotangent
    w_tiles: (C//tile_c, K*K*tile_c, M) — ``plan.tile_weights`` layout
    returns: (dx_pad fp-matched to x_pad, d_offsets, dw_tiles fp32) —
             dx_pad includes the zero padding (caller un-pads), dw_tiles
             is in the same blocked layout as ``w_tiles``.

    ``cores`` splits the batch axis into per-core shards (Megacore
    ``parallel`` semantics on the leading grid axis; must divide N —
    ``ops.check_batch_split`` raises the friendly error).  Each core
    emits a partial ``d_weights`` block; the ``sum`` epilogue here
    reduces them (exact no-op at cores=1).

    ``dw_flush_every_step`` controls the d_weights accumulator->output
    mirror cadence: every grid step (required under the interpreter,
    which re-materializes output block buffers per revisit) or only on
    the core-local last spatial step (the compiled cadence,
    n_per_core*h_tiles*w_tiles fewer modeled dw writes per core).
    ``None`` follows ``interpret``.
    """
    n, hp, wp, c = x_pad.shape
    _, ho, wo, _ = offsets.shape
    assert ho % tile_h == 0 and wo % tile_w == 0, (ho, wo, tile_h, tile_w)
    assert g.shape[:3] == (n, ho, wo), (g.shape, offsets.shape)
    assert cores >= 1 and n % cores == 0, (n, cores)
    n_per_core = n // cores
    h_tiles, w_tiles_n = ho // tile_h, wo // tile_w
    k2 = kernel_size * kernel_size
    tc = tile_c or c
    assert c % tc == 0
    c_steps = c // tc
    assert w_tiles.shape[0] == c_steps and w_tiles.shape[1] == k2 * tc
    m = w_tiles.shape[2]
    plan = DCLPlan(
        band=BandSpec(kernel_size=kernel_size, stride=stride,
                      dilation=dilation, offset_bound=offset_bound,
                      tile_h=tile_h, tile_w=tile_w),
        tile_c=tc, tile_m=None, cores=cores)
    band_h, band_w = plan.band.band_h, plan.band.band_w
    plan.band.check_padded(hp, wp, h_tiles, w_tiles_n)
    if dw_flush_every_step is None:
        dw_flush_every_step = interpret

    dx0 = jnp.zeros_like(x_pad)
    out_shapes = (
        jax.ShapeDtypeStruct((n, hp, wp, c), x_pad.dtype),        # dx_pad
        jax.ShapeDtypeStruct((n, ho, wo, 2 * k2), offsets.dtype),  # d_off
        # Per-core d_weights partials, reduced by the epilogue below.
        jax.ShapeDtypeStruct((cores, c_steps, k2 * tc, m), jnp.float32),
    )
    npc = n_per_core
    dxp, doff, dw_partials = pl.pallas_call(
        functools.partial(
            _bwd_zerocopy_kernel, plan, n_per_core=npc,
            dw_flush_every_step=dw_flush_every_step),
        grid=(cores, n_per_core, h_tiles, w_tiles_n, c_steps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),      # dx seed (aliased)
            pl.BlockSpec(memory_space=pltpu.ANY),      # whole padded input
            pl.BlockSpec((1, tile_h, tile_w, 2 * k2),
                         lambda co, b, j, ww, cc: (co * npc + b, j, ww, 0)),
            pl.BlockSpec((1, tile_h, tile_w, m),
                         lambda co, b, j, ww, cc: (co * npc + b, j, ww, 0)),
            pl.BlockSpec((1, k2 * tc, m),
                         lambda co, b, j, ww, cc: (cc, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec(memory_space=pltpu.ANY),      # dx_pad (aliased)
            pl.BlockSpec((1, tile_h, tile_w, 2 * k2),
                         lambda co, b, j, ww, cc: (co * npc + b, j, ww, 0)),
            pl.BlockSpec((1, 1, k2 * tc, m),
                         lambda co, b, j, ww, cc: (co, cc, 0, 0)),
        ),
        out_shape=out_shapes,
        scratch_shapes=[
            # Band scratch follows x's dtype (the fp32 training path;
            # plan.band_scratch() would pin float32 — keep it general).
            pltpu.VMEM((N_BUFFERS, band_h, band_w, tc), x_pad.dtype),
            pltpu.VMEM((band_h, band_w, tc), x_pad.dtype),
            # Private per core: hardware gives each core its own scratch
            # instance; interpret mode runs core subgrids sequentially
            # and the per-core init zeroes it between shards.
            pltpu.VMEM((c_steps, k2 * tc, m), jnp.float32),
            pltpu.VMEM((tile_h, tile_w, k2, 2), jnp.float32),
            plan.dma_sem(),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        input_output_aliases={0: 0},
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(dx0, x_pad, offsets, g, w_tiles)
    # Cheap epilogue: reduce the per-core d_weights partials.  Exact at
    # cores=1 (singleton-axis sum); at cores>1 this is the only place
    # the fp32 summation order differs from the sequential kernel.
    return dxp, doff, jnp.sum(dw_partials, axis=0)
