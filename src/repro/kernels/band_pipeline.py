"""Unified band-pipeline kernel emitter (the Eq. 6 dataflow, once).

Every bounded DCL kernel in this package runs the same dataflow: the
padded input stays whole in ``ANY``/HBM, and per (batch, row-tile,
width-tile[, M-tile], C-chunk) grid step one Eq. 6 ``(band_h, band_w)``
band chunk streams into double-buffered VMEM scratch via
``pltpu.make_async_copy`` while the previous chunk's gather + MXU work
rides on top.  Before this module, four kernels (``deform_sample``,
``deform_conv_fused``, ``deform_conv_q``, ``deform_conv_bwd``) each
re-implemented that staging, the grid construction, and the accumulator
flush cadence by hand.  Now there is exactly one emitter:

* ``BandSpec`` — the Eq. 6 geometry of one call (kernel size, stride,
  dilation, trained offset bound, spatial tiles) with the derived band
  extents and halo;
* ``DCLPlan`` — one kernel instantiation: the ``BandSpec`` plus channel
  tiles, the staged-band dtype (fp32 or int8 — the band DMA geometry is
  dtype-independent, only the element width changes), the MXU
  accumulator dtype (fp32 or exact int32), the epilogue
  (``cast`` / ``dequant`` / ``requant`` — fp32 emission, fused
  per-channel dequant, or int8 re-emission for layer chaining), the
  optional fused offset-conv stage, and the Megacore ``cores`` axis of
  the backward grid;
* ``BandStager`` — the double-buffered ``make_async_copy`` pipeline
  (warmup / prefetch / wait), used by every kernel body;
* ``forward_call`` — emits the whole family of forward kernels
  (sample-only, fused fp32, fused int8, int8 chain) from a ``DCLPlan``;
  the backward kernel (``deform_conv_bwd``) builds its grid, scratch
  and staging from the same plan.

New capabilities the emitter unlocks (ROADMAP int8 follow-ups):

* **fused int8 offset-conv stage** (``DCLPlan.fuse_offsets``): the
  offset-generating 3x3 conv has a *regular* receptive field, which is
  a strict subset of the Eq. 6 band (the band covers the deformed taps,
  the offset conv needs only the undeformed ones).  With the whole
  channel extent staged (``c_steps == 1`` — enforced) the kernel
  computes the offsets from the already-staged int8 band with one
  static-index im2col gather + int8 MXU contraction and a fp32 dequant:
  no separate fp32 offset pass, and the offsets never exist in HBM.
* **int8 output emission with per-channel requant**
  (``epilogue="requant"``): the int32 accumulator is rescaled by
  ``s_x * s_w[m] / s_y`` (bias folded as ``b[m] / s_y``), rounded and
  clipped onto the next layer's activation grid, and emitted int8 —
  back-to-back DCLs chain int8 -> int8 with no fp32 HBM round-trip
  between layers (``ops.deform_conv_chain``).

Geometry helpers (``band_geometry``, ``corner_geometry``, the bilinear
gathers) live here too so the emitter is self-contained; the kernel
modules re-export them for compatibility.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

Array = jax.Array

N_BUFFERS = 2     # double buffering: fetch band i+1 while computing band i


# ---------------------------------------------------------------------------
# Eq. 6 geometry
# ---------------------------------------------------------------------------

def band_geometry(*, kernel_size: int, stride: int, dilation: int,
                  offset_bound: float, tile_h: int) -> tuple[int, int]:
    """(halo, band_h): halo = ceil(B)+1 rows each side (bilinear +1);
    band_h per Eq. 6 with the bilinear corner accounted.  The same
    algebra applies along width with ``tile_h`` replaced by ``tile_w``.
    Delegates to ``core.tiling.band_extent`` so the kernels and the
    traffic/VMEM models can never disagree on the geometry.
    """
    from repro.core.tiling import band_extent
    hb = int(math.ceil(offset_bound))
    band_h = band_extent(tile_h, kernel_size=kernel_size, stride=stride,
                         dilation=dilation, offset_bound=offset_bound)
    return hb, band_h


def _tap_grid(*, kernel_size: int, stride: int, dilation: int, halo: int,
              tile_h: int, tile_w: int):
    """Band-local *undeformed* tap positions of one output tile (int32):
    ``rows`` (tile_h, 1, K*K) and ``cols`` (1, tile_w, K*K), with the
    band starting ``halo`` rows/cols before the first tap.  The single
    source of the Eq. 6 base algebra — shared by the bilinear corner
    geometry (which adds the offsets on top) and the fused offset-conv
    stage (which gathers exactly these positions)."""
    k, s, d = kernel_size, stride, dilation
    k2 = k * k
    ky = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0).reshape(k2) * d
    kx = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1).reshape(k2) * d
    oy = jax.lax.iota(jnp.int32, tile_h) * s + halo
    ox = jax.lax.iota(jnp.int32, tile_w) * s + halo
    rows = oy[:, None, None] + ky[None, None, :]
    cols = ox[None, :, None] + kx[None, None, :]
    return rows, cols


def corner_geometry(off, *, kernel_size: int, stride: int, dilation: int,
                    offset_bound: float, tile_h: int, wo: int):
    """Bilinear corner geometry for one output tile, in band-local coords.

    off: (tile_h, wo, K*K, 2) raw offsets (clamped here to the Eq. 5 bound).
    Returns (y0, x0, ty, tx): int32 top-left corner indices and fp32
    fractional coefficients, each (tile_h, wo, K*K).  Shared between the
    forward gather (``_bilinear_from_band``) and the backward kernel of
    ``deform_conv_bwd.py`` — the same bound ``B`` that keeps forward
    gathers in-band keeps backward scatters in-band, so both sides use
    one geometry.
    """
    k, s, d = kernel_size, stride, dilation
    hb = int(math.ceil(offset_bound))       # static: offset_bound is Python

    # Positions/coefficients in fp32 (address generation is full precision
    # even on a bf16 datapath).
    off = jnp.clip(off.astype(jnp.float32), -offset_bound, offset_bound)

    # Base tap positions in band-local (pre-padded) coordinates: the band
    # starts ``hb`` rows above the first tap row, and the width axis is
    # pre-padded by (pad + hb) so the same formula applies.
    rows, cols = _tap_grid(kernel_size=k, stride=s, dilation=d, halo=hb,
                           tile_h=tile_h, tile_w=wo)
    pos_y = rows.astype(jnp.float32) + off[..., 0]    # (tile_h, wo, k2)
    pos_x = cols.astype(jnp.float32) + off[..., 1]

    y0f = jnp.floor(pos_y)
    x0f = jnp.floor(pos_x)
    ty = pos_y - y0f
    tx = pos_x - x0f
    return y0f.astype(jnp.int32), x0f.astype(jnp.int32), ty, tx


def _bilinear_from_band(band, off, *, kernel_size: int, stride: int,
                        dilation: int, offset_bound: float, tile_h: int,
                        wo: int):
    """Sample (tile_h, wo, K*K) positions from a VMEM band.

    band: (band_h, w_pad, tc) zero-padded input rows
    off:  (tile_h, wo, K*K, 2) raw offsets (clamped here)
    returns (tile_h, wo, K*K, tc) interpolated values
    """
    k2 = kernel_size * kernel_size
    band_h, w_pad, tc = band.shape
    y0, x0, ty, tx = corner_geometry(
        off, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=tile_h, wo=wo)

    flat = band.reshape(band_h * w_pad, tc)
    p = tile_h * wo * k2

    def corner(yc, xc, wgt):
        idx = (yc * w_pad + xc).reshape(p)
        v = jnp.take(flat, idx, axis=0)           # VMEM gather — in-band
        return v.astype(jnp.float32) * wgt.reshape(p, 1)

    # Values accumulate in fp32, round once.
    out = corner(y0, x0, (1 - ty) * (1 - tx))
    out += corner(y0, x0 + 1, (1 - ty) * tx)
    out += corner(y0 + 1, x0, ty * (1 - tx))
    out += corner(y0 + 1, x0 + 1, ty * tx)
    return out.reshape(tile_h, wo, k2, tc).astype(band.dtype)


def _bilinear_int8_from_band(band, off, *, kernel_size: int, stride: int,
                             dilation: int, offset_bound: float,
                             tile_h: int, wo: int):
    """Sample an int8 VMEM band with fp32 coefficients -> int8 patches.

    band: (band_h, w_pad, tc) int8; off: (tile_h, wo, K*K, 2) raw.
    Returns (tile_h*wo*K*K, tc) int8 — integer values on the activation
    grid (the convex bilinear mix of int8 values stays in [-127, 127]).
    """
    k2 = kernel_size * kernel_size
    band_h, w_pad, tc = band.shape
    y0, x0, ty, tx = corner_geometry(
        off, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=tile_h, wo=wo)

    flat = band.reshape(band_h * w_pad, tc)
    p = tile_h * wo * k2
    idx00 = (y0 * w_pad + x0).reshape(p)
    ty = ty.reshape(p, 1)
    tx = tx.reshape(p, 1)

    def gat(idx):
        return jnp.take(flat, idx, axis=0).astype(jnp.float32)

    # Same corner order + accumulation order as the fp32 gather, so the
    # pre-round fp32 values match ``_bilinear_from_band`` bit-for-bit.
    out = gat(idx00) * ((1 - ty) * (1 - tx))
    out += gat(idx00 + 1) * ((1 - ty) * tx)
    out += gat(idx00 + w_pad) * (ty * (1 - tx))
    out += gat(idx00 + w_pad + 1) * (ty * tx)
    return jnp.round(out).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Band staging: the double-buffered make_async_copy pipeline
# ---------------------------------------------------------------------------

def make_band_dma(x_hbm, band_ref, sem_ref, *, batch, row0, col0, c0,
                  band_h: int, band_w: int, tile_c: int, slot):
    """DMA descriptor for one (row-tile, width-tile, C-chunk) band:
    HBM -> VMEM scratch slot.  Reconstructed identically to start and to
    wait (the standard Pallas async-copy pattern)."""
    return pltpu.make_async_copy(
        x_hbm.at[batch,
                 pl.ds(row0, band_h),
                 pl.ds(col0, band_w),
                 pl.ds(c0, tile_c)],
        band_ref.at[slot],
        sem_ref.at[slot])


class BandStager:
    """Double-buffered Eq. 6 band staging for one (batch, row-tile,
    width-tile) grid position: chunk ``cc+1``'s HBM -> VMEM copy rides
    under chunk ``cc``'s gather + MXU work.

    ``stage(cc, c_steps)`` is the whole pipeline (warmup at the first
    chunk, prefetch of the next, wait on the current) and returns the
    staged band view; kernels that need to interleave other DMAs (the
    backward's d_input read-modify-write) call ``warmup`` / ``prefetch``
    / ``wait`` individually to keep their overlap structure explicit.
    """

    def __init__(self, x_hbm, band_ref, sem_ref, *, batch, row0, col0,
                 band_h: int, band_w: int, tile_c: int):
        self.x_hbm = x_hbm
        self.band_ref = band_ref
        self.sem_ref = sem_ref
        self.batch = batch
        self.row0 = row0
        self.col0 = col0
        self.band_h = band_h
        self.band_w = band_w
        self.tile_c = tile_c

    def dma(self, step, slot):
        return make_band_dma(
            self.x_hbm, self.band_ref, self.sem_ref, batch=self.batch,
            row0=self.row0, col0=self.col0, c0=step * self.tile_c,
            band_h=self.band_h, band_w=self.band_w, tile_c=self.tile_c,
            slot=slot)

    def warmup(self):
        """Start the first chunk's fetch (call under ``cc == 0``)."""
        self.dma(0, 0).start()

    def prefetch(self, cc, c_steps):
        """Start chunk ``cc+1``'s fetch into the other buffer slot."""
        @pl.when(cc + 1 < c_steps)
        def _prefetch():
            self.dma(cc + 1, (cc + 1) % N_BUFFERS).start()

    def wait(self, cc):
        """Block on chunk ``cc`` and return its staged band view."""
        self.dma(cc, cc % N_BUFFERS).wait()
        return self.band_ref[cc % N_BUFFERS]

    def stage(self, cc, c_steps):
        @pl.when(cc == 0)
        def _warmup():
            self.warmup()
        self.prefetch(cc, c_steps)
        return self.wait(cc)


# ---------------------------------------------------------------------------
# BandSpec / DCLPlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BandSpec:
    """Eq. 6 band geometry of one bounded DCL call (hashable/static)."""
    kernel_size: int
    stride: int
    dilation: int
    offset_bound: float
    tile_h: int
    tile_w: int

    @property
    def k2(self) -> int:
        return self.kernel_size * self.kernel_size

    @property
    def halo(self) -> int:
        return int(math.ceil(self.offset_bound))

    @property
    def band_h(self) -> int:
        return band_geometry(kernel_size=self.kernel_size,
                             stride=self.stride, dilation=self.dilation,
                             offset_bound=self.offset_bound,
                             tile_h=self.tile_h)[1]

    @property
    def band_w(self) -> int:
        return band_geometry(kernel_size=self.kernel_size,
                             stride=self.stride, dilation=self.dilation,
                             offset_bound=self.offset_bound,
                             tile_h=self.tile_w)[1]

    def check_padded(self, hp: int, wp: int, h_tiles: int,
                     w_tiles: int) -> None:
        s = self.stride
        assert (h_tiles - 1) * self.tile_h * s + self.band_h <= hp, \
            "underpadded H"
        assert (w_tiles - 1) * self.tile_w * s + self.band_w <= wp, \
            "underpadded W"


@dataclasses.dataclass(frozen=True)
class DCLPlan:
    """One kernel instantiation of the band pipeline (hashable/static).

    ``tile_m=None`` selects the sample-only kernel (stage 1: no MXU
    contraction, patches are the output).  ``band_dtype`` is the staged
    band's element type (``"float32"`` or ``"int8"`` — one geometry, two
    densities); ``acc_dtype`` the MXU accumulator (``"float32"`` or the
    exact ``"int32"`` of the s8 x s8 datapath).  ``epilogue`` selects the
    flush: ``"cast"`` (fp32 accumulator -> output dtype), ``"dequant"``
    (int32 -> fp32 via the per-channel combined scale), ``"requant"``
    (int32 -> int8 on the next layer's grid — layer chaining).
    ``fuse_offsets`` computes the offsets in-kernel from the staged band
    (requires ``c_steps == 1``); ``cores`` is the Megacore core axis of
    the backward grid (forward kernels keep it at 1).
    """
    band: BandSpec
    tile_c: int
    tile_m: int | None = None
    band_dtype: str = "float32"
    acc_dtype: str = "float32"
    epilogue: str = "cast"
    fuse_offsets: bool = False
    cores: int = 1

    def __post_init__(self):
        assert self.epilogue in ("cast", "dequant", "requant"), self.epilogue
        if self.band_dtype not in ("float32", "bfloat16", "float16",
                                   "int8"):
            raise ValueError(
                f"unsupported band dtype {self.band_dtype!r}; the band "
                f"pipeline stages float32, bfloat16, float16 or int8 "
                f"bands — cast the input first")
        assert self.acc_dtype in ("float32", "int32"), self.acc_dtype

    @property
    def contract(self) -> bool:
        return self.tile_m is not None

    def jnp_band_dtype(self):
        return jnp.dtype(self.band_dtype)

    def jnp_acc_dtype(self):
        return jnp.int32 if self.acc_dtype == "int32" else jnp.float32

    # -- shared scratch/grid builders ---------------------------------
    def band_scratch(self):
        # Fused-offset plans stage the whole C extent once per spatial
        # tile (c_steps == 1, fetched at mm == 0 only) — there is
        # nothing to overlap, so a single slot halves the kernel's
        # largest VMEM buffer.
        n_buf = 1 if self.fuse_offsets else N_BUFFERS
        return pltpu.VMEM((n_buf, self.band.band_h, self.band.band_w,
                           self.tile_c), self.jnp_band_dtype())

    def dma_sem(self):
        return pltpu.SemaphoreType.DMA((N_BUFFERS,))

    def stager(self, x_hbm, band_ref, sem_ref, *, batch, row0, col0):
        return BandStager(x_hbm, band_ref, sem_ref, batch=batch, row0=row0,
                          col0=col0, band_h=self.band.band_h,
                          band_w=self.band.band_w, tile_c=self.tile_c)

    def sample(self, band, off_raw):
        """Dtype-dispatched bilinear gather of one tile from the staged
        band: fp32 values, or int8 re-rounded onto the activation grid
        (the quantized datapath's patch requantization)."""
        b = self.band
        fn = _bilinear_int8_from_band if self.band_dtype == "int8" \
            else _bilinear_from_band
        return fn(band, off_raw, kernel_size=b.kernel_size, stride=b.stride,
                  dilation=b.dilation, offset_bound=b.offset_bound,
                  tile_h=b.tile_h, wo=b.tile_w)


def offset_conv_stage(plan: DCLPlan, band, woff_ref, off_scale_ref,
                      off_bias_ref):
    """Fused offset-conv stage: offsets from the already-staged band.

    The offset conv's taps are the *undeformed* grid positions — a
    static-index subset of the Eq. 6 band (band-local row ``t*s + hb +
    ky*d`` for output row ``t``) — so one im2col gather + int8 MXU
    contraction produces the raw offsets without any extra HBM traffic:

        off[t, u, :] = (sum_{ky,kx,c} q_x[tap] * q_woff) * s_x*s_woff + b

    (exact int32 accumulation, fp32 dequant).  Requires the whole
    channel extent staged (``c_steps == 1`` — the offsets must be
    complete before the first bilinear sample consumes them).
    Returns raw fp32 offsets (tile_h, tile_w, K*K, 2); the Eq. 5 clamp
    happens in ``corner_geometry`` exactly as for streamed offsets.
    """
    b = plan.band
    k2 = b.k2
    band_h, band_w, tc = band.shape
    rows, cols = _tap_grid(kernel_size=b.kernel_size, stride=b.stride,
                           dilation=b.dilation, halo=b.halo,
                           tile_h=b.tile_h, tile_w=b.tile_w)
    idx = (rows * band_w + cols).reshape(-1)          # static indices
    flat = band.reshape(band_h * band_w, tc)
    taps = jnp.take(flat, idx, axis=0)                # (th*tw*k2, tc)
    lhs = taps.reshape(b.tile_h * b.tile_w, k2 * tc)
    acc = jnp.dot(lhs, woff_ref[0], preferred_element_type=jnp.int32)
    off = acc.astype(jnp.float32) * off_scale_ref[0] + off_bias_ref[0]
    return off.reshape(b.tile_h, b.tile_w, k2, 2)


# ---------------------------------------------------------------------------
# Unified forward kernel (sample-only / fused fp32 / fused int8 / chain)
# ---------------------------------------------------------------------------

def _forward_kernel(plan: DCLPlan, has_scale: bool, has_bias: bool, *refs):
    b = plan.band
    k2 = b.k2
    it = iter(refs)
    x_hbm = next(it)
    off_ref = None if plan.fuse_offsets else next(it)
    woff_ref = next(it) if plan.fuse_offsets else None
    off_scale_ref = next(it) if plan.fuse_offsets else None
    off_bias_ref = next(it) if plan.fuse_offsets else None
    w_ref = next(it) if plan.contract else None
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    out_ref = next(it)
    band_ref = next(it)
    acc_ref = next(it) if plan.contract else None
    off_scratch = next(it) if plan.fuse_offsets else None
    sem_ref = next(it)

    i = pl.program_id(0)
    j = pl.program_id(1)
    ww = pl.program_id(2)
    mm = pl.program_id(3) if plan.contract else None
    c_axis = 4 if plan.contract else 3
    cc = pl.program_id(c_axis)
    c_steps = pl.num_programs(c_axis)

    stager = plan.stager(x_hbm, band_ref, sem_ref, batch=i,
                         row0=j * (b.tile_h * b.stride),
                         col0=ww * (b.tile_w * b.stride))

    if plan.contract:
        @pl.when(cc == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

    if plan.fuse_offsets:
        # Fused-offset plans stage the whole C extent (c_steps == 1), so
        # the band is identical across the sequential M-tile axis — fetch
        # it once per spatial tile (the scratch persists) instead of
        # re-DMAing it every mm step, matching dcl_chain_hbm_bytes, which
        # charges the band once per spatial tile.
        @pl.when(mm == 0)
        def _fetch_band():
            stager.warmup()
            stager.dma(0, 0).wait()
        band = band_ref[0]
    else:
        # Double buffering: the next C-chunk's band streams in underneath
        # this chunk's gather + MXU work.
        band = stager.stage(cc, c_steps)

    if plan.fuse_offsets:
        # The offsets are identical across the M-tile axis — compute the
        # stage once per spatial tile (mm == 0; the axis is sequential
        # "arbitrary", so the scratch persists) and reuse it for the
        # remaining M-tiles instead of re-running the im2col gather +
        # MXU contraction m_tiles times.
        @pl.when(mm == 0)
        def _offsets():
            off_scratch[...] = offset_conv_stage(
                plan, band, woff_ref, off_scale_ref, off_bias_ref)
        off = off_scratch[...]
    else:
        off = off_ref[0].reshape(b.tile_h, b.tile_w, k2, 2)
    patches = plan.sample(band, off)

    if not plan.contract:
        # The fp32 gather returns (th, tw, k2, tc); the int8 gather
        # returns the MXU-flat (th*tw*k2, tc) — one reshape serves both
        # (identical row-major layout), so sample-only int8 plans emit
        # requantized patches instead of crashing on the block shape.
        out_ref[0] = patches.reshape(b.tile_h, b.tile_w, k2, plan.tile_c)
        return

    # (th*tw, k2*tc) @ (k2*tc, tm) on the MXU — fp32 accumulation on the
    # fp32 datapath, exact int32 on the s8 x s8 datapath.
    lhs = patches.reshape(b.tile_h * b.tile_w, k2 * plan.tile_c)
    acc_ref[...] += jnp.dot(lhs, w_ref[0],
                            preferred_element_type=plan.jnp_acc_dtype())

    @pl.when(cc == c_steps - 1)
    def _flush():
        tm = out_ref.shape[-1]
        acc = acc_ref[...]
        if plan.epilogue == "cast":
            y = acc
        else:
            y = acc.astype(jnp.float32) * scale_ref[0]
            if has_bias:
                y = y + bias_ref[0]
            if plan.epilogue == "requant":
                y = jnp.clip(jnp.round(y), -127, 127)
        out_ref[0] = y.reshape(b.tile_h, b.tile_w, tm).astype(out_ref.dtype)


def forward_call(plan: DCLPlan, x_pad: Array, offsets: Array | None,
                 w_tiles: Array | None = None, *,
                 scale: Array | None = None, bias: Array | None = None,
                 woff_tiles: Array | None = None,
                 off_scale: Array | None = None,
                 off_bias: Array | None = None,
                 ho: int | None = None, wo: int | None = None,
                 out_dtype=None, interpret: bool = True) -> Array:
    """Emit + run one forward band-pipeline kernel from a ``DCLPlan``.

    x_pad:   (N, Hp, Wp, C) zero-padded input, left whole in ANY/HBM
             (fp32 or int8 per ``plan.band_dtype``)
    offsets: (N, Ho, Wo, 2*K*K) raw offsets — ``None`` iff the plan
             fuses the offset-conv stage (then ``ho``/``wo`` name the
             padded output extent and ``woff_tiles``/``off_scale``/
             ``off_bias`` carry the quantized offset conv)
    w_tiles: (C//tile_c, K*K*tile_c, M) ``plan``-blocked deform weights
             (``None`` for the sample-only kernel)
    scale/bias: (1, M) fp32 epilogue operands (dequant/requant plans)
    returns: (N, Ho, Wo, M) — or (N, Ho, Wo, K*K, C) patches when the
             plan has no contraction stage.
    """
    b = plan.band
    n, hp, wp, c = x_pad.shape
    if offsets is not None:
        _, ho, wo, _ = offsets.shape
    assert ho is not None and wo is not None
    assert ho % b.tile_h == 0 and wo % b.tile_w == 0, \
        (ho, wo, b.tile_h, b.tile_w)
    h_tiles, w_tiles_n = ho // b.tile_h, wo // b.tile_w
    k2 = b.k2
    tc = plan.tile_c
    assert c % tc == 0, (c, tc)
    c_steps = c // tc
    assert x_pad.dtype == plan.jnp_band_dtype(), \
        (x_pad.dtype, plan.band_dtype)
    if plan.fuse_offsets:
        assert c_steps == 1, (
            "fused offset-conv stage needs the whole channel extent "
            "staged (c_steps == 1)")
        assert woff_tiles is not None and off_scale is not None \
            and off_bias is not None
    b.check_padded(hp, wp, h_tiles, w_tiles_n)

    grid: tuple[int, ...]
    in_ops: list[Array] = [x_pad]
    in_specs: list = [pl.BlockSpec(memory_space=pltpu.ANY)]
    scratch = [plan.band_scratch()]

    if plan.contract:
        assert w_tiles is not None
        assert w_tiles.shape[0] == c_steps and w_tiles.shape[1] == k2 * tc
        m = w_tiles.shape[2]
        tm = plan.tile_m or m
        assert m % tm == 0
        grid = (n, h_tiles, w_tiles_n, m // tm, c_steps)
        if not plan.fuse_offsets:
            in_ops.append(offsets)
            in_specs.append(pl.BlockSpec(
                (1, b.tile_h, b.tile_w, 2 * k2),
                lambda i, j, ww, mm, cc: (i, j, ww, 0)))
        else:
            in_ops += [woff_tiles, off_scale, off_bias]
            in_specs += [
                pl.BlockSpec((1, k2 * tc, 2 * k2),
                             lambda i, j, ww, mm, cc: (0, 0, 0)),
                pl.BlockSpec((1, 2 * k2),
                             lambda i, j, ww, mm, cc: (0, 0)),
                pl.BlockSpec((1, 2 * k2),
                             lambda i, j, ww, mm, cc: (0, 0)),
            ]
        in_ops.append(w_tiles)
        in_specs.append(pl.BlockSpec((1, k2 * tc, tm),
                                     lambda i, j, ww, mm, cc: (cc, 0, mm)))
        has_scale = scale is not None
        has_bias = bias is not None
        if has_scale:
            assert scale.shape == (1, m), scale.shape
            in_ops.append(scale)
            in_specs.append(pl.BlockSpec((1, tm),
                                         lambda i, j, ww, mm, cc: (0, mm)))
        if has_bias:
            assert bias.shape == (1, m), bias.shape
            in_ops.append(bias)
            in_specs.append(pl.BlockSpec((1, tm),
                                         lambda i, j, ww, mm, cc: (0, mm)))
        if plan.epilogue != "cast":
            assert has_scale, "dequant/requant epilogues need a scale"
        if out_dtype is None:
            out_dtype = jnp.int8 if plan.epilogue == "requant" \
                else jnp.float32
        out_specs = pl.BlockSpec((1, b.tile_h, b.tile_w, tm),
                                 lambda i, j, ww, mm, cc: (i, j, ww, mm))
        out_shape = jax.ShapeDtypeStruct((n, ho, wo, m), out_dtype)
        scratch.append(pltpu.VMEM((b.tile_h * b.tile_w, tm),
                                  plan.jnp_acc_dtype()))
        if plan.fuse_offsets:
            scratch.append(pltpu.VMEM((b.tile_h, b.tile_w, k2, 2),
                                      jnp.float32))
        semantics = ("parallel", "parallel", "parallel", "arbitrary",
                     "arbitrary")
    else:
        assert not plan.fuse_offsets, "sample-only plans stream offsets"
        has_scale = has_bias = False
        grid = (n, h_tiles, w_tiles_n, c_steps)
        in_ops.append(offsets)
        in_specs.append(pl.BlockSpec((1, b.tile_h, b.tile_w, 2 * k2),
                                     lambda i, j, ww, cc: (i, j, ww, 0)))
        out_specs = pl.BlockSpec((1, b.tile_h, b.tile_w, k2, tc),
                                 lambda i, j, ww, cc: (i, j, ww, 0, cc))
        out_shape = jax.ShapeDtypeStruct((n, ho, wo, k2, c),
                                         out_dtype or x_pad.dtype)
        semantics = ("parallel", "parallel", "parallel", "arbitrary")

    scratch.append(plan.dma_sem())
    return pl.pallas_call(
        functools.partial(_forward_kernel, plan, has_scale, has_bias),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=tpu_compiler_params(dimension_semantics=semantics),
        interpret=interpret,
    )(*in_ops)
