"""Flash-attention Pallas kernel: blockwise online-softmax on the MXU.

The §Roofline memory term of every dense-attention cell is dominated by
the (Sq, Sk) score tensor round-tripping HBM (pre-fusion accounting; on
TPU, XLA fuses part of the chain but still materializes scores at long
S).  This kernel is the structural fix — the same insight as the paper's
bounded-RF dataflow, applied to attention: *hold a (block_q, block_k)
score tile in VMEM, never writing scores to HBM at all*, carrying the
online-softmax statistics (running max m, normalizer l, accumulator acc)
in VMEM scratch across the K-block grid axis.

Layout: head-major (BH, S, Dh) so every block is a clean 2-D MXU tile.
Causal masking is positional (absolute indices from the block ids);
fully-masked K-blocks are skipped with ``pl.when`` — the causal schedule
does half the work of the rectangular one.

Validated against ``ref.flash_attention_ref`` over shape/dtype sweeps in
``tests/test_flash_attention.py`` (interpret mode on CPU; the TPU is the
lowering target).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, k_steps: int,
                  causal: bool, softcap: float | None, sk_valid: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                 # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        k_idx = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        keep = k_idx < sk_valid                          # tail padding
        if causal:
            q_idx = qb * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            keep &= k_idx <= q_idx
        s = jnp.where(keep, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        corr = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    if causal:
        # K-blocks entirely above the diagonal contribute nothing.
        pl.when(kb * block_k <= qb * block_q + block_q - 1)(compute)
    else:
        compute()

    @pl.when(kb == k_steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "softcap", "block_q", "block_k", "interpret"))
def flash_attention_bh(q: Array, k: Array, v: Array, *, causal: bool = True,
                       softcap: float | None = None, block_q: int = 128,
                       block_k: int = 128,
                       interpret: bool = True) -> Array:
    """Head-major flash attention.

    q: (BH, Sq, Dh); k, v: (BH, Sk, Dh).  Returns (BH, Sq, Dh).
    Shapes are padded to the block grid internally.
    """
    bh, sq, dh = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    k_steps = (sk + pk) // bk

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=1.0 / math.sqrt(dh), block_q=bq,
            block_k=bk, k_steps=k_steps, causal=causal, softcap=softcap,
            sk_valid=sk),
        grid=(bh, (sq + pq) // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    softcap: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None) -> Array:
    """GQA layout wrapper.

    q: (B, Sq, KV, G, Dh); k, v: (B, Sk, KV, Dh) — the layout used by
    ``repro.models.layers``.  KV heads are broadcast across the group.
    Returns (B, Sq, KV, G, Dh).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, kv, g, dh = q.shape
    sk = k.shape[1]
    qh = q.transpose(0, 2, 3, 1, 4).reshape(b * kv * g, sq, dh)
    kh = jnp.broadcast_to(k[:, :, :, None], (b, sk, kv, g, dh)) \
        .transpose(0, 2, 3, 1, 4).reshape(b * kv * g, sk, dh)
    vh = jnp.broadcast_to(v[:, :, :, None], (b, sk, kv, g, dh)) \
        .transpose(0, 2, 3, 1, 4).reshape(b * kv * g, sk, dh)
    oh = flash_attention_bh(qh, kh, vh, causal=causal, softcap=softcap,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return oh.reshape(b, kv, g, sq, dh).transpose(0, 3, 1, 2, 4)
