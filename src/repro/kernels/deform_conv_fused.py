"""Fused Pallas kernel: bilinear sampling + dynamic convolution (stage 1+2).

The paper's accelerator pipelines two stages *through DRAM*: interpolated
patches are written to the output buffer, transferred to DRAM, and
re-fetched as inputs of the dynamic-convolution stage (their Fig. 4).
On TPU we can do better: the sampled (T_H*T_W, K^2*T_C) patch tile is
exactly an im2col operand for the MXU, so this kernel samples into VMEM
registers and immediately feeds the MXU — the patches never exist in
HBM.  Per Eq. 7, that removes 2*K^2*T_W*T_N elements/tile of round-trip
traffic (the dominant HBM term for small N; see EXPERIMENTS.md §Perf).

The zero-copy kernel is emitted by ``band_pipeline.forward_call`` from a
fp32 ``DCLPlan`` (grid ``(n, h_tiles, w_tiles, m_tiles, c_steps)``,
double-buffered band stager, fp32 MXU accumulation, plain-cast flush) —
the same emitter that instantiates the int8 and chained variants
(``deform_conv_q``) and whose band staging the backward kernel shares.

``deform_conv_fused_banded`` (legacy) consumes the HBM-materialized
overlapping bands of ``kernels.plan.pad_and_band`` (a
``band_h/(tile_h*stride)``-fold duplication of the input written and
re-read through HBM) and stages full-width bands per block via the
BlockSpec pipeline.  Kept as the parity/regression baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params
from .band_pipeline import (BandSpec, DCLPlan, _bilinear_from_band,
                            forward_call)

Array = jax.Array


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "tile_m", "interpret"))
def deform_conv_fused_zerocopy(x_pad: Array, offsets: Array,
                               w_tiles: Array, *, kernel_size: int,
                               stride: int, dilation: int,
                               offset_bound: float, tile_h: int,
                               tile_w: int, tile_c: int | None = None,
                               tile_m: int | None = None,
                               interpret: bool = True) -> Array:
    """Fused DCL over the whole padded input (no band materialization).

    x_pad:   (N, Hp, Wp, C) zero-padded input, left whole in ANY/HBM
    offsets: (N, Ho, Wo, 2*K*K), Ho = h_tiles*tile_h, Wo = w_tiles*tile_w
    w_tiles: (C//tile_c, K*K*tile_c, M) — deform weights pre-tiled by
             ``plan.tile_weights`` so each C-step reads one contiguous block.
    returns: (N, Ho, Wo, M)
    """
    c = x_pad.shape[-1]
    m = w_tiles.shape[2]
    plan = DCLPlan(
        band=BandSpec(kernel_size=kernel_size, stride=stride,
                      dilation=dilation, offset_bound=offset_bound,
                      tile_h=tile_h, tile_w=tile_w),
        tile_c=tile_c or c, tile_m=tile_m or m, epilogue="cast",
        band_dtype=x_pad.dtype.name)
    return forward_call(plan, x_pad, offsets, w_tiles,
                        out_dtype=x_pad.dtype, interpret=interpret)


# ---------------------------------------------------------------------------
# Legacy banded dataflow (HBM-materialized bands) — parity baseline
# ---------------------------------------------------------------------------

def _fused_kernel(bands_ref, off_ref, w_ref, out_ref, acc_ref, *,
                  kernel_size: int, stride: int, dilation: int,
                  offset_bound: float, tile_h: int, wo: int, c_steps: int):
    k2 = kernel_size * kernel_size
    cc = pl.program_id(3)

    @pl.when(cc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    off = off_ref[0].reshape(tile_h, wo, k2, 2)
    patches = _bilinear_from_band(
        bands_ref[0, 0], off, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h, wo=wo)
    tc = patches.shape[-1]
    # (tile_h*wo, k2*tc) @ (k2*tc, tm) on the MXU, fp32 accumulation.
    lhs = patches.reshape(tile_h * wo, k2 * tc)
    acc_ref[...] += jnp.dot(lhs, w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(cc == c_steps - 1)
    def _flush():
        tm = out_ref.shape[-1]
        out_ref[0] = acc_ref[...].reshape(tile_h, wo, tm).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_c", "tile_m", "interpret"))
def deform_conv_fused_banded(bands: Array, offsets: Array, w_tiles: Array, *,
                             kernel_size: int, stride: int, dilation: int,
                             offset_bound: float, tile_h: int,
                             tile_c: int | None = None,
                             tile_m: int | None = None,
                             interpret: bool = True) -> Array:
    """Fused DCL over pre-banded input.

    bands:   (N, n_tiles, band_h, w_pad, C)
    offsets: (N, Ho, Wo, 2*K*K)
    w_tiles: (C//tile_c, K*K*tile_c, M) — deform weights pre-tiled by
             ``plan.tile_weights`` so each C-step reads one contiguous block.
    returns: (N, Ho, Wo, M)
    """
    n, n_tiles, band_h, w_pad, c = bands.shape
    _, ho, wo, _ = offsets.shape
    k2 = kernel_size * kernel_size
    tc = tile_c or c
    assert c % tc == 0
    c_steps = c // tc
    assert w_tiles.shape[0] == c_steps and w_tiles.shape[1] == k2 * tc
    m = w_tiles.shape[2]
    tm = tile_m or m
    assert m % tm == 0

    return pl.pallas_call(
        functools.partial(
            _fused_kernel, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
            wo=wo, c_steps=c_steps),
        grid=(n, n_tiles, m // tm, c_steps),
        in_specs=[
            pl.BlockSpec((1, 1, band_h, w_pad, tc),
                         lambda i, j, mm, cc: (i, j, 0, 0, cc)),
            pl.BlockSpec((1, tile_h, wo, 2 * k2),
                         lambda i, j, mm, cc: (i, j, 0, 0)),
            pl.BlockSpec((1, k2 * tc, tm),
                         lambda i, j, mm, cc: (cc, 0, mm)),
        ],
        out_specs=pl.BlockSpec((1, tile_h, wo, tm),
                               lambda i, j, mm, cc: (i, j, 0, mm)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, m), bands.dtype),
        scratch_shapes=[pltpu.VMEM((tile_h * wo, tm), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(bands, offsets, w_tiles)
