"""Tiled MXU matmul — the TPU analogue of the paper's systolic array.

The paper's computation engine (Fig. 4/5) is a 2-D systolic array fed by
double-buffered on-chip tiles.  On TPU the MXU *is* the systolic array;
this kernel supplies the tiling/dataflow around it: (bm, bk) x (bk, bn)
VMEM blocks, fp32 accumulation in a VMEM scratch register across the
contraction grid axis, result written once on the last K step.

Used by the offset-generating convolution (as im2col matmul) and as the
generic building block everywhere a plain matmul is the hot spot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

Array = jax.Array


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"))
def matmul(x: Array, w: Array, *, block_m: int = 256, block_n: int = 256,
           block_k: int = 256, interpret: bool = True) -> Array:
    """``x @ w`` with explicit VMEM tiling and fp32 accumulation.

    x: (M, K), w: (K, N) -> (M, N) in x.dtype.  Shapes are padded to the
    block grid and un-padded on return, so arbitrary sizes work.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    # MXU alignment: sublane multiples of 8, lane multiples of 128 where
    # the operand allows it (small operands keep their natural size).
    pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pad_m), (0, pad_k))) if pad_m or pad_k else x
    wp = jnp.pad(w, ((0, pad_k), (0, pad_n))) if pad_k or pad_n else w
    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]
