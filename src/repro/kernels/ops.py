"""Public entry points for the Pallas kernels (padding, tiling, dispatch).

The dispatch mirrors the paper's co-design argument:

* ``offset_bound`` given (the Eq. 5-trained model) -> the Pallas
  bounded-halo kernels: static HBM->VMEM bands, no irregular HBM access.
* ``offset_bound`` None (the lambda=0 baseline) -> the pure-XLA gather
  path of ``repro.core.deform_conv`` — dynamic gathers from HBM, exactly
  the "irregular DRAM access" regime the paper measures against.

Bounded kernels support two dataflows (``dataflow=``):

* ``"zero_copy"`` (default) — the input is zero-padded once and handed
  whole to the kernel in ``ANY``/HBM memory space; the kernel issues
  double-buffered ``make_async_copy`` DMAs per Eq. 6 (row, width) band.
  Nothing is duplicated in HBM and VMEM is bounded independent of image
  size.  Tile sizes default to the Sec. 3.2 chooser
  (``repro.core.tiling.choose_kernel_tiles``); pass explicit tiles to
  override.
* ``"banded"`` (legacy) — ``_pad_and_band`` materializes overlapping
  full-width row bands in HBM via an XLA gather (a
  ``band_h/(tile_h*stride)`` ~ 2-3x duplication of the input) before
  the kernel runs.  Kept as the parity baseline; see EXPERIMENTS.md
  §Perf for the modeled traffic difference.

``interpret`` defaults to True off-TPU (this container is CPU-only); on
a real TPU backend it auto-disables.

The bounded ``deform_conv`` path is differentiable: it is wrapped in a
``jax.custom_vjp`` whose backward is the fused zero-copy kernel of
``deform_conv_bwd.py`` (d_input, d_offsets, d_weights in one band-DMA
pass), so Eq. 5-bounded *training* also runs the zero-copy dataflow —
never an XLA gather/scatter against HBM.

``deform_conv(precision="int8")`` dispatches the quantized inference
datapath (``deform_conv_q.py``): symmetric int8 band DMA + int8 MXU
contraction with int32 accumulation, fp32 bilinear coefficients, fused
per-out-channel dequant epilogue — tiles resolved against the
dtype-aware budgets (4x Eq. 6 band density).  Scales come from
``repro.quant`` calibration or dynamic absmax.

Parallel training (PR 4), two composable levels:

* ``cores=`` splits the *backward* kernel's batch grid axis into
  per-core shards (Megacore ``parallel`` dimension semantics; see
  ``deform_conv_bwd.py``) with a cheap per-core ``d_weights`` reduce
  epilogue.
* When a mesh is active (``distributed.sharding.use_rules(mesh=...)``)
  and the 'batch' logical axis maps to real mesh axes, the bounded
  fp32 path wraps itself in ``shard_map`` over those axes: each device
  runs the full zero-copy fwd/bwd kernels on its batch shard and the
  custom VJP psums ``d_weights`` across the data axes — data-parallel
  DCL training never falls back to GSPMD partitioning the kernel
  internals (which replicates / re-gathers).  ``shard_batch`` selects
  the mode: None (auto: shard when the mesh divides the batch),
  True (require sharding — non-divisible batches raise), False (never).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.deform_conv import DCLConfig, sample_patches
from repro.core.tiling import LayerShape, choose_kernel_tiles
from repro.distributed.sharding import batch_mesh_axes
from .deform_sample import (band_geometry, deform_sample_banded,
                            deform_sample_zerocopy)
from .deform_conv_fused import (deform_conv_fused_banded,
                                deform_conv_fused_zerocopy)
from .deform_conv_bwd import deform_conv_bwd_zerocopy
from .deform_conv_q import deform_conv_fused_zerocopy_q
from .matmul import matmul  # re-export  # noqa: F401

Array = jax.Array

DEFAULT_DATAFLOW = "zero_copy"


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def check_channel_tiles(c: int, m: int, tile_c: int | None,
                        tile_m: int | None = None) -> None:
    """Reject channel tiles that don't divide the layer — a clear
    ``ValueError`` at the public entry instead of a deep Pallas
    BlockSpec shape error (or a bare kernel assert) later."""
    if tile_c is not None and c % tile_c != 0:
        raise ValueError(
            f"tile_c={tile_c} does not divide C={c}; the fused kernels "
            f"step the channel axis in contiguous tile_c chunks — pass a "
            f"divisor of C (or tile_c=None for the Sec. 3.2 chooser, "
            f"which snaps to divisors)")
    if tile_m is not None and m % tile_m != 0:
        raise ValueError(
            f"tile_m={tile_m} does not divide M={m}; the output-channel "
            f"grid axis needs a divisor of M (or tile_m=None for the "
            f"chooser)")


def check_batch_split(n: int, *, cores: int = 1,
                      shard_of: int | None = None) -> None:
    """Reject batch splits that don't divide the batch — a clear
    ``ValueError`` at the public entry (à la ``check_channel_tiles``)
    instead of a deep Pallas grid assert / shard_map shape error later.

    ``shard_of`` names the pre-shard global batch in the message when
    ``n`` is already a per-device shard (mesh sharding composes with
    the core split: each device's shard is further split over cores).
    """
    if cores < 1:
        raise ValueError(f"cores={cores} must be >= 1")
    if n % cores != 0:
        ctx = (f" (per-device shard of global batch N={shard_of})"
               if shard_of is not None else "")
        raise ValueError(
            f"cores={cores} does not divide batch N={n}{ctx}; the "
            f"Megacore backward splits the batch grid axis into "
            f"per-core shards — pass a divisor of the batch (or "
            f"cores=1 for the sequential backward kernel)")


@dataclasses.dataclass(frozen=True)
class _ShardSpec:
    """Hashable mesh context of one batch-sharded deform_conv call."""
    mesh: Mesh
    axes: tuple[str, ...]

    def pspec(self, rank: int) -> P:
        """Full-rank PartitionSpec sharding dim 0 over the batch axes."""
        return P(self.axes, *([None] * (rank - 1)))


def resolve_batch_shard(n: int, *, shard_batch: bool | None = None,
                        cores: int = 1) -> _ShardSpec | None:
    """Decide whether (and how) to shard the batch axis over the active
    mesh, validating the core split either way.

    * ``shard_batch=None`` (auto): shard iff a mesh is active under
      ``distributed.sharding.use_rules`` and its batch-mapped axes
      divide ``n``; otherwise run unsharded (same silent-fallback
      philosophy as ``logical_spec``).
    * ``shard_batch=True``: require sharding — no active mesh or a
      non-dividing batch raises a ``ValueError`` naming the sizes.
    * ``shard_batch=False``: never shard.
    """
    got = batch_mesh_axes() if shard_batch is not False else None
    if got is None:
        if shard_batch:
            raise ValueError(
                "shard_batch=True but no mesh maps the 'batch' logical "
                "axis — activate one with distributed.sharding."
                "use_rules(mesh=...) (axes of size > 1 required)")
        check_batch_split(n, cores=cores)
        return None
    mesh, axes, size = got
    if n % size != 0:
        if shard_batch:
            raise ValueError(
                f"batch N={n} does not divide the mesh batch axes "
                f"{axes} (total size {size}); the shard_map kernel "
                f"path needs equal per-device shards — pad the batch "
                f"to a multiple of {size} or pass shard_batch=False")
        check_batch_split(n, cores=cores)
        return None
    check_batch_split(n // size, cores=cores, shard_of=n)
    return _ShardSpec(mesh=mesh, axes=axes)


def tile_weights(w: Array, tile_c: int) -> Array:
    """(K*K, C, M) deform weights -> (C//tile_c, K*K*tile_c, M) blocks
    so the fused kernel's C-step reads one contiguous VMEM block."""
    k2, c, m = w.shape
    assert c % tile_c == 0, (c, tile_c)
    n_c = c // tile_c
    wt = w.reshape(k2, n_c, tile_c, m).transpose(1, 0, 2, 3)
    return wt.reshape(n_c, k2 * tile_c, m)


def untile_weights(wt: Array, kernel_size: int) -> Array:
    """Inverse of ``tile_weights``: (C//tc, K*K*tc, M) -> (K*K, C, M)."""
    k2 = kernel_size * kernel_size
    n_c, k2tc, m = wt.shape
    tc = k2tc // k2
    w = wt.reshape(n_c, k2, tc, m).transpose(1, 0, 2, 3)
    return w.reshape(k2, n_c * tc, m)


@functools.lru_cache(maxsize=256)
def resolve_tiles(h: int, w: int, c: int, m: int, *, kernel_size: int,
                  stride: int, dilation: int, offset_bound: float,
                  tile_h: int | None, tile_w: int | None,
                  tile_c: int | None, tile_m: int | None,
                  objective: str = "training",
                  dtype: str | None = None,
                  cores: int = 1
                  ) -> tuple[int, int, int, int]:
    """Fill unspecified tile sizes from the Sec. 3.2 chooser; explicit
    arguments win.  ``objective="training"`` (the ``deform_conv``
    default — the same resolved tiles serve the forward kernel and its
    custom-VJP backward) minimizes combined fwd+bwd zero-copy traffic
    under both VMEM working sets; the forward-only ``deform_sample``
    resolves with ``objective="forward"``.  ``dtype`` selects the
    element-width-aware budgets (``"int8"`` exploits the 4x band
    density of the quantized datapath); ``cores`` evaluates the
    training objective at the per-core backward traffic of the
    Megacore split."""
    if None in (tile_h, tile_w, tile_c, tile_m):
        shape = LayerShape(h=h, w=w, c_in=c, c_out=m,
                           kernel_size=kernel_size, stride=stride,
                           offset_bound=offset_bound)
        kt = choose_kernel_tiles(shape, dilation=dilation,
                                 objective=objective, dtype=dtype,
                                 cores=cores)
        tile_h = tile_h or kt.tile_h
        tile_w = tile_w or kt.tile_w
        tile_c = tile_c or kt.tile_c
        tile_m = tile_m or kt.tile_m
    check_channel_tiles(c, m, tile_c, tile_m)
    return tile_h, tile_w, tile_c, tile_m


def _pad_and_band(x: Array, *, kernel_size: int, stride: int, dilation: int,
                  offset_bound: float, tile_h: int,
                  ho: int) -> tuple[Array, int]:
    """Zero-pad x and slice it into overlapping row bands (legacy banded
    dataflow).

    Returns (bands, n_tiles): bands (N, n_tiles, band_h, w_pad, C).  The
    top/left zero padding of ``pad + halo`` (+1 bottom/right for the
    bilinear corner) makes every in-band corner index valid, so the
    kernel needs no masks — the bounded receptive field is the guarantee.
    """
    n, h, w, c = x.shape
    pad = dilation * (kernel_size // 2)
    hb, band_h = band_geometry(kernel_size=kernel_size, stride=stride,
                               dilation=dilation, offset_bound=offset_bound,
                               tile_h=tile_h)
    n_tiles = -(-ho // tile_h)

    p0 = pad + hb
    hp_needed = (n_tiles - 1) * tile_h * stride + band_h
    p1 = max(0, hp_needed - p0 - h)
    # Left pad aligns the kernel's band-local base (ox*S + hb); the +1 is
    # only needed on the right for the bilinear corner x0+1.
    xp = jnp.pad(x, ((0, 0), (p0, p1), (pad + hb, pad + hb + 1), (0, 0)))

    # Overlapping bands via a row gather (the halo duplication the paper
    # pays in BRAM; here it is an HBM-materialized copy produced by XLA —
    # exactly the redundant traffic the zero-copy dataflow removes).
    starts = jnp.arange(n_tiles) * (tile_h * stride)
    rows = starts[:, None] + jnp.arange(band_h)[None, :]     # (n_tiles, band_h)
    bands = jnp.take(xp, rows.reshape(-1), axis=1)
    bands = bands.reshape(n, n_tiles, band_h, xp.shape[2], c)
    return bands, n_tiles


def _pad_zerocopy(x: Array, *, kernel_size: int, stride: int, dilation: int,
                  offset_bound: float, tile_h: int, tile_w: int,
                  ho: int, wo: int) -> Array:
    """Zero-pad x once for the zero-copy kernels — no band
    materialization; every (row-tile, width-tile) Eq. 6 band is a plain
    rectangular window of the result, DMA'd by the kernel itself."""
    n, h, w, c = x.shape
    pad = dilation * (kernel_size // 2)
    hb, band_h = band_geometry(kernel_size=kernel_size, stride=stride,
                               dilation=dilation, offset_bound=offset_bound,
                               tile_h=tile_h)
    _, band_w = band_geometry(kernel_size=kernel_size, stride=stride,
                              dilation=dilation, offset_bound=offset_bound,
                              tile_h=tile_w)
    h_tiles = ho // tile_h
    w_tiles = wo // tile_w
    p0 = pad + hb
    pb = max(0, (h_tiles - 1) * tile_h * stride + band_h - p0 - h)
    pr = max(0, (w_tiles - 1) * tile_w * stride + band_w - p0 - w)
    return jnp.pad(x, ((0, 0), (p0, pb), (p0, pr), (0, 0)))


def _out_hw(h: int, w: int, *, kernel_size: int, stride: int,
            dilation: int) -> tuple[int, int]:
    from repro.core.tiling import out_hw
    return out_hw(h, w, kernel_size=kernel_size, stride=stride,
                  dilation=dilation)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "dataflow", "interpret"))
def deform_sample(x: Array, offsets: Array, *, kernel_size: int = 3,
                  stride: int = 1, dilation: int = 1,
                  offset_bound: float | None = None,
                  tile_h: int | None = 8, tile_w: int | None = None,
                  tile_c: int | None = None,
                  dataflow: str = DEFAULT_DATAFLOW,
                  interpret: bool | None = None) -> Array:
    """Stage 1: bilinear patch sampling.

    x: (N, H, W, C); offsets: (N, Ho, Wo, 2*K*K) raw offset-conv output.
    Returns (N, Ho, Wo, K*K, C).
    """
    n, h, w, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    k2 = kernel_size * kernel_size

    if offset_bound is None:
        # Unbounded model: irregular-gather baseline (paper's lambda=0).
        cfg = DCLConfig(in_channels=c, out_channels=1,
                        kernel_size=kernel_size, stride=stride,
                        dilation=dilation)
        return sample_patches(x, offsets.reshape(n, ho, wo, k2, 2), cfg)

    if interpret is None:
        interpret = default_interpret()
    check_channel_tiles(c, c, tile_c)

    if dataflow == "banded":
        th = tile_h or 8
        pad_h = (-ho) % th
        if pad_h:
            offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
        bands, n_tiles = _pad_and_band(
            x, kernel_size=kernel_size, stride=stride, dilation=dilation,
            offset_bound=offset_bound, tile_h=th, ho=ho + pad_h)
        patches = deform_sample_banded(
            bands, offsets, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=th,
            tile_c=tile_c, interpret=interpret)
        return patches[:, :ho]

    if dataflow != "zero_copy":
        raise ValueError(
            f"unknown dataflow {dataflow!r}; expected 'zero_copy' or "
            f"'banded'")
    th, tw, tc, _ = resolve_tiles(
        h, w, c, c, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
        tile_w=tile_w, tile_c=tile_c, tile_m=c, objective="forward")
    th, tw = min(th, ho), min(tw, wo)
    pad_h, pad_w = (-ho) % th, (-wo) % tw
    if pad_h or pad_w:
        offsets = jnp.pad(offsets,
                          ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    xp = _pad_zerocopy(
        x, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=th, tile_w=tw,
        ho=ho + pad_h, wo=wo + pad_w)
    patches = deform_sample_zerocopy(
        xp, offsets, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=th, tile_w=tw,
        tile_c=tc, interpret=interpret)
    return patches[:, :ho, :wo]


# ---------------------------------------------------------------------------
# Bounded path: custom VJP over the fused kernels.
#
# Forward runs the zero-copy (or legacy banded) fused kernel; backward
# runs the fused zero-copy backward kernel of ``deform_conv_bwd.py``
# regardless of the forward dataflow (gradients are a property of the
# math, not the dataflow — both forwards match ``ref.py`` bit-for-near).
# Residuals are just (x, offsets, w): patches are recomputed in-kernel
# from the Eq. 6 band, which the traffic model favors over saving the
# (N, Ho, Wo, K^2, C) patch tensor (see ``deform_conv_bwd.py``).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _DCSpec:
    """Hashable static configuration of one bounded deform_conv call."""
    kernel_size: int
    stride: int
    dilation: int
    offset_bound: float
    tile_h: int | None
    tile_w: int | None
    tile_c: int | None
    tile_m: int | None
    dataflow: str
    interpret: bool
    cores: int = 1          # Megacore batch split of the backward grid


def _bounded_forward(spec: _DCSpec, x: Array, offsets: Array,
                     w: Array) -> Array:
    ho, wo = offsets.shape[1], offsets.shape[2]
    c, m = x.shape[-1], w.shape[-1]

    if spec.dataflow == "banded":
        th = spec.tile_h or 8
        tc = spec.tile_c or c
        pad_h = (-ho) % th
        if pad_h:
            offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
        bands, n_tiles = _pad_and_band(
            x, kernel_size=spec.kernel_size, stride=spec.stride,
            dilation=spec.dilation, offset_bound=spec.offset_bound,
            tile_h=th, ho=ho + pad_h)
        w_tiles = tile_weights(w.astype(x.dtype), tc)
        y = deform_conv_fused_banded(
            bands, offsets, w_tiles, kernel_size=spec.kernel_size,
            stride=spec.stride, dilation=spec.dilation,
            offset_bound=spec.offset_bound, tile_h=th, tile_c=tc,
            tile_m=spec.tile_m, interpret=spec.interpret)
        return y[:, :ho]

    if spec.dataflow != "zero_copy":
        raise ValueError(
            f"unknown dataflow {spec.dataflow!r}; expected 'zero_copy' or "
            f"'banded'")
    th, tw, tc, tm = _spec_tiles(spec, x, offsets, w)
    xp, offsets, w_tiled = _zerocopy_inputs(spec, x, offsets, w, th, tw, tc)
    y = deform_conv_fused_zerocopy(
        xp, offsets, w_tiled, kernel_size=spec.kernel_size,
        stride=spec.stride, dilation=spec.dilation,
        offset_bound=spec.offset_bound, tile_h=th, tile_w=tw,
        tile_c=tc, tile_m=tm, interpret=spec.interpret)
    return y[:, :ho, :wo]


def _zerocopy_inputs(spec: _DCSpec, x: Array, offsets: Array, w: Array,
                     th: int, tw: int, tc: int,
                     extra: Array | None = None):
    """Shared input prep of the zero-copy forward and backward kernels:
    pad offsets (and ``extra``, the backward cotangent) to tile
    multiples, zero-pad the input per ``_pad_zerocopy``, and block the
    weights.  One code path so the backward's un-pad slice can never
    disagree with the forward's padded geometry."""
    ho, wo = offsets.shape[1], offsets.shape[2]
    pad_h, pad_w = (-ho) % th, (-wo) % tw
    if pad_h or pad_w:
        offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
        if extra is not None:
            extra = jnp.pad(extra, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    xp = _pad_zerocopy(
        x, kernel_size=spec.kernel_size, stride=spec.stride,
        dilation=spec.dilation, offset_bound=spec.offset_bound,
        tile_h=th, tile_w=tw, ho=ho + pad_h, wo=wo + pad_w)
    w_tiled = tile_weights(w.astype(x.dtype), tc)
    if extra is not None:
        return xp, offsets, w_tiled, extra
    return xp, offsets, w_tiled


def _spec_tiles(spec: _DCSpec, x: Array, offsets: Array,
                w: Array) -> tuple[int, int, int, int]:
    """Resolve (tile_h, tile_w, tile_c, tile_m) for one call — chooser
    defaults (combined fwd+bwd traffic), explicit spec values win, and
    spatial tiles are clamped to the output extent."""
    ho, wo = offsets.shape[1], offsets.shape[2]
    th, tw, tc, tm = resolve_tiles(
        x.shape[1], x.shape[2], x.shape[-1], w.shape[-1],
        kernel_size=spec.kernel_size, stride=spec.stride,
        dilation=spec.dilation, offset_bound=spec.offset_bound,
        tile_h=spec.tile_h, tile_w=spec.tile_w, tile_c=spec.tile_c,
        tile_m=spec.tile_m, cores=spec.cores)
    return min(th, ho), min(tw, wo), tc, tm


def _deform_conv_int8(x: Array, offsets: Array, w: Array, *,
                      kernel_size: int, stride: int, dilation: int,
                      offset_bound: float, tile_h: int | None,
                      tile_w: int | None, tile_c: int | None,
                      tile_m: int | None, x_scale: Array | None,
                      w_scale: Array | None, interpret: bool) -> Array:
    """int8 inference datapath: quantize (symmetric, per-tensor x /
    per-out-channel w), pad the int8 plane (0 -> 0, so padding and
    quantization commute), and run the fused int8->int32 zero-copy
    kernel with its per-M dequant epilogue.  Tiles resolve against the
    dtype-aware budgets (4x band density).  Training quantized models
    goes through ``repro.quant.qat`` (fake-quant over the fp32
    custom-VJP path), not here — ``jnp.round`` has no useful gradient.
    """
    from repro.quant.qtypes import compute_scale, quantize_values

    n, h, w_, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    m = w.shape[-1]
    th, tw, tc, tm = resolve_tiles(
        h, w_, c, m, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
        tile_w=tile_w, tile_c=tile_c, tile_m=tile_m,
        objective="forward", dtype="int8")
    th, tw = min(th, ho), min(tw, wo)

    sx = compute_scale(x) if x_scale is None \
        else jnp.asarray(x_scale, jnp.float32)
    sw = compute_scale(w, axis=-1) if w_scale is None \
        else jnp.asarray(w_scale, jnp.float32).reshape(1, 1, m)
    xq = quantize_values(x, sx)
    wq = quantize_values(w, sw)

    pad_h, pad_w = (-ho) % th, (-wo) % tw
    if pad_h or pad_w:
        offsets = jnp.pad(offsets, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    xp = _pad_zerocopy(
        xq, kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=th, tile_w=tw,
        ho=ho + pad_h, wo=wo + pad_w)
    w_tiled = tile_weights(wq, tc)
    scale = (sx * sw).reshape(1, m).astype(jnp.float32)
    y = deform_conv_fused_zerocopy_q(
        xp, offsets.astype(jnp.float32), w_tiled, scale,
        kernel_size=kernel_size, stride=stride, dilation=dilation,
        offset_bound=offset_bound, tile_h=th, tile_w=tw, tile_c=tc,
        tile_m=tm, interpret=interpret)
    return y[:, :ho, :wo].astype(x.dtype)


def _bounded_backward(spec: _DCSpec, x: Array, offsets: Array, w: Array,
                      gy: Array) -> tuple[Array, Array, Array]:
    """(d_input, d_offsets, d_weights) of one bounded call via the fused
    zero-copy backward kernel — shared by the single-device VJP and the
    per-shard body of the ``shard_map`` VJP."""
    n, h, w_, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    th, tw, tc, _ = _spec_tiles(spec, x, offsets, w)
    off_dtype = offsets.dtype
    xp, offsets, w_tiled, gy = _zerocopy_inputs(spec, x, offsets, w,
                                                th, tw, tc, extra=gy)
    dxp, doff, dwt = deform_conv_bwd_zerocopy(
        xp, offsets, gy, w_tiled, kernel_size=spec.kernel_size,
        stride=spec.stride, dilation=spec.dilation,
        offset_bound=spec.offset_bound, tile_h=th, tile_w=tw, tile_c=tc,
        cores=spec.cores, interpret=spec.interpret)
    # Un-pad: _pad_zerocopy put pad+hb zero rows/cols top-left.
    p0 = spec.dilation * (spec.kernel_size // 2) \
        + int(math.ceil(spec.offset_bound))
    dx = dxp[:, p0:p0 + h, p0:p0 + w_]
    doff = doff[:, :ho, :wo]
    dw = untile_weights(dwt, spec.kernel_size)
    return (dx.astype(x.dtype), doff.astype(off_dtype),
            dw.astype(w.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _deform_conv_bounded(spec: _DCSpec, x: Array, offsets: Array,
                         w: Array) -> Array:
    return _bounded_forward(spec, x, offsets, w)


def _deform_conv_bounded_fwd(spec, x, offsets, w):
    return _bounded_forward(spec, x, offsets, w), (x, offsets, w)


def _deform_conv_bounded_bwd(spec, res, gy):
    x, offsets, w = res
    return _bounded_backward(spec, x, offsets, w, gy)


_deform_conv_bounded.defvjp(_deform_conv_bounded_fwd,
                            _deform_conv_bounded_bwd)


# ---------------------------------------------------------------------------
# Mesh-sharded bounded path: shard_map over the batch axis, custom VJP
# with an explicit d_weights psum epilogue.
#
# The custom_vjp wraps the shard_maps (one for forward, one for
# backward) rather than the other way round, so gradient correctness
# never depends on shard_map's transpose rules: each device runs the
# zero-copy kernels on its batch shard; d_input/d_offsets are
# batch-sharded like their primals, and the replicated weights'
# cotangent is psummed across the batch mesh axes inside the backward
# body (this also covers the QAT fake-quant path — the STE wrappers
# act on the replicated weights *outside* this function, so the psummed
# kernel dw is exactly the cotangent they consume).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _deform_conv_sharded(spec: _DCSpec, shard: _ShardSpec, x: Array,
                         offsets: Array, w: Array) -> Array:
    pb = shard.pspec(4)
    fn = shard_map(functools.partial(_bounded_forward, spec),
                   mesh=shard.mesh,
                   in_specs=(pb, pb, P(None, None, None)),
                   out_specs=pb, check_rep=False)
    return fn(x, offsets, w)


def _deform_conv_sharded_fwd(spec, shard, x, offsets, w):
    return _deform_conv_sharded(spec, shard, x, offsets, w), (x, offsets, w)


def _deform_conv_sharded_bwd(spec, shard, res, gy):
    x, offsets, w = res
    pb = shard.pspec(4)
    rep_w = P(None, None, None)

    def body(x, offsets, w, gy):
        dx, doff, dw = _bounded_backward(spec, x, offsets, w, gy)
        # psum epilogue: w is replicated across the batch axes, so its
        # cotangent is the sum of every shard's partial d_weights.
        return dx, doff, jax.lax.psum(dw, shard.axes)

    fn = shard_map(body, mesh=shard.mesh,
                   in_specs=(pb, pb, rep_w, pb),
                   out_specs=(pb, pb, rep_w), check_rep=False)
    return fn(x, offsets, w, gy)


_deform_conv_sharded.defvjp(_deform_conv_sharded_fwd,
                            _deform_conv_sharded_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "stride", "dilation", "offset_bound",
                     "tile_h", "tile_w", "tile_c", "tile_m", "dataflow",
                     "precision", "cores", "shard", "interpret"))
def _deform_conv_impl(x: Array, offsets: Array, w: Array, *,
                      kernel_size: int, stride: int, dilation: int,
                      offset_bound: float | None,
                      tile_h: int | None, tile_w: int | None,
                      tile_c: int | None, tile_m: int | None,
                      dataflow: str, precision: str, cores: int,
                      shard: _ShardSpec | None,
                      x_scale: Array | None, w_scale: Array | None,
                      interpret: bool | None) -> Array:
    n, h, w_, c = x.shape
    ho, wo = offsets.shape[1], offsets.shape[2]
    k2 = kernel_size * kernel_size
    m = w.shape[-1]
    check_channel_tiles(c, m, tile_c, tile_m)
    if precision not in ("fp32", "int8"):
        raise ValueError(
            f"unknown precision {precision!r}; expected 'fp32' or 'int8'")

    if precision == "int8":
        if offset_bound is None:
            raise ValueError(
                "precision='int8' requires a trained offset_bound — the "
                "quantized datapath exists because Eq. 6 bounds the band; "
                "the unbounded gather baseline has no int8 kernel")
        if dataflow != "zero_copy":
            raise ValueError(
                f"precision='int8' supports only the zero-copy dataflow "
                f"(got {dataflow!r})")
        if interpret is None:
            interpret = default_interpret()
        return _deform_conv_int8(
            x, offsets, w, kernel_size=kernel_size, stride=stride,
            dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
            tile_w=tile_w, tile_c=tile_c, tile_m=tile_m,
            x_scale=x_scale, w_scale=w_scale, interpret=interpret)

    if offset_bound is None:
        cfg = DCLConfig(in_channels=c, out_channels=m,
                        kernel_size=kernel_size, stride=stride,
                        dilation=dilation)
        patches = sample_patches(x, offsets.reshape(n, ho, wo, k2, 2), cfg)
        y = jnp.einsum("nhwkc,kcm->nhwm", patches, w,
                       preferred_element_type=jnp.float32)
        return y.astype(x.dtype)

    if interpret is None:
        interpret = default_interpret()
    spec = _DCSpec(kernel_size=kernel_size, stride=stride, dilation=dilation,
                   offset_bound=offset_bound, tile_h=tile_h, tile_w=tile_w,
                   tile_c=tile_c, tile_m=tile_m, dataflow=dataflow,
                   interpret=interpret, cores=cores)
    if shard is not None:
        return _deform_conv_sharded(spec, shard, x, offsets, w)
    return _deform_conv_bounded(spec, x, offsets, w)


def deform_conv(x: Array, offsets: Array, w: Array, *, kernel_size: int = 3,
                stride: int = 1, dilation: int = 1,
                offset_bound: float | None = None,
                tile_h: int | None = None, tile_w: int | None = None,
                tile_c: int | None = None, tile_m: int | None = None,
                dataflow: str = DEFAULT_DATAFLOW,
                precision: str = "fp32",
                cores: int = 1,
                shard_batch: bool | None = None,
                x_scale: Array | None = None,
                w_scale: Array | None = None,
                interpret: bool | None = None) -> Array:
    """Fused DCL stage 1+2: y = g(x, o) * w_deform  (Eq. 2).

    x: (N, H, W, C); offsets: (N, Ho, Wo, 2*K*K); w: (K*K, C, M).
    Returns (N, Ho, Wo, M).  Unspecified tile sizes are resolved by the
    Sec. 3.2 chooser against the combined fwd+bwd zero-copy traffic
    model.  The bounded path is differentiable end-to-end: ``jax.grad``
    routes through the fused backward kernel of ``deform_conv_bwd.py``
    (a ``jax.custom_vjp``), never through an XLA gather/scatter.

    ``cores`` splits the backward kernel's batch grid axis per Megacore
    core (``parallel`` dimension semantics + per-core d_weights reduce;
    must divide the per-device batch — ``check_batch_split`` raises the
    friendly error).  ``shard_batch`` controls the data-parallel
    ``shard_map`` wrap of the bounded fp32 path over the active mesh's
    batch axes (see ``resolve_batch_shard``: None = auto, True =
    require, False = never).  Sharding is resolved OUTSIDE this
    function's own jit boundary from
    ``distributed.sharding.current_rules()`` — the mesh context is a
    static cache key of ``_deform_conv_impl``, so eager/top-level calls
    under different ``use_rules`` contexts never reuse a stale layout.
    The usual jit caveat still applies one level up: a CALLER's
    ``jax.jit`` bakes the context seen at its own trace time into its
    cache (the Trainer builds its step inside ``use_rules(mesh=...)``
    and keeps one mesh per instance for exactly this reason); pass
    ``shard_batch=True`` to fail loudly instead of silently running
    unsharded when the mesh matters.

    ``precision="int8"`` (bounded zero-copy only) runs the quantized
    inference datapath of ``deform_conv_q.py``: int8 band DMA + int8
    MXU contraction with int32 accumulation, fp32 bilinear
    coefficients, fused per-out-channel dequant epilogue.  ``x_scale``
    (per-tensor) / ``w_scale`` (per-out-channel, shape (M,)) override
    the dynamic absmax observers with calibrated values
    (``repro.quant.calibrate``); tiles resolve against the int8
    dtype-aware budgets (4x Eq. 6 band density per VMEM byte).
    """
    shard = None
    if offset_bound is not None and precision == "fp32":
        shard = resolve_batch_shard(x.shape[0], shard_batch=shard_batch,
                                    cores=cores)
    else:
        if shard_batch:
            raise ValueError(
                "shard_batch=True requires the bounded fp32 kernel path "
                "(offset_bound set, precision='fp32'); the unbounded "
                "gather baseline and the int8 inference datapath "
                "partition via GSPMD instead")
        if cores != 1:
            raise ValueError(
                f"cores={cores} applies to the bounded fp32 kernel path "
                f"(offset_bound set, precision='fp32') — only its fused "
                f"backward has the Megacore batch split; this call "
                f"dispatches the "
                f"{'int8 inference' if precision == 'int8' else 'unbounded gather'} "
                f"path, so pass cores=1")
    return _deform_conv_impl(
        x, offsets, w, kernel_size=kernel_size, stride=stride,
        dilation=dilation, offset_bound=offset_bound, tile_h=tile_h,
        tile_w=tile_w, tile_c=tile_c, tile_m=tile_m, dataflow=dataflow,
        precision=precision, cores=cores, shard=shard,
        x_scale=x_scale, w_scale=w_scale, interpret=interpret)
